# LAPQ workspace driver.  `make verify` is the tier-1 gate CI mirrors.

CARGO ?= cargo

.PHONY: build test test-poll fmt fmt-check clippy verify bench-smoke artifacts clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# The same suite against the readiness-polled serving transport (CI runs
# both this and the LAPQ_KERNEL=scalar pass after the default tier).
test-poll:
	LAPQ_SERVE_IO=poll $(CARGO) test -q

fmt:
	$(CARGO) fmt

fmt-check:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Tier-1 verify: what the CI build+test jobs run on a clean machine with
# no Python or PJRT installed (pure-Rust CPU backend).
verify: build test

# Perf trajectory smoke: bounded perf runs that write
# rust/bench_results/BENCH_hotpath.json, BENCH_int_infer.json,
# BENCH_calib.json, BENCH_mixed.json, BENCH_serve.json, BENCH_wire.json
# and BENCH_fleet.json (uploaded as CI artifacts).
bench-smoke:
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_hotpath
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_int_gemm
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_calib
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_mixed
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_serve
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_wire
	BENCH_SMOKE=1 $(CARGO) bench --bench perf_fleet

# Layer-1/2 AOT artifacts (optional; requires Python + JAX).  The default
# build never needs them: the CPU backend executes the model zoo natively.
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts

clean:
	$(CARGO) clean
