//! Hermetic `poll(2)` bindings: the minimal raw-FFI surface the
//! event-driven serving core needs, vendored so the fully-offline build
//! keeps working without the `libc` crate.
//!
//! Scope is deliberately tiny — readiness polling, a self-pipe waker,
//! and an fd-limit raise for the idle-connection bench:
//!
//! * [`poll`] over `#[repr(C)]` [`PollFd`] entries (`EINTR` is absorbed
//!   into an empty wakeup, so callers never see it).
//! * [`WakePipe`]: a nonblocking self-pipe whose read end sits in the
//!   poll set; any thread calls [`WakePipe::wake`] to interrupt a
//!   blocked reactor.
//! * [`raise_nofile`]: best-effort `RLIMIT_NOFILE` bump toward a target
//!   (10k sockets need more than the common 1024 soft default).
//!
//! Everything is `cfg(unix)`; non-unix builds get stubs that return
//! `ErrorKind::Unsupported`, and the reactor refuses `serve.io=poll`
//! there before any of this is reached.

use std::io;

/// One entry in the poll set, matching the kernel's `struct pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLHUP | POLLERR) != 0
    }

    /// The fd itself is invalid (closed out from under the set).
    pub fn invalid(&self) -> bool {
        self.revents & POLLNVAL != 0
    }
}

// Event bits — identical values on Linux and the BSDs (incl. macOS).
pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

#[cfg(unix)]
mod sys {
    use super::*;

    #[cfg(target_os = "linux")]
    type NfdsT = usize; // nfds_t is unsigned long on Linux
    #[cfg(not(target_os = "linux"))]
    type NfdsT = u32;

    #[cfg(target_os = "linux")]
    const O_NONBLOCK: i32 = 0o4000;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: i32 = 0x0004;

    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: i32 = 8;

    const F_GETFL: i32 = 3;
    const F_SETFL: i32 = 4;

    #[repr(C)]
    struct Rlimit {
        rlim_cur: u64,
        rlim_max: u64,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
        fn pipe(fds: *mut i32) -> i32;
        fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn close(fd: i32) -> i32;
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }

    /// Block until any fd is ready or `timeout_ms` elapses (-1 = forever).
    /// Returns how many entries have nonzero `revents`; an interrupted
    /// call (`EINTR`) reports 0 ready fds instead of an error.
    pub fn poll_impl(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }

    fn set_nonblocking(fd: i32) -> io::Result<()> {
        let flags = unsafe { fcntl(fd, F_GETFL, 0) };
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub struct WakePipeImpl {
        r: i32,
        w: i32,
    }

    impl WakePipeImpl {
        pub fn new() -> io::Result<WakePipeImpl> {
            let mut fds = [0i32; 2];
            if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
                return Err(io::Error::last_os_error());
            }
            let p = WakePipeImpl { r: fds[0], w: fds[1] };
            // Nonblocking on both ends: a full pipe must not block the
            // waker, a drained pipe must not block the reactor.
            set_nonblocking(p.r)?;
            set_nonblocking(p.w)?;
            Ok(p)
        }

        pub fn read_fd(&self) -> i32 {
            self.r
        }

        /// Nudge the poller.  A full pipe (EAGAIN) already guarantees a
        /// pending wakeup, so the result is ignored.
        pub fn wake(&self) {
            let b = [1u8];
            unsafe { write(self.w, b.as_ptr(), 1) };
        }

        /// Swallow every queued wake byte.
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                let n = unsafe { read(self.r, buf.as_mut_ptr(), buf.len()) };
                if n <= 0 {
                    return;
                }
            }
        }
    }

    impl Drop for WakePipeImpl {
        fn drop(&mut self) {
            unsafe {
                close(self.r);
                close(self.w);
            }
        }
    }

    /// Raise the soft `RLIMIT_NOFILE` toward `target` (clamped at the
    /// hard limit).  Returns the resulting soft limit.
    pub fn raise_nofile_impl(target: u64) -> io::Result<u64> {
        let mut lim = Rlimit { rlim_cur: 0, rlim_max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } < 0 {
            return Err(io::Error::last_os_error());
        }
        if lim.rlim_cur >= target {
            return Ok(lim.rlim_cur);
        }
        let want = target.min(lim.rlim_max);
        let new = Rlimit { rlim_cur: want, rlim_max: lim.rlim_max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(want)
    }
}

#[cfg(unix)]
pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    sys::poll_impl(fds, timeout_ms)
}

#[cfg(not(unix))]
pub fn poll(_fds: &mut [PollFd], _timeout_ms: i32) -> io::Result<usize> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "poll(2) requires a unix platform"))
}

/// Self-pipe waker: the read end lives in the reactor's poll set, any
/// thread writes one byte to interrupt a blocked `poll`.
pub struct WakePipe {
    #[cfg(unix)]
    inner: sys::WakePipeImpl,
}

impl WakePipe {
    #[cfg(unix)]
    pub fn new() -> io::Result<WakePipe> {
        Ok(WakePipe { inner: sys::WakePipeImpl::new()? })
    }

    #[cfg(not(unix))]
    pub fn new() -> io::Result<WakePipe> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "self-pipe requires a unix platform"))
    }

    /// The fd to register with `POLLIN`.
    pub fn read_fd(&self) -> i32 {
        #[cfg(unix)]
        {
            self.inner.read_fd()
        }
        #[cfg(not(unix))]
        {
            -1
        }
    }

    pub fn wake(&self) {
        #[cfg(unix)]
        self.inner.wake();
    }

    pub fn drain(&self) {
        #[cfg(unix)]
        self.inner.drain();
    }
}

/// Best-effort soft fd-limit raise toward `target`; returns the new
/// (or already-sufficient) soft limit.
#[cfg(unix)]
pub fn raise_nofile(target: u64) -> io::Result<u64> {
    sys::raise_nofile_impl(target)
}

#[cfg(not(unix))]
pub fn raise_nofile(_target: u64) -> io::Result<u64> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "rlimit requires a unix platform"))
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn wake_pipe_reports_readable_then_drains() {
        let p = WakePipe::new().unwrap();
        let mut fds = [PollFd::new(p.read_fd(), POLLIN)];
        // nothing pending: an immediate poll times out with 0 ready
        assert_eq!(poll(&mut fds, 0).unwrap(), 0);
        p.wake();
        p.wake();
        fds[0].revents = 0;
        assert_eq!(poll(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].readable());
        p.drain();
        fds[0].revents = 0;
        assert_eq!(poll(&mut fds, 0).unwrap(), 0, "drained pipe is quiet");
    }

    #[test]
    fn raise_nofile_is_monotone() {
        let cur = raise_nofile(0).unwrap();
        assert!(cur > 0);
        let again = raise_nofile(cur).unwrap();
        assert!(again >= cur);
    }
}
