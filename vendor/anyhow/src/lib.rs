//! Offline stand-in for the `anyhow` crate: the subset of its API this
//! workspace uses, with the same semantics.
//!
//! * [`Error`] — an opaque error value holding a message and an optional
//!   chain of causes.
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — formatted construction.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the prior error as the cause.
//!
//! Display shows the outermost message; `{:#}` shows the full
//! colon-separated chain (matching anyhow's alternate formatting).

use std::fmt;

/// Opaque error: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` as the cause of a new contextual error.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }

    /// The root cause message (innermost of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let parts: Vec<&str> = self.chain().collect();
            write!(f, "{}", parts.join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Fold the std source chain into ours so nothing is lost.
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        err.expect("at least one message")
    }
}

/// Attach context to fallible values (`Result` / `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag was {fail}");
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.root_cause(), "x = 3");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = "12a".parse::<u32>().map(|v| v.to_string())?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
