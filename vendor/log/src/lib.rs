//! Offline stand-in for the `log` facade crate: levels, `Record` /
//! `Metadata`, a global logger slot, and the five logging macros.
//!
//! Matches the parts of the real crate this workspace touches, including
//! `Level <= LevelFilter` comparisons.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Global maximum-verbosity filter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Target/level pair describing a record before formatting.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log event: metadata plus preformatted arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Sink for log records.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error <= LevelFilter::Trace);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
    }

    #[test]
    fn macros_expand_without_logger() {
        // No logger installed in this test binary: must be a silent no-op.
        info!("hello {}", 1);
        error!("bad {}", "thing");
        warn!("warn");
        debug!("debug");
        trace!("trace");
    }
}
