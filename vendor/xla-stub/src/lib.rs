//! Typed stub of the `xla` PJRT bindings used by `lapq`'s optional PJRT
//! engine (`--features xla`).
//!
//! The signatures mirror the real crate closely enough that
//! `rust/src/runtime/engine.rs` compiles unmodified; every runtime entry
//! point returns [`Error::StubUnavailable`], so `PjRtClient::cpu()` (and
//! therefore engine boot) fails cleanly and the coordinator falls back to
//! the pure-Rust CPU backend.  To execute real HLO artifacts, replace this
//! path dependency with actual PJRT bindings via `[patch]`.

use std::path::Path;

/// Stub error: every operation reports PJRT as unavailable.
#[derive(Debug, Clone)]
pub enum Error {
    StubUnavailable(&'static str),
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::StubUnavailable(what))
}

/// Element types the engine understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Scalar types that can cross the Literal boundary.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Host-side tensor value (stub: shape metadata only).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    ty: ElementType,
}

/// Array shape (dims + element type).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { dims: vec![], ty: T::TY }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], ty: T::TY }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal { dims: dims.to_vec(), ty: self.ty })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu (xla stub: patch in real PJRT bindings)")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_boot_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
    }

    #[test]
    fn literal_metadata_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 1]);
        assert_eq!(s.ty(), ElementType::F32);
    }
}
