//! Admission control for the concurrent server: a bounded connection
//! queue with backpressure and the exponential-backoff policy the
//! accept loops share (the typed `overloaded` shed response itself is
//! [`crate::proto::Response::Overloaded`]).
//!
//! Backpressure model: the accept loop is never allowed to buffer
//! unbounded work.  Connections it cannot hand to a worker immediately
//! go into a bounded queue; when that is full the client gets
//! `{"ok":false,"error":"overloaded","retry_after_ms":N}` on the spot
//! and the connection is closed — a fast, typed shed beats a silent
//! multi-second stall.  Dropping the queue's sender is the graceful
//! shutdown signal: workers drain what was admitted, then exit.

use crate::coordinator::metrics;
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused; either way the item comes back to the
/// caller (to shed with a typed response or drop at shutdown).
pub enum PushError<T> {
    /// The queue is at capacity — shed.
    Full(T),
    /// Every receiver is gone — shutting down.
    Closed(T),
}

impl<T> PushError<T> {
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(t) | PushError::Closed(t) => t,
        }
    }
}

/// Producer half of a bounded queue; `push` never blocks.
pub struct BoundedQueue<T> {
    tx: SyncSender<T>,
    depth: Arc<AtomicUsize>,
    gauge: &'static str,
}

/// Consumer half, shareable across a worker pool.  `recv` serializes
/// dequeue (not processing) behind a mutex.
pub struct SharedReceiver<T> {
    rx: Arc<Mutex<Receiver<T>>>,
    depth: Arc<AtomicUsize>,
    gauge: &'static str,
}

impl<T> Clone for SharedReceiver<T> {
    fn clone(&self) -> Self {
        SharedReceiver { rx: self.rx.clone(), depth: self.depth.clone(), gauge: self.gauge }
    }
}

/// A bounded MPMC-ish queue of capacity `cap` whose depth is published
/// as the `gauge` metric.
pub fn bounded<T>(cap: usize, gauge: &'static str) -> (BoundedQueue<T>, SharedReceiver<T>) {
    let (tx, rx) = sync_channel(cap.max(1));
    let depth = Arc::new(AtomicUsize::new(0));
    metrics::set(gauge, 0.0);
    (
        BoundedQueue { tx, depth: depth.clone(), gauge },
        SharedReceiver { rx: Arc::new(Mutex::new(rx)), depth, gauge },
    )
}

impl<T> BoundedQueue<T> {
    /// Enqueue without blocking; on refusal the item comes back inside
    /// the typed [`PushError`].
    pub fn push(&self, t: T) -> Result<(), PushError<T>> {
        // Count *before* sending (rolled back on failure): a consumer's
        // decrement always follows the matching increment, so the
        // counter can never underflow/wrap even though the two sides
        // race.
        let d = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        match self.tx.try_send(t) {
            Ok(()) => {
                metrics::set(self.gauge, d as f64);
                Ok(())
            }
            Err(e) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                Err(match e {
                    TrySendError::Full(t) => PushError::Full(t),
                    TrySendError::Disconnected(t) => PushError::Closed(t),
                })
            }
        }
    }
}

impl<T> SharedReceiver<T> {
    fn took(&self, t: T) -> Option<T> {
        let d = self.depth.fetch_sub(1, Ordering::SeqCst).saturating_sub(1);
        metrics::set(self.gauge, d as f64);
        Some(t)
    }

    /// Blocking dequeue; `None` once every producer is gone and the
    /// queue has drained (the shutdown signal).
    pub fn recv(&self) -> Option<T> {
        let guard = self.rx.lock().unwrap_or_else(|p| p.into_inner());
        match guard.recv() {
            Ok(t) => self.took(t),
            Err(_) => None,
        }
    }

    /// Non-blocking dequeue; `None` when nothing is immediately
    /// available (empty *or* closed — callers distinguish shutdown via
    /// the next blocking `recv`).
    pub fn try_recv(&self) -> Option<T> {
        let guard = self.rx.lock().unwrap_or_else(|p| p.into_inner());
        match guard.try_recv() {
            Ok(t) => self.took(t),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Dequeue, waiting at most `timeout`; `None` on timeout or close.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<T> {
        let guard = self.rx.lock().unwrap_or_else(|p| p.into_inner());
        match guard.recv_timeout(timeout) {
            Ok(t) => self.took(t),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }
}

/// Exponential backoff with jitter for accept-loop failures.
///
/// The failure *budget* resets once `window` has elapsed since the
/// first failure of the current burst — not on the next successful
/// accept, which would let a slow-burning fault (one failure every few
/// seconds, each followed by a success) evade the budget forever.
pub struct Backoff {
    base: Duration,
    cap: Duration,
    window: Duration,
    budget: u32,
    failures: u32,
    first: Option<Instant>,
    rng: Pcg32,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, window: Duration, budget: u32) -> Backoff {
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        Backoff { base, cap, window, budget, failures: 0, first: None, rng: Pcg32::seeded(seed) }
    }

    /// The policy both accept loops use: 10 ms doubling, capped at
    /// 250 ms (a failure sleep must not block healthy accepts for
    /// long), budget of 32 failures per 30 s window.  The worst-case
    /// sum of all budgeted sleeps (~7 s nominal) sits well inside the
    /// window, so a persistently dead listener exhausts the budget
    /// deterministically instead of racing the window reset.
    pub fn accept_loop() -> Backoff {
        Backoff::new(
            Duration::from_millis(10),
            Duration::from_millis(250),
            Duration::from_secs(30),
            32,
        )
    }

    /// Record a failure.  `Some(delay)` — sleep that long and retry
    /// (exponential in the burst length, jittered ±50%); `None` — the
    /// budget is exhausted inside one window, surface the error.
    pub fn on_failure(&mut self) -> Option<Duration> {
        let now = Instant::now();
        if let Some(t0) = self.first {
            if now.duration_since(t0) >= self.window {
                self.failures = 0;
                self.first = None;
            }
        }
        if self.first.is_none() {
            self.first = Some(now);
        }
        self.failures += 1;
        if self.failures >= self.budget {
            return None;
        }
        let exp = self.failures.saturating_sub(1).min(16);
        let raw = self.base.as_secs_f64() * f64::from(1u32 << exp);
        let capped = raw.min(self.cap.as_secs_f64());
        // jitter in [0.5, 1.5): desynchronizes competing retriers
        let jitter = 0.5 + self.rng.uniform() as f64;
        Some(Duration::from_secs_f64(capped * jitter))
    }

    /// Failures in the current window (for logs).
    pub fn failures(&self) -> u32 {
        self.failures
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_queue_sheds_when_full() {
        let (q, rx) = bounded::<u32>(2, "test_q_depth");
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        match q.push(3) {
            Err(PushError::Full(t)) => assert_eq!(t, 3, "full push hands the item back"),
            _ => panic!("third push must bounce off the bound"),
        }
        assert_eq!(rx.recv(), Some(1));
        assert!(q.push(4).is_ok());
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(rx.recv_timeout(Duration::from_millis(50)), Some(4));
        assert_eq!(rx.try_recv(), None, "drained queue has nothing immediate");
        // drop the producer: drained receivers see the shutdown signal
        drop(q);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn backoff_grows_and_exhausts() {
        let mut b = Backoff::new(
            Duration::from_millis(10),
            Duration::from_millis(80),
            Duration::from_secs(60),
            5,
        );
        let mut prev = Duration::ZERO;
        for i in 0..4 {
            let d = b.on_failure().unwrap_or_else(|| panic!("budget hit early at {i}"));
            // jitter is ±50%, so each delay sits in [0.5x, 1.5x) of the
            // exponential schedule capped at 80ms
            let nominal = Duration::from_millis((10u64 << i).min(80));
            assert!(d >= nominal / 2 && d < nominal * 3 / 2, "step {i}: {d:?} vs {nominal:?}");
            assert!(d * 3 >= prev, "delays must not collapse: {d:?} after {prev:?}");
            prev = d;
        }
        assert!(b.on_failure().is_none(), "5th failure exhausts the budget");
    }

    #[test]
    fn backoff_budget_resets_on_elapsed_window() {
        let mut b = Backoff::new(
            Duration::from_millis(1),
            Duration::from_millis(1),
            Duration::from_millis(30),
            3,
        );
        assert!(b.on_failure().is_some());
        assert!(b.on_failure().is_some());
        assert_eq!(b.failures(), 2);
        std::thread::sleep(Duration::from_millis(40));
        // a fresh window: the burst counter restarts instead of tripping
        assert!(b.on_failure().is_some(), "window elapsed, budget must reset");
        assert_eq!(b.failures(), 1);
    }
}
