//! Dynamic micro-batching for the infer path: coalesce requests that
//! arrive close together into one [`crate::runtime::int::InferSession`]
//! call over the batch-parallel integer kernels, then scatter each
//! request's rows back to its connection.
//!
//! One batcher thread is the coalescing point.  It pulls the first
//! pending job, then keeps collecting *compatible* jobs (same model
//! key, same input signature) until one of:
//!
//! * the batch reaches `max_batch`,
//! * the batch reaches the number of currently-connected clients (there
//!   is nobody left who could contribute — waiting longer only adds
//!   latency; this is what keeps a single sequential client at
//!   single-request latency), or
//! * `batch_window_ms` has elapsed since the first job.
//!
//! Because every row of the integer kernels accumulates independently,
//! a coalesced execution is **bit-for-bit identical** to serving the
//! same requests sequentially (pinned by `InferSession::infer_many`
//! tests and the multi-client service test).

use super::admission::{self, BoundedQueue, PushError, SharedReceiver};
use super::registry::ModelRegistry;
use crate::config::ServeCfg;
use crate::coordinator::jobs::{self, InferReply};
use crate::coordinator::metrics;
use crate::runtime::EngineHandle;
use crate::tensor::{Data, HostTensor};
use anyhow::{anyhow, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One queued infer request: inputs in, exactly one reply out.
struct InferJob {
    key: String,
    inputs: Vec<HostTensor>,
    reply: mpsc::Sender<Result<InferReply>>,
}

/// Handle to the batcher thread.  Dropping it drains and joins.
pub struct Batcher {
    queue: Option<BoundedQueue<InferJob>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the batcher thread.  `active_conns` is the pool's live
    /// connection gauge — the batcher's upper bound on how many
    /// requests could possibly join a batch.
    pub fn start(
        eng: EngineHandle,
        registry: Arc<ModelRegistry>,
        cfg: &ServeCfg,
        active_conns: Arc<AtomicUsize>,
    ) -> Result<Batcher> {
        Batcher::start_named(
            eng,
            registry,
            cfg,
            active_conns,
            "serve_infer_queue_depth",
            "serve-batcher".into(),
        )
    }

    /// [`Batcher::start`] with explicit gauge/thread names, so
    /// per-model lanes ([`super::lanes::LaneSet`]) each publish their
    /// own queue depth instead of fighting over one metric.
    pub fn start_named(
        eng: EngineHandle,
        registry: Arc<ModelRegistry>,
        cfg: &ServeCfg,
        active_conns: Arc<AtomicUsize>,
        gauge: &'static str,
        thread_name: String,
    ) -> Result<Batcher> {
        // The same depth-tracked bounded queue the accept loop uses.
        let (queue, rx) = admission::bounded::<InferJob>(cfg.queue_bound.max(1), gauge);
        let window = Duration::from_secs_f64(cfg.batch_window_ms.max(0.0) / 1e3);
        let max_batch = cfg.max_batch.max(1);
        let thread = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || run(eng, registry, window, max_batch, active_conns, rx))
            .context("spawning batcher thread")?;
        Ok(Batcher { queue: Some(queue), thread: Some(thread) })
    }

    /// Submit one infer request and block for its reply.  `None` means
    /// the batcher queue is full — shed the request (typed overload
    /// response) instead of stalling the connection.
    pub fn try_submit(&self, key: &str, inputs: Vec<HostTensor>) -> Option<Result<InferReply>> {
        let (rtx, rrx) = mpsc::channel();
        let job = InferJob { key: key.to_string(), inputs, reply: rtx };
        match self.queue.as_ref().expect("batcher alive").push(job) {
            Ok(()) => {}
            Err(PushError::Full(_)) => return None,
            Err(PushError::Closed(_)) => return Some(Err(anyhow!("batcher is shut down"))),
        }
        Some(rrx.recv().unwrap_or_else(|_| Err(anyhow!("batcher dropped the reply"))))
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Closing the queue lets the thread drain queued jobs and exit.
        self.queue.take();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Two jobs may share a batch iff they target the same packed model and
/// their tensors concatenate along the batch axis: same arity, same
/// dtype, same trailing dims.
fn compatible(a: &InferJob, b: &InferJob) -> bool {
    a.key == b.key
        && a.inputs.len() == b.inputs.len()
        && a.inputs.iter().zip(&b.inputs).all(|(x, y)| {
            !x.shape.is_empty()
                && x.shape.len() == y.shape.len()
                && x.shape[1..] == y.shape[1..]
                && matches!(
                    (&x.data, &y.data),
                    (Data::F32(_), Data::F32(_)) | (Data::I32(_), Data::I32(_))
                )
        })
}

fn run(
    eng: EngineHandle,
    registry: Arc<ModelRegistry>,
    window: Duration,
    max_batch: usize,
    active_conns: Arc<AtomicUsize>,
    rx: SharedReceiver<InferJob>,
) {
    // The most requests that could plausibly still join this batch: one
    // per live connection (each connection has at most one in flight).
    let target = || active_conns.load(Ordering::Relaxed).clamp(1, max_batch);
    let mut carry: Option<InferJob> = None;
    loop {
        let first = match carry.take() {
            Some(j) => j,
            None => match rx.recv() {
                Some(j) => j,
                None => return, // all submitters gone: shutdown
            },
        };
        let mut batch = vec![first];
        if max_batch > 1 && !window.is_zero() {
            let deadline = Instant::now() + window;
            'collect: while batch.len() < target() {
                let j = match rx.try_recv() {
                    Some(j) => j,
                    None => {
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        match rx.recv_timeout(left) {
                            Some(j) => j,
                            None => break, // window over (or closing)
                        }
                    }
                };
                if compatible(&batch[0], &j) {
                    batch.push(j);
                } else {
                    // incompatible: flush what we have, lead the next batch
                    carry = Some(j);
                    break 'collect;
                }
            }
        }
        execute(&eng, &registry, batch);
    }
}

/// One panic-contained coalesced execution (the batcher thread must
/// outlive any single bad request).
fn run_parts(
    eng: &EngineHandle,
    registry: &ModelRegistry,
    key: &str,
    parts: &[Vec<HostTensor>],
) -> Result<Vec<InferReply>> {
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        jobs::infer_batched(eng, registry, key, parts)
    }));
    match caught {
        Ok(r) => r,
        Err(p) => Err(anyhow!(
            "internal panic: {}",
            crate::proto::wire::panic_text(p.as_ref())
        )),
    }
}

fn one_reply(outcome: Result<Vec<InferReply>>) -> Result<InferReply> {
    outcome.and_then(|mut rs| rs.pop().ok_or_else(|| anyhow!("empty batch reply")))
}

/// Run one coalesced batch and scatter per-request replies.
///
/// If the *coalesced* execution fails, the batch is re-run one part at
/// a time: a malformed request (ragged NCF pair, out-of-range id) must
/// fail only its own connection, never the innocent requests that
/// happened to share its window — otherwise batching would break the
/// "identical to sequential serving" contract on the error path too.
fn execute(eng: &EngineHandle, registry: &ModelRegistry, jobs: Vec<InferJob>) {
    let key = jobs[0].key.clone();
    let mut parts = Vec::with_capacity(jobs.len());
    let mut replies = Vec::with_capacity(jobs.len());
    for j in jobs {
        parts.push(j.inputs);
        replies.push(j.reply);
    }
    metrics::record_hist("serve_batch_size", parts.len() as f64);
    metrics::add("serve_batched_requests", parts.len() as f64);
    metrics::inc("serve_batches");
    match run_parts(eng, registry, &key, &parts) {
        Ok(rs) if rs.len() == replies.len() => {
            for (r, tx) in rs.into_iter().zip(replies) {
                let _ = tx.send(Ok(r));
            }
        }
        outcome => {
            if replies.len() == 1 {
                let tx = replies.into_iter().next().expect("one reply");
                let _ = tx.send(one_reply(outcome));
                return;
            }
            // Coalesced failure: isolate it.  Each part runs alone and
            // every connection gets exactly its own outcome.
            metrics::inc("serve_batch_retries");
            for (part, tx) in parts.into_iter().zip(replies) {
                let solo = run_parts(eng, registry, &key, std::slice::from_ref(&part));
                let _ = tx.send(one_reply(solo));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_model_is_a_structured_error() {
        let eng = EngineHandle::cpu().unwrap();
        let registry = Arc::new(ModelRegistry::new(2));
        let active = Arc::new(AtomicUsize::new(1));
        let b = Batcher::start(eng, registry, &ServeCfg::default(), active).unwrap();
        let x = HostTensor::zeros(vec![1, 64]);
        let r = b.try_submit("nope", vec![x]).expect("queue has room");
        let e = r.expect_err("missing model must error");
        assert!(format!("{e:#}").contains("no packed model"), "{e:#}");
    }

    #[test]
    fn compatible_requires_key_arity_shape_kind() {
        let (tx, _rx) = mpsc::channel();
        let job = |key: &str, t: HostTensor| InferJob {
            key: key.into(),
            inputs: vec![t],
            reply: tx.clone(),
        };
        let a = job("k", HostTensor::zeros(vec![1, 64]));
        assert!(compatible(&a, &job("k", HostTensor::zeros(vec![4, 64]))));
        assert!(!compatible(&a, &job("other", HostTensor::zeros(vec![1, 64]))));
        assert!(!compatible(&a, &job("k", HostTensor::zeros(vec![1, 32]))));
        assert!(!compatible(&a, &job("k", HostTensor::i32(vec![1, 64], vec![0; 64]))));
    }
}
