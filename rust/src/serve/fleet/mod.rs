//! The fleet tier: one router process in front of N pool-server
//! replicas, turning the single-process pool into one cell of a
//! horizontally-scaled deployment.
//!
//! * [`ring`] — consistent-hash ring (FNV-1a, virtual nodes): stable
//!   key→replica placement and a deterministic failover order; also the
//!   home of the hash the sharded [`crate::serve::registry`] selects
//!   shards with, so the two layers agree on what "the key's home" is.
//! * [`health`] — per-replica failure streaks, threshold ejection with
//!   timed re-admission, and the background `ping` prober.
//! * [`router`] — [`router::Router`]: the front-tier listener.  Speaks
//!   the ordinary JSON/bin1 wire on both sides and relays raw bytes, so
//!   a fleet's responses are byte-identical to a single pool server's;
//!   sheds retry onto the next ring candidate, transport failures fail
//!   over and feed the health table.
//!
//! Deterministic training + packing is what makes transparent failover
//! sound: every replica packs bit-identical artifacts from the same
//! config, so any replica can answer for any key.
//!
//! Knobs live in [`crate::config::FleetCfg`] (`-s fleet.*` overrides,
//! `repro route --replicas ...`); fleet behaviour is tracked by
//! `benches/perf_fleet.rs` (`BENCH_fleet.json`).

pub mod health;
pub mod ring;
pub mod router;

pub use health::HealthTable;
pub use ring::Ring;
pub use router::{Router, RouterHandle};
