//! Replica health tracking for the front-tier router.
//!
//! Every routed request reports its transport outcome here, and a
//! background pinger probes each replica with `{"cmd":"ping"}` on a
//! fixed interval.  `fail_threshold` consecutive failures eject a
//! replica for `eject_ms`; after that window it re-enters on probation
//! (one success resets it fully, one more failure re-ejects
//! immediately).  Ejection only reorders routing — an ejected replica
//! is still tried as a last resort when every healthy candidate fails,
//! so a fleet that is entirely "down" still gets one best-effort
//! attempt per request.

use crate::coordinator::metrics;
use crate::proto::wire::Client;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Slot {
    consecutive_failures: u32,
    ejected_until: Option<Instant>,
}

/// Shared replica health state (router connections + pinger thread).
pub struct HealthTable {
    slots: Vec<Mutex<Slot>>,
    fail_threshold: u32,
    eject: Duration,
}

impl HealthTable {
    pub fn new(n: usize, fail_threshold: u32, eject_ms: u64) -> HealthTable {
        HealthTable {
            slots: (0..n)
                .map(|_| Mutex::new(Slot { consecutive_failures: 0, ejected_until: None }))
                .collect(),
            fail_threshold: fail_threshold.max(1),
            eject: Duration::from_millis(eject_ms),
        }
    }

    fn slot(&self, i: usize) -> std::sync::MutexGuard<'_, Slot> {
        self.slots[i].lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Routable right now?  An elapsed ejection window counts as ok
    /// (probation) — the next failure re-ejects without waiting for
    /// the threshold again.
    pub fn ok(&self, i: usize) -> bool {
        match self.slot(i).ejected_until {
            Some(until) => Instant::now() >= until,
            None => true,
        }
    }

    /// A request or ping succeeded: full reset (clears probation too).
    pub fn on_success(&self, i: usize) {
        let mut s = self.slot(i);
        if s.ejected_until.is_some() {
            log::info!("fleet replica {i} re-admitted");
        }
        s.consecutive_failures = 0;
        s.ejected_until = None;
    }

    /// A request or ping failed at the transport level (connect error,
    /// EOF, corrupt frame) — sheds don't count, they are the replica
    /// protecting itself, not dying.
    pub fn on_failure(&self, i: usize) {
        let mut s = self.slot(i);
        s.consecutive_failures += 1;
        let on_probation = s.ejected_until.is_some_and(|u| Instant::now() >= u);
        if s.consecutive_failures >= self.fail_threshold || on_probation {
            s.ejected_until = Some(Instant::now() + self.eject);
            metrics::inc("router_ejections");
            log::warn!(
                "fleet replica {i} ejected for {:?} after {} consecutive failures",
                self.eject,
                s.consecutive_failures
            );
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Healthy replica count (for the router's `models` fan-out and
    /// metrics).
    pub fn healthy(&self) -> usize {
        (0..self.slots.len()).filter(|&i| self.ok(i)).count()
    }
}

/// Probe every replica each `interval` with a fresh connection and one
/// `ping`, feeding the shared table, until `stop` flips.  Fresh
/// connections on purpose: the probe then exercises the same accept
/// path a new client would, catching listeners that still hold old
/// connections but no longer accept.
pub fn spawn_pinger(
    addrs: Vec<SocketAddr>,
    table: Arc<HealthTable>,
    interval: Duration,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("fleet-pinger".into())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for (i, addr) in addrs.iter().enumerate() {
                    let up = Client::connect(addr)
                        .and_then(|mut c| c.call_raw("{\"cmd\":\"ping\"}"))
                        .is_ok();
                    if up {
                        table.on_success(i);
                    } else {
                        table.on_failure(i);
                    }
                }
                metrics::set("router_healthy_replicas", table.healthy() as f64);
                std::thread::sleep(interval);
            }
        })
        .expect("spawn fleet-pinger")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_ejects_and_window_readmits() {
        let t = HealthTable::new(2, 3, 20);
        assert!(t.ok(0));
        t.on_failure(0);
        t.on_failure(0);
        assert!(t.ok(0), "below threshold stays routable");
        t.on_failure(0);
        assert!(!t.ok(0), "threshold reached ejects");
        assert!(t.ok(1), "other replicas unaffected");
        std::thread::sleep(Duration::from_millis(30));
        assert!(t.ok(0), "elapsed window re-admits on probation");
        t.on_failure(0);
        assert!(!t.ok(0), "probation failure re-ejects immediately");
    }

    #[test]
    fn success_resets_streak() {
        let t = HealthTable::new(1, 2, 1000);
        t.on_failure(0);
        t.on_success(0);
        t.on_failure(0);
        assert!(t.ok(0), "streak was reset by the success");
        t.on_failure(0);
        assert!(!t.ok(0));
        t.on_success(0);
        assert!(t.ok(0), "success during ejection re-admits");
    }
}
