//! Consistent-hash ring over pool-server replicas.
//!
//! The router places every replica at `vnodes` pseudo-random points on
//! a 64-bit ring (FNV-1a of `"{replica}#{vnode}"`); a request's routing
//! key hashes to a point and walks clockwise, yielding replicas in ring
//! order.  Properties the fleet tier leans on:
//!
//! - **Stability** — the same key always lands on the same replica (so
//!   a replica's registry shard stays hot for "its" models), and adding
//!   or removing one replica only remaps ~1/N of the key space.
//! - **Failover order is deterministic** — [`Ring::candidates`] yields
//!   *every* replica exactly once, in the key's ring order, so retry
//!   (after an overload shed or a transport failure) walks a stable
//!   sequence instead of picking randomly.

/// FNV-1a 64-bit — the crate's dependency-free stable hash, shared by
/// the registry's shard selector and the router's ring placement.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// `points` is sorted by hash; each point names a replica index in
/// `0..n`.
pub struct Ring {
    points: Vec<(u64, usize)>,
    n: usize,
}

impl Ring {
    /// A ring over `n` replicas (min 1) with `vnodes` points each
    /// (min 1).  More vnodes → smoother key spread at O(n·vnodes)
    /// memory; 64 keeps the spread within a few percent for small
    /// fleets.
    pub fn new(n: usize, vnodes: usize) -> Ring {
        let n = n.max(1);
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(n * vnodes);
        for i in 0..n {
            for v in 0..vnodes {
                points.push((fnv1a(format!("replica{i}#vnode{v}").as_bytes()), i));
            }
        }
        points.sort_unstable();
        Ring { points, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// All replica indices in the key's ring order: the owner first,
    /// then each distinct successor walking clockwise.  Always yields
    /// every replica exactly once.
    pub fn candidates(&self, key: &str) -> Vec<usize> {
        let h = fnv1a(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.n];
        let mut out = Vec::with_capacity(self.n);
        for k in 0..self.points.len() {
            let (_, i) = self.points[(start + k) % self.points.len()];
            if !seen[i] {
                seen[i] = true;
                out.push(i);
                if out.len() == self.n {
                    break;
                }
            }
        }
        out
    }

    /// The key's owning replica (first ring candidate).
    pub fn owner(&self, key: &str) -> usize {
        self.candidates(key)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_cover_all_replicas_once() {
        let ring = Ring::new(5, 64);
        for key in ["mlp3", "cnn6:w8a8:LAPQ", "ncf:w[8.4.2]a4:LAPQ", "x"] {
            let c = ring.candidates(key);
            let mut sorted = c.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4], "key {key}: {c:?}");
        }
    }

    #[test]
    fn candidates_are_deterministic() {
        let a = Ring::new(3, 64);
        let b = Ring::new(3, 64);
        for key in ["mlp3", "cnn6", "mlp3:w8a8:MinMax"] {
            assert_eq!(a.candidates(key), b.candidates(key));
        }
    }

    #[test]
    fn keys_spread_across_replicas() {
        let ring = Ring::new(4, 64);
        let mut counts = [0usize; 4];
        for i in 0..256 {
            counts[ring.owner(&format!("model{i}:w8a8:LAPQ"))] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 16, "replica {i} starved: {counts:?}");
        }
    }

    #[test]
    fn single_replica_ring() {
        let ring = Ring::new(1, 8);
        assert_eq!(ring.candidates("anything"), vec![0]);
    }

    #[test]
    fn fnv1a_is_the_reference_function() {
        // Pinned reference vectors so the registry's shard mapping and
        // the ring's placement can never silently drift.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
