//! The front-tier router: one thin process that makes N pool servers
//! look like one.
//!
//! Clients speak the ordinary JSON-lines / bin1 wire to the router; the
//! router consistent-hashes each request's routing key (`key` for
//! `infer`, `model` for `pack`/`quantize`) over the replica ring
//! ([`super::ring::Ring`]) and relays raw wire bytes both ways — it
//! never re-serializes a replica's response, which is what makes the
//! fleet answer byte-identical to a single pool server.
//!
//! Fault handling per request, walking the key's ring order (healthy
//! replicas first, ejected ones as a last resort):
//!
//! * **Transport failure** (connect refused, EOF, corrupt frame) before
//!   any response byte was relayed → feed [`super::health`], drop the
//!   cached upstream connection, try the next candidate
//!   (`router_failovers`).  Deterministic replicas make this safe: every
//!   replica packs bit-identical artifacts from the same config.
//! * **Overload shed** (`{"error":"overloaded"...}`) → the replica is
//!   alive but saturated; sleep on the shared [`Backoff`] and try the
//!   next candidate (`router_shed_retries`).  When every candidate
//!   sheds (or the retry budget is spent), the last shed line is
//!   relayed verbatim so the client sees the normal typed overload
//!   response.
//! * Mid-response failure cannot be retried transparently (part of the
//!   reply is already on the client's socket): the client gets a
//!   structured error line and keeps its connection.
//!
//! `ping` / `metrics` / `hello` / unknown commands are answered
//! locally (the router has its own metrics); `models` fans out to every
//! healthy replica and merges; `shutdown` stops the router itself, not
//! the replicas.

use super::health::{self, HealthTable};
use super::ring::Ring;
use crate::config::FleetCfg;
use crate::coordinator::metrics;
use crate::proto::wire::{negotiate, Incoming, WireMode, WireReader};
use crate::proto::{frame, ReqId, Request, Response};
use crate::serve::admission::Backoff;
use crate::util::json::Reader;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The first bytes of a shed response line (alphabetical-key writers
/// make this prefix stable, with or without an `"id"` echo).
const SHED_PREFIX: &str = "{\"error\":\"overloaded\"";

/// State shared by every router connection thread and the pinger.
struct RouterCtx {
    replicas: Vec<SocketAddr>,
    ring: Ring,
    health: Arc<HealthTable>,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

/// Handle for stopping a running [`Router`] from another thread.
#[derive(Clone)]
pub struct RouterHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl RouterHandle {
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the accept loop if it is blocked in accept().
        let _ = TcpStream::connect(self.addr);
    }
}

/// The front-tier listener plus its replica ring.
pub struct Router {
    listener: TcpListener,
    pub addr: SocketAddr,
    ctx: Arc<RouterCtx>,
    ping_interval: Duration,
}

impl Router {
    /// Bind the front-tier listener (`addr`, port 0 for ephemeral) over
    /// the replicas named by `cfg.replicas`.  Nothing runs until
    /// [`Router::serve`].
    pub fn bind(addr: &str, cfg: &FleetCfg) -> Result<Router> {
        if cfg.replicas.is_empty() {
            anyhow::bail!("fleet.replicas is empty (need at least one pool server address)");
        }
        let mut replicas = Vec::with_capacity(cfg.replicas.len());
        for spec in &cfg.replicas {
            let a = spec
                .to_socket_addrs()
                .with_context(|| format!("resolve replica '{spec}'"))?
                .next()
                .with_context(|| format!("replica '{spec}' resolved to nothing"))?;
            replicas.push(a);
        }
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        let n = replicas.len();
        let ctx = Arc::new(RouterCtx {
            replicas,
            ring: Ring::new(n, cfg.vnodes),
            health: Arc::new(HealthTable::new(n, cfg.fail_threshold, cfg.eject_ms)),
            stop: Arc::new(AtomicBool::new(false)),
            addr,
        });
        log::info!(
            "router on {addr}: {n} replicas, {} vnodes, ping every {} ms, eject after {} failures for {} ms",
            cfg.vnodes.max(1),
            cfg.ping_interval_ms,
            cfg.fail_threshold.max(1),
            cfg.eject_ms
        );
        Ok(Router {
            listener,
            addr,
            ctx,
            ping_interval: Duration::from_millis(cfg.ping_interval_ms.max(1)),
        })
    }

    pub fn shutdown_handle(&self) -> RouterHandle {
        RouterHandle { stop: self.ctx.stop.clone(), addr: self.addr }
    }

    /// Serve until `max_conns` connections have been accepted
    /// (`usize::MAX` for forever), the shutdown flag is raised, or the
    /// accept-failure budget is exhausted.  Thread per connection: the
    /// router does no compute, a connection thread is mostly parked in
    /// `read`, and the replicas behind it enforce the real admission
    /// limits.
    pub fn serve(self, max_conns: usize) -> Result<()> {
        let pinger = health::spawn_pinger(
            self.ctx.replicas.clone(),
            self.ctx.health.clone(),
            self.ping_interval,
            self.ctx.stop.clone(),
        );
        let mut backoff = Backoff::accept_loop();
        let mut accepted = 0usize;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut result = Ok(());
        for stream in self.listener.incoming() {
            if self.ctx.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(e) => match backoff.on_failure() {
                    Some(delay) => {
                        log::warn!(
                            "router accept failed ({} in window): {e}; retrying in {delay:?}",
                            backoff.failures()
                        );
                        std::thread::sleep(delay);
                        continue;
                    }
                    None => {
                        result = Err(e).context("router accept failing persistently");
                        break;
                    }
                },
            };
            accepted += 1;
            metrics::inc("router_conns");
            let ctx = self.ctx.clone();
            conns.push(std::thread::spawn(move || {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handle_conn(&ctx, stream)
                }));
            }));
            // Reap finished connection threads so a long-lived router
            // does not accumulate handles.
            conns.retain(|h| !h.is_finished());
            if accepted >= max_conns {
                break;
            }
        }
        self.ctx.stop.store(true, Ordering::SeqCst);
        for h in conns {
            let _ = h.join();
        }
        let _ = pinger.join();
        result
    }
}

/// What the light request scan extracts: enough to route, never the
/// tensor payloads (those relay as raw bytes).
#[derive(Default)]
struct Scan {
    cmd: String,
    key: Option<String>,
    model: Option<String>,
    id: Option<ReqId>,
}

fn scan_request(line: &str) -> Result<Scan, String> {
    let mut s = Scan::default();
    let mut r = Reader::new(line);
    r.obj(|r, k| match k {
        "cmd" => {
            s.cmd = r.string_cow()?.into_owned();
            Ok(())
        }
        "key" => {
            s.key = Some(r.string_cow()?.into_owned());
            Ok(())
        }
        "model" => {
            s.model = Some(r.string_cow()?.into_owned());
            Ok(())
        }
        "id" => match r.peek() {
            Some(b'"') => {
                s.id = Some(ReqId::Str(r.string_cow()?.into_owned()));
                Ok(())
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                s.id = Some(ReqId::Num(r.number()?));
                Ok(())
            }
            _ => r.skip_value(0),
        },
        _ => r.skip_value(0),
    })?;
    r.expect_end()?;
    Ok(s)
}

/// A relayed response unit is terminal when it carries a top-level
/// `"ok"` — stream chunks (`{"chunk":...}`) and quantize events
/// (`{"event":...}`) don't, the final reply and every error do.  An
/// unparseable line is treated as terminal so a misbehaving replica
/// cannot wedge the relay loop.
fn line_is_terminal(line: &str) -> bool {
    let mut has_ok = false;
    let mut r = Reader::new(line);
    let scan = r.obj(|r, k| {
        if k == "ok" {
            has_ok = true;
        }
        r.skip_value(0)
    });
    scan.is_err() || has_ok
}

/// One request unit headed upstream, by reference to the client
/// reader's buffer — re-sent verbatim to each retry candidate.
enum Unit<'a> {
    Line(&'a str),
    Frame { kind: u8, payload: &'a [u8] },
}

/// One cached connection to a replica.  Cached per client connection
/// (not pooled globally) so the upstream's negotiated wire mode always
/// mirrors this client's.
struct Upstream {
    writer: TcpStream,
    reader: WireReader<TcpStream>,
}

impl Upstream {
    /// Connect and replay the client's latest `hello`, if any, so the
    /// replica's negotiated mode matches what the client expects.
    fn connect(addr: &SocketAddr, hello_line: Option<&str>) -> Result<Upstream> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let writer = stream.try_clone().context("clone stream")?;
        let mut up = Upstream { writer, reader: WireReader::new(stream) };
        if let Some(h) = hello_line {
            up.send_line(h)?;
            match up.reader.next() {
                Incoming::Line => {}
                _ => anyhow::bail!("replica {addr} rejected hello replay"),
            }
        }
        Ok(up)
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn send_frame(&mut self, kind: u8, payload: &[u8], buf: &mut Vec<u8>) -> Result<()> {
        rewrap_frame(kind, payload, buf);
        self.writer.write_all(buf)?;
        self.writer.flush()?;
        Ok(())
    }
}

/// Rebuild the exact wire bytes of a frame from its verified payload
/// (the reader strips header + CRC; both are deterministic functions of
/// kind + payload, so this is byte-identical to what was read).
fn rewrap_frame(kind: u8, payload: &[u8], buf: &mut Vec<u8>) {
    buf.clear();
    frame::begin(buf, kind);
    buf.extend_from_slice(payload);
    frame::finish(buf);
}

fn write_resp(
    w: &mut TcpStream,
    resp: &Response,
    id: Option<&ReqId>,
    out: &mut String,
) -> std::io::Result<()> {
    out.clear();
    resp.write_json_id(id, out);
    out.push('\n');
    w.write_all(out.as_bytes())?;
    w.flush()
}

/// Outcome of one relay attempt against one replica.
enum Attempt {
    /// Terminal unit relayed; the request is done.
    Done,
    /// The replica shed before sending anything else — retryable.
    Shed,
    /// Transport died; `mid_response` means bytes already reached the
    /// client, so no transparent retry is possible.
    Failed { mid_response: bool },
}

/// One client connection: scan, route, relay, until EOF.
fn handle_conn(ctx: &RouterCtx, stream: TcpStream) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "<unknown>".into());
    log::info!("router conn from {peer}");
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            log::warn!("router conn {peer}: clone failed: {e}");
            return;
        }
    };
    let mut reader = WireReader::new(stream);
    let mut mode = WireMode::Json;
    let mut stream_replies = false;
    let mut hello_line: Option<String> = None;
    let mut upstreams: HashMap<usize, Upstream> = HashMap::new();
    // Shed-retry pacing, shared across this connection's requests: a
    // shed storm exhausts the budget and the client gets the shed.
    let mut backoff = Backoff::new(
        Duration::from_millis(5),
        Duration::from_millis(100),
        Duration::from_secs(1),
        8,
    );
    let mut out = String::new();
    let mut bin: Vec<u8> = Vec::new();
    loop {
        let sent = match reader.next() {
            Incoming::Eof => break,
            Incoming::TooLarge { limit_bytes } => {
                let _ = write_resp(&mut writer, &Response::TooLarge { limit_bytes }, None, &mut out);
                break;
            }
            Incoming::Corrupt(msg) => {
                let _ = write_resp(&mut writer, &Response::error(msg), None, &mut out);
                break;
            }
            Incoming::Line => {
                if reader.line().trim().is_empty() {
                    continue;
                }
                metrics::inc("router_requests");
                let line = reader.line();
                let scan = match scan_request(line) {
                    Ok(s) => s,
                    Err(e) => {
                        let resp = Response::error(format!("bad request: {e}"));
                        if write_resp(&mut writer, &resp, None, &mut out).is_err() {
                            break;
                        }
                        continue;
                    }
                };
                match scan.cmd.as_str() {
                    "ping" => {
                        write_resp(&mut writer, &Response::Pong, scan.id.as_ref(), &mut out)
                    }
                    "metrics" => {
                        write_resp(&mut writer, &Response::metrics(), scan.id.as_ref(), &mut out)
                    }
                    "hello" => {
                        let resp = match Request::parse_line(line) {
                            Ok((Request::Hello { wire, stream }, _)) => {
                                let resp =
                                    negotiate(&wire, stream, &mut mode, &mut stream_replies);
                                if matches!(resp, Response::Hello { .. }) {
                                    hello_line = Some(line.to_string());
                                    renegotiate_upstreams(&mut upstreams, line);
                                }
                                resp
                            }
                            Ok(_) => Response::error("hello line did not parse as hello"),
                            Err(e) => Response::error(format!("{e:#}")),
                        };
                        write_resp(&mut writer, &resp, scan.id.as_ref(), &mut out)
                    }
                    "shutdown" => {
                        let _ = write_resp(
                            &mut writer,
                            &Response::Stopping,
                            scan.id.as_ref(),
                            &mut out,
                        );
                        ctx.stop.store(true, Ordering::SeqCst);
                        let _ = TcpStream::connect(ctx.addr); // wake the accept loop
                        break;
                    }
                    "models" => {
                        let resp =
                            merged_models(ctx, &mut upstreams, hello_line.as_deref());
                        write_resp(&mut writer, &resp, scan.id.as_ref(), &mut out)
                    }
                    "infer" | "pack" | "quantize" => {
                        let key = scan.key.or(scan.model).unwrap_or_default();
                        relay(
                            ctx,
                            &mut upstreams,
                            hello_line.as_deref(),
                            &key,
                            Unit::Line(line),
                            scan.id.as_ref(),
                            &mut writer,
                            &mut backoff,
                            &mut out,
                            &mut bin,
                        )
                    }
                    _ => write_resp(
                        &mut writer,
                        &Response::UnknownCmd { cmd: scan.cmd },
                        scan.id.as_ref(),
                        &mut out,
                    ),
                }
            }
            Incoming::Frame(kind) => {
                metrics::inc("router_requests");
                if mode != WireMode::Bin1 {
                    let resp = Response::error(
                        "binary frame before a successful hello/bin1 handshake",
                    );
                    write_resp(&mut writer, &resp, None, &mut out)
                } else if kind != frame::KIND_INFER_REQ {
                    let resp = Response::error(format!("unexpected frame kind {kind}"));
                    write_resp(&mut writer, &resp, None, &mut out)
                } else {
                    match frame::decode_infer_request_id(reader.payload()) {
                        Err(e) => {
                            let resp = Response::error(format!("bad frame: {e}"));
                            write_resp(&mut writer, &resp, None, &mut out)
                        }
                        Ok((ir, id)) => relay(
                            ctx,
                            &mut upstreams,
                            hello_line.as_deref(),
                            &ir.key,
                            Unit::Frame { kind, payload: reader.payload() },
                            id.as_ref(),
                            &mut writer,
                            &mut backoff,
                            &mut out,
                            &mut bin,
                        ),
                    }
                }
            }
        };
        if let Err(e) = sent {
            log::warn!("router conn {peer}: write failed: {e}");
            break;
        }
    }
}

/// Replay a fresh `hello` on every cached upstream so their negotiated
/// modes track the client's; an upstream that fails the replay is
/// dropped and will reconnect (with the replay) on next use.
fn renegotiate_upstreams(upstreams: &mut HashMap<usize, Upstream>, hello_line: &str) {
    upstreams.retain(|_, up| {
        up.send_line(hello_line).is_ok() && matches!(up.reader.next(), Incoming::Line)
    });
}

/// Fan `models` out to every healthy replica and merge: union of model
/// zoos (sorted), union of packed artifacts (first replica seen wins a
/// duplicate key).
fn merged_models(
    ctx: &RouterCtx,
    upstreams: &mut HashMap<usize, Upstream>,
    hello_line: Option<&str>,
) -> Response {
    let mut models: Vec<String> = Vec::new();
    let mut packs: Vec<(String, Vec<u32>)> = Vec::new();
    let mut answered = 0usize;
    for i in 0..ctx.replicas.len() {
        if !ctx.health.ok(i) {
            continue;
        }
        let resp = ask_models(ctx, upstreams, hello_line, i);
        match resp {
            Some(Response::Models { models: m, packs: p }) => {
                answered += 1;
                ctx.health.on_success(i);
                models.extend(m);
                for pack in p {
                    if !packs.iter().any(|(k, _)| *k == pack.0) {
                        packs.push(pack);
                    }
                }
            }
            _ => {
                upstreams.remove(&i);
                ctx.health.on_failure(i);
            }
        }
    }
    if answered == 0 {
        return Response::error("no healthy replica answered models");
    }
    models.sort();
    models.dedup();
    packs.sort_by(|a, b| a.0.cmp(&b.0));
    Response::Models { models, packs }
}

fn ask_models(
    ctx: &RouterCtx,
    upstreams: &mut HashMap<usize, Upstream>,
    hello_line: Option<&str>,
    i: usize,
) -> Option<Response> {
    if !upstreams.contains_key(&i) {
        let up = Upstream::connect(&ctx.replicas[i], hello_line).ok()?;
        upstreams.insert(i, up);
    }
    let up = upstreams.get_mut(&i)?;
    up.send_line("{\"cmd\":\"models\"}").ok()?;
    match up.reader.next() {
        Incoming::Line => Response::from_line(up.reader.line()).ok(),
        _ => None,
    }
}

/// Route one request unit: walk the key's ring candidates (healthy
/// first), send the raw unit, relay response units until terminal.
/// Returns an `Err` only for *client-side* write failures (which end
/// the connection); replica failures are handled internally.
#[allow(clippy::too_many_arguments)]
fn relay(
    ctx: &RouterCtx,
    upstreams: &mut HashMap<usize, Upstream>,
    hello_line: Option<&str>,
    route_key: &str,
    unit: Unit<'_>,
    id: Option<&ReqId>,
    client: &mut TcpStream,
    backoff: &mut Backoff,
    out: &mut String,
    bin: &mut Vec<u8>,
) -> std::io::Result<()> {
    let mut order = ctx.ring.candidates(route_key);
    // Stable partition: healthy candidates keep ring order up front,
    // ejected ones trail as a last resort.
    order.sort_by_key(|&i| !ctx.health.ok(i));
    let mut last_shed: Option<String> = None;
    let mut frame_buf: Vec<u8> = Vec::new();
    for &i in &order {
        if !upstreams.contains_key(&i) {
            match Upstream::connect(&ctx.replicas[i], hello_line) {
                Ok(up) => {
                    upstreams.insert(i, up);
                }
                Err(e) => {
                    log::warn!("router: replica {i} ({}) unreachable: {e:#}", ctx.replicas[i]);
                    ctx.health.on_failure(i);
                    metrics::inc("router_failovers");
                    continue;
                }
            }
        }
        let up = upstreams.get_mut(&i).expect("just inserted");
        let sent = match &unit {
            Unit::Line(l) => up.send_line(l),
            Unit::Frame { kind, payload } => up.send_frame(*kind, payload, &mut frame_buf),
        };
        if sent.is_err() {
            upstreams.remove(&i);
            ctx.health.on_failure(i);
            metrics::inc("router_failovers");
            continue;
        }
        let mut relayed_any = false;
        let attempt = loop {
            match up.reader.next() {
                Incoming::Line => {
                    let rl = up.reader.line();
                    if !relayed_any && rl.starts_with(SHED_PREFIX) {
                        last_shed = Some(rl.to_string());
                        break Attempt::Shed;
                    }
                    out.clear();
                    out.push_str(rl);
                    out.push('\n');
                    let terminal = line_is_terminal(rl);
                    client.write_all(out.as_bytes())?;
                    client.flush()?;
                    relayed_any = true;
                    if terminal {
                        break Attempt::Done;
                    }
                }
                Incoming::Frame(kind) => {
                    rewrap_frame(kind, up.reader.payload(), bin);
                    client.write_all(bin)?;
                    client.flush()?;
                    relayed_any = true;
                    if kind != frame::KIND_INFER_CHUNK {
                        break Attempt::Done;
                    }
                }
                Incoming::Eof | Incoming::Corrupt(_) | Incoming::TooLarge { .. } => {
                    break Attempt::Failed { mid_response: relayed_any };
                }
            }
        };
        match attempt {
            Attempt::Done => {
                ctx.health.on_success(i);
                metrics::inc("router_relayed");
                return Ok(());
            }
            Attempt::Shed => {
                // Alive-but-saturated: not a health failure.  Pace the
                // retry; a spent budget means the whole fleet is
                // saturated — surface the shed.
                metrics::inc("router_shed_retries");
                match backoff.on_failure() {
                    Some(delay) => std::thread::sleep(delay),
                    None => break,
                }
            }
            Attempt::Failed { mid_response } => {
                upstreams.remove(&i);
                ctx.health.on_failure(i);
                metrics::inc("router_failovers");
                if mid_response {
                    let resp = Response::error(format!(
                        "replica failed mid-response for '{route_key}'"
                    ));
                    return write_resp(client, &resp, id, out);
                }
            }
        }
    }
    if let Some(shed) = last_shed {
        out.clear();
        out.push_str(&shed);
        out.push('\n');
        client.write_all(out.as_bytes())?;
        return client.flush();
    }
    metrics::inc("router_no_replica");
    write_resp(client, &Response::error(format!("no healthy replica for '{route_key}'")), id, out)
}
