//! Per-model batcher lanes: one coalescing micro-batch lane per hot
//! registry key, so two models batch concurrently instead of
//! head-of-line blocking each other through the single global batcher.
//!
//! Lanes are created lazily, first-come first-served, up to
//! `serve.max_lanes`; once the cap is reached, further keys hash onto
//! an existing lane (stable per key, so a key's requests always share
//! one coalescing point and the batcher's compatibility check keeps
//! mixed traffic from cross-batching).  `max_lanes = 1` reproduces the
//! old single-batcher behaviour exactly.
//!
//! Each lane is a plain [`Batcher`] with its own thread and its own
//! depth gauge (`serve_infer_queue_depth_lane<N>`); the submit contract
//! is identical, so [`super::pool`] treats a `LaneSet` exactly like the
//! single batcher it replaces.

use super::batcher::Batcher;
use super::registry::ModelRegistry;
use crate::config::ServeCfg;
use crate::coordinator::jobs::InferReply;
use crate::runtime::EngineHandle;
use crate::tensor::HostTensor;
use anyhow::Result;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Mutex};

pub struct LaneSet {
    eng: EngineHandle,
    registry: Arc<ModelRegistry>,
    cfg: ServeCfg,
    active_conns: Arc<AtomicUsize>,
    max_lanes: usize,
    /// key -> index into `pool` (first-come assignment).
    assign: Mutex<HashMap<String, usize>>,
    /// The live lanes; grows up to `max_lanes`, never shrinks.
    pool: Mutex<Vec<Arc<Batcher>>>,
}

impl LaneSet {
    pub fn start(
        eng: EngineHandle,
        registry: Arc<ModelRegistry>,
        cfg: &ServeCfg,
        active_conns: Arc<AtomicUsize>,
    ) -> Result<LaneSet> {
        let lanes = LaneSet {
            eng,
            registry,
            cfg: cfg.clone(),
            active_conns,
            max_lanes: cfg.max_lanes.max(1),
            assign: Mutex::new(HashMap::new()),
            pool: Mutex::new(Vec::new()),
        };
        // Lane 0 exists up front: the common single-model deployment
        // never takes the lane-creation path at all.
        lanes.spawn_lane(0)?;
        Ok(lanes)
    }

    fn spawn_lane(&self, idx: usize) -> Result<Arc<Batcher>> {
        // Lane gauges are keyed by a 'static name (the metrics registry
        // contract); lanes are bounded by max_lanes and live for the
        // server's lifetime, so one leaked name per lane is finite.
        let gauge: &'static str = match idx {
            0 => "serve_infer_queue_depth",
            _ => Box::leak(format!("serve_infer_queue_depth_lane{idx}").into_boxed_str()),
        };
        let b = Arc::new(Batcher::start_named(
            self.eng.clone(),
            self.registry.clone(),
            &self.cfg,
            self.active_conns.clone(),
            gauge,
            format!("serve-batcher-{idx}"),
        )?);
        let mut pool = self.pool.lock().unwrap_or_else(|p| p.into_inner());
        debug_assert_eq!(pool.len(), idx);
        pool.push(b.clone());
        Ok(b)
    }

    /// The lane serving `key`: the key's assigned lane, a fresh lane if
    /// there is still room, or a stable hash pick among the existing
    /// lanes once the cap is reached.
    fn lane_for(&self, key: &str) -> Result<Arc<Batcher>> {
        let idx = {
            let mut assign = self.assign.lock().unwrap_or_else(|p| p.into_inner());
            match assign.get(key) {
                Some(&i) => i,
                None => {
                    let next = assign.len();
                    let i = if next < self.max_lanes {
                        next
                    } else {
                        let mut h = DefaultHasher::new();
                        key.hash(&mut h);
                        (h.finish() as usize) % self.max_lanes
                    };
                    assign.insert(key.to_string(), i);
                    i
                }
            }
        };
        // Lane 0 is pre-spawned; later lanes spawn on first assignment.
        // The spawn happens outside the assign lock but the pool lock
        // serializes it; a racing submitter for the same new key waits
        // on `pool` and then finds the lane present.
        loop {
            {
                let pool = self.pool.lock().unwrap_or_else(|p| p.into_inner());
                if let Some(b) = pool.get(idx) {
                    return Ok(b.clone());
                }
                // Lanes are assigned densely (next == assign.len()), so
                // at most one lane is missing and it is ours to create.
            }
            self.spawn_lane(idx)?;
        }
    }

    /// Same contract as [`Batcher::try_submit`]: `None` means the
    /// lane's queue is full — shed with the typed overload response.
    pub fn try_submit(&self, key: &str, inputs: Vec<HostTensor>) -> Option<Result<InferReply>> {
        match self.lane_for(key) {
            Ok(lane) => lane.try_submit(key, inputs),
            Err(e) => Some(Err(e)),
        }
    }

    /// Live lane count (for logs/tests).
    pub fn lanes(&self) -> usize {
        self.pool.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(max_lanes: usize) -> LaneSet {
        let eng = EngineHandle::cpu().unwrap();
        let registry = Arc::new(ModelRegistry::new(2));
        let cfg = ServeCfg { max_lanes, ..Default::default() };
        LaneSet::start(eng, registry, &cfg, Arc::new(AtomicUsize::new(1))).unwrap()
    }

    #[test]
    fn lanes_grow_to_cap_then_hash() {
        let ls = mk(2);
        assert_eq!(ls.lanes(), 1, "lane 0 pre-spawned");
        // distinct keys claim distinct lanes up to the cap
        let _ = ls.try_submit("a", vec![HostTensor::zeros(vec![1, 4])]);
        let _ = ls.try_submit("b", vec![HostTensor::zeros(vec![1, 4])]);
        assert_eq!(ls.lanes(), 2);
        // past the cap: no new lanes, keys still served
        let r = ls.try_submit("c", vec![HostTensor::zeros(vec![1, 4])]);
        assert!(r.is_some(), "hashed lane accepts the request");
        assert_eq!(ls.lanes(), 2, "cap holds");
        // assignment is stable
        let a1 = ls.lane_for("c").unwrap();
        let a2 = ls.lane_for("c").unwrap();
        assert!(Arc::ptr_eq(&a1, &a2));
    }

    #[test]
    fn single_lane_reproduces_global_batcher() {
        let ls = mk(1);
        let r = ls.try_submit("nope", vec![HostTensor::zeros(vec![1, 64])]).unwrap();
        let e = r.expect_err("missing model must error");
        assert!(format!("{e:#}").contains("no packed model"), "{e:#}");
        assert_eq!(ls.lanes(), 1);
    }
}
