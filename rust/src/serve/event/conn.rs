//! Per-connection state for the reactor: the push-based decoder, the
//! queue of decoded-but-undispatched inputs, and the cursor-tracked
//! output buffer that makes partial `write(2)`s safe.
//!
//! The output buffer is the nonblocking twin of `write_all`: a short
//! write advances a cursor and the remainder stays queued for the next
//! `POLLOUT`, so a response is delivered whole or the connection dies —
//! never silently truncated.  Workers append through [`ConnWriter`]
//! (behind the mutex), the reactor alone writes to the socket.
//!
//! Two offsets guard the bytes:
//!
//! * `committed` — end of the last *complete* response (or stream
//!   chunk): [`ConnWriter::flush`] is the commit point, mirroring the
//!   per-response / per-chunk `flush()` calls in
//!   [`crate::proto::wire::write_response_ex`].  The reactor flushes
//!   only committed bytes, so a half-serialized response never reaches
//!   the socket.
//! * `cursor` — how far the socket has accepted committed bytes.
//!
//! Backpressure: once a connection buffers `cap` bytes the writer
//! latches `overflowed` and refuses further appends (the uncommitted
//! tail is rolled back to the last response boundary).  The reactor
//! then sheds the connection with the typed `overloaded` line — the
//! never-reading-client defense, pinned by `tests/event_serve.rs`.

use crate::proto::wire::{FeedDecoder, WireMode};
use crate::proto::{ReqId, Request, Response};
use std::collections::VecDeque;
use std::io::{self, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};

/// Queued output for one connection.
pub struct OutBuf {
    buf: Vec<u8>,
    /// Bytes before `cursor` have been accepted by the socket.
    cursor: usize,
    /// Bytes before `committed` form complete responses/chunks; only
    /// these are eligible for the socket.
    committed: usize,
    /// Backpressure cap on buffered-but-unsent bytes (soft: a single
    /// response may finish past it; the *next* append overflows).
    cap: usize,
    /// Latched on overflow; every later append is refused.
    pub overflowed: bool,
}

impl OutBuf {
    pub fn new(cap: usize) -> OutBuf {
        OutBuf { buf: Vec::new(), cursor: 0, committed: 0, cap: cap.max(1), overflowed: false }
    }

    /// Committed bytes the socket has not accepted yet.
    pub fn flushable(&self) -> usize {
        self.committed - self.cursor
    }

    /// Everything buffered past the socket cursor (committed or not).
    fn buffered(&self) -> usize {
        self.buf.len() - self.cursor
    }

    /// True once every committed byte reached the socket and nothing
    /// uncommitted is pending behind it.
    pub fn is_drained(&self) -> bool {
        self.cursor == self.buf.len()
    }

    /// Append a complete, already-serialized line past the cap check:
    /// the overflow shed notice must go out even though the queue is
    /// full by definition when it is needed.
    pub fn force_committed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
        self.committed = self.buf.len();
    }

    /// Reclaim consumed prefix; amortized O(1) per byte.
    fn reclaim(&mut self) {
        if self.cursor == self.buf.len() {
            self.buf.clear();
            self.cursor = 0;
            self.committed = 0;
        } else if self.cursor > 64 * 1024 {
            self.buf.drain(..self.cursor);
            self.committed -= self.cursor;
            self.cursor = 0;
        }
    }
}

fn lock(out: &Mutex<OutBuf>) -> MutexGuard<'_, OutBuf> {
    out.lock().unwrap_or_else(|p| p.into_inner())
}

/// The `Write` handle workers (and mid-request stream observers) use:
/// appends under the mutex, commits on `flush`, and wakes the reactor
/// so committed bytes leave promptly.
pub struct ConnWriter {
    pub out: Arc<Mutex<OutBuf>>,
    pub waker: Arc<poll_shim::WakePipe>,
}

impl Write for ConnWriter {
    fn write(&mut self, bytes: &[u8]) -> io::Result<usize> {
        let mut o = lock(&self.out);
        if o.overflowed || o.buffered() >= o.cap {
            // Roll the uncommitted tail back to the last response
            // boundary so the shed line lands on a clean frame edge.
            let committed = o.committed;
            o.buf.truncate(committed);
            o.overflowed = true;
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "output queue overflow (client not reading)",
            ));
        }
        o.buf.extend_from_slice(bytes);
        Ok(bytes.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        {
            let mut o = lock(&self.out);
            o.committed = o.buf.len();
        }
        self.waker.wake();
        Ok(())
    }
}

/// One decoded unit waiting for in-order dispatch.  Dispatch order is
/// what keeps pipelined `hello` negotiation and response ordering
/// byte-identical to the blocking path: nothing here is interpreted
/// until everything before it has been.
pub enum Pending {
    /// A complete JSON line (may still fail to parse — at dispatch
    /// time, under the current negotiated mode).
    Line(String),
    /// A CRC-verified bin1 frame.
    Frame { kind: u8, payload: Vec<u8> },
    /// Reader-level failure (`too_large` / corrupt): write the typed
    /// response, then close — same as the blocking path's fatal exits.
    Fatal(Response),
}

/// A request handed to the worker pool, with everything needed to
/// serialize its response without touching the reactor's state.
pub struct WorkItem {
    pub slot: usize,
    pub gen: u64,
    pub req: Request,
    pub id: Option<ReqId>,
    pub mode: WireMode,
    pub stream: bool,
    pub out: Arc<Mutex<OutBuf>>,
}

/// One reactor-owned connection.
pub struct Conn {
    pub sock: TcpStream,
    pub peer: String,
    /// Generation of this slot: stale completions (for a conn that died
    /// and whose slot was reused) are ignored by comparing this.
    pub gen: u64,
    pub decoder: FeedDecoder,
    pub pending: VecDeque<Pending>,
    pub out: Arc<Mutex<OutBuf>>,
    pub mode: WireMode,
    pub stream_replies: bool,
    /// One request in flight per connection (response-order guarantee).
    pub busy: bool,
    /// Client half-closed (EOF) or input abandoned (fatal/drain).
    pub read_closed: bool,
    /// Flush what is queued, then close (fatal reply or overflow shed).
    pub close_after_flush: bool,
}

impl Conn {
    pub fn new(sock: TcpStream, peer: String, gen: u64, out_cap: usize) -> Conn {
        Conn {
            sock,
            peer,
            gen,
            decoder: FeedDecoder::new(),
            pending: VecDeque::new(),
            out: Arc::new(Mutex::new(OutBuf::new(out_cap))),
            mode: WireMode::Json,
            stream_replies: false,
            busy: false,
            read_closed: false,
            close_after_flush: false,
        }
    }

    /// Committed-but-unsent bytes (drives `POLLOUT` registration).
    pub fn out_flushable(&self) -> usize {
        lock(&self.out).flushable()
    }

    /// The writer latched overflow: this client is not reading.
    pub fn out_overflowed(&self) -> bool {
        lock(&self.out).overflowed
    }

    /// Append a complete response line past the cap (the shed notice).
    pub fn force_line(&mut self, bytes: &[u8]) {
        lock(&self.out).force_committed(bytes);
    }

    /// Push committed bytes into the socket until it would block.
    /// `Ok(true)` means everything queued (committed *and* pending
    /// serialization) is on the wire.
    pub fn flush(&mut self) -> io::Result<bool> {
        let mut o = lock(&self.out);
        while o.cursor < o.committed {
            match self.sock.write(&o.buf[o.cursor..o.committed]) {
                Ok(0) => {
                    return Err(io::Error::new(io::ErrorKind::WriteZero, "socket wrote zero"))
                }
                Ok(n) => o.cursor += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e),
            }
        }
        o.reclaim();
        Ok(o.is_drained())
    }

    /// Nothing left to do for this connection (used by drain/close).
    pub fn is_idle(&self) -> bool {
        !self.busy && self.pending.is_empty()
    }
}
