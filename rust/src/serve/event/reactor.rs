//! The poll loop: one thread owns the listener, every connection
//! socket, and the self-pipe waker; `cfg.workers` threads run the
//! requests.  Idle connections are just fds in the poll set — 10k of
//! them cost zero threads and zero per-connection buffers beyond the
//! (empty) decoder.
//!
//! Data path per wakeup:
//!
//! 1. drain the waker, apply worker completions (`busy = false`),
//! 2. accept (nonblocking) up to `serve.max_conns` live sockets,
//! 3. per readable conn: read until `WouldBlock` (bounded for
//!    fairness), push into its [`crate::proto::wire::FeedDecoder`],
//! 4. decode complete lines/frames into the conn's pending queue,
//! 5. dispatch in order while the conn has no request in flight:
//!    `hello` negotiates inline, `shutdown` starts the drain, fatal
//!    reader errors get their typed reply and close the conn after the
//!    flush; everything else becomes a [`WorkItem`] for the workers —
//!    which run the *same* [`pool::dispatch`] and serialize with the
//!    *same* `write_response_ex` as the blocking transport,
//! 6. flush committed output (partial writes keep their cursor), shed
//!    connections whose output queue overflowed, close what is done.
//!
//! One request in flight per connection preserves the blocking path's
//! response ordering, which is what makes the two `serve.io` modes
//! byte-identical under pipelining.

use super::super::admission::{self, PushError};
use super::super::pool::{self, Shared};
use super::conn::{Conn, ConnWriter, Pending, WorkItem};
use crate::config::ServeCfg;
use crate::coordinator::metrics;
use crate::proto::wire::{self, Feed, WireMode};
use crate::proto::{frame, ReqId, Request, Response};
use anyhow::{Context, Result};
use poll_shim::{PollFd, WakePipe, POLLIN, POLLOUT};
use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Decoded-but-undispatched units a single connection may hold before
/// the reactor stops reading from it (pipelining backpressure).
const PENDING_CAP: usize = 64;
/// Socket read chunk.
const READ_CHUNK: usize = 64 * 1024;
/// Per-connection read budget per wakeup: one firehosing client must
/// not starve the rest of the poll set.
const READ_FAIR: usize = 1 << 20;
/// Poll timeout: the stop flag is re-checked at least this often even
/// if no fd ever becomes ready.
const POLL_TICK_MS: i32 = 1000;

/// Serve the listener in readiness-polled mode.  Same exit contract as
/// the threads transport: returns once `max_accept` connections have
/// been accepted and finished, the shutdown flag drained every
/// connection, or the transport failed irrecoverably.
pub(crate) fn serve_poll(
    listener: TcpListener,
    shared: Arc<Shared>,
    cfg: ServeCfg,
    max_accept: usize,
) -> Result<()> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let max_conns = cfg.max_conns.max(8);
    // Best-effort: the fd budget must cover the connection budget.
    let _ = poll_shim::raise_nofile(max_conns as u64 + 64);
    let waker = Arc::new(WakePipe::new().context("reactor wake pipe")?);
    let completions: Arc<Mutex<Vec<(usize, u64)>>> = Arc::new(Mutex::new(Vec::new()));
    let (queue, rx) =
        admission::bounded::<WorkItem>(cfg.queue_bound.max(1), "serve_event_queue_depth");
    let workers = cfg.workers.max(1);
    let mut pool_threads = Vec::with_capacity(workers);
    for i in 0..workers {
        let shared = shared.clone();
        let rx = rx.clone();
        let waker = waker.clone();
        let completions = completions.clone();
        pool_threads.push(
            std::thread::Builder::new()
                .name(format!("serve-eworker-{i}"))
                .spawn(move || worker_loop(shared, rx, waker, completions))
                .context("spawning event worker")?,
        );
    }
    // Workers hold the only receiver clones: a dead pool surfaces as
    // PushError::Closed instead of a silently growing queue.
    drop(rx);
    log::info!(
        "reactor on {}: {} workers, {} max conns, {} KiB out queue",
        shared.addr,
        workers,
        max_conns,
        cfg.out_queue_kib.max(1)
    );

    let out_cap = cfg.out_queue_kib.max(1) * 1024;
    let mut slots: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut gen_counter: u64 = 0;
    let mut accepted = 0usize;
    let mut live = 0usize;
    let mut draining = false;
    let mut pool_gone = false;
    let mut pollfds: Vec<PollFd> = Vec::new();
    let mut poll_map: Vec<usize> = Vec::new();
    let mut scratch = vec![0u8; READ_CHUNK];
    // Reused serialization buffers (same idea as serve_conn).
    let mut out = String::new();
    let mut bin: Vec<u8> = Vec::new();

    loop {
        if (shared.stop.load(Ordering::SeqCst) || pool_gone) && !draining {
            draining = true;
            for conn in slots.iter_mut().flatten() {
                // Graceful drain: no new input, in-flight requests
                // finish, queued output flushes, then the socket closes.
                conn.read_closed = true;
                conn.pending.clear();
            }
        }
        let accepting = !draining && accepted < max_accept;
        if !accepting && live == 0 {
            break;
        }

        // ---- build the poll set: waker, listener, every live conn ----
        pollfds.clear();
        poll_map.clear();
        pollfds.push(PollFd::new(waker.read_fd(), POLLIN));
        // The listener stays registered even when not accepting so a
        // shutdown-handle connect() always wakes the loop; such
        // connections are accepted and dropped below.
        pollfds.push(PollFd::new(listener.as_raw_fd(), POLLIN));
        for (idx, slot) in slots.iter().enumerate() {
            let Some(c) = slot else { continue };
            let mut ev: i16 = 0;
            if !c.read_closed && c.pending.len() < PENDING_CAP {
                ev |= POLLIN;
            }
            if c.out_flushable() > 0 {
                ev |= POLLOUT;
            }
            // ev == 0 still reports POLLERR/POLLHUP, which is all we
            // need from a conn that is mid-request with nothing queued.
            pollfds.push(PollFd::new(c.sock.as_raw_fd(), ev));
            poll_map.push(idx);
        }
        poll_shim::poll(&mut pollfds, POLL_TICK_MS).context("poll(2)")?;
        waker.drain();

        // Readiness per slot (conns accepted later this iteration
        // default to not-ready and are polled next time around).
        let mut ready: Vec<(bool, bool)> = vec![(false, false); slots.len()];
        for (pi, &idx) in poll_map.iter().enumerate() {
            let pfd = &pollfds[pi + 2];
            ready[idx] = (pfd.readable() || pfd.invalid(), pfd.writable());
        }

        // ---- worker completions: the conn may dispatch its next unit ----
        {
            let mut done = completions.lock().unwrap_or_else(|p| p.into_inner());
            for (idx, gen) in done.drain(..) {
                if let Some(Some(c)) = slots.get_mut(idx) {
                    if c.gen == gen {
                        c.busy = false;
                    }
                }
            }
        }

        // ---- accept everything pending ----
        loop {
            match listener.accept() {
                Ok((sock, peer)) => {
                    if !accepting || accepted >= max_accept {
                        drop(sock); // drain-phase wakeup connection
                        continue;
                    }
                    accepted += 1;
                    metrics::inc("serve_conns");
                    if live >= max_conns {
                        // Typed shed while the socket is still blocking.
                        pool::shed(sock, shared.retry_hint_ms());
                        continue;
                    }
                    if let Err(e) = sock.set_nonblocking(true) {
                        log::warn!("conn from {peer}: nonblocking failed: {e}");
                        continue;
                    }
                    gen_counter += 1;
                    log::info!("conn from {peer}");
                    let conn = Conn::new(sock, peer.to_string(), gen_counter, out_cap);
                    shared.active_conns.fetch_add(1, Ordering::SeqCst);
                    live += 1;
                    match free.pop() {
                        Some(i) => slots[i] = Some(conn),
                        None => slots.push(Some(conn)),
                    }
                    if accepted >= max_accept {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Transient accept failures self-heal on the next
                    // wakeup; the listener itself keeps polling.
                    log::warn!("accept failed: {e}");
                    break;
                }
            }
        }

        // ---- per-connection work ----
        for idx in 0..slots.len() {
            let Some(mut conn) = slots[idx].take() else { continue };
            let (can_read, _can_write) = ready.get(idx).copied().unwrap_or((false, false));
            let mut dead = false;
            if can_read && !conn.read_closed && conn.pending.len() < PENDING_CAP {
                if let Err(e) = read_some(&mut conn, &mut scratch) {
                    log::debug!("conn {}: read failed: {e}", conn.peer);
                    dead = true;
                }
            }
            if !dead {
                pump(&mut conn);
                dispatch(
                    &mut conn,
                    &shared,
                    &queue,
                    &waker,
                    idx,
                    &mut pool_gone,
                    &mut out,
                    &mut bin,
                );
                if conn.out_overflowed() && !conn.close_after_flush {
                    // Never-reading client: typed shed past the cap, one
                    // best-effort flush, then close — holding the queue
                    // open would just leak the buffer.
                    metrics::inc("serve_shed");
                    let mut line = String::new();
                    Response::Overloaded { retry_after_ms: shared.retry_hint_ms() }
                        .write_json(&mut line);
                    line.push('\n');
                    conn.force_line(line.as_bytes());
                    conn.pending.clear();
                    conn.read_closed = true;
                    conn.close_after_flush = true;
                    let _ = conn.flush();
                    log::info!("conn {}: output queue overflow, shedding", conn.peer);
                    dead = true;
                }
            }
            if !dead {
                match conn.flush() {
                    Err(e) => {
                        log::debug!("conn {}: write failed: {e}", conn.peer);
                        dead = true;
                    }
                    Ok(flushed) => {
                        let finished = conn.close_after_flush || conn.read_closed || draining;
                        if flushed && conn.is_idle() && finished {
                            dead = true;
                        }
                    }
                }
            }
            if dead {
                live -= 1;
                shared.active_conns.fetch_sub(1, Ordering::SeqCst);
                free.push(idx);
                // conn (and its socket) drops here
            } else {
                slots[idx] = Some(conn);
            }
        }
    }

    // Joining after the queue closes lets workers finish in-flight
    // requests (their conns are already gone; the writes are no-ops).
    drop(queue);
    for t in pool_threads {
        let _ = t.join();
    }
    if pool_gone {
        anyhow::bail!("connection queue closed: worker pool is gone");
    }
    Ok(())
}

/// Read until the socket would block (or EOF, or the fairness budget).
fn read_some(conn: &mut Conn, scratch: &mut [u8]) -> std::io::Result<()> {
    let mut total = 0usize;
    loop {
        if conn.pending.len() >= PENDING_CAP {
            break;
        }
        match conn.sock.read(scratch) {
            Ok(0) => {
                conn.read_closed = true;
                break;
            }
            Ok(n) => {
                conn.decoder.push(&scratch[..n]);
                total += n;
                if total >= READ_FAIR {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Decode buffered bytes into pending units (bounded by PENDING_CAP).
fn pump(conn: &mut Conn) {
    while conn.pending.len() < PENDING_CAP {
        match conn.decoder.next() {
            Feed::More => break,
            Feed::Line(l) => {
                if l.trim().is_empty() {
                    continue; // keep-alive blank lines, as in serve_conn
                }
                metrics::inc("service_requests");
                conn.pending.push_back(Pending::Line(l));
            }
            Feed::Frame { kind, payload } => {
                metrics::inc("service_requests");
                conn.pending.push_back(Pending::Frame { kind, payload });
            }
            Feed::TooLarge { limit_bytes } => {
                conn.pending.push_back(Pending::Fatal(Response::TooLarge { limit_bytes }));
                conn.read_closed = true;
                break;
            }
            Feed::Corrupt(msg) => {
                conn.pending.push_back(Pending::Fatal(Response::error(msg)));
                conn.read_closed = true;
                break;
            }
        }
    }
}

/// serve_conn's error accounting, shared by reactor and workers.
fn count_error(resp: &Response) {
    if matches!(
        resp,
        Response::Error { .. } | Response::UnknownCmd { .. } | Response::TooLarge { .. }
    ) {
        metrics::inc("service_errors");
    }
}

/// Serialize a reactor-produced response straight into the conn's
/// output queue (same writer the workers use → same bytes).
#[allow(clippy::too_many_arguments)]
fn push_response(
    conn: &mut Conn,
    resp: &Response,
    id: Option<&ReqId>,
    waker: &Arc<WakePipe>,
    out: &mut String,
    bin: &mut Vec<u8>,
) {
    count_error(resp);
    let mut w = ConnWriter { out: conn.out.clone(), waker: waker.clone() };
    // An overflow error here latches `overflowed`; the sweep sheds.
    let _ = wire::write_response_ex(&mut w, resp, conn.mode, conn.stream_replies, id, out, bin);
}

/// In-order dispatch: pop pending units until the conn has a request in
/// flight (or nothing left).  Mirrors one iteration of serve_conn per
/// unit.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    conn: &mut Conn,
    shared: &Shared,
    queue: &admission::BoundedQueue<WorkItem>,
    waker: &Arc<WakePipe>,
    slot: usize,
    pool_gone: &mut bool,
    out: &mut String,
    bin: &mut Vec<u8>,
) {
    while !conn.busy && !conn.close_after_flush {
        let Some(unit) = conn.pending.pop_front() else { break };
        let (req, id) = match unit {
            Pending::Fatal(resp) => {
                push_response(conn, &resp, None, waker, out, bin);
                conn.pending.clear();
                conn.close_after_flush = true;
                return;
            }
            Pending::Line(line) => {
                let parsed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    Request::parse_line(&line)
                }));
                match parsed {
                    Ok(Ok(pair)) => pair,
                    Ok(Err(e)) => {
                        let resp = Response::error(format!("{e:#}"));
                        push_response(conn, &resp, None, waker, out, bin);
                        continue;
                    }
                    Err(p) => {
                        let msg = format!("internal panic: {}", wire::panic_text(p.as_ref()));
                        push_response(conn, &Response::error(msg), None, waker, out, bin);
                        continue;
                    }
                }
            }
            Pending::Frame { kind, payload } => {
                if conn.mode != WireMode::Bin1 {
                    let resp =
                        Response::error("binary frame before a successful hello/bin1 handshake");
                    push_response(conn, &resp, None, waker, out, bin);
                    continue;
                }
                if kind != frame::KIND_INFER_REQ {
                    let resp = Response::error(format!("unexpected frame kind {kind}"));
                    push_response(conn, &resp, None, waker, out, bin);
                    continue;
                }
                match frame::decode_infer_request_id(&payload) {
                    Ok((ir, id)) => (Request::Infer(ir), id),
                    Err(e) => {
                        let resp = Response::error(format!("bad frame: {e}"));
                        push_response(conn, &resp, None, waker, out, bin);
                        continue;
                    }
                }
            }
        };
        match req {
            // Negotiation mutates the conn's mode/stream *before* the
            // reply serializes — identical ordering to the blocking
            // path's dispatch_caught.
            Request::Hello { wire: w, stream: want_stream } => {
                let resp =
                    wire::negotiate(&w, want_stream, &mut conn.mode, &mut conn.stream_replies);
                push_response(conn, &resp, id.as_ref(), waker, out, bin);
            }
            Request::Shutdown => {
                shared.stop.store(true, Ordering::SeqCst);
                push_response(conn, &Response::Stopping, id.as_ref(), waker, out, bin);
            }
            req => {
                let item = WorkItem {
                    slot,
                    gen: conn.gen,
                    req,
                    id,
                    mode: conn.mode,
                    stream: conn.stream_replies,
                    out: conn.out.clone(),
                };
                match queue.push(item) {
                    Ok(()) => conn.busy = true,
                    Err(PushError::Full(item)) => {
                        // Request-level shed: the conn survives, exactly
                        // like the threads path's batcher-full shed.
                        metrics::inc("serve_shed");
                        let resp =
                            Response::Overloaded { retry_after_ms: shared.retry_hint_ms() };
                        push_response(conn, &resp, item.id.as_ref(), waker, out, bin);
                    }
                    Err(PushError::Closed(item)) => {
                        let resp = Response::error("worker pool is gone");
                        push_response(conn, &resp, item.id.as_ref(), waker, out, bin);
                        conn.close_after_flush = true;
                        *pool_gone = true;
                        return;
                    }
                }
            }
        }
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    rx: admission::SharedReceiver<WorkItem>,
    waker: Arc<WakePipe>,
    completions: Arc<Mutex<Vec<(usize, u64)>>>,
) {
    let mut out = String::new();
    let mut bin: Vec<u8> = Vec::new();
    while let Some(item) = rx.recv() {
        let WorkItem { slot, gen, req, id, mode, stream, out: oq } = item;
        let mut writer = ConnWriter { out: oq, waker: waker.clone() };
        let resp = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool::dispatch(&shared, req, &mut writer)
        })) {
            Ok(r) => r,
            Err(p) => {
                Response::error(format!("internal panic: {}", wire::panic_text(p.as_ref())))
            }
        };
        count_error(&resp);
        // Write errors (overflowed queue, vanished conn) are the
        // reactor's problem; the completion must be recorded regardless.
        let _ = wire::write_response_ex(
            &mut writer,
            &resp,
            mode,
            stream,
            id.as_ref(),
            &mut out,
            &mut bin,
        );
        completions.lock().unwrap_or_else(|p| p.into_inner()).push((slot, gen));
        waker.wake();
    }
}
