//! The readiness-polled serving transport (`serve.io = poll`): one
//! reactor thread owns every socket, a small worker pool runs the
//! requests, and idle connections cost nothing but an fd.
//!
//! Layout:
//!
//! * [`conn`] — per-connection state: the push-based
//!   [`crate::proto::wire::FeedDecoder`], the decoded-but-undispatched
//!   queue, and the cursor-tracked output buffer whose partial writes
//!   make short `write(2)`s queue remainders instead of truncating.
//! * [`reactor`] — the poll loop itself: nonblocking accept, reads,
//!   decode, in-order dispatch to the worker queue, opportunistic and
//!   `POLLOUT`-driven flushing, backpressure shedding, graceful drain.
//!
//! The contract with the `threads` transport is **byte identity**: both
//! modes parse with the same grammar, dispatch through
//! [`super::pool::dispatch`], and serialize through
//! [`crate::proto::wire::write_response_ex`] — the only thing that
//! changes is who blocks where.  `tests/event_serve.rs` pins this by
//! diffing the two modes' bytes under concurrent load.

#[cfg(unix)]
pub mod conn;
#[cfg(unix)]
pub mod reactor;

#[cfg(unix)]
pub(crate) use reactor::serve_poll;

#[cfg(not(unix))]
pub(crate) fn serve_poll(
    _listener: std::net::TcpListener,
    _shared: std::sync::Arc<super::pool::Shared>,
    _cfg: crate::config::ServeCfg,
    _max_conns: usize,
) -> anyhow::Result<()> {
    anyhow::bail!("serve.io=poll requires a unix platform (use serve.io=threads)")
}
