//! The concurrent server: a worker pool over the typed wire protocol of
//! [`crate::proto`], sharing the connection loop
//! ([`crate::proto::wire::serve_conn`]) and the `Request`/`Response`
//! surface with the blocking `coordinator::service`, so the two paths
//! cannot drift.
//!
//! Concurrency model:
//!
//! * The accept loop (caller's thread) admits connections into a
//!   bounded queue ([`super::admission`]); a full queue sheds the
//!   connection with the typed `overloaded` response instead of letting
//!   it stall unseen.  Accept failures retry under exponential backoff
//!   with jitter.
//! * `workers` threads each own one connection at a time, so `workers`
//!   is also the ceiling on concurrently-served (persistent)
//!   connections.
//! * **Read path** (`ping` / `models` / `metrics` / `infer`) never
//!   touches the Runner lock: `infer` goes through the shared
//!   [`ModelRegistry`] + per-model batcher lanes
//!   ([`super::lanes::LaneSet`]), `models` reads the engine manifest
//!   directly.  Note that while connections (parse, I/O, waiting) are
//!   handled in parallel across workers, each model's infer *compute*
//!   executes on its lane's batcher thread — by design, since the
//!   integer kernels are already batch-parallel across cores and one
//!   coalesced execution saturates the machine.
//! * **Exclusive path** (`quantize` / `pack`) takes the write half of
//!   the `RwLock<Runner>`: those jobs own the engine for seconds to
//!   minutes and keep exactly the sequential semantics of the blocking
//!   service, while read traffic keeps flowing around them.
//! * Shutdown (`{"cmd":"shutdown"}` or [`PoolHandle::shutdown`]) stops
//!   accepting, drains admitted connections, joins the workers.

use super::admission::{self, Backoff};
use super::lanes::LaneSet;
use super::registry::ModelRegistry;
use crate::config::{ExperimentConfig, ServeCfg};
use crate::coordinator::jobs::Runner;
use crate::coordinator::metrics;
use crate::coordinator::service::StreamObserver;
use crate::proto::{wire, Request, Response};
use crate::runtime::int::PackOpts;
use crate::runtime::EngineHandle;
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock, RwLockWriteGuard};

/// Shared state every worker holds: the exclusive Runner behind an
/// `RwLock`, the read path's registry + batcher lanes, and the shutdown
/// flag.  `pub(crate)` so the readiness-polled reactor
/// ([`super::event`]) serves from the exact same state.
pub(crate) struct Shared {
    pub(crate) eng: EngineHandle,
    pub(crate) runner: RwLock<Runner>,
    /// Read-path view of the packed-model LRU (same Arc the Runner fills).
    pub(crate) registry: Arc<ModelRegistry>,
    pub(crate) lanes: LaneSet,
    pub(crate) active_conns: Arc<AtomicUsize>,
    pub(crate) retry_after_ms: u64,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) addr: SocketAddr,
}

impl Shared {
    /// Write lock with poison recovery: a panicking job already became
    /// a structured error response, and the CPU backend recovers its
    /// own state — the Runner stays usable.
    fn write_runner(&self) -> RwLockWriteGuard<'_, Runner> {
        self.runner.write().unwrap_or_else(|p| p.into_inner())
    }

    /// What to tell a shed client.  When an exclusive job (quantize /
    /// pack) holds the Runner, the stall is seconds-to-minutes — a
    /// batch-window-sized hint would invite a retry storm; tell clients
    /// to back off much longer instead.
    pub(crate) fn retry_hint_ms(&self) -> u64 {
        let exclusive_busy =
            matches!(self.runner.try_write(), Err(std::sync::TryLockError::WouldBlock));
        if exclusive_busy {
            EXCLUSIVE_RETRY_MS
        } else {
            self.retry_after_ms
        }
    }
}

/// Shed hint while an exclusive job owns the engine.
const EXCLUSIVE_RETRY_MS: u64 = 1000;

/// Handle for stopping a running [`PoolServer`] from another thread.
#[derive(Clone)]
pub struct PoolHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl PoolHandle {
    /// Request graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the accept loop if it is blocked in accept().
        let _ = TcpStream::connect(self.addr);
    }
}

pub struct PoolServer {
    listener: TcpListener,
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    cfg: ServeCfg,
}

impl PoolServer {
    /// Bind to `addr` (port 0 for ephemeral) and assemble the serving
    /// state: registry, Runner, micro-batcher.  Nothing runs until
    /// [`PoolServer::serve`].
    pub fn bind(addr: &str, eng: EngineHandle, cfg: ServeCfg) -> Result<PoolServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        let registry = Arc::new(ModelRegistry::with_options(
            cfg.registry_cap,
            cfg.registry_shards,
            cfg.spill_dir.as_ref().map(std::path::PathBuf::from),
        ));
        let runner = Runner::with_registry(eng.clone(), registry.clone());
        let active_conns = Arc::new(AtomicUsize::new(0));
        let lanes = LaneSet::start(eng.clone(), registry.clone(), &cfg, active_conns.clone())?;
        let retry_after_ms = (cfg.batch_window_ms.max(0.0) * 2.0) as u64 + 10;
        // `Shared.stop` is the single shutdown flag: handles, the accept
        // loop and the `shutdown` command all share it through `shared`.
        let shared = Arc::new(Shared {
            eng,
            runner: RwLock::new(runner),
            registry: registry.clone(),
            lanes,
            active_conns,
            retry_after_ms,
            stop: Arc::new(AtomicBool::new(false)),
            addr,
        });
        log::info!(
            "pool server on {addr} (io {}): {} workers, batch window {} ms, max batch {}, queue {}, registry cap {} x{} shards{}, max lanes {}",
            cfg.io.key(),
            cfg.workers.max(1),
            cfg.batch_window_ms,
            cfg.max_batch,
            cfg.queue_bound,
            cfg.registry_cap,
            cfg.registry_shards.max(1),
            match &cfg.spill_dir {
                Some(d) => format!(" (spill {d})"),
                None => String::new(),
            },
            cfg.max_lanes.max(1)
        );
        Ok(PoolServer { listener, addr, shared, registry, cfg })
    }

    /// The registry this server reads from (shared with its Runner).
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Warm the registry before taking traffic: run a full `pack` job
    /// (train → calibrate → quantize) per config on the exclusive path.
    /// Returns the registry keys in config order.
    pub fn preload(&self, cfgs: &[ExperimentConfig]) -> Result<Vec<String>> {
        let mut keys = Vec::with_capacity(cfgs.len());
        for cfg in cfgs {
            let mut runner = self.shared.write_runner();
            let (sum, _qm) = runner.pack(cfg, &PackOpts::default())?;
            log::info!("preloaded {}", sum.key);
            keys.push(sum.key);
        }
        Ok(keys)
    }

    /// A handle that can stop this server once [`PoolServer::serve`] is
    /// running on another thread.
    pub fn shutdown_handle(&self) -> PoolHandle {
        PoolHandle { stop: self.shared.stop.clone(), addr: self.addr }
    }

    /// Serve until `max_conns` connections have been accepted
    /// (`usize::MAX` for forever), the shutdown flag is raised, or the
    /// accept-failure budget is exhausted.  All three exits drain the
    /// admitted queue and join the workers before returning.
    ///
    /// `serve.io` picks the connection transport: `threads` runs the
    /// blocking one-worker-per-connection loop below; `poll` hands the
    /// listener to the readiness-polled reactor ([`super::event`]),
    /// which serves the same `Shared` state through the same dispatch,
    /// byte-identically.
    pub fn serve(self, max_conns: usize) -> Result<()> {
        if matches!(self.cfg.io, crate::config::IoMode::Poll) {
            let PoolServer { listener, shared, cfg, .. } = self;
            return super::event::serve_poll(listener, shared, cfg, max_conns);
        }
        let workers = self.cfg.workers.max(1);
        let (queue, srx) =
            admission::bounded::<TcpStream>(self.cfg.queue_bound, "serve_queue_depth");
        let mut pool = Vec::with_capacity(workers);
        for i in 0..workers {
            let shared = self.shared.clone();
            let srx = srx.clone();
            pool.push(
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(shared, srx))
                    .context("spawning worker")?,
            );
        }
        // The workers hold the only receiver clones now: if every one of
        // them dies, the channel disconnects and push() reports Closed —
        // keeping our clone would mask a dead pool as a healthy queue.
        drop(srx);
        let mut backoff = Backoff::accept_loop();
        let mut accepted = 0usize;
        let mut result = Ok(());
        for stream in self.listener.incoming() {
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(e) => match backoff.on_failure() {
                    Some(delay) => {
                        log::warn!(
                            "accept failed ({} in window): {e}; retrying in {delay:?}",
                            backoff.failures()
                        );
                        std::thread::sleep(delay);
                        continue;
                    }
                    None => {
                        result = Err(e).context("accept failing persistently");
                        break;
                    }
                },
            };
            accepted += 1;
            metrics::inc("serve_conns");
            match queue.push(stream) {
                Ok(()) => {}
                // At capacity: typed shed so the client knows to back off.
                Err(admission::PushError::Full(s)) => shed(s, self.shared.retry_hint_ms()),
                // Every worker is dead: no admitted connection will ever
                // be served.  Keep the typed-response contract for this
                // last client, then surface the failure instead of
                // reporting a clean exit.
                Err(admission::PushError::Closed(mut s)) => {
                    metrics::inc("service_errors");
                    let _ = write_line(&mut s, &Response::error("worker pool is gone"));
                    result = Err(anyhow::anyhow!("connection queue closed: worker pool is gone"));
                    break;
                }
            }
            if accepted >= max_conns {
                break;
            }
        }
        // Graceful drain: closing the queue lets every worker finish the
        // connections already admitted, then exit.
        drop(queue);
        for w in pool {
            let _ = w.join();
        }
        result
    }
}

/// Write one JSON-line response outside the connection loop (the shed
/// path and the dead-pool path run on the accept thread, before any
/// worker owns the connection).
///
/// Short-write audit: on the blocking path `write_all` already loops
/// over partial writes and retries `Interrupted`, so a line is written
/// whole or errors — never truncated.  Only call this on *blocking*
/// sockets; a nonblocking socket can return `WouldBlock` mid-line,
/// which `write_all` surfaces as an error after a partial write.  The
/// reactor never uses this: its writes go through the cursor-tracked
/// output queue ([`super::event`]), which is the nonblocking-safe
/// equivalent.
pub(crate) fn write_line(w: &mut dyn Write, resp: &Response) -> std::io::Result<()> {
    let mut line = String::new();
    resp.write_json(&mut line);
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Overload path: typed response, then close.  The client learns *why*
/// and *when to retry* instead of seeing a silent hang or reset.
pub(crate) fn shed(mut stream: TcpStream, retry_after_ms: u64) {
    metrics::inc("serve_shed");
    let _ = write_line(&mut stream, &Response::Overloaded { retry_after_ms });
}

fn worker_loop(shared: Arc<Shared>, rx: admission::SharedReceiver<TcpStream>) {
    while let Some(stream) = rx.recv() {
        shared.active_conns.fetch_add(1, Ordering::SeqCst);
        wire::serve_conn(stream, usize::MAX, |req, writer| dispatch(&shared, req, writer));
        shared.active_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Same contract as the blocking service: job and validation failures
/// become structured `{"ok":false}` errors (panics are contained by the
/// connection loop).  Shared verbatim with the reactor's worker pool,
/// which is what makes the two `serve.io` modes byte-identical.
pub(crate) fn dispatch(shared: &Shared, req: Request, writer: &mut dyn Write) -> Response {
    match dispatch_inner(shared, req, writer) {
        Ok(resp) => resp,
        Err(e) => Response::error(format!("{e:#}")),
    }
}

fn dispatch_inner(shared: &Shared, req: Request, writer: &mut dyn Write) -> Result<Response> {
    Ok(match req {
        Request::Ping => Response::Pong,
        Request::Models => Response::models(&shared.eng, &shared.registry),
        Request::Metrics => Response::metrics(),
        Request::Infer(ir) => {
            let crate::proto::InferRequest { key, inputs } = ir;
            match shared.lanes.try_submit(&key, inputs) {
                // Batcher queue full: typed shed on the request, the
                // connection itself stays up.
                None => {
                    metrics::inc("serve_shed");
                    Response::Overloaded { retry_after_ms: shared.retry_hint_ms() }
                }
                // A key that was never packed (and has no spill to
                // reload) gets the typed miss, so clients can react
                // without string-matching the generic error.
                Some(Err(e)) if crate::proto::is_model_not_packed(&e) => {
                    Response::ModelNotPacked { key }
                }
                Some(reply) => Response::Infer { reply: reply? },
            }
        }
        Request::Quantize { cfg, stream } => {
            let mut runner = shared.write_runner();
            let res = if stream {
                let mut obs = StreamObserver::new(writer);
                runner.run_observed(&cfg, &mut obs)?
            } else {
                runner.run(&cfg)?
            };
            Response::quantize(&cfg, &res)
        }
        Request::Pack { cfg, po2 } => {
            let mut runner = shared.write_runner();
            let (sum, _qm) = runner.pack(&cfg, &PackOpts { po2_scales: po2 })?;
            Response::Pack { packed: sum }
        }
        Request::Shutdown => {
            shared.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(shared.addr); // wake the accept loop
            Response::Stopping
        }
        Request::Hello { .. } => Response::error("hello outside the connection loop"),
        Request::Unknown { cmd } => Response::UnknownCmd { cmd },
    })
}
