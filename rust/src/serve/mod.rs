//! The concurrent serving subsystem: worker pool, dynamic
//! micro-batching, shared model registry, admission control.
//!
//! `coordinator::service` is the *blocking* reference server — one
//! connection at a time, which is exactly right for minutes-long
//! quantization jobs and for tests that want strictly sequential
//! semantics.  This module layers the production face on top of it,
//! speaking the identical JSON-lines protocol through the same response
//! builders:
//!
//! * [`pool`] — [`pool::PoolServer`]: N worker threads serving
//!   connections concurrently.  Read-only traffic (`infer`, `models`,
//!   `metrics`) runs in parallel; exclusive jobs (`quantize`, `pack`)
//!   serialize on the write half of an `RwLock<Runner>`, preserving the
//!   sequential engine-ownership semantics.
//! * [`registry`] — [`registry::ModelRegistry`]: an `Arc`-shared LRU of
//!   packed [`crate::runtime::int::QuantizedModel`]s with capacity,
//!   preload, and hit/miss/eviction counters, replacing the Runner's
//!   private MRU cache.
//! * [`batcher`] — [`batcher::Batcher`]: coalesces infer requests
//!   arriving within `batch_window_ms` (or up to `max_batch` / the live
//!   connection count) into one batched integer-kernel execution,
//!   bit-for-bit identical to serving them sequentially.
//! * [`admission`] — bounded queues with a typed
//!   `{"error":"overloaded","retry_after_ms":..}` shed response,
//!   graceful drain-and-shutdown, and the shared accept-retry
//!   exponential backoff.
//! * [`lanes`] — [`lanes::LaneSet`]: per-model batcher lanes, so two
//!   hot models coalesce concurrently instead of head-of-line blocking
//!   each other through one batcher thread (`serve.max_lanes`).
//! * [`fleet`] — the fleet tier: a consistent-hash front-tier router
//!   over N pool-server replicas with health checks, ejection and
//!   overload-aware retry ([`fleet::Router`]), plus the hash ring the
//!   sharded registry and the router share.  The registry itself is
//!   hash-sharded with one global LRU budget and spills evicted
//!   artifacts to disk for transparent reload.
//! * [`event`] — the readiness-polled reactor (`serve.io = poll`): one
//!   thread polls every connection for readability/writability over the
//!   vendored `poll(2)` shim, assembles partial reads, queues partial
//!   writes, and feeds decoded requests to a small worker pool — so 10k
//!   idle connections cost one polling thread, not 10k blocked ones.
//!   Byte-identical to the `threads` transport (same dispatch, same
//!   writers), pinned by the cross-mode tests.
//!
//! Knobs live in [`crate::config::ServeCfg`] (`-s serve.*` overrides,
//! `repro serve --io/--workers/--batch-window-ms/...`); load behaviour
//! is tracked by `benches/perf_serve.rs` (`BENCH_serve.json`).

pub mod admission;
pub mod batcher;
pub mod event;
pub mod fleet;
pub mod lanes;
pub mod pool;
pub mod registry;

pub use batcher::Batcher;
pub use fleet::{Router, RouterHandle};
pub use lanes::LaneSet;
pub use pool::{PoolHandle, PoolServer};
pub use registry::{ModelRegistry, RegistryStats};
