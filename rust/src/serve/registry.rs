//! Shared packed-model registry: the serving subsystem's LRU of
//! [`QuantizedModel`] artifacts, replacing the Runner's private
//! single-owner MRU cache.
//!
//! The registry is `Arc`-shared between the [`crate::coordinator::jobs::
//! Runner`] (which fills it from `pack` jobs) and the concurrent read
//! path (pool workers + micro-batcher, which only `get`).  Internally an
//! `RwLock` guards the LRU order; lookups take the write lock too (a
//! hit refreshes recency), but the critical section is a few pointer
//! moves — microseconds against the milliseconds of an infer call.
//!
//! The `registry_size` / `registry_hits` / `registry_misses` /
//! `registry_evictions` gauges are kept current (each op publishes the
//! counters it changed, after releasing the lock), so the
//! `{"cmd":"metrics"}` endpoint always reflects cache behaviour.

use crate::coordinator::metrics;
use crate::runtime::int::QuantizedModel;
use std::sync::{Arc, RwLock};

/// Counter snapshot (also mirrored into the metrics registry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub size: usize,
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct Inner {
    cap: usize,
    /// front = most recently used
    entries: Vec<(String, Arc<QuantizedModel>)>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe LRU of packed models, keyed by the pack key
/// (`model:wNaM:METHOD`, or `model:w[8.4.2]aM:METHOD` for mixed-precision
/// plans) with bare-model-name fallback.
pub struct ModelRegistry {
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    /// An empty registry holding at most `cap` models (min 1).
    pub fn new(cap: usize) -> ModelRegistry {
        let inner =
            Inner { cap: cap.max(1), entries: Vec::new(), hits: 0, misses: 0, evictions: 0 };
        ModelRegistry { inner: RwLock::new(inner) }
    }

    /// Recover the guard even if a panicking holder poisoned the lock —
    /// the registry's state is a plain LRU list, always consistent.
    fn write(&self) -> std::sync::RwLockWriteGuard<'_, Inner> {
        self.inner.write().unwrap_or_else(|p| p.into_inner())
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, Inner> {
        self.inner.read().unwrap_or_else(|p| p.into_inner())
    }

    /// Look up by exact key or bare model name (most recently used
    /// wins), refreshing the entry's recency on a hit.  This is the
    /// serving hot path: exactly one gauge update per call, issued
    /// after the registry lock is released.
    pub fn get(&self, key: &str) -> Option<Arc<QuantizedModel>> {
        let mut m = self.write();
        let pos = m.entries.iter().position(|(k, qm)| k == key || qm.model == key);
        let (out, gauge, count) = match pos {
            Some(p) => {
                let entry = m.entries.remove(p);
                let qm = entry.1.clone();
                m.entries.insert(0, entry);
                m.hits += 1;
                (Some(qm), "registry_hits", m.hits)
            }
            None => {
                m.misses += 1;
                (None, "registry_misses", m.misses)
            }
        };
        drop(m);
        metrics::set(gauge, count as f64);
        out
    }

    /// Insert (or refresh) `key`, evicting least-recently-used entries
    /// beyond capacity.  Cold path (one `pack` job per call): the full
    /// gauge set is republished, outside the lock.
    pub fn put(&self, key: String, qm: Arc<QuantizedModel>) {
        let mut m = self.write();
        m.entries.retain(|(k, _)| *k != key);
        m.entries.insert(0, (key, qm));
        while m.entries.len() > m.cap {
            let (evicted, _) = m.entries.pop().expect("non-empty");
            m.evictions += 1;
            log::info!("registry evicted {evicted}");
        }
        let (size, evictions) = (m.entries.len(), m.evictions);
        drop(m);
        metrics::set("registry_size", size as f64);
        metrics::set("registry_evictions", evictions as f64);
    }

    /// Whether `key` (exact or bare model name) is resident, without
    /// touching recency or the hit/miss counters.
    pub fn contains(&self, key: &str) -> bool {
        self.read().entries.iter().any(|(k, qm)| k == key || qm.model == key)
    }

    /// Resident keys, most recently used first.
    pub fn keys(&self) -> Vec<String> {
        self.read().entries.iter().map(|(k, _)| k.clone()).collect()
    }

    /// Resident `(key, per-layer weight bits)` pairs, most recently used
    /// first — what the `models` response echoes so clients can tell a
    /// mixed pack from a uniform one without fetching the artifact.
    pub fn entries_wbits(&self) -> Vec<(String, Vec<u32>)> {
        self.read().entries.iter().map(|(k, qm)| (k.clone(), qm.wbits())).collect()
    }

    pub fn len(&self) -> usize {
        self.read().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.read().cap
    }

    /// Counter snapshot for tests and the service response.
    pub fn stats(&self) -> RegistryStats {
        let m = self.read();
        RegistryStats {
            size: m.entries.len(),
            capacity: m.cap,
            hits: m.hits,
            misses: m.misses,
            evictions: m.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuantParams;

    fn dummy(model: &str) -> Arc<QuantizedModel> {
        Arc::new(QuantizedModel {
            model: model.to_string(),
            quant: QuantParams::passthrough(0),
            active_w: Vec::new(),
            active_a: Vec::new(),
            params: Vec::new(),
            layers: Vec::new(),
        })
    }

    #[test]
    fn lru_insert_get_evict() {
        let r = ModelRegistry::new(2);
        assert!(r.is_empty());
        r.put("a:w8a8:MMSE".into(), dummy("a"));
        r.put("b:w8a8:MMSE".into(), dummy("b"));
        assert_eq!(r.len(), 2);
        // touching `a` makes `b` the LRU victim
        assert!(r.get("a:w8a8:MMSE").is_some());
        r.put("c:w8a8:MMSE".into(), dummy("c"));
        assert_eq!(r.len(), 2);
        assert!(r.contains("a:w8a8:MMSE"));
        assert!(!r.contains("b:w8a8:MMSE"), "b must have been evicted: {:?}", r.keys());
        let s = r.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.capacity, 2);
    }

    #[test]
    fn bare_model_name_resolves() {
        let r = ModelRegistry::new(4);
        r.put("mlp3:w8a8:LAPQ".into(), dummy("mlp3"));
        assert!(r.get("mlp3").is_some());
        assert!(r.get("cnn6").is_none());
        let s = r.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn put_refreshes_existing_key() {
        let r = ModelRegistry::new(2);
        r.put("a".into(), dummy("a"));
        r.put("b".into(), dummy("b"));
        r.put("a".into(), dummy("a2"));
        assert_eq!(r.len(), 2);
        assert_eq!(r.keys(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(r.get("a").unwrap().model, "a2");
        assert_eq!(r.stats().evictions, 0);
    }

    #[test]
    fn capacity_floor_is_one() {
        let r = ModelRegistry::new(0);
        assert_eq!(r.capacity(), 1);
        r.put("a".into(), dummy("a"));
        r.put("b".into(), dummy("b"));
        assert_eq!(r.len(), 1);
    }
}
