//! Shared packed-model registry: the serving subsystem's LRU of
//! [`QuantizedModel`] artifacts, replacing the Runner's private
//! single-owner MRU cache.
//!
//! The registry is `Arc`-shared between the [`crate::coordinator::jobs::
//! Runner`] (which fills it from `pack` jobs) and the concurrent read
//! path (pool workers + micro-batcher, which only `get`).
//!
//! **Sharding.**  Entries live in N independent shards selected by the
//! FNV-1a hash of the pack key, each behind its own `RwLock` — so two
//! hot models churning concurrently contend on different locks instead
//! of one.  Recency is global: a monotonic tick (`AtomicU64`) stamps
//! every touch, and eviction removes the entry whose *tick* is globally
//! oldest (each shard keeps its own MRU→LRU order, so the victim is
//! the oldest shard tail).  The observable semantics are therefore
//! exactly those of one global LRU under one capacity budget — sharding
//! is purely a contention optimization, and `ModelRegistry::new(cap)`
//! (one shard) reproduces the historical behaviour bit for bit.
//!
//! **Disk spill.**  With a spill directory configured, evicted models
//! are persisted via [`QuantizedModel::save`] and
//! [`ModelRegistry::get_or_reload`] transparently reloads them on a
//! miss (miss → load → re-admit) instead of surfacing an error — the
//! fleet tier's answer to "the registry is smaller than the model
//! catalog".  `registry_spill_*` / `registry_reload_*` counters track
//! both directions.
//!
//! The aggregate `registry_size` / `registry_hits` / `registry_misses`
//! / `registry_evictions` gauges keep their historical names (each op
//! publishes the counters it changed, after releasing the shard lock);
//! per-shard behaviour is additionally published as
//! `registry_hits_shard{i}` / `registry_misses_shard{i}` /
//! `registry_evictions_shard{i}`, so the `{"cmd":"metrics"}` endpoint
//! shows both the cache and its contention profile.

use super::fleet::ring::fnv1a;
use crate::coordinator::metrics;
use crate::runtime::int::QuantizedModel;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

pub use crate::config::DEFAULT_REGISTRY_SHARDS;

/// Counter snapshot (also mirrored into the metrics registry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub size: usize,
    pub capacity: usize,
    pub shards: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub spills: u64,
    pub reloads: u64,
}

/// One resident entry: pack key, artifact, last-used global tick.
type Entry = (String, Arc<QuantizedModel>, u64);

/// front = most recently used (within the shard; ticks give the global
/// order).
#[derive(Default)]
struct Shard {
    entries: Vec<Entry>,
}

/// A spilled artifact we can transparently reload: its pack key, the
/// bare model name (for the fallback lookup) and where it was saved.
struct SpillRecord {
    key: String,
    model: String,
    dir: PathBuf,
}

/// Thread-safe sharded LRU of packed models, keyed by the pack key
/// (`model:wNaM:METHOD`, or `model:w[8.4.2]aM:METHOD` for mixed-precision
/// plans) with bare-model-name fallback, under one global capacity
/// budget, with optional disk spill of evicted artifacts.
pub struct ModelRegistry {
    shards: Vec<RwLock<Shard>>,
    cap: usize,
    /// Global recency clock: every touch stamps the entry.
    tick: AtomicU64,
    hits: Vec<AtomicU64>,
    misses: Vec<AtomicU64>,
    evictions: Vec<AtomicU64>,
    spills: AtomicU64,
    reloads: AtomicU64,
    spill_dir: Option<PathBuf>,
    /// Most recently spilled first (same winner rule as the LRU lookup).
    spilled: Mutex<Vec<SpillRecord>>,
}

impl ModelRegistry {
    /// An empty single-shard registry holding at most `cap` models
    /// (min 1) — the historical constructor, exact-LRU semantics.
    pub fn new(cap: usize) -> ModelRegistry {
        ModelRegistry::with_options(cap, 1, None)
    }

    /// An empty registry with `shards` hash shards (min 1) under one
    /// global `cap` budget (min 1), spilling evicted artifacts into
    /// `spill_dir` when given.
    pub fn with_options(
        cap: usize,
        shards: usize,
        spill_dir: Option<PathBuf>,
    ) -> ModelRegistry {
        let n = shards.max(1);
        ModelRegistry {
            shards: (0..n).map(|_| RwLock::new(Shard::default())).collect(),
            cap: cap.max(1),
            tick: AtomicU64::new(0),
            hits: (0..n).map(|_| AtomicU64::new(0)).collect(),
            misses: (0..n).map(|_| AtomicU64::new(0)).collect(),
            evictions: (0..n).map(|_| AtomicU64::new(0)).collect(),
            spills: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            spill_dir,
            spilled: Mutex::new(Vec::new()),
        }
    }

    fn shard_of(&self, key: &str) -> usize {
        (fnv1a(key.as_bytes()) % self.shards.len() as u64) as usize
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Recover the guard even if a panicking holder poisoned the lock —
    /// each shard's state is a plain LRU list, always consistent.
    fn write(&self, i: usize) -> std::sync::RwLockWriteGuard<'_, Shard> {
        self.shards[i].write().unwrap_or_else(|p| p.into_inner())
    }

    fn read(&self, i: usize) -> std::sync::RwLockReadGuard<'_, Shard> {
        self.shards[i].read().unwrap_or_else(|p| p.into_inner())
    }

    fn spill_log(&self) -> std::sync::MutexGuard<'_, Vec<SpillRecord>> {
        self.spilled.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn sum(counters: &[AtomicU64]) -> u64 {
        counters.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Find the live entry matching `key` (exact key or bare model
    /// name) with the *globally* newest tick, refresh it, and return
    /// the artifact plus its shard.  Never holds two shard locks.
    fn lookup_touch(&self, key: &str) -> Option<(usize, Arc<QuantizedModel>)> {
        let matches = |e: &Entry| e.0 == key || e.1.model == key;
        // Pass 1 (read locks, one shard at a time): most recent match.
        let mut best: Option<(usize, u64)> = None;
        for i in 0..self.shards.len() {
            if let Some(e) = self.read(i).entries.iter().find(|e| matches(e)) {
                if best.map_or(true, |(_, t)| e.2 > t) {
                    best = Some((i, e.2));
                }
            }
        }
        let (si, _) = best?;
        // Pass 2: re-find under the write lock (the entry may have
        // moved or been evicted in between — then it is simply a miss).
        let mut shard = self.write(si);
        let pos = shard.entries.iter().position(matches)?;
        let mut entry = shard.entries.remove(pos);
        entry.2 = self.next_tick();
        let qm = entry.1.clone();
        shard.entries.insert(0, entry);
        Some((si, qm))
    }

    /// Look up by exact key or bare model name (most recently used
    /// wins), refreshing the entry's recency on a hit.  This is the
    /// serving hot path: the aggregate gauge plus the touched shard's
    /// gauge are published after every shard lock is released.
    pub fn get(&self, key: &str) -> Option<Arc<QuantizedModel>> {
        match self.lookup_touch(key) {
            Some((si, qm)) => {
                let n = self.hits[si].fetch_add(1, Ordering::Relaxed) + 1;
                metrics::set("registry_hits", Self::sum(&self.hits) as f64);
                metrics::set(&format!("registry_hits_shard{si}"), n as f64);
                Some(qm)
            }
            None => {
                let si = self.shard_of(key);
                let n = self.misses[si].fetch_add(1, Ordering::Relaxed) + 1;
                metrics::set("registry_misses", Self::sum(&self.misses) as f64);
                metrics::set(&format!("registry_misses_shard{si}"), n as f64);
                None
            }
        }
    }

    /// [`ModelRegistry::get`] with transparent spill reload: a miss on
    /// a key that was evicted to disk loads the artifact back
    /// ([`QuantizedModel::load`]), re-admits it under its original pack
    /// key and returns it — the caller cannot tell a reload from a hit
    /// except through the `registry_reload*` counters.  Disk I/O runs
    /// outside every shard lock.
    pub fn get_or_reload(&self, key: &str) -> Option<Arc<QuantizedModel>> {
        if let Some(qm) = self.get(key) {
            return Some(qm);
        }
        let (spill_key, dir) = {
            let log = self.spill_log();
            let rec = log.iter().find(|r| r.key == key || r.model == key)?;
            (rec.key.clone(), rec.dir.clone())
        };
        match QuantizedModel::load(&dir) {
            Ok(qm) => {
                let arc = Arc::new(qm);
                let n = self.reloads.fetch_add(1, Ordering::Relaxed) + 1;
                metrics::set("registry_reloads", n as f64);
                log::info!("registry reloaded {spill_key} from {dir:?}");
                self.put(spill_key, arc.clone());
                Some(arc)
            }
            Err(e) => {
                metrics::inc("registry_reload_errors");
                log::warn!("registry reload of {spill_key} from {dir:?} failed: {e:#}");
                None
            }
        }
    }

    /// Insert (or refresh) `key`, evicting globally-least-recently-used
    /// entries beyond the capacity budget (spilling them to disk when a
    /// spill directory is configured).  Cold path (one `pack` job per
    /// call): the full gauge set is republished, outside the locks.
    pub fn put(&self, key: String, qm: Arc<QuantizedModel>) {
        let si = self.shard_of(&key);
        {
            let mut shard = self.write(si);
            shard.entries.retain(|(k, _, _)| *k != key);
            let tick = self.next_tick();
            shard.entries.insert(0, (key, qm, tick));
        }
        self.enforce_cap();
        metrics::set("registry_size", self.len() as f64);
        metrics::set("registry_evictions", Self::sum(&self.evictions) as f64);
    }

    /// Pop globally-oldest entries until the budget holds.  Each shard's
    /// tail is its least-recent entry, so the global victim is the tail
    /// with the smallest tick.  Locks are taken one shard at a time;
    /// spill I/O happens with no lock held.
    fn enforce_cap(&self) {
        loop {
            let total: usize = (0..self.shards.len()).map(|i| self.read(i).entries.len()).sum();
            if total <= self.cap {
                return;
            }
            let mut victim: Option<(usize, u64)> = None;
            for i in 0..self.shards.len() {
                if let Some(e) = self.read(i).entries.last() {
                    if victim.map_or(true, |(_, t)| e.2 < t) {
                        victim = Some((i, e.2));
                    }
                }
            }
            let Some((vi, _)) = victim else { return };
            let Some((key, qm, _)) = self.write(vi).entries.pop() else { continue };
            let n = self.evictions[vi].fetch_add(1, Ordering::Relaxed) + 1;
            metrics::set(&format!("registry_evictions_shard{vi}"), n as f64);
            self.spill(&key, &qm);
            log::info!("registry evicted {key}");
        }
    }

    /// Persist an evicted artifact for later [`Self::get_or_reload`].
    /// A save failure is logged and counted, never fatal: the registry
    /// degrades to the historical evict-means-gone behaviour.
    fn spill(&self, key: &str, qm: &QuantizedModel) {
        let Some(base) = &self.spill_dir else { return };
        let dir = base.join(spill_dir_name(key));
        match qm.save(&dir) {
            Ok(()) => {
                let n = self.spills.fetch_add(1, Ordering::Relaxed) + 1;
                metrics::set("registry_spills", n as f64);
                let mut log = self.spill_log();
                log.retain(|r| r.key != key);
                log.insert(
                    0,
                    SpillRecord { key: key.to_string(), model: qm.model.clone(), dir },
                );
            }
            Err(e) => {
                metrics::inc("registry_spill_errors");
                log::warn!("registry spill of {key} failed: {e:#}");
            }
        }
    }

    /// Whether `key` (exact or bare model name) is resident, without
    /// touching recency or the hit/miss counters.
    pub fn contains(&self, key: &str) -> bool {
        (0..self.shards.len())
            .any(|i| self.read(i).entries.iter().any(|(k, qm, _)| k == key || qm.model == key))
    }

    /// Every entry across shards, most recently used first (by global
    /// tick).
    fn collect_sorted<T>(&self, f: impl Fn(&Entry) -> T) -> Vec<T> {
        let mut all: Vec<(u64, T)> = Vec::new();
        for i in 0..self.shards.len() {
            all.extend(self.read(i).entries.iter().map(|e| (e.2, f(e))));
        }
        all.sort_by(|a, b| b.0.cmp(&a.0));
        all.into_iter().map(|(_, t)| t).collect()
    }

    /// Resident keys, most recently used first.
    pub fn keys(&self) -> Vec<String> {
        self.collect_sorted(|e| e.0.clone())
    }

    /// Resident `(key, per-layer weight bits)` pairs, most recently used
    /// first — what the `models` response echoes so clients can tell a
    /// mixed pack from a uniform one without fetching the artifact.
    pub fn entries_wbits(&self) -> Vec<(String, Vec<u32>)> {
        self.collect_sorted(|e| (e.0.clone(), e.1.wbits()))
    }

    pub fn len(&self) -> usize {
        (0..self.shards.len()).map(|i| self.read(i).entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The spill directory, when spilling is configured.
    pub fn spill_dir(&self) -> Option<&PathBuf> {
        self.spill_dir.as_ref()
    }

    /// Counter snapshot for tests and the service response.
    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            size: self.len(),
            capacity: self.cap,
            shards: self.shards.len(),
            hits: Self::sum(&self.hits),
            misses: Self::sum(&self.misses),
            evictions: Self::sum(&self.evictions),
            spills: self.spills.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
        }
    }
}

/// Filesystem-safe directory name for a spilled pack key: sanitized
/// text for humans plus the FNV hash so distinct keys (`cnn6:w[8.4]a4`
/// vs `cnn6:w[8,4]a4`-style collisions after sanitizing) can never
/// share a directory.
fn spill_dir_name(key: &str) -> String {
    let san: String = key
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect();
    format!("{san}-{:08x}", fnv1a(key.as_bytes()) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuantParams;

    fn dummy(model: &str) -> Arc<QuantizedModel> {
        Arc::new(QuantizedModel {
            model: model.to_string(),
            quant: QuantParams::passthrough(0),
            active_w: Vec::new(),
            active_a: Vec::new(),
            params: Vec::new(),
            layers: Vec::new(),
        })
    }

    #[test]
    fn lru_insert_get_evict() {
        let r = ModelRegistry::new(2);
        assert!(r.is_empty());
        r.put("a:w8a8:MMSE".into(), dummy("a"));
        r.put("b:w8a8:MMSE".into(), dummy("b"));
        assert_eq!(r.len(), 2);
        // touching `a` makes `b` the LRU victim
        assert!(r.get("a:w8a8:MMSE").is_some());
        r.put("c:w8a8:MMSE".into(), dummy("c"));
        assert_eq!(r.len(), 2);
        assert!(r.contains("a:w8a8:MMSE"));
        assert!(!r.contains("b:w8a8:MMSE"), "b must have been evicted: {:?}", r.keys());
        let s = r.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.capacity, 2);
    }

    #[test]
    fn bare_model_name_resolves() {
        let r = ModelRegistry::new(4);
        r.put("mlp3:w8a8:LAPQ".into(), dummy("mlp3"));
        assert!(r.get("mlp3").is_some());
        assert!(r.get("cnn6").is_none());
        let s = r.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn put_refreshes_existing_key() {
        let r = ModelRegistry::new(2);
        r.put("a".into(), dummy("a"));
        r.put("b".into(), dummy("b"));
        r.put("a".into(), dummy("a2"));
        assert_eq!(r.len(), 2);
        assert_eq!(r.keys(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(r.get("a").unwrap().model, "a2");
        assert_eq!(r.stats().evictions, 0);
    }

    #[test]
    fn capacity_floor_is_one() {
        let r = ModelRegistry::new(0);
        assert_eq!(r.capacity(), 1);
        r.put("a".into(), dummy("a"));
        r.put("b".into(), dummy("b"));
        assert_eq!(r.len(), 1);
    }

    /// The sharded registry must behave exactly like one global LRU:
    /// whatever shard an entry hashes to, the *globally* least recently
    /// touched entry is the victim.
    #[test]
    fn sharded_eviction_is_globally_lru() {
        let r = ModelRegistry::with_options(2, 4, None);
        assert_eq!(r.shard_count(), 4);
        r.put("a:w8a8:MMSE".into(), dummy("a"));
        r.put("b:w8a8:MMSE".into(), dummy("b"));
        assert!(r.get("a:w8a8:MMSE").is_some());
        r.put("c:w8a8:MMSE".into(), dummy("c"));
        assert_eq!(r.len(), 2);
        assert!(r.contains("a:w8a8:MMSE"), "recently touched entry survived: {:?}", r.keys());
        assert!(r.contains("c:w8a8:MMSE"));
        assert!(!r.contains("b:w8a8:MMSE"), "global LRU victim: {:?}", r.keys());
        // keys() reports the global recency order across shards
        assert_eq!(r.keys(), vec!["c:w8a8:MMSE".to_string(), "a:w8a8:MMSE".to_string()]);
        assert_eq!(r.stats().evictions, 1);
        assert_eq!(r.stats().shards, 4);
    }

    #[test]
    fn bare_name_resolves_across_shards_most_recent_wins() {
        let r = ModelRegistry::with_options(8, 8, None);
        // Same model under two pack keys, which land on (likely)
        // different shards; the later-touched one must win.
        r.put("mlp3:w8a8:LAPQ".into(), dummy("mlp3"));
        r.put("mlp3:w4a4:MMSE".into(), dummy("mlp3"));
        assert_eq!(r.get("mlp3").unwrap().model, "mlp3");
        assert_eq!(r.keys()[0], "mlp3:w4a4:MMSE");
        assert!(r.get("mlp3:w8a8:LAPQ").is_some());
        assert_eq!(r.keys()[0], "mlp3:w8a8:LAPQ");
    }

    #[test]
    fn spill_and_reload_roundtrip() {
        let base =
            std::env::temp_dir().join(format!("lapq_registry_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let r = ModelRegistry::with_options(1, 2, Some(base.clone()));
        r.put("mlp3:w8a8:MMSE".into(), dummy("mlp3"));
        r.put("cnn6:w8a8:MMSE".into(), dummy("cnn6"));
        // mlp3 was evicted and spilled ...
        assert!(!r.contains("mlp3:w8a8:MMSE"));
        assert_eq!(r.stats().spills, 1);
        // ... plain get still misses ...
        assert!(r.get("mlp3:w8a8:MMSE").is_none());
        // ... but get_or_reload brings it back (evicting cnn6 in turn).
        let qm = r.get_or_reload("mlp3:w8a8:MMSE").expect("reload from spill");
        assert_eq!(qm.model, "mlp3");
        assert!(r.contains("mlp3:w8a8:MMSE"));
        let s = r.stats();
        assert_eq!(s.reloads, 1);
        assert!(s.spills >= 2, "cnn6's eviction must spill too: {s:?}");
        // bare-model-name fallback resolves through the spill log too
        assert!(r.get_or_reload("cnn6").is_some());
        assert_eq!(r.stats().reloads, 2);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn reload_without_spill_dir_is_a_plain_miss() {
        let r = ModelRegistry::with_options(1, 2, None);
        r.put("a".into(), dummy("a"));
        r.put("b".into(), dummy("b"));
        assert!(r.get_or_reload("a").is_none());
        assert_eq!(r.stats().reloads, 0);
    }
}
