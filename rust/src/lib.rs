//! # LAPQ — Loss Aware Post-training Quantization
//!
//! A production-grade reproduction of *"Loss Aware Post-training
//! Quantization"* (Nahshan et al., 2019) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 1** (build time): Pallas fake-quant / Lp-error / quant-matmul
//!   kernels (`python/compile/kernels/`).
//! * **Layer 2** (build time): JAX model graphs whose quantization step
//!   sizes are *runtime inputs*, lowered once to HLO text
//!   (`python/compile/models/`, `python/compile/aot.py`).
//! * **Layer 3** (this crate): the coordinator — PJRT runtime, synthetic
//!   data substrates, the LAPQ calibration pipeline (layer-wise Lp →
//!   quadratic approximation → Powell joint optimization), the
//!   post-training-quantization baselines it is compared against (MMSE,
//!   ACIQ, KLD, min-max), trainer, evaluator, loss-landscape analysis and
//!   a job service.
//!
//! Python never runs after `make artifacts`; the `repro` binary is
//! self-contained.

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod lapq;
pub mod optim;
pub mod prop;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate result alias (anyhow-based; all layers bubble rich context).
pub type Result<T> = anyhow::Result<T>;
