//! # LAPQ — Loss Aware Post-training Quantization
//!
//! A production-grade reproduction of *"Loss Aware Post-training
//! Quantization"* (Nahshan et al., 2019) built around a pluggable
//! execution runtime:
//!
//! * **Runtime** (`runtime::backend`): the [`runtime::Backend`] trait
//!   abstracts sessions, batches, `train_step`, `eval`, `hitrate` and
//!   `acts`.  The **default backend is a pure-Rust CPU executor**
//!   (`runtime::cpu`) that runs the builtin model zoo — `mlp3`, `cnn6`,
//!   `dwsep`, `resmini`, `ncf` — natively: dense/conv/embedding forward,
//!   reverse-mode gradients for training, and fake-quant with runtime Δ
//!   vectors (paper Eq. 1).  `cargo build && cargo test` need no Python,
//!   no PJRT and no network.
//! * **Optional PJRT engine** (`--features xla`): executes the AOT
//!   HLO-text artifacts produced by `python/compile/aot.py` (JAX + Pallas
//!   kernels) through the `xla` bindings.  The workspace vendors a typed
//!   stub of those bindings so the feature always compiles; patch in the
//!   real crate to run it.
//! * **Integer inference engine** (`runtime::int`): packs a calibrated
//!   session into a deployable artifact (i8 / nibble-packed i4 weights,
//!   per-channel scales, i32 bias) and executes `mlp3`/`cnn6`/`ncf` with
//!   real integer kernels — bit-compatible with the fake-quant reference
//!   under the power-of-two scales `pack` emits.  Served through the
//!   coordinator's `pack`/`infer` endpoints and the CLI.
//! * **Coordinator** (`coordinator`, `lapq`, `quant`, `optim`,
//!   `analysis`): synthetic data substrates, the LAPQ calibration
//!   pipeline (layer-wise Lp → quadratic approximation → Powell joint
//!   optimization), the post-training-quantization baselines it is
//!   compared against (MMSE, ACIQ, KLD, min-max), trainer, evaluator,
//!   loss-landscape analysis and a TCP job service.
//! * **Concurrent serving** (`serve`): the production face of the job
//!   service — a worker pool over the same JSON-lines protocol, an
//!   `Arc`-shared LRU registry of packed models, dynamic micro-batching
//!   of infer traffic onto the batch-parallel integer kernels
//!   (bit-for-bit identical to sequential serving), and admission
//!   control with typed overload shedding.

// The crate is clippy-clean under `-D warnings` with these scoped
// exceptions (numerical code indexes freely; `lapq::lapq` is deliberate).
#![allow(unknown_lints)]
#![allow(
    clippy::module_inception,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::needless_range_loop,
    clippy::manual_range_contains,
    clippy::unnecessary_map_or,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod lapq;
pub mod optim;
pub mod prop;
pub mod proto;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Crate result alias (anyhow-based; all layers bubble rich context).
pub type Result<T> = anyhow::Result<T>;
