//! Mini property-testing framework (substrate for the absent `proptest`).
//!
//! A [`Gen`] draws random cases from a [`Pcg32`]; [`forall`] runs `N`
//! cases and, on failure, greedily shrinks the failing case via
//! [`Shrink::shrink`] candidates before panicking with the minimal
//! reproduction and its seed.

use crate::util::rng::Pcg32;

/// Case generator.
pub trait Gen<T> {
    fn gen(&self, rng: &mut Pcg32) -> T;
}

impl<T, F: Fn(&mut Pcg32) -> T> Gen<T> for F {
    fn gen(&self, rng: &mut Pcg32) -> T {
        self(rng)
    }
}

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<f32> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        let n = self.len();
        if n > 0 {
            out.push(self[..n / 2].to_vec());
            out.push(self[1..].to_vec());
            // shrink one element
            for (i, x) in self.iter().enumerate().take(4) {
                for s in x.shrink() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

/// Run `n_cases` random cases of `prop`; shrink + panic on failure.
pub fn forall<T: Shrink + std::fmt::Debug>(
    seed: u64,
    n_cases: usize,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg32::seeded(seed);
    for case_i in 0..n_cases {
        let case = gen.gen(&mut rng);
        if !prop(&case) {
            // greedy shrink
            let mut min = case;
            'outer: loop {
                for cand in min.shrink() {
                    if !prop(&cand) {
                        min = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!("property failed (seed={seed}, case #{case_i}); minimal case: {min:?}");
        }
    }
}

/// Common generators.
pub mod gens {
    use super::*;

    /// Vector of standard normals with random length in [1, max_len].
    pub fn normal_vec(max_len: usize) -> impl Gen<Vec<f32>> {
        move |rng: &mut Pcg32| {
            let n = 1 + rng.below(max_len as u32) as usize;
            rng.normal_vec(n)
        }
    }

    /// Uniform float in [lo, hi].
    pub fn uniform(lo: f32, hi: f32) -> impl Gen<f32> {
        move |rng: &mut Pcg32| rng.range(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, gens::normal_vec(64), |v: &Vec<f32>| !v.is_empty());
    }

    #[test]
    #[should_panic(expected = "minimal case")]
    fn failing_property_shrinks() {
        // fails whenever the vec contains a value > 1; shrinker should
        // reduce the witness aggressively.
        forall(2, 500, gens::normal_vec(64), |v: &Vec<f32>| v.iter().all(|&x| x < 1.0));
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let v = vec![3.0f32, -2.0, 5.5];
        for s in v.shrink() {
            assert!(s.len() < v.len() || s.iter().zip(&v).any(|(a, b)| a != b));
        }
    }
}
