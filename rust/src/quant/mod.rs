//! Quantization substrate: the host-side mirror of the Layer-1 kernels
//! plus every clipping-threshold selection method the paper compares.
//!
//! * [`quantizer`] — bit-exact mirror of `kernels/fake_quant.py` (Eq. 1).
//! * [`lp`] + [`search`] — Eq. 12 layer-wise L_p minimization.
//! * [`minmax`] / [`mmse`] / [`aciq`] / [`kld`] — the baselines of Table 1.
//! * [`bias_correction`] — Banner et al.'s per-channel mean correction.
//! * [`histogram`] — fixed-bin histograms for the KLD calibrator.

pub mod aciq;
pub mod bias_correction;
pub mod histogram;
pub mod kld;
pub mod lp;
pub mod minmax;
pub mod mmse;
pub mod quantizer;
pub mod search;

/// Which tensor population a step size is calibrated for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridKind {
    /// Symmetric signed grid (weights; signed activations).
    Signed,
    /// Non-negative grid (post-ReLU activations).
    Unsigned,
}

impl GridKind {
    pub fn from_signed(signed: bool) -> Self {
        if signed {
            GridKind::Signed
        } else {
            GridKind::Unsigned
        }
    }

    /// Largest integer level of an M-bit grid (`qmax`), matching
    /// `kernels.fake_quant.grid_qmax`.
    pub fn qmax(self, bits: u32) -> f32 {
        match self {
            GridKind::Signed => (2i64.pow(bits - 1) - 1) as f32,
            GridKind::Unsigned => (2i64.pow(bits) - 1) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(GridKind::Signed.qmax(2), 1.0);
        assert_eq!(GridKind::Signed.qmax(4), 7.0);
        assert_eq!(GridKind::Signed.qmax(8), 127.0);
        assert_eq!(GridKind::Unsigned.qmax(2), 3.0);
        assert_eq!(GridKind::Unsigned.qmax(4), 15.0);
        assert_eq!(GridKind::Unsigned.qmax(8), 255.0);
    }
}
