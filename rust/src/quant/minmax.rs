//! Min-max (L_inf) calibration — Gong et al. [8]: the clip range is the
//! largest absolute value, i.e. no clipping at all.  The weakest baseline
//! at low bits (outliers dictate a huge step) but lossless at the tails.

use super::GridKind;
use crate::util::stats;

/// Step size from the max-abs statistic.
pub fn minmax_delta(xs: &[f32], qmax: f32, kind: GridKind) -> f32 {
    let c = match kind {
        GridKind::Signed => stats::max_abs(xs),
        GridKind::Unsigned => stats::min_max(xs).1.max(0.0),
    };
    if qmax > 0.0 {
        c / qmax
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantizer::fake_quant_one;

    #[test]
    fn covers_full_range() {
        let xs = [0.5f32, -2.0, 1.0];
        let qmax = 7.0;
        let d = minmax_delta(&xs, qmax, GridKind::Signed);
        // no value may clip: |x| <= Δ·qmax
        for &x in &xs {
            assert!(x.abs() <= d * qmax + 1e-6);
        }
    }

    #[test]
    fn unsigned_uses_max_only() {
        let xs = [-5.0f32, 0.2, 0.9];
        let d = minmax_delta(&xs, 15.0, GridKind::Unsigned);
        assert!((d - 0.9 / 15.0).abs() < 1e-7);
    }

    #[test]
    fn max_value_roundtrips_exactly_at_high_bits() {
        let xs = [0.31f32, -1.7, 0.05];
        let qmax = GridKind::Signed.qmax(8);
        let d = minmax_delta(&xs, qmax, GridKind::Signed);
        let q = fake_quant_one(-1.7, d, qmax, GridKind::Signed);
        assert!((q + 1.7).abs() < d);
    }
}
