//! ACIQ — Banner et al. [1]: analytical clipping for integer quantization.
//!
//! Fit a Gaussian or Laplace to the tensor, then pick the clip value `c`
//! minimizing the *expected* distortion
//!
//! ```text
//! E[(Q(X)-X)^2] = clip_term(c) + (Δ(c)^2)/12 · P(|X|<c)
//! ```
//!
//! where `clip_term` integrates the tail error analytically.  Instead of
//! hard-coding the paper's per-bitwidth constants we minimize the closed
//! form numerically (golden section), which generalizes to any bitwidth
//! and both distributions.  The distribution is selected by a simple
//! kurtosis test (Laplace kurtosis 6 vs Gaussian 3).

use super::search::golden_section;
use super::GridKind;
use crate::util::stats;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    Gauss,
    Laplace,
}

/// Expected squared clipping error of a Laplace(0, b) beyond ±c, i.e.
/// `2·∫_c^∞ (x-c)^2 (1/2b) e^{-x/b} dx = b^2 e^{-c/b} · 2`.
fn laplace_clip_term(b: f64, c: f64) -> f64 {
    2.0 * b * b * (-c / b).exp()
}

/// Gaussian N(0, σ²) tail distortion `2·∫_c^∞ (x-c)^2 φ(x/σ)/σ dx`.
fn gauss_clip_term(sigma: f64, c: f64) -> f64 {
    let a = c / sigma;
    let phi = (-0.5 * a * a).exp() / (2.0 * std::f64::consts::PI).sqrt();
    let tail = 0.5 * erfc(a / std::f64::consts::SQRT_2);
    sigma * sigma * ((1.0 + a * a) * tail - a * phi) * 2.0
}

/// Complementary error function (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7).
pub fn erfc(x: f64) -> f64 {
    let sign_neg = x < 0.0;
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))))
        * (-x * x).exp();
    if sign_neg {
        2.0 - y
    } else {
        y
    }
}

/// Expected MSE of quantizing `dist` with clip `c` on an M-bit grid with
/// `n_pos` positive levels (Δ = c / n_pos).
fn expected_mse(dist: Dist, scale: f64, c: f64, n_pos: f64) -> f64 {
    let delta = c / n_pos;
    let rounding = delta * delta / 12.0;
    match dist {
        Dist::Laplace => laplace_clip_term(scale, c) + rounding,
        Dist::Gauss => gauss_clip_term(scale, c) + rounding,
    }
}

/// Fit scale and pick the analytically optimal clip; return Δ = c/qmax.
pub fn aciq_delta(xs: &[f32], bits: u32, kind: GridKind) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let qmax = kind.qmax(bits) as f64;
    if qmax <= 0.0 {
        return 0.0;
    }
    // Center is assumed 0 (symmetric grids); for unsigned populations the
    // one-sided density doubles, which cancels in the argmin.
    let sigma = stats::std_dev(xs).max(1e-12) as f64;
    let b = stats::mean_abs(xs).max(1e-12) as f64;
    let dist = select_dist(xs);
    let scale = match dist {
        Dist::Gauss => sigma,
        Dist::Laplace => b,
    };
    let hi = stats::max_abs(xs) as f64;
    if hi == 0.0 {
        return 0.0;
    }
    let mut f = |c: f64| expected_mse(dist, scale, c, qmax);
    let c = golden_section(hi * 1e-3, hi, hi * 1e-5, &mut f);
    (c / qmax) as f32
}

/// Kurtosis-based model selection.
pub fn select_dist(xs: &[f32]) -> Dist {
    let m = stats::mean(xs) as f64;
    let n = xs.len() as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
    if var == 0.0 {
        return Dist::Gauss;
    }
    let m4 = xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>() / n;
    let kurt = m4 / (var * var);
    // midpoint between Gaussian (3) and Laplace (6)
    if kurt > 4.5 {
        Dist::Laplace
    } else {
        Dist::Gauss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::lp::lp_error_sum;
    use crate::quant::minmax::minmax_delta;
    use crate::util::rng::Pcg32;

    #[test]
    fn erfc_reference_points() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.157_299_2).abs() < 1e-5);
        assert!((erfc(-1.0) - 1.842_700_8).abs() < 1e-5);
        assert!(erfc(5.0) < 2e-11);
    }

    #[test]
    fn detects_distributions() {
        let mut rng = Pcg32::seeded(31);
        let gauss = rng.normal_vec(50_000);
        assert_eq!(select_dist(&gauss), Dist::Gauss);
        let lap: Vec<f32> = (0..50_000).map(|_| rng.laplace(1.0)).collect();
        assert_eq!(select_dist(&lap), Dist::Laplace);
    }

    #[test]
    fn near_empirical_optimum_gauss_4bit() {
        let mut rng = Pcg32::seeded(32);
        let xs = rng.normal_vec(32_768);
        let qmax = GridKind::Signed.qmax(4);
        let d = aciq_delta(&xs, 4, GridKind::Signed);
        let e = lp_error_sum(&xs, d, qmax, 2.0, GridKind::Signed);
        // empirical optimum by dense scan
        let mut best = f64::INFINITY;
        for i in 1..=400 {
            best = best.min(lp_error_sum(&xs, i as f32 * 0.005, qmax, 2.0, GridKind::Signed));
        }
        assert!(e <= best * 1.10, "analytic {e} vs empirical {best}");
    }

    #[test]
    fn clips_harder_at_lower_bits() {
        let mut rng = Pcg32::seeded(33);
        let xs = rng.normal_vec(32_768);
        // optimal *clip value* c = Δ·qmax shrinks as bits shrink
        let c2 = aciq_delta(&xs, 2, GridKind::Signed) * GridKind::Signed.qmax(2);
        let c4 = aciq_delta(&xs, 4, GridKind::Signed) * GridKind::Signed.qmax(4);
        let c8 = aciq_delta(&xs, 8, GridKind::Signed) * GridKind::Signed.qmax(8);
        assert!(c2 < c4 && c4 < c8, "c2={c2} c4={c4} c8={c8}");
        let d_mm = minmax_delta(&xs, GridKind::Signed.qmax(4), GridKind::Signed);
        assert!(c4 < d_mm * GridKind::Signed.qmax(4));
    }

    #[test]
    fn handles_degenerate_inputs() {
        assert_eq!(aciq_delta(&[], 4, GridKind::Signed), 0.0);
        assert_eq!(aciq_delta(&[0.0; 32], 4, GridKind::Signed), 0.0);
    }
}
