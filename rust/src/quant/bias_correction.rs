//! Per-channel quantization bias correction — Banner et al. [1], applied
//! on top of any calibration method (paper §5.3, Table 4).
//!
//! Quantization shifts the mean of each output channel's weights:
//! `E[Q(W_c)] != E[W_c]`.  The correction adds the difference back so the
//! quantized channel keeps the FP32 mean, which matters most for compact
//! (depthwise) layers with few weights per channel.

use super::quantizer::fake_quant_one;
use super::GridKind;
use crate::tensor::HostTensor;

/// Compute a corrected weight tensor: for each output channel c (last
/// axis, HWIO / (in,out) layouts), shift `W_c` so that `mean(Q(W_c))`
/// matches the original `mean(W_c)`.
///
/// Because the graph re-quantizes the corrected FP32 weights at run time
/// (the correction cannot be applied post-quantization as in Banner et
/// al.'s deployment), a single shift can stall below the bin width; we
/// iterate the fixed point a few times, keeping the shift that best
/// matches the target mean.
pub fn bias_corrected_weights(w: &HostTensor, delta: f32, qmax: f32) -> HostTensor {
    let k = w.last_axis();
    let mut out = w.clone();
    if delta <= 0.0 || k == 0 {
        return out;
    }
    let n_rows = w.len() / k;
    let data = out.f_mut();
    for c in 0..k {
        // target: the FP32 channel mean
        let mut target = 0.0f64;
        for r in 0..n_rows {
            target += data[r * k + c] as f64;
        }
        target /= n_rows as f64;

        let q_mean = |shift: f64, data: &[f32]| -> f64 {
            let mut s = 0.0f64;
            for r in 0..n_rows {
                let x = (data[r * k + c] as f64 + shift) as f32;
                s += fake_quant_one(x, delta, qmax, GridKind::Signed) as f64;
            }
            s / n_rows as f64
        };

        // fixed-point iteration on the channel shift, keeping the best
        let mut shift = 0.0f64;
        let mut best_shift = 0.0f64;
        let mut best_err = (q_mean(0.0, data) - target).abs();
        for _ in 0..6 {
            let err = target - q_mean(shift, data);
            if err.abs() < best_err {
                best_err = err.abs();
                best_shift = shift;
            }
            if err.abs() < 1e-9 {
                break;
            }
            shift += err;
        }
        let err = target - q_mean(shift, data);
        if err.abs() < best_err {
            best_shift = shift;
        }
        for r in 0..n_rows {
            data[r * k + c] += best_shift as f32;
        }
    }
    out
}

/// Channel-mean shift between W and Q(W) — the statistic the correction
/// removes.  Exposed for tests and the Table-4 bench.
pub fn channel_mean_shift(w: &HostTensor, delta: f32, qmax: f32) -> Vec<f32> {
    let k = w.last_axis();
    let data = w.f();
    let n_rows = data.len() / k;
    (0..k)
        .map(|c| {
            let mut s = 0.0f64;
            for r in 0..n_rows {
                let x = data[r * k + c];
                s += (fake_quant_one(x, delta, qmax, GridKind::Signed) - x) as f64;
            }
            (s / n_rows as f64) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn weight(seed: u64) -> HostTensor {
        let mut rng = Pcg32::seeded(seed);
        // biased channels: channel c has mean 0.02*c
        let (rows, k) = (64usize, 8usize);
        let mut data = vec![0.0f32; rows * k];
        for r in 0..rows {
            for c in 0..k {
                data[r * k + c] = rng.normal() * 0.1 + 0.02 * c as f32;
            }
        }
        HostTensor::f32(vec![rows, k], data)
    }

    /// |mean(Q(W'_c)) - mean(W_c)| per channel, vs the original tensor.
    fn mean_err_vs_original(corrected: &HostTensor, orig: &HostTensor, d: f32, q: f32) -> f32 {
        let k = orig.last_axis();
        let n_rows = orig.len() / k;
        let mut total = 0.0f32;
        for c in 0..k {
            let target: f64 =
                (0..n_rows).map(|r| orig.f()[r * k + c] as f64).sum::<f64>() / n_rows as f64;
            let got: f64 = (0..n_rows)
                .map(|r| fake_quant_one(corrected.f()[r * k + c], d, q, GridKind::Signed) as f64)
                .sum::<f64>()
                / n_rows as f64;
            total += (got - target).abs() as f32;
        }
        total
    }

    #[test]
    fn correction_reduces_mean_shift() {
        let w = weight(41);
        let (delta, qmax) = (0.15f32, 1.0f32); // aggressive 2-bit-ish grid
        let before = mean_err_vs_original(&w, &w, delta, qmax);
        let corrected = bias_corrected_weights(&w, delta, qmax);
        let after = mean_err_vs_original(&corrected, &w, delta, qmax);
        assert!(after <= before * 0.5, "shift before {before} after {after}");
    }

    #[test]
    fn zero_delta_noop() {
        let w = weight(42);
        assert_eq!(bias_corrected_weights(&w, 0.0, 7.0), w);
    }

    #[test]
    fn preserves_shape_and_fp32_direction() {
        let w = weight(43);
        let c = bias_corrected_weights(&w, 0.05, 7.0);
        assert_eq!(c.shape, w.shape);
        // correction is small relative to the weights themselves
        let max_diff = w
            .f()
            .iter()
            .zip(c.f())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 0.05, "{max_diff}");
    }
}
