//! Layer-wise L_p quantization error (paper Eq. 12) on host tensors.
//!
//! Host mirror of `kernels/lp_error.py`; the scalar-Δ minimization that
//! LAPQ phase 1 performs thousands of times runs here (microseconds per
//! call on weight tensors) rather than through PJRT — the *loss* metric is
//! what needs the compiled graph, not the tensor-local error.

use super::quantizer::fake_quant_one;
use super::GridKind;

/// `sum(|Q(x) - x|^p)` — the inner objective of Eq. 12.
pub fn lp_error_sum(xs: &[f32], delta: f32, qmax: f32, p: f32, kind: GridKind) -> f64 {
    let mut acc = 0.0f64;
    for &x in xs {
        let err = (fake_quant_one(x, delta, qmax, kind) - x).abs() as f64;
        if err > 0.0 {
            acc += err.powf(p as f64);
        }
    }
    acc
}

/// Eq. 12: `(sum |Q(x)-x|^p)^{1/p}`.
pub fn lp_error(xs: &[f32], delta: f32, qmax: f32, p: f32, kind: GridKind) -> f64 {
    lp_error_sum(xs, delta, qmax, p, kind).powf(1.0 / p as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        rng.normal_vec(4096)
    }

    #[test]
    fn zero_delta_zero_error() {
        assert_eq!(lp_error_sum(&samples(), 0.0, 7.0, 2.0, GridKind::Signed), 0.0);
    }

    #[test]
    fn interior_minimum_exists() {
        // Fig. 4: too-small Δ clips hard, too-large Δ rounds hard.
        let xs = samples();
        let deltas: Vec<f32> = (1..=60).map(|i| i as f32 * 0.02).collect();
        let errs: Vec<f64> =
            deltas.iter().map(|&d| lp_error_sum(&xs, d, 7.0, 2.0, GridKind::Signed)).collect();
        let best = errs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(best > 0 && best < deltas.len() - 1, "best at edge: {best}");
    }

    #[test]
    fn optimal_delta_grows_with_p() {
        // Larger p weights outliers more -> wider clip range -> larger Δ*
        // (the monotone trade-off behind Fig. 4 / the p-trajectory).
        let xs = samples();
        let grid: Vec<f32> = (1..=300).map(|i| i as f32 * 0.004).collect();
        let best_for = |p: f32| -> f32 {
            grid.iter()
                .copied()
                .min_by(|&a, &b| {
                    lp_error_sum(&xs, a, 7.0, p, GridKind::Signed)
                        .partial_cmp(&lp_error_sum(&xs, b, 7.0, p, GridKind::Signed))
                        .unwrap()
                })
                .unwrap()
        };
        let d2 = best_for(2.0);
        let d4 = best_for(4.0);
        assert!(d4 >= d2, "Δ*(p=4)={d4} < Δ*(p=2)={d2}");
    }

    #[test]
    fn matches_bruteforce_small() {
        let xs = [0.1f32, -0.2, 0.35, 1.4];
        let (delta, qmax, p) = (0.1f32, 7.0f32, 2.0f32);
        let mut want = 0.0f64;
        for &x in &xs {
            let q = (x / delta).round().clamp(-qmax, qmax) * delta;
            want += ((q - x).abs() as f64).powi(2);
        }
        let got = lp_error_sum(&xs, delta, qmax, p, GridKind::Signed);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }
}
