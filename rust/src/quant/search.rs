//! Scalar minimizers for the per-layer Δ search: coarse grid scan followed
//! by golden-section refinement.  Robust to the piecewise-flat objectives
//! fake-quantization induces (many Δ map to the same rounding pattern).

/// Golden-section minimization of `f` on `[lo, hi]`.
pub fn golden_section(mut lo: f64, mut hi: f64, tol: f64, f: &mut impl FnMut(f64) -> f64) -> f64 {
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut c = hi - INV_PHI * (hi - lo);
    let mut d = lo + INV_PHI * (hi - lo);
    let mut fc = f(c);
    let mut fd = f(d);
    while (hi - lo).abs() > tol {
        if fc < fd {
            hi = d;
            d = c;
            fd = fc;
            c = hi - INV_PHI * (hi - lo);
            fc = f(c);
        } else {
            lo = c;
            c = d;
            fc = fd;
            d = lo + INV_PHI * (hi - lo);
            fd = f(d);
        }
    }
    0.5 * (lo + hi)
}

/// Coarse-to-fine scalar minimization: scan `n_grid` points of `[lo, hi]`,
/// then golden-section around the best cell.  Returns (x*, f(x*)).
pub fn grid_then_golden(
    lo: f64,
    hi: f64,
    n_grid: usize,
    tol: f64,
    f: &mut impl FnMut(f64) -> f64,
) -> (f64, f64) {
    assert!(hi > lo && n_grid >= 3);
    let step = (hi - lo) / (n_grid - 1) as f64;
    let mut best_i = 0;
    let mut best_v = f64::INFINITY;
    for i in 0..n_grid {
        let v = f(lo + step * i as f64);
        if v < best_v {
            best_v = v;
            best_i = i;
        }
    }
    let wlo = lo + step * best_i.saturating_sub(1) as f64;
    let whi = (lo + step * (best_i + 1) as f64).min(hi);
    let x = golden_section(wlo, whi, tol, f);
    let fx = f(x);
    if fx <= best_v {
        (x, fx)
    } else {
        (lo + step * best_i as f64, best_v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_finds_parabola_min() {
        let mut f = |x: f64| (x - 1.3).powi(2) + 0.5;
        let x = golden_section(-10.0, 10.0, 1e-8, &mut f);
        assert!((x - 1.3).abs() < 1e-6, "{x}");
    }

    #[test]
    fn grid_then_golden_handles_multimodal() {
        // global min at x≈4.9, local min near 1.2
        let mut f = |x: f64| (x - 4.9).powi(2).min((x - 1.2).powi(2) + 0.8);
        let (x, v) = grid_then_golden(0.0, 8.0, 33, 1e-8, &mut f);
        assert!((x - 4.9).abs() < 1e-4, "{x}");
        assert!(v < 1e-6);
    }

    #[test]
    fn grid_then_golden_flat_regions() {
        // stair-like objective (mimics quantization plateaus)
        let mut f = |x: f64| ((x * 3.0).floor() - 6.0).abs();
        let (x, v) = grid_then_golden(0.0, 5.0, 26, 1e-6, &mut f);
        assert_eq!(v, 0.0);
        assert!((2.0..2.4).contains(&x), "{x}");
    }

    #[test]
    fn respects_bounds() {
        let mut f = |x: f64| -x; // min at upper bound
        let (x, _) = grid_then_golden(0.0, 2.0, 11, 1e-9, &mut f);
        assert!(x <= 2.0 + 1e-9 && x > 1.7);
    }
}
