//! Fixed-bin histograms over absolute values — the density substrate for
//! the KLD calibrator (TensorRT uses 2048 bins of |x|; so do we).

/// Histogram of |x| over `[0, max_abs]` with `n_bins` equal bins.
#[derive(Clone, Debug)]
pub struct AbsHistogram {
    pub counts: Vec<u64>,
    pub bin_width: f64,
    pub total: u64,
}

impl AbsHistogram {
    pub fn build(xs: &[f32], n_bins: usize) -> Self {
        let max_abs = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        let bin_width = if max_abs > 0.0 { max_abs / n_bins as f64 } else { 1.0 };
        let mut counts = vec![0u64; n_bins];
        for &x in xs {
            let mut b = ((x.abs() as f64) / bin_width) as usize;
            if b >= n_bins {
                b = n_bins - 1;
            }
            counts[b] += 1;
        }
        AbsHistogram { counts, bin_width, total: xs.len() as u64 }
    }

    pub fn n_bins(&self) -> usize {
        self.counts.len()
    }

    /// Upper edge of bin `i` (a candidate clip threshold).
    pub fn edge(&self, i: usize) -> f64 {
        (i + 1) as f64 * self.bin_width
    }
}

/// KL(P‖Q) between two (unnormalized) discrete distributions, with the
/// TensorRT smoothing convention: bins where P=0 contribute nothing;
/// Q gets a tiny epsilon where P>0 but Q=0.
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    let ps: f64 = p.iter().sum();
    let qs: f64 = q.iter().sum();
    if ps == 0.0 || qs == 0.0 {
        return 0.0;
    }
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            let pn = pi / ps;
            let qn = (qi / qs).max(1e-12);
            kl += pn * (pn / qn).ln();
        }
    }
    kl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_everything() {
        let xs = [0.1f32, -0.2, 0.85, -0.95, 0.5];
        let h = AbsHistogram::build(&xs, 10);
        assert_eq!(h.counts.iter().sum::<u64>(), 5);
        assert_eq!(h.total, 5);
        // max |x| = 0.95 lands in the last bin; 0.85 in bin 8
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.counts[8], 1);
    }

    #[test]
    fn kl_zero_for_identical() {
        let p = [0.25, 0.25, 0.5];
        assert!(kl_divergence(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_for_different() {
        let p = [0.9, 0.1];
        let q = [0.1, 0.9];
        assert!(kl_divergence(&p, &q) > 0.5);
    }

    #[test]
    fn kl_ignores_p_zero_bins() {
        let p = [0.0, 1.0];
        let q = [0.5, 0.5];
        let kl = kl_divergence(&p, &q);
        assert!((kl - (1.0f64 / 0.5).ln()).abs() < 1e-9);
    }
}
