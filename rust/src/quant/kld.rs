//! KL-divergence calibration — Migacz [19] (the TensorRT INT8 scheme),
//! generalized to arbitrary bitwidths.
//!
//! Build a 2048-bin histogram of |x|; for every candidate clip threshold T
//! (bin edge), form the clipped reference distribution P (outliers folded
//! into the last kept bin) and the quantized distribution Q (kept bins
//! merged into `2^{M-1}` levels, then re-expanded); pick T minimizing
//! KL(P‖Q).  Returns the implied step size Δ = T / qmax.

use super::histogram::{kl_divergence, AbsHistogram};
use super::GridKind;

pub const N_BINS: usize = 2048;

/// Step size chosen by KL calibration for an M-bit grid.
pub fn kld_delta(xs: &[f32], bits: u32, kind: GridKind) -> f32 {
    let qmax = kind.qmax(bits);
    if crate::util::stats::max_abs(xs) == 0.0 {
        return 0.0;
    }
    let hist = AbsHistogram::build(xs, N_BINS);
    if hist.total == 0 {
        return 0.0;
    }
    // Number of representable magnitude levels.
    let n_levels = match kind {
        GridKind::Signed => 1usize << (bits - 1),
        GridKind::Unsigned => 1usize << bits,
    };
    let start = (n_levels * 2).min(hist.n_bins());
    let mut best_t = hist.edge(hist.n_bins() - 1);
    let mut best_kl = f64::INFINITY;

    for end in (start..=hist.n_bins()).step_by(16) {
        // Reference P: bins [0, end) plus all outliers folded into bin end-1.
        let mut p: Vec<f64> = hist.counts[..end].iter().map(|&c| c as f64).collect();
        let outliers: u64 = hist.counts[end..].iter().sum();
        *p.last_mut().unwrap() += outliers as f64;

        // Quantized Q: merge `end` bins into n_levels groups, spread back
        // proportionally to P's support (empty source bins stay empty).
        let mut q = vec![0.0f64; end];
        for lvl in 0..n_levels {
            let lo = lvl * end / n_levels;
            let hi = ((lvl + 1) * end / n_levels).max(lo + 1);
            let total: f64 = p[lo..hi].iter().sum();
            let support = p[lo..hi].iter().filter(|&&v| v > 0.0).count();
            if support > 0 {
                let share = total / support as f64;
                for i in lo..hi {
                    if p[i] > 0.0 {
                        q[i] = share;
                    }
                }
            }
        }
        let kl = kl_divergence(&p, &q);
        if kl < best_kl {
            best_kl = kl;
            best_t = hist.edge(end - 1);
        }
    }
    (best_t / qmax as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::lp::lp_error_sum;
    use crate::quant::minmax::minmax_delta;

    fn heavy_tailed(n: usize) -> Vec<f32> {
        // Laplace has heavier tails than Gaussian: clipping should win.
        let mut rng = crate::util::rng::Pcg32::seeded(21);
        (0..n).map(|_| rng.laplace(1.0)).collect()
    }

    #[test]
    fn clips_below_minmax_on_heavy_tails() {
        let xs = heavy_tailed(16384);
        let d_kld = kld_delta(&xs, 4, GridKind::Signed);
        let d_mm = minmax_delta(&xs, GridKind::Signed.qmax(4), GridKind::Signed);
        assert!(d_kld > 0.0);
        assert!(d_kld < d_mm, "kld {d_kld} should clip vs minmax {d_mm}");
    }

    #[test]
    fn reasonable_mse_vs_minmax_at_4bit() {
        let xs = heavy_tailed(16384);
        let qmax = GridKind::Signed.qmax(4);
        let d_kld = kld_delta(&xs, 4, GridKind::Signed);
        let d_mm = minmax_delta(&xs, qmax, GridKind::Signed);
        let e_kld = lp_error_sum(&xs, d_kld, qmax, 2.0, GridKind::Signed);
        let e_mm = lp_error_sum(&xs, d_mm, qmax, 2.0, GridKind::Signed);
        assert!(e_kld < e_mm * 1.5, "KLD wildly off: {e_kld} vs {e_mm}");
    }

    #[test]
    fn zero_input() {
        assert_eq!(kld_delta(&[0.0; 64], 4, GridKind::Signed), 0.0);
    }

    #[test]
    fn works_unsigned() {
        let xs: Vec<f32> = heavy_tailed(8192).into_iter().map(|x| x.abs()).collect();
        let d = kld_delta(&xs, 4, GridKind::Unsigned);
        assert!(d > 0.0);
    }
}
