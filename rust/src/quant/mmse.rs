//! L_p-optimal layer-wise calibration (LAPQ phase 1; MMSE baseline at p=2).
//!
//! For a tensor population `xs` and grid bound `qmax`, finds the step size
//! minimizing Eq. 12's `e_p(Δ)` by coarse grid + golden-section
//! ([`search::grid_then_golden`]).  The search interval is
//! `[max|x| / (8·qmax), max|x| / qmax]` — from aggressive clipping to
//! min-max — which brackets the optimum for every p in the paper's grid.

use super::lp::lp_error_sum;
use super::search::grid_then_golden;
use super::GridKind;
use crate::util::stats;

/// Configuration of the scalar Δ search.
#[derive(Clone, Copy, Debug)]
pub struct LpSearch {
    pub n_grid: usize,
    pub tol: f64,
    /// Lower bound of the search window as a fraction of the min-max step.
    pub lo_frac: f64,
}

impl Default for LpSearch {
    fn default() -> Self {
        LpSearch { n_grid: 48, tol: 1e-5, lo_frac: 1.0 / 8.0 }
    }
}

/// Δ minimizing `sum(|Q(x)-x|^p)`; returns (delta, error_sum).
pub fn lp_optimal_delta(
    xs: &[f32],
    qmax: f32,
    p: f32,
    kind: GridKind,
    cfg: LpSearch,
) -> (f32, f64) {
    let max_abs = match kind {
        GridKind::Signed => stats::max_abs(xs),
        GridKind::Unsigned => stats::min_max(xs).1.max(0.0),
    };
    if max_abs == 0.0 || qmax <= 0.0 {
        return (0.0, 0.0);
    }
    let hi = (max_abs / qmax) as f64;
    let lo = hi * cfg.lo_frac;
    let mut f = |d: f64| lp_error_sum(xs, d as f32, qmax, p, kind);
    let (d, e) = grid_then_golden(lo, hi, cfg.n_grid, tol_abs(cfg.tol, hi), &mut f);
    (d as f32, e)
}

fn tol_abs(rel: f64, scale: f64) -> f64 {
    (rel * scale).max(1e-12)
}

/// MMSE baseline: p = 2.
pub fn mmse_delta(xs: &[f32], qmax: f32, kind: GridKind) -> f32 {
    lp_optimal_delta(xs, qmax, 2.0, kind, LpSearch::default()).0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        crate::util::rng::Pcg32::seeded(seed).normal_vec(n)
    }

    #[test]
    fn beats_minmax_at_low_bits() {
        let xs = gauss(8192, 3);
        let qmax = GridKind::Signed.qmax(3);
        let d_mmse = mmse_delta(&xs, qmax, GridKind::Signed);
        let d_minmax = super::super::minmax::minmax_delta(&xs, qmax, GridKind::Signed);
        let e_mmse = lp_error_sum(&xs, d_mmse, qmax, 2.0, GridKind::Signed);
        let e_minmax = lp_error_sum(&xs, d_minmax, qmax, 2.0, GridKind::Signed);
        assert!(e_mmse < e_minmax, "{e_mmse} !< {e_minmax}");
        assert!(d_mmse < d_minmax);
    }

    #[test]
    fn near_bruteforce_optimum() {
        let xs = gauss(4096, 4);
        let qmax = 7.0;
        let (d, e) = lp_optimal_delta(&xs, qmax, 2.0, GridKind::Signed, LpSearch::default());
        // dense brute-force reference
        let mut best = f64::INFINITY;
        for i in 1..=600 {
            let cand = i as f32 * 0.002;
            best = best.min(lp_error_sum(&xs, cand, qmax, 2.0, GridKind::Signed));
        }
        assert!(e <= best * 1.02, "search {e} vs brute {best} (d={d})");
    }

    #[test]
    fn zero_tensor_gives_zero_delta() {
        let xs = vec![0.0f32; 128];
        assert_eq!(mmse_delta(&xs, 7.0, GridKind::Signed), 0.0);
    }

    #[test]
    fn unsigned_population() {
        let xs: Vec<f32> = gauss(4096, 5).into_iter().map(|x| x.max(0.0)).collect();
        let d = mmse_delta(&xs, GridKind::Unsigned.qmax(4), GridKind::Unsigned);
        assert!(d > 0.0);
        let e = lp_error_sum(&xs, d, 15.0, 2.0, GridKind::Unsigned);
        let e_wide = lp_error_sum(&xs, d * 3.0, 15.0, 2.0, GridKind::Unsigned);
        assert!(e < e_wide);
    }
}
