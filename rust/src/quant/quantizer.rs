//! Host mirror of the Pallas fake-quant kernel (paper Eq. 1).
//!
//! Bit-exact with `python/compile/kernels/ref.py::fake_quant_ref`:
//! `jnp.round` is round-half-to-even, while Rust's `f32::round` is
//! round-half-away-from-zero, so the tie-breaking is implemented
//! explicitly in [`round_half_even`].

use super::GridKind;

/// Round-half-to-even, matching `jnp.round` / HLO `round-nearest-even`.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // round-half-away-from-zero
    if (x - x.trunc()).abs() == 0.5 {
        // halfway case: pick the even neighbour
        let lo = x.floor();
        let hi = x.ceil();
        if (lo as i64) % 2 == 0 {
            lo
        } else {
            hi
        }
    } else {
        r
    }
}

/// Quantize one value to the `delta` grid bounded by `qmax` levels.
#[inline]
pub fn fake_quant_one(x: f32, delta: f32, qmax: f32, kind: GridKind) -> f32 {
    if delta <= 0.0 {
        return x;
    }
    let q = round_half_even(x / delta);
    let lo = match kind {
        GridKind::Signed => -qmax,
        GridKind::Unsigned => 0.0,
    };
    q.clamp(lo, qmax) * delta
}

/// Quantize-dequantize a slice into a new vector.
pub fn fake_quant(xs: &[f32], delta: f32, qmax: f32, kind: GridKind) -> Vec<f32> {
    xs.iter().map(|&x| fake_quant_one(x, delta, qmax, kind)).collect()
}

/// In-place variant used by bias correction.
pub fn fake_quant_inplace(xs: &mut [f32], delta: f32, qmax: f32, kind: GridKind) {
    for x in xs {
        *x = fake_quant_one(*x, delta, qmax, kind);
    }
}

/// Clipping range `c` implied by a step size (c = Δ·qmax).
pub fn clip_range(delta: f32, qmax: f32) -> f32 {
    delta * qmax
}

/// Step size implied by a clipping range.
pub fn delta_from_clip(c: f32, qmax: f32) -> f32 {
    if qmax > 0.0 {
        c / qmax
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_delta_identity() {
        let xs = [0.3, -1.7, 42.0];
        assert_eq!(fake_quant(&xs, 0.0, 7.0, GridKind::Signed), xs.to_vec());
    }

    #[test]
    fn grid_snap() {
        // Δ=0.5, signed 4-bit (qmax=7): x=0.74 -> 1.5·0.5? no: 0.74/0.5=1.48 -> 1 -> 0.5
        assert_eq!(fake_quant_one(0.74, 0.5, 7.0, GridKind::Signed), 0.5);
        assert_eq!(fake_quant_one(0.76, 0.5, 7.0, GridKind::Signed), 1.0);
        assert_eq!(fake_quant_one(-0.76, 0.5, 7.0, GridKind::Signed), -1.0);
    }

    #[test]
    fn clipping() {
        assert_eq!(fake_quant_one(100.0, 0.1, 7.0, GridKind::Signed), 0.7);
        assert_eq!(fake_quant_one(-100.0, 0.1, 7.0, GridKind::Signed), -0.7);
        assert_eq!(fake_quant_one(-1.0, 0.1, 15.0, GridKind::Unsigned), 0.0);
    }

    #[test]
    fn round_half_even_matches_numpy() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(0.4999), 0.0);
        assert_eq!(round_half_even(1.2), 1.0);
    }

    #[test]
    fn idempotent() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.037).collect();
        let once = fake_quant(&xs, 0.07, 7.0, GridKind::Signed);
        let twice = fake_quant(&once, 0.07, 7.0, GridKind::Signed);
        for (a, b) in once.iter().zip(&twice) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn error_bounded_inside_range() {
        let delta = 0.05f32;
        let qmax = 7.0f32;
        for i in 0..1000 {
            let x = -delta * qmax + (2.0 * delta * qmax) * (i as f32 / 999.0);
            let err = (fake_quant_one(x, delta, qmax, GridKind::Signed) - x).abs();
            assert!(err <= delta / 2.0 + 1e-6);
        }
    }

    #[test]
    fn level_count_bound() {
        use std::collections::HashSet;
        let xs: Vec<f32> = (0..4096).map(|i| ((i * 2654435761u32 as usize) as f32).sin() * 3.0).collect();
        for bits in [2u32, 3, 4] {
            let qmax = GridKind::Signed.qmax(bits);
            let q = fake_quant(&xs, 0.2, qmax, GridKind::Signed);
            let levels: HashSet<i64> = q.iter().map(|&v| (v / 0.2).round() as i64).collect();
            assert!(levels.len() <= (1usize << bits) - 1, "bits={bits}: {}", levels.len());
        }
    }
}
