//! Quantization jobs: one [`ExperimentConfig`] in, one [`JobResult`] out.
//!
//! The [`Runner`] owns the engine handle and a **trained-model cache** —
//! every (model, seed, steps) FP32 training run happens once and is shared
//! by all methods/bitwidths that quantize it (exactly how the paper reuses
//! one pretrained checkpoint across its table rows).

use super::evaluator::EvalSet;
use super::trainer::{train_full, TrainCfg, TrainReport};
use super::workload::{Split, Workload};
use crate::config::ExperimentConfig;
use crate::lapq::calibration::{collect, CalibData};
use crate::lapq::pipeline::{calibrate, calibrate_with_init, InitKind, QuantOutcome};
use crate::runtime::{EngineHandle, SessionId};
use crate::tensor::HostTensor;
use anyhow::Result;
use std::collections::HashMap;

/// Outcome of a full quantization job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub model: String,
    pub bits_label: String,
    pub method: String,
    /// Task metric (accuracy or hit-rate) of the FP32 model.
    pub fp32_metric: f32,
    /// Task metric under the calibrated quantization.
    pub quant_metric: f32,
    pub outcome: QuantOutcome,
    pub seconds: f64,
}

pub struct Runner {
    pub eng: EngineHandle,
    /// (model, seed, steps) -> trained FP32 params.
    trained: HashMap<(String, u64, usize), (Vec<HostTensor>, TrainReport)>,
    /// cached val sets per (model, seed, val_size)
    val_batches: usize,
}

impl Runner {
    pub fn new(eng: EngineHandle) -> Self {
        Runner { eng, trained: HashMap::new(), val_batches: 0 }
    }

    /// Train (or fetch cached) FP32 parameters for a config.
    pub fn trained_params(
        &mut self,
        cfg: &ExperimentConfig,
    ) -> Result<(Vec<HostTensor>, TrainReport)> {
        let key = (cfg.model.clone(), cfg.seed, cfg.train_steps);
        if let Some(hit) = self.trained.get(&key) {
            return Ok(hit.clone());
        }
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let workload = Workload::for_model(&spec, cfg.seed)?;
        let tcfg = TrainCfg { steps: cfg.train_steps, base_lr: cfg.lr, ..Default::default() };
        let (sess, report) = train_full(&self.eng, &cfg.model, &workload, cfg.seed, &tcfg)?;
        let params = self.eng.get_params(sess)?;
        self.eng.drop_session(sess)?;
        self.trained.insert(key.clone(), (params, report));
        Ok(self.trained[&key].clone())
    }

    /// Set up (session, workload, val set, calib data) for a config.
    fn prepare(
        &mut self,
        cfg: &ExperimentConfig,
    ) -> Result<(SessionId, Workload, EvalSet, CalibData)> {
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let workload = Workload::for_model(&spec, cfg.seed)?;
        let (params, _) = self.trained_params(cfg)?;
        let sess = self.eng.create_session(&cfg.model, params)?;
        let n_val = cfg.val_size.div_ceil(spec.eval_batch()).max(1);
        let val = EvalSet::register(&self.eng, &spec, &workload, Split::Val, n_val)?;
        let calib = collect(&self.eng, sess, &spec, &workload, cfg.calib_size)?;
        self.val_batches = val.batches.len();
        Ok((sess, workload, val, calib))
    }

    fn finish(
        &self,
        cfg: &ExperimentConfig,
        sess: SessionId,
        val: &EvalSet,
        calib: &CalibData,
        outcome: QuantOutcome,
        t0: std::time::Instant,
    ) -> Result<JobResult> {
        let fp32_metric = val.metric(&self.eng, sess, None)?;
        let quant_metric = val.metric(&self.eng, sess, Some(&outcome.quant))?;
        calib.release(&self.eng);
        for &b in &val.batches {
            let _ = self.eng.drop_batch(b);
        }
        self.eng.drop_session(sess)?;
        Ok(JobResult {
            model: cfg.model.clone(),
            bits_label: cfg.bits.label(),
            method: outcome.method.name().to_string(),
            fp32_metric,
            quant_metric,
            outcome,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run a full job with the configured method.
    pub fn run(&mut self, cfg: &ExperimentConfig) -> Result<JobResult> {
        let t0 = std::time::Instant::now();
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let (sess, _w, val, calib) = self.prepare(cfg)?;
        let outcome = calibrate(&self.eng, sess, &spec, cfg, &calib)?;
        let mut res = self.finish(cfg, sess, &val, &calib, outcome, t0)?;
        res.method = cfg.method.name().to_string();
        log::info!(
            "job {} {} {}: fp32 {:.3} -> quant {:.3} ({:.1}s)",
            res.model,
            res.bits_label,
            res.method,
            res.fp32_metric,
            res.quant_metric,
            res.seconds
        );
        Ok(res)
    }

    /// Table-3 ablation entry: explicit init, joint phase optional.
    pub fn run_with_init(
        &mut self,
        cfg: &ExperimentConfig,
        init: InitKind,
        run_joint: bool,
    ) -> Result<JobResult> {
        let t0 = std::time::Instant::now();
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let (sess, _w, val, calib) = self.prepare(cfg)?;
        let outcome = calibrate_with_init(&self.eng, sess, &spec, cfg, &calib, init, run_joint)?;
        self.finish(cfg, sess, &val, &calib, outcome, t0)
    }

    /// Lower-level access for analysis benches: trained session + calib.
    pub fn session_with_calib(
        &mut self,
        cfg: &ExperimentConfig,
    ) -> Result<(SessionId, EvalSet, CalibData)> {
        let (sess, _w, val, calib) = self.prepare(cfg)?;
        Ok((sess, val, calib))
    }
}
