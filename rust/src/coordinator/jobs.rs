//! Quantization jobs: one [`ExperimentConfig`] in, one [`JobResult`] out.
//!
//! The [`Runner`] owns the engine handle and a **trained-model cache** —
//! every (model, seed, steps) FP32 training run happens once and is shared
//! by all methods/bitwidths that quantize it (exactly how the paper reuses
//! one pretrained checkpoint across its table rows).  Serving state lives
//! in an `Arc`-shared [`ModelRegistry`] (LRU of packed
//! [`QuantizedModel`]s keyed by `model:wN aN:method`), fed by
//! [`Runner::pack`] and consumed by [`Runner::infer`] — and, through
//! [`infer_shared`] / [`infer_batched`], by the concurrent serving
//! subsystem's read path without taking any Runner lock.

use super::evaluator::EvalSet;
use super::metrics;
use super::trainer::{train_full, TrainCfg, TrainReport};
use super::workload::{Split, Workload};
use crate::config::ExperimentConfig;
use crate::lapq::calibration::{collect, CalibData};
use crate::lapq::calibrator::{Calibrator, InitKind, QuantOutcome};
use crate::lapq::events::{CalibObserver, NullObserver};
use crate::runtime::cpu::ops::Arr;
use crate::runtime::int::{ExecMode, InferSession, PackOpts, QuantizedModel};
use crate::runtime::{EngineHandle, SessionId};
use crate::serve::registry::ModelRegistry;
use crate::tensor::HostTensor;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Outcome of a full quantization job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub model: String,
    pub bits_label: String,
    pub method: String,
    /// Task metric (accuracy or hit-rate) of the FP32 model.
    pub fp32_metric: f32,
    /// Task metric under the calibrated quantization.
    pub quant_metric: f32,
    pub outcome: QuantOutcome,
    pub seconds: f64,
}

/// Default capacity of the packed-model registry (one shared default
/// with `ServeCfg`, owned by the config layer).
pub const PACKED_CACHE_CAP: usize = crate::config::DEFAULT_REGISTRY_CAP;

/// What a `pack` job reports back (the artifact itself lands in the
/// Runner's cache and optionally on disk).
#[derive(Clone, Debug)]
pub struct PackSummary {
    pub key: String,
    pub model: String,
    pub bits_label: String,
    pub method: String,
    pub int_params: usize,
    pub f32_bytes: usize,
    pub packed_bytes: usize,
    /// Task metric of the FP32 model on the val set.
    pub fp32_metric: f32,
    /// Task metric under the *effective* (packed, po2-snapped) grids.
    pub quant_metric: f32,
    pub seconds: f64,
    /// Per-layer weight widths of the packed artifact (32 = FP32 layer).
    /// Uniform packs report the uniform width in every quantized slot.
    pub wbits: Vec<u32>,
}

/// One integer-engine forward pass served from the cache.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub key: String,
    pub logits: Arr,
    pub rows: usize,
    pub int_layers: usize,
    /// Wall time of the *execution* that produced this reply.  For a
    /// request coalesced by the micro-batcher this is the whole batch's
    /// execution time (every batch-mate reports the same value), not the
    /// marginal cost of this request alone — only the timing differs
    /// from sequential serving; the logits are bit-for-bit identical.
    pub seconds: f64,
}

pub struct Runner {
    pub eng: EngineHandle,
    /// (model, seed, steps) -> trained FP32 params.
    trained: HashMap<(String, u64, usize), (Vec<HostTensor>, TrainReport)>,
    /// cached val sets per (model, seed, val_size)
    val_batches: usize,
    /// Packed-model LRU, shareable with the concurrent serving path.
    registry: Arc<ModelRegistry>,
}

impl Runner {
    pub fn new(eng: EngineHandle) -> Self {
        Self::with_registry(eng, Arc::new(ModelRegistry::new(PACKED_CACHE_CAP)))
    }

    /// A Runner whose pack jobs publish into an externally shared
    /// registry (the pool server's read path consumes it lock-free with
    /// respect to the Runner).
    pub fn with_registry(eng: EngineHandle, registry: Arc<ModelRegistry>) -> Self {
        Runner { eng, trained: HashMap::new(), val_batches: 0, registry }
    }

    /// The packed-model registry this Runner fills.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Train (or fetch cached) FP32 parameters for a config.
    pub fn trained_params(
        &mut self,
        cfg: &ExperimentConfig,
    ) -> Result<(Vec<HostTensor>, TrainReport)> {
        let key = (cfg.model.clone(), cfg.seed, cfg.train_steps);
        if let Some(hit) = self.trained.get(&key) {
            return Ok(hit.clone());
        }
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let workload = Workload::for_model(&spec, cfg.seed)?;
        let tcfg = TrainCfg { steps: cfg.train_steps, base_lr: cfg.lr, ..Default::default() };
        let (sess, report) = train_full(&self.eng, &cfg.model, &workload, cfg.seed, &tcfg)?;
        let params = self.eng.get_params(sess)?;
        self.eng.drop_session(sess)?;
        self.trained.insert(key.clone(), (params, report));
        Ok(self.trained[&key].clone())
    }

    /// Set up (session, workload, val set, calib data) for a config.
    fn prepare(
        &mut self,
        cfg: &ExperimentConfig,
    ) -> Result<(SessionId, Workload, EvalSet, CalibData)> {
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let workload = Workload::for_model(&spec, cfg.seed)?;
        let (params, _) = self.trained_params(cfg)?;
        let sess = self.eng.create_session(&cfg.model, params)?;
        let n_val = cfg.val_size.div_ceil(spec.eval_batch()).max(1);
        let val = EvalSet::register(&self.eng, &spec, &workload, Split::Val, n_val)?;
        let calib = collect(&self.eng, sess, &spec, &workload, cfg.calib_size)?;
        self.val_batches = val.batches.len();
        Ok((sess, workload, val, calib))
    }

    /// Release everything a job acquired: calib batches, val batches,
    /// the session.  Must run on success, error and panic paths alike —
    /// the service outlives all three.
    fn cleanup(&self, sess: SessionId, val: &EvalSet, calib: &CalibData) {
        calib.release(&self.eng);
        for &b in &val.batches {
            let _ = self.eng.drop_batch(b);
        }
        let _ = self.eng.drop_session(sess);
    }

    fn finish(
        &self,
        cfg: &ExperimentConfig,
        sess: SessionId,
        val: &EvalSet,
        calib: &CalibData,
        outcome: QuantOutcome,
        t0: std::time::Instant,
    ) -> Result<JobResult> {
        let fp32_metric = val.metric(&self.eng, sess, None)?;
        let quant_metric = val.metric(&self.eng, sess, Some(&outcome.quant))?;
        self.cleanup(sess, val, calib);
        Ok(JobResult {
            model: cfg.model.clone(),
            bits_label: cfg.bits.label(),
            method: outcome.method.name().to_string(),
            fp32_metric,
            quant_metric,
            outcome,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run a full job with the configured method (standard composition,
    /// no observer).
    pub fn run(&mut self, cfg: &ExperimentConfig) -> Result<JobResult> {
        self.run_observed(cfg, &mut NullObserver)
    }

    /// Run a full job with the configured method, streaming
    /// [`crate::lapq::CalibEvent`]s into `obs` (CLI progress lines, the
    /// service's event frames).
    pub fn run_observed(
        &mut self,
        cfg: &ExperimentConfig,
        obs: &mut dyn CalibObserver,
    ) -> Result<JobResult> {
        let cal = Calibrator::from_config(cfg);
        let res = self.run_with(cfg, &cal, obs)?;
        log::info!(
            "job {} {} {}: fp32 {:.3} -> quant {:.3} ({:.1}s)",
            res.model,
            res.bits_label,
            res.method,
            res.fp32_metric,
            res.quant_metric,
            res.seconds
        );
        Ok(res)
    }

    /// Run a job through an explicitly composed [`Calibrator`] — the
    /// entry point every bench and ablation builds on.
    pub fn run_with(
        &mut self,
        cfg: &ExperimentConfig,
        cal: &Calibrator,
        obs: &mut dyn CalibObserver,
    ) -> Result<JobResult> {
        let t0 = std::time::Instant::now();
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let (sess, _w, val, calib) = self.prepare(cfg)?;
        let outcome = match cal.run(&self.eng, sess, &spec, cfg, &calib, obs) {
            Ok(o) => o,
            Err(e) => {
                self.cleanup(sess, &val, &calib);
                return Err(e);
            }
        };
        self.finish(cfg, sess, &val, &calib, outcome, t0)
    }

    /// Table-3 ablation entry: explicit init, joint phase optional.
    pub fn run_with_init(
        &mut self,
        cfg: &ExperimentConfig,
        init: InitKind,
        run_joint: bool,
    ) -> Result<JobResult> {
        let cal = Calibrator::from_init(cfg, init, run_joint);
        self.run_with(cfg, &cal, &mut NullObserver)
    }

    /// Lower-level access for analysis benches: trained session + calib.
    pub fn session_with_calib(
        &mut self,
        cfg: &ExperimentConfig,
    ) -> Result<(SessionId, EvalSet, CalibData)> {
        let (sess, _w, val, calib) = self.prepare(cfg)?;
        Ok((sess, val, calib))
    }

    /// Cache key for a pack job.
    pub fn pack_key(cfg: &ExperimentConfig) -> String {
        Self::pack_key_planned(cfg, None)
    }

    /// Cache key for a pack job with an allocated bit plan.  Uniform
    /// packs keep the config-derivable `model:wNaM:METHOD` form; a mixed
    /// plan embeds its per-layer widths (`cnn6:w[8.4.2]a4:LAPQ`) so mixed
    /// and uniform artifacts of the same config can never collide in the
    /// registry LRU.
    pub fn pack_key_planned(cfg: &ExperimentConfig, wbits: Option<&[u32]>) -> String {
        match wbits {
            Some(plan) => {
                let joined =
                    plan.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(".");
                format!("{}:w[{}]a{}:{}", cfg.model, joined, cfg.bits.acts, cfg.method.name())
            }
            None => format!(
                "{}:w{}a{}:{}",
                cfg.model,
                cfg.bits.weights,
                cfg.bits.acts,
                cfg.method.name()
            ),
        }
    }

    /// Full pack job: train (cached) → calibrate → quantize the session
    /// parameters into a [`QuantizedModel`], report fp32 vs packed-grid
    /// metrics, and park the artifact in the MRU cache under
    /// [`Runner::pack_key`].
    pub fn pack(
        &mut self,
        cfg: &ExperimentConfig,
        opts: &PackOpts,
    ) -> Result<(PackSummary, Arc<QuantizedModel>)> {
        let t0 = std::time::Instant::now();
        let spec = self.eng.manifest().model(&cfg.model)?.clone();
        let (sess, _w, val, calib) = self.prepare(cfg)?;
        // Catch unwinds too: the service survives kernel panics via its
        // own catch_unwind, so cleanup must not be skipped or the engine
        // would leak this job's session and batches on every bad request.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let cal = Calibrator::from_config(cfg);
            let outcome = cal.run(&self.eng, sess, &spec, cfg, &calib, &mut NullObserver)?;
            let active = (outcome.mask.weights.as_slice(), outcome.mask.acts.as_slice());
            let qm = self.eng.pack(&cfg.model, sess, &outcome.quant, Some(active), opts)?;
            // Metrics under the grids the artifact actually encodes.
            let fp32_metric = val.metric(&self.eng, sess, None)?;
            let quant_metric = val.metric(&self.eng, sess, Some(&qm.quant))?;
            Ok::<_, anyhow::Error>((qm, outcome.wbits, fp32_metric, quant_metric))
        }));
        self.cleanup(sess, &val, &calib);
        let (qm, plan, fp32_metric, quant_metric) = match result {
            Ok(r) => r?,
            Err(payload) => std::panic::resume_unwind(payload),
        };
        let key = if cfg.mixed.enabled {
            Self::pack_key_planned(cfg, plan.as_deref())
        } else {
            Self::pack_key(cfg)
        };
        let summary = PackSummary {
            key: key.clone(),
            model: qm.model.clone(),
            bits_label: cfg.bits.label(),
            method: cfg.method.name().to_string(),
            int_params: qm.int_params(),
            f32_bytes: qm.f32_bytes(),
            packed_bytes: qm.packed_bytes(),
            fp32_metric,
            quant_metric,
            seconds: t0.elapsed().as_secs_f64(),
            wbits: qm.wbits(),
        };
        let arc = Arc::new(qm);
        self.registry.put(key, arc.clone());
        metrics::observe("pack", summary.seconds, 1);
        log::info!(
            "pack {}: {} int params, {} -> {} bytes, fp32 {:.3} -> int-grid {:.3} ({:.1}s)",
            summary.key,
            summary.int_params,
            summary.f32_bytes,
            summary.packed_bytes,
            summary.fp32_metric,
            summary.quant_metric,
            summary.seconds
        );
        Ok((summary, arc))
    }

    /// Look up a packed model by exact key or bare model name (most
    /// recently used wins), refreshing its LRU position.
    pub fn packed_get(&self, key: &str) -> Option<Arc<QuantizedModel>> {
        self.registry.get(key)
    }

    /// Serve one batched prediction from the registry with the integer
    /// engine.  `inputs` is `(x,)` for vision, `(users, items)` for NCF.
    pub fn infer(&self, key: &str, inputs: &[HostTensor]) -> Result<InferReply> {
        infer_shared(&self.eng, &self.registry, key, inputs)
    }
}

/// Resolve `key` to its packed artifact + model spec (the shared
/// lookup both read-path entry points start from).  A miss first tries
/// the registry's disk spill (transparent reload); only a key that was
/// never packed — or whose spill is gone — errors, carrying the
/// [`crate::proto::MODEL_NOT_PACKED`] token so dispatchers can answer
/// with the typed response instead of a generic error.
fn packed_for<'e>(
    eng: &'e EngineHandle,
    registry: &ModelRegistry,
    key: &str,
) -> Result<(&'e crate::runtime::ModelSpec, Arc<QuantizedModel>)> {
    let qm = registry.get_or_reload(key).ok_or_else(|| {
        anyhow::anyhow!(
            "{}: no packed model '{key}' in registry or spill (run pack first)",
            crate::proto::MODEL_NOT_PACKED
        )
    })?;
    let spec = eng.manifest().model(&qm.model)?;
    Ok((spec, qm))
}

fn reply_from(key: &str, res: crate::runtime::int::InferResult, seconds: f64) -> InferReply {
    let rows = res.logits.shape.first().copied().unwrap_or(0);
    let int_layers = res.int_layers;
    InferReply { key: key.to_string(), logits: res.logits, rows, int_layers, seconds }
}

/// One prediction from the shared registry — the lock-free-with-respect-
/// to-the-Runner read path the concurrent server uses.  Inputs are
/// borrowed straight through to the kernels (no copies on this path).
pub fn infer_shared(
    eng: &EngineHandle,
    registry: &ModelRegistry,
    key: &str,
    inputs: &[HostTensor],
) -> Result<InferReply> {
    let (spec, qm) = packed_for(eng, registry, key)?;
    let t0 = std::time::Instant::now();
    let sess = InferSession::new(spec, &qm)?;
    let res = sess.infer(inputs, ExecMode::Int)?;
    let seconds = t0.elapsed().as_secs_f64();
    metrics::observe("infer", seconds, res.logits.shape.first().copied().unwrap_or(0));
    metrics::inc(&format!("infer_{}", qm.model));
    Ok(reply_from(key, res, seconds))
}

/// One *coalesced* execution over the batch-parallel integer kernels:
/// `parts[i]` is request `i`'s input tuple; the reply vector maps back
/// one-to-one.  This is what the micro-batcher calls; row-independent
/// kernels make the result bit-for-bit identical to serving each part
/// separately.  Every reply carries the same `seconds` — the coalesced
/// execution's wall time — since the parts are not timed individually.
pub fn infer_batched(
    eng: &EngineHandle,
    registry: &ModelRegistry,
    key: &str,
    parts: &[Vec<HostTensor>],
) -> Result<Vec<InferReply>> {
    let (spec, qm) = packed_for(eng, registry, key)?;
    let t0 = std::time::Instant::now();
    let sess = InferSession::new(spec, &qm)?;
    let results = sess.infer_many(parts, ExecMode::Int)?;
    let seconds = t0.elapsed().as_secs_f64();
    let total_rows: usize =
        results.iter().map(|r| r.logits.shape.first().copied().unwrap_or(0)).sum();
    metrics::observe("infer", seconds, total_rows);
    metrics::inc(&format!("infer_{}", qm.model));
    Ok(results.into_iter().map(|res| reply_from(key, res, seconds)).collect())
}
