//! Batched validation evaluation: task metric (accuracy / hit-rate@10)
//! and mean loss over a registered batch set.

use super::workload::{MetricKind, Split, Workload};
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{BatchId, EngineHandle, QuantParams, SessionId};
use anyhow::Result;

/// A registered evaluation set (metric batches stay resident in the
/// engine so repeated evaluations ship no data).
pub struct EvalSet {
    pub batches: Vec<BatchId>,
    pub kind: MetricKind,
    /// Samples per batch.
    pub per_batch: usize,
}

impl EvalSet {
    /// Build + register `count` metric batches from a split.
    pub fn register(
        eng: &EngineHandle,
        spec: &ModelSpec,
        workload: &Workload,
        split: Split,
        count: usize,
    ) -> Result<EvalSet> {
        let (raw, kind) = workload.metric_batches(spec, split, count);
        let per_batch = raw[0][0].shape[0];
        let batches = raw.into_iter().map(|b| eng.register_batch(b)).collect::<Result<_>>()?;
        Ok(EvalSet { batches, kind, per_batch })
    }

    pub fn total(&self) -> usize {
        self.batches.len() * self.per_batch
    }

    /// Task metric in [0,1] under optional quantization.
    pub fn metric(
        &self,
        eng: &EngineHandle,
        sess: SessionId,
        quant: Option<&QuantParams>,
    ) -> Result<f32> {
        let mut good = 0.0f32;
        for &b in &self.batches {
            good += match self.kind {
                MetricKind::Accuracy => eng.eval(sess, quant.cloned(), b)?.1,
                MetricKind::HitRate => eng.hitrate(sess, quant.cloned(), b)?,
            };
        }
        Ok(good / self.total() as f32)
    }
}

/// Mean loss over a set of loss batches (vision: (x,y); ncf: (u,i,l)).
pub fn mean_loss(
    eng: &EngineHandle,
    sess: SessionId,
    quant: Option<&QuantParams>,
    batches: &[BatchId],
) -> Result<f64> {
    let mut acc = 0.0f64;
    for &b in batches {
        acc += eng.eval(sess, quant.cloned(), b)?.0 as f64;
    }
    Ok(acc / batches.len().max(1) as f64)
}
