//! Job queue: run a batch of experiment configs through one [`Runner`],
//! with failure isolation (one bad job doesn't sink the sweep) and a
//! printed/CSV summary — this is what every table bench drives.

use super::jobs::{JobResult, Runner};
use super::metrics;
use crate::benchkit::Table;
use crate::config::ExperimentConfig;
use anyhow::Result;

pub struct Scheduler {
    pub queue: Vec<ExperimentConfig>,
    pub results: Vec<JobResult>,
    pub failures: Vec<(ExperimentConfig, String)>,
}

impl Scheduler {
    pub fn new() -> Self {
        Scheduler { queue: Vec::new(), results: Vec::new(), failures: Vec::new() }
    }

    pub fn push(&mut self, cfg: ExperimentConfig) -> &mut Self {
        self.queue.push(cfg);
        self
    }

    /// Run everything sequentially (XLA is internally parallel; jobs share
    /// the trained-model cache inside `runner`).
    pub fn run_all(&mut self, runner: &mut Runner) -> Result<()> {
        let jobs = std::mem::take(&mut self.queue);
        let total = jobs.len();
        for (i, cfg) in jobs.into_iter().enumerate() {
            log::info!(
                "[{}/{}] {} {} {}",
                i + 1,
                total,
                cfg.model,
                cfg.bits.label(),
                cfg.method.name()
            );
            metrics::inc("scheduler_jobs");
            match runner.run(&cfg) {
                Ok(res) => self.results.push(res),
                Err(e) => {
                    metrics::inc("scheduler_failures");
                    log::error!("job failed: {e:#}");
                    self.failures.push((cfg, format!("{e:#}")));
                }
            }
        }
        Ok(())
    }

    /// Paper-style comparison table of all results.
    pub fn summary_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["Model", "W/A", "Method", "FP32", "Quant", "Δcalib loss", "evals", "sec"],
        );
        for r in &self.results {
            t.row(&[
                r.model.clone(),
                r.bits_label.clone(),
                r.method.clone(),
                crate::benchkit::pct(r.fp32_metric),
                crate::benchkit::pct(r.quant_metric),
                format!("{:+.4}", r.outcome.calib_loss - r.outcome.fp32_calib_loss),
                r.outcome.joint_evals.to_string(),
                format!("{:.1}", r.seconds),
            ]);
        }
        t
    }
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_and_summary_shape() {
        let mut s = Scheduler::new();
        s.push(ExperimentConfig::default());
        assert_eq!(s.queue.len(), 1);
        let t = s.summary_table("t");
        assert!(t.rows.is_empty());
    }
}
