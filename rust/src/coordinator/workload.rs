//! Workload abstraction: couples a model to its data substrate and batch
//! shapes, so the trainer / evaluator / LAPQ pipeline are task-agnostic.

use crate::data::ncf::SynthNcf;
use crate::data::vision::SynthVision;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::HostTensor;
use anyhow::{bail, Result};

/// Index-space layout (samples are generated on demand; splits are ranges).
const TRAIN_BASE: u64 = 0;
const VAL_BASE: u64 = 10_000_000;
const CALIB_BASE: u64 = 20_000_000;

pub enum Workload {
    Vision {
        data: SynthVision,
        /// For mlp3: random-project images to this many features.
        feature_dim: Option<usize>,
    },
    Ncf {
        data: SynthNcf,
    },
}

impl Workload {
    /// Build the standard workload for a model.
    pub fn for_model(spec: &ModelSpec, seed: u64) -> Result<Workload> {
        match spec.task.as_str() {
            "vision" => {
                let feature_dim = if spec.input_spec["eval"][0].shape.len() == 2 {
                    Some(spec.input_spec["eval"][0].shape[1])
                } else {
                    None
                };
                Ok(Workload::Vision { data: SynthVision::new(seed), feature_dim })
            }
            "ncf" => Ok(Workload::Ncf { data: SynthNcf::new(seed, 2000, 1000, 12) }),
            other => bail!("unknown task {other}"),
        }
    }

    /// Training batch for global step `step`.
    pub fn train_batch(&self, spec: &ModelSpec, step: u64) -> Vec<HostTensor> {
        let n = spec.train_batch();
        match self {
            Workload::Vision { data, feature_dim } => {
                let start = TRAIN_BASE + step * n as u64;
                let (x, y) = match feature_dim {
                    Some(d) => data.batch_features(start, n, *d),
                    None => data.batch(start, n),
                };
                vec![x, y]
            }
            Workload::Ncf { data } => {
                let (u, i, l) = data.train_batch(step, n, 4);
                vec![u, i, l]
            }
        }
    }

    /// `count` evaluation batches (inputs + labels) from a named split.
    pub fn eval_batches(&self, spec: &ModelSpec, split: Split, count: usize) -> Vec<Vec<HostTensor>> {
        let n = spec.eval_batch();
        let base = split.base();
        (0..count)
            .map(|k| match self {
                Workload::Vision { data, feature_dim } => {
                    let start = base + (k * n) as u64;
                    let (x, y) = match feature_dim {
                        Some(d) => data.batch_features(start, n, *d),
                        None => data.batch(start, n),
                    };
                    vec![x, y]
                }
                Workload::Ncf { data } => {
                    let (u, i, l) = data.train_batch(base + 1000 + k as u64, n, 4);
                    vec![u, i, l]
                }
            })
            .collect()
    }

    /// Activation-collection batches (inputs only) from the calib split.
    pub fn acts_batches(&self, spec: &ModelSpec, count: usize) -> Vec<Vec<HostTensor>> {
        self.eval_batches(spec, Split::Calib, count)
            .into_iter()
            .map(|mut b| match self {
                Workload::Vision { .. } => {
                    b.truncate(1);
                    b
                }
                Workload::Ncf { .. } => {
                    b.truncate(2);
                    b
                }
            })
            .collect()
    }

    /// Task metric batches: vision reuses eval batches (accuracy); NCF
    /// builds mlperf hit-rate batches.  Returns (batches, entry_kind).
    pub fn metric_batches(
        &self,
        spec: &ModelSpec,
        split: Split,
        count: usize,
    ) -> (Vec<Vec<HostTensor>>, MetricKind) {
        match self {
            Workload::Vision { .. } => (self.eval_batches(spec, split, count), MetricKind::Accuracy),
            Workload::Ncf { data } => {
                let n = spec.input_spec["hitrate"][0].shape[0];
                let start = match split {
                    Split::Val => 0,
                    Split::Calib => 1000,
                    Split::Train => 500,
                };
                let batches = (0..count)
                    .map(|k| {
                        let (u, p, negs) = data.eval_batch(start + k * n, n);
                        vec![u, p, negs]
                    })
                    .collect();
                (batches, MetricKind::HitRate)
            }
        }
    }
}

/// Which metric a metric-batch evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Accuracy,
    HitRate,
}

/// Disjoint sample splits (index-space offsets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Calib,
}

impl Split {
    fn base(self) -> u64 {
        match self {
            Split::Train => TRAIN_BASE,
            Split::Val => VAL_BASE,
            Split::Calib => CALIB_BASE,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn spec(name: &str) -> Option<ModelSpec> {
        Manifest::builtin().model(name).ok().cloned()
    }

    #[test]
    fn vision_batches_shape() {
        let Some(spec) = spec("cnn6") else { return };
        let w = Workload::for_model(&spec, 1).unwrap();
        let tb = w.train_batch(&spec, 0);
        assert_eq!(tb.len(), 2);
        assert_eq!(tb[0].shape[0], spec.train_batch());
        let eb = w.eval_batches(&spec, Split::Val, 2);
        assert_eq!(eb.len(), 2);
        assert_eq!(eb[0][0].shape[0], spec.eval_batch());
        // acts batches drop labels
        assert_eq!(w.acts_batches(&spec, 1)[0].len(), 1);
    }

    #[test]
    fn mlp_uses_features() {
        let Some(spec) = spec("mlp3") else { return };
        let w = Workload::for_model(&spec, 1).unwrap();
        let tb = w.train_batch(&spec, 0);
        assert_eq!(tb[0].shape, vec![spec.train_batch(), 64]);
    }

    #[test]
    fn splits_disjoint_batches() {
        let Some(spec) = spec("cnn6") else { return };
        let w = Workload::for_model(&spec, 1).unwrap();
        let a = w.eval_batches(&spec, Split::Val, 1);
        let b = w.eval_batches(&spec, Split::Calib, 1);
        assert_ne!(a[0][0].f(), b[0][0].f());
    }

    #[test]
    fn ncf_metric_batches() {
        let Some(spec) = spec("ncf") else { return };
        let w = Workload::for_model(&spec, 1).unwrap();
        let (mb, kind) = w.metric_batches(&spec, Split::Val, 2);
        assert_eq!(kind, MetricKind::HitRate);
        assert_eq!(mb[0].len(), 3);
        assert_eq!(mb[0][2].shape[1], 99);
    }
}
