//! Layer-3 coordinator: training, evaluation, job orchestration, metrics
//! and the TCP job service — the deployment-facing half of the system.

pub mod evaluator;
pub mod jobs;
pub mod metrics;
pub mod scheduler;
pub mod service;
pub mod trainer;
pub mod workload;
