//! TCP job service: JSON-lines protocol for submitting quantization jobs
//! to a running coordinator (the "deployment" face of the system).
//!
//! Protocol (one JSON object per line):
//!   {"cmd":"ping"}                         -> {"ok":true,"pong":true}
//!   {"cmd":"models"}                       -> {"ok":true,"models":[...]}
//!   {"cmd":"metrics"}                      -> {"ok":true,"metrics":{...}}
//!   {"cmd":"quantize", ...config fields}   -> {"ok":true,"result":{...}}
//!
//! The listener thread accepts connections and forwards jobs to the
//! single Runner (PJRT engine behind it); responses stream back on the
//! same connection.  `max_requests` bounds the serve loop for tests.

use super::jobs::Runner;
use super::metrics;
use crate::config::ExperimentConfig;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

pub struct Service {
    listener: TcpListener,
    pub addr: std::net::SocketAddr,
}

impl Service {
    /// Bind to `addr` (use port 0 for ephemeral).
    pub fn bind(addr: &str) -> Result<Service> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        log::info!("service listening on {addr}");
        Ok(Service { listener, addr })
    }

    /// Serve until `max_requests` requests have been handled
    /// (`usize::MAX` for forever).  Connections are handled sequentially:
    /// quantization jobs are minutes-long and own the PJRT engine.
    pub fn serve(&self, runner: &mut Runner, max_requests: usize) -> Result<()> {
        let mut handled = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            handled += self.handle_conn(stream, runner, max_requests - handled)?;
            if handled >= max_requests {
                break;
            }
        }
        Ok(())
    }

    fn handle_conn(
        &self,
        stream: TcpStream,
        runner: &mut Runner,
        budget: usize,
    ) -> Result<usize> {
        let peer = stream.peer_addr()?;
        log::info!("conn from {peer}");
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        let mut handled = 0usize;
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            metrics::inc("service_requests");
            let resp = self.dispatch(&line, runner);
            writer.write_all(resp.dump().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            handled += 1;
            if handled >= budget {
                break;
            }
        }
        Ok(handled)
    }

    fn dispatch(&self, line: &str, runner: &mut Runner) -> Json {
        match self.dispatch_inner(line, runner) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        }
    }

    fn dispatch_inner(&self, line: &str, runner: &mut Runner) -> Result<Json> {
        let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        let cmd = req.get("cmd").and_then(|c| c.as_str()).unwrap_or("");
        match cmd {
            "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])),
            "models" => {
                let models: Vec<Json> = runner
                    .eng
                    .manifest()
                    .models
                    .keys()
                    .map(|k| Json::Str(k.clone()))
                    .collect();
                Ok(Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::Arr(models))]))
            }
            "metrics" => {
                Ok(Json::obj(vec![("ok", Json::Bool(true)), ("metrics", metrics::dump())]))
            }
            "quantize" => {
                let cfg = ExperimentConfig::from_json(&req)?;
                let res = runner.run(&cfg)?;
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "result",
                        Json::obj(vec![
                            ("model", Json::Str(res.model)),
                            ("bits", Json::Str(res.bits_label)),
                            ("method", Json::Str(res.method)),
                            ("fp32_metric", Json::Num(res.fp32_metric as f64)),
                            ("quant_metric", Json::Num(res.quant_metric as f64)),
                            ("calib_loss", Json::Num(res.outcome.calib_loss)),
                            ("fp32_calib_loss", Json::Num(res.outcome.fp32_calib_loss)),
                            ("joint_evals", Json::Num(res.outcome.joint_evals as f64)),
                            ("seconds", Json::Num(res.seconds)),
                        ]),
                    ),
                ]))
            }
            other => anyhow::bail!("unknown cmd '{other}'"),
        }
    }
}

/// Minimal client for tests and scripting.
pub fn request(addr: &std::net::SocketAddr, body: &Json) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(body.dump().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}
