//! TCP job service: the blocking face of the wire protocol
//! ([`crate::proto`]) for submitting quantization and serving jobs to a
//! running coordinator.
//!
//! Protocol (JSON lines by default; see README "Wire protocol"):
//!   {"cmd":"ping"}                         -> {"ok":true,"pong":true}
//!   {"cmd":"models"}                       -> {"ok":true,"models":[...]}
//!   {"cmd":"metrics"}                      -> {"ok":true,"metrics":{...}}
//!   {"cmd":"hello","wire":"bin1"}          -> {"ok":true,"wire":"bin1"}
//!   {"cmd":"quantize", ...config fields,   -> {"ok":true,"result":{...}}
//!        "stream":bool?}                      ("stream":true interleaves
//!                                             {"event":...} progress
//!                                             frames before the result)
//!   {"cmd":"pack", ...config fields,       -> {"ok":true,"packed":{...}}
//!        "po2":bool?}                         (artifact cached under "key")
//!   {"cmd":"infer", "key":"...",           -> {"ok":true,"result":
//!        "x":[[...]] | "x":[...]+"shape",        {"logits":[[...]],
//!        or "users":[...],"items":[...]}          "predictions":[...],...}}
//!
//! This is the *blocking* server: connections are handled strictly
//! sequentially, which is the right semantics for minutes-long
//! quantization jobs and for tests that want a deterministic order.
//! The concurrent production face — worker pool, micro-batching,
//! admission control — lives in [`crate::serve`] and speaks the same
//! protocol through the same typed [`crate::proto::Request`] /
//! [`crate::proto::Response`] surface and the same connection loop
//! ([`crate::proto::wire::serve_conn`]), so the two paths cannot drift.
//!
//! Long calibrations are never silent: with `"stream":true` the quantize
//! handler forwards the calibrator's [`CalibEvent`]s as one JSON frame
//! per line (`{"event":"phase_start",...}`, throttled evals, phase ends,
//! degenerate warnings) on the same connection, then the final
//! `{"ok":...}` response.  Every error — malformed JSON, unknown `cmd`,
//! an oversized line, a failing job, even a panic inside a kernel —
//! comes back as `{"ok":false,...}` on the same connection; the line
//! loop and the listener keep serving.  Accept failures retry under the
//! shared exponential-backoff policy
//! ([`crate::serve::admission::Backoff`]).  `max_requests` bounds the
//! serve loop for tests.

use super::jobs::Runner;
use crate::lapq::events::{CalibEvent, CalibObserver, EvalThrottle};
use crate::proto::{wire, Request, Response};
use crate::serve::admission::Backoff;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};

/// Forwards calibration events to the connection as `{"event":...}`
/// frames.  Evals go through the shared [`EvalThrottle`] (improvements +
/// 1 in N); phase boundaries and degenerate warnings always ship.  A
/// broken pipe flips `dead` so the job finishes without further write
/// attempts (the final response write surfaces the disconnect).
pub(crate) struct StreamObserver<'a> {
    w: &'a mut dyn Write,
    throttle: EvalThrottle,
    dead: bool,
}

impl<'a> StreamObserver<'a> {
    pub(crate) fn new(w: &'a mut dyn Write) -> Self {
        StreamObserver { w, throttle: EvalThrottle::new(25), dead: false }
    }
}

impl CalibObserver for StreamObserver<'_> {
    fn on_event(&mut self, ev: &CalibEvent) {
        if self.dead || !self.throttle.admit(ev) {
            return;
        }
        let frame = ev.to_json().dump();
        let ok = self
            .w
            .write_all(frame.as_bytes())
            .and_then(|_| self.w.write_all(b"\n"))
            .and_then(|_| self.w.flush());
        if let Err(e) = ok {
            log::warn!("event stream write failed: {e}");
            self.dead = true;
        }
    }
}

pub struct Service {
    listener: TcpListener,
    pub addr: std::net::SocketAddr,
}

impl Service {
    /// Bind to `addr` (use port 0 for ephemeral).
    pub fn bind(addr: &str) -> Result<Service> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        log::info!("service listening on {addr}");
        Ok(Service { listener, addr })
    }

    /// Serve until `max_requests` requests have been handled
    /// (`usize::MAX` for forever).  Connections are handled sequentially:
    /// quantization jobs are minutes-long and own the engine.  A broken
    /// connection never takes the listener down.
    pub fn serve(&self, runner: &mut Runner, max_requests: usize) -> Result<()> {
        let mut handled = 0usize;
        let mut backoff = Backoff::accept_loop();
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    // Transient accept errors (ECONNABORTED, brief fd
                    // pressure) retry under jittered exponential
                    // backoff; a listener that keeps failing inside one
                    // budget window is surfaced instead of spinning.
                    match backoff.on_failure() {
                        Some(delay) => {
                            log::warn!(
                                "accept failed ({} in window): {e}; retrying in {delay:?}",
                                backoff.failures()
                            );
                            std::thread::sleep(delay);
                            continue;
                        }
                        None => return Err(e).context("accept failing persistently"),
                    }
                }
            };
            handled += wire::serve_conn(stream, max_requests - handled, |req, writer| {
                dispatch(runner, req, writer)
            });
            if handled >= max_requests {
                break;
            }
        }
        Ok(())
    }
}

/// Job and validation failures become structured `{"ok":false}` errors;
/// panics are already contained by the connection loop.
fn dispatch(runner: &mut Runner, req: Request, writer: &mut dyn Write) -> Response {
    match dispatch_inner(runner, req, writer) {
        Ok(resp) => resp,
        Err(e) => Response::error(format!("{e:#}")),
    }
}

fn dispatch_inner(
    runner: &mut Runner,
    req: Request,
    writer: &mut dyn Write,
) -> Result<Response> {
    Ok(match req {
        Request::Ping => Response::Pong,
        Request::Models => Response::models(&runner.eng, &runner.registry()),
        Request::Metrics => Response::metrics(),
        Request::Quantize { cfg, stream } => {
            let res = if stream {
                let mut obs = StreamObserver::new(writer);
                runner.run_observed(&cfg, &mut obs)?
            } else {
                runner.run(&cfg)?
            };
            Response::quantize(&cfg, &res)
        }
        Request::Pack { cfg, po2 } => {
            // Deliberately no write-to-disk option here: letting a
            // network client choose a server-side path would be a
            // remote file-write primitive.  Saving artifacts is the
            // CLI's job (`repro pack --out DIR`).
            let opts = crate::runtime::int::PackOpts { po2_scales: po2 };
            let (sum, _qm) = runner.pack(&cfg, &opts)?;
            Response::Pack { packed: sum }
        }
        Request::Infer(ir) => match runner.infer(&ir.key, &ir.inputs) {
            Ok(reply) => Response::Infer { reply },
            // Typed miss: the key was never packed and has no spill to
            // reload from, so clients don't string-match the error.
            Err(e) if crate::proto::is_model_not_packed(&e) => {
                Response::ModelNotPacked { key: ir.key }
            }
            Err(e) => return Err(e),
        },
        Request::Shutdown => {
            Response::error("shutdown is not supported on the blocking service")
        }
        // Negotiation is the connection loop's job; reaching here means
        // a caller bypassed it.
        Request::Hello { .. } => Response::error("hello outside the connection loop"),
        Request::Unknown { cmd } => Response::UnknownCmd { cmd },
    })
}

/// Minimal client for tests and scripting.
pub fn request(addr: &std::net::SocketAddr, body: &Json) -> Result<Json> {
    let mut client = wire::Client::connect(addr)?;
    client.call_raw(&body.dump())
}

/// Type-checked client call (the `proto`-native flavour of [`request`]).
pub fn request_typed(addr: &std::net::SocketAddr, req: &Request) -> Result<Json> {
    let mut client = wire::Client::connect(addr)?;
    client.call(req)
}
