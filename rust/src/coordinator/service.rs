//! TCP job service: JSON-lines protocol for submitting quantization and
//! serving jobs to a running coordinator (the "deployment" face of the
//! system).
//!
//! Protocol (one JSON object per line):
//!   {"cmd":"ping"}                         -> {"ok":true,"pong":true}
//!   {"cmd":"models"}                       -> {"ok":true,"models":[...]}
//!   {"cmd":"metrics"}                      -> {"ok":true,"metrics":{...}}
//!   {"cmd":"quantize", ...config fields,   -> {"ok":true,"result":{...}}
//!        "stream":bool?}                      ("stream":true interleaves
//!                                             {"event":...} progress
//!                                             frames before the result)
//!   {"cmd":"pack", ...config fields,       -> {"ok":true,"packed":{...}}
//!        "po2":bool?}                         (artifact cached under "key")
//!   {"cmd":"infer", "key":"...",           -> {"ok":true,"result":
//!        "x":[[...]] | "x":[...]+"shape",        {"logits":[[...]],
//!        or "users":[...],"items":[...]}          "predictions":[...],...}}
//!
//! This is the *blocking* server: connections are handled strictly
//! sequentially, which is the right semantics for minutes-long
//! quantization jobs and for tests that want a deterministic order.
//! The concurrent production face — worker pool, micro-batching,
//! admission control — lives in [`crate::serve`] and speaks the same
//! protocol through the response builders below, so the two paths
//! cannot drift.
//!
//! Long calibrations are never silent: with `"stream":true` the quantize
//! handler forwards the calibrator's [`CalibEvent`]s as one JSON frame
//! per line (`{"event":"phase_start",...}`, throttled evals, phase ends,
//! degenerate warnings) on the same connection, then the final
//! `{"ok":...}` response.  Every error — malformed JSON, unknown `cmd`,
//! a failing job, even a panic inside a kernel — comes back as
//! `{"ok":false,"error":...}` on the same connection; the line loop and
//! the listener keep serving.  Accept failures retry under the shared
//! exponential-backoff policy ([`crate::serve::admission::Backoff`]):
//! jittered doubling delays, with the failure budget resetting once the
//! window has elapsed (not merely on the next success).  `max_requests`
//! bounds the serve loop for tests.

use super::jobs::{InferReply, JobResult, PackSummary, Runner};
use super::metrics;
use crate::config::ExperimentConfig;
use crate::lapq::events::{CalibEvent, CalibObserver, EvalThrottle};
use crate::serve::admission::Backoff;
use crate::tensor::HostTensor;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// Forwards calibration events to the connection as `{"event":...}`
/// frames.  Evals go through the shared [`EvalThrottle`] (improvements +
/// 1 in N); phase boundaries and degenerate warnings always ship.  A
/// broken pipe flips `dead` so the job finishes without further write
/// attempts (the final response write surfaces the disconnect).
pub(crate) struct StreamObserver<'a> {
    w: &'a mut dyn Write,
    throttle: EvalThrottle,
    dead: bool,
}

impl<'a> StreamObserver<'a> {
    pub(crate) fn new(w: &'a mut dyn Write) -> Self {
        StreamObserver { w, throttle: EvalThrottle::new(25), dead: false }
    }
}

impl CalibObserver for StreamObserver<'_> {
    fn on_event(&mut self, ev: &CalibEvent) {
        if self.dead || !self.throttle.admit(ev) {
            return;
        }
        let frame = ev.to_json().dump();
        let ok = self
            .w
            .write_all(frame.as_bytes())
            .and_then(|_| self.w.write_all(b"\n"))
            .and_then(|_| self.w.flush());
        if let Err(e) = ok {
            log::warn!("event stream write failed: {e}");
            self.dead = true;
        }
    }
}

// ---------------------------------------------------------------------------
// Request/response wire format — the single source, shared by this
// blocking server and the concurrent pool (`serve::pool`) so the two
// paths cannot drift.

/// `"stream":true` on a quantize request.
pub(crate) fn stream_flag(req: &Json) -> bool {
    req.get("stream").and_then(|v| v.as_bool()).unwrap_or(false)
}

/// Pack options from a request (`"po2"` defaults to true).
pub(crate) fn pack_opts_from(req: &Json) -> crate::runtime::int::PackOpts {
    crate::runtime::int::PackOpts {
        po2_scales: req.get("po2").and_then(|v| v.as_bool()).unwrap_or(true),
    }
}

/// The infer lookup key: `"key"` (from pack) with `"model"` fallback.
pub(crate) fn infer_key(req: &Json) -> Result<&str> {
    req.get("key")
        .or_else(|| req.get("model"))
        .and_then(|v| v.as_str())
        .context("infer needs 'key' (from pack) or 'model'")
}

pub(crate) fn ping_response() -> Json {
    Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])
}

pub(crate) fn models_response(eng: &crate::runtime::EngineHandle) -> Json {
    let models: Vec<Json> =
        eng.manifest().models.keys().map(|k| Json::Str(k.clone())).collect();
    Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::Arr(models))])
}

pub(crate) fn metrics_response() -> Json {
    Json::obj(vec![("ok", Json::Bool(true)), ("metrics", metrics::dump())])
}

/// Structured failure (counts into `service_errors`).
pub(crate) fn error_json(msg: String) -> Json {
    metrics::inc("service_errors");
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg))])
}

pub(crate) fn quantize_response(cfg: &ExperimentConfig, res: &JobResult) -> Json {
    let bools = |v: &[bool]| Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect());
    let trace = Json::Arr(res.outcome.trace.iter().map(|t| t.to_json()).collect());
    let joint = match cfg.method {
        crate::config::Method::Lapq => cfg.lapq.joint.optimizer.name(),
        _ => "none",
    };
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "result",
            Json::obj(vec![
                ("model", Json::Str(res.model.clone())),
                ("bits", Json::Str(res.bits_label.clone())),
                ("method", Json::Str(res.method.clone())),
                ("joint", Json::Str(joint.into())),
                ("fp32_metric", Json::Num(res.fp32_metric as f64)),
                ("quant_metric", Json::Num(res.quant_metric as f64)),
                ("calib_loss", Json::Num(res.outcome.calib_loss)),
                ("init_loss", Json::Num(res.outcome.init_loss)),
                ("fp32_calib_loss", Json::Num(res.outcome.fp32_calib_loss)),
                ("joint_evals", Json::Num(res.outcome.joint_evals as f64)),
                ("active_w", bools(&res.outcome.mask.weights)),
                ("active_a", bools(&res.outcome.mask.acts)),
                ("trace", trace),
                // The exact config that produced this result —
                // lossless, so the run is reproducible from the
                // response alone.
                ("config", cfg.to_json()),
                ("seconds", Json::Num(res.seconds)),
            ]),
        ),
    ])
}

pub(crate) fn pack_response(sum: &PackSummary) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "packed",
            Json::obj(vec![
                ("key", Json::Str(sum.key.clone())),
                ("model", Json::Str(sum.model.clone())),
                ("bits", Json::Str(sum.bits_label.clone())),
                ("method", Json::Str(sum.method.clone())),
                ("int_params", Json::Num(sum.int_params as f64)),
                ("f32_bytes", Json::Num(sum.f32_bytes as f64)),
                ("packed_bytes", Json::Num(sum.packed_bytes as f64)),
                ("fp32_metric", Json::Num(sum.fp32_metric as f64)),
                ("quant_metric", Json::Num(sum.quant_metric as f64)),
                ("seconds", Json::Num(sum.seconds)),
            ]),
        ),
    ])
}

pub(crate) fn infer_response(reply: &InferReply) -> Json {
    let c = reply.logits.last_dim().max(1);
    let mut logits_rows = Vec::new();
    let mut predictions = Vec::new();
    for row in reply.logits.data.chunks(c) {
        logits_rows.push(Json::arr_f32(row));
        if c > 1 {
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            predictions.push(Json::Num(best as f64));
        } else {
            let hit = row.first().is_some_and(|&v| v > 0.0);
            predictions.push(Json::Num(if hit { 1.0 } else { 0.0 }));
        }
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "result",
            Json::obj(vec![
                ("key", Json::Str(reply.key.clone())),
                ("rows", Json::Num(reply.rows as f64)),
                ("int_layers", Json::Num(reply.int_layers as f64)),
                ("seconds", Json::Num(reply.seconds)),
                ("logits", Json::Arr(logits_rows)),
                ("predictions", Json::Arr(predictions)),
            ]),
        ),
    ])
}

pub struct Service {
    listener: TcpListener,
    pub addr: std::net::SocketAddr,
}

impl Service {
    /// Bind to `addr` (use port 0 for ephemeral).
    pub fn bind(addr: &str) -> Result<Service> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        log::info!("service listening on {addr}");
        Ok(Service { listener, addr })
    }

    /// Serve until `max_requests` requests have been handled
    /// (`usize::MAX` for forever).  Connections are handled sequentially:
    /// quantization jobs are minutes-long and own the engine.  A broken
    /// connection never takes the listener down.
    pub fn serve(&self, runner: &mut Runner, max_requests: usize) -> Result<()> {
        let mut handled = 0usize;
        let mut backoff = Backoff::accept_loop();
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    // Transient accept errors (ECONNABORTED, brief fd
                    // pressure) retry under jittered exponential
                    // backoff; a listener that keeps failing inside one
                    // budget window is surfaced instead of spinning.
                    match backoff.on_failure() {
                        Some(delay) => {
                            log::warn!(
                                "accept failed ({} in window): {e}; retrying in {delay:?}",
                                backoff.failures()
                            );
                            std::thread::sleep(delay);
                            continue;
                        }
                        None => return Err(e).context("accept failing persistently"),
                    }
                }
            };
            handled += self.handle_conn(stream, runner, max_requests - handled);
            if handled >= max_requests {
                break;
            }
        }
        Ok(())
    }

    /// Serve one connection; returns how many requests it consumed.
    /// I/O errors end the connection (logged), not the service.
    fn handle_conn(&self, stream: TcpStream, runner: &mut Runner, budget: usize) -> usize {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".to_string());
        log::info!("conn from {peer}");
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(e) => {
                log::warn!("conn {peer}: clone failed: {e}");
                return 0;
            }
        };
        let reader = BufReader::new(stream);
        let mut handled = 0usize;
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            metrics::inc("service_requests");
            let resp = self.dispatch(&line, runner, &mut writer);
            let ok = writer
                .write_all(resp.dump().as_bytes())
                .and_then(|_| writer.write_all(b"\n"))
                .and_then(|_| writer.flush());
            if let Err(e) = ok {
                log::warn!("conn {peer}: write failed: {e}");
                break;
            }
            handled += 1;
            if handled >= budget {
                break;
            }
        }
        handled
    }

    /// Every failure mode becomes a structured `{"ok":false}` response:
    /// parse/config errors, job errors, and panics unwinding out of a
    /// kernel (the CPU backend recovers its mutex from poisoning, so the
    /// runner stays usable afterwards).
    fn dispatch(&self, line: &str, runner: &mut Runner, writer: &mut dyn Write) -> Json {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.dispatch_inner(line, runner, writer)
        }));
        match caught {
            Ok(Ok(j)) => j,
            Ok(Err(e)) => error_json(format!("{e:#}")),
            Err(payload) => {
                error_json(format!("internal panic: {}", panic_text(payload.as_ref())))
            }
        }
    }

    fn dispatch_inner(
        &self,
        line: &str,
        runner: &mut Runner,
        writer: &mut dyn Write,
    ) -> Result<Json> {
        let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        let cmd = req.get("cmd").and_then(|c| c.as_str()).unwrap_or("");
        match cmd {
            "ping" => Ok(ping_response()),
            "models" => Ok(models_response(&runner.eng)),
            "metrics" => Ok(metrics_response()),
            "quantize" => {
                let cfg = ExperimentConfig::from_json(&req)?;
                let res = if stream_flag(&req) {
                    let mut obs = StreamObserver::new(writer);
                    runner.run_observed(&cfg, &mut obs)?
                } else {
                    runner.run(&cfg)?
                };
                Ok(quantize_response(&cfg, &res))
            }
            "pack" => {
                let cfg = ExperimentConfig::from_json(&req)?;
                // Deliberately no write-to-disk option here: letting a
                // network client choose a server-side path would be a
                // remote file-write primitive.  Saving artifacts is the
                // CLI's job (`repro pack --out DIR`).
                let (sum, _qm) = runner.pack(&cfg, &pack_opts_from(&req))?;
                Ok(pack_response(&sum))
            }
            "infer" => {
                let key = infer_key(&req)?;
                let inputs = parse_infer_inputs(&req)?;
                let reply = runner.infer(key, &inputs)?;
                Ok(infer_response(&reply))
            }
            other => anyhow::bail!("unknown cmd '{other}'"),
        }
    }
}

pub(crate) fn panic_text(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Decode the wire form of an infer batch: `users`+`items` i32 arrays
/// (NCF), nested `x` rows (feature models), or flat `x` + `shape`
/// (images).
pub(crate) fn parse_infer_inputs(req: &Json) -> Result<Vec<HostTensor>> {
    if let (Some(u), Some(it)) = (req.get("users"), req.get("items")) {
        let to_i32 = |j: &Json, what: &str| -> Result<Vec<i32>> {
            let arr = j.as_arr().with_context(|| format!("'{what}' must be an array"))?;
            let out: Vec<i32> = arr.iter().filter_map(|v| v.as_f64()).map(|v| v as i32).collect();
            if out.len() != arr.len() {
                anyhow::bail!("non-numeric entries in '{what}'");
            }
            Ok(out)
        };
        let users = to_i32(u, "users")?;
        let items = to_i32(it, "items")?;
        let ut = HostTensor::i32(vec![users.len()], users);
        let it = HostTensor::i32(vec![items.len()], items);
        return Ok(vec![ut, it]);
    }
    let x = req.get("x").context("infer needs 'x' (vision) or 'users'+'items' (ncf)")?;
    let rows = x.as_arr().context("'x' must be an array")?;
    if rows.is_empty() {
        anyhow::bail!("'x' is empty");
    }
    if rows[0].as_arr().is_some() {
        let cols = rows[0].as_arr().unwrap_or(&[]).len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            let rr = r.as_arr().context("'x' rows must all be arrays")?;
            if rr.len() != cols {
                anyhow::bail!("ragged 'x' rows ({} vs {cols})", rr.len());
            }
            data.extend(rr.iter().filter_map(|v| v.as_f64()).map(|v| v as f32));
        }
        if data.len() != rows.len() * cols {
            anyhow::bail!("non-numeric entries in 'x'");
        }
        return Ok(vec![HostTensor::f32(vec![rows.len(), cols], data)]);
    }
    let data: Vec<f32> = rows.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect();
    if data.len() != rows.len() {
        anyhow::bail!("non-numeric entries in 'x'");
    }
    let shape = req.get("shape").context("flat 'x' needs a 'shape' array")?.usize_arr();
    if shape.iter().product::<usize>() != data.len() {
        anyhow::bail!("shape {shape:?} does not cover {} values", data.len());
    }
    Ok(vec![HostTensor::f32(shape, data)])
}

/// Minimal client for tests and scripting.
pub fn request(addr: &std::net::SocketAddr, body: &Json) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(body.dump().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}
