//! FP32 training loop: produces the pretrained models that post-training
//! quantization starts from (the paper downloads torchvision checkpoints;
//! we train our stand-ins from scratch through the AOT `train_step`
//! artifact — Python never runs).

use super::workload::Workload;
use crate::runtime::{EngineHandle, SessionId};
use crate::tensor::init::init_params;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub base_lr: f32,
    /// Linear warmup steps, then cosine decay to `base_lr * min_lr_frac`.
    pub warmup: usize,
    pub min_lr_frac: f32,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { steps: 300, base_lr: 0.05, warmup: 20, min_lr_frac: 0.05, log_every: 50 }
    }
}

/// Loss curve + timing of one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub seconds: f64,
    pub steps: usize,
}

/// Cosine schedule with warmup.
pub fn lr_at(cfg: &TrainCfg, step: usize) -> f32 {
    if step < cfg.warmup {
        return cfg.base_lr * (step + 1) as f32 / cfg.warmup as f32;
    }
    let t = (step - cfg.warmup) as f32 / (cfg.steps.saturating_sub(cfg.warmup)).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    cfg.base_lr * (cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos)
}

/// Create a fresh session for `model`, train it, return (session, report).
pub fn train_full(
    eng: &EngineHandle,
    model: &str,
    workload: &Workload,
    seed: u64,
    cfg: &TrainCfg,
) -> Result<(SessionId, TrainReport)> {
    let spec = eng.manifest().model(model)?.clone();
    let sess = eng.create_session(model, init_params(&spec.params, seed))?;
    let t0 = std::time::Instant::now();
    let mut losses = Vec::new();
    let mut final_loss = f32::NAN;
    for step in 0..cfg.steps {
        let batch = workload.train_batch(&spec, step as u64);
        let bid = eng.register_batch(batch)?;
        let lr = lr_at(cfg, step);
        let loss = eng.train_step(sess, bid, lr)?;
        eng.drop_batch(bid)?;
        final_loss = loss;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log::info!("train {model} step {step:>5} lr {lr:.4} loss {loss:.4}");
            losses.push((step, loss));
        }
    }
    Ok((
        sess,
        TrainReport { losses, final_loss, seconds: t0.elapsed().as_secs_f64(), steps: cfg.steps },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainCfg { steps: 100, base_lr: 1.0, warmup: 10, min_lr_frac: 0.1, log_every: 10 };
        assert!(lr_at(&cfg, 0) < 0.2); // warmup start
        assert!((lr_at(&cfg, 9) - 1.0).abs() < 1e-6); // warmup end
        assert!(lr_at(&cfg, 50) < 1.0);
        let end = lr_at(&cfg, 99);
        assert!(end >= 0.1 - 1e-6 && end < 0.15, "{end}");
    }

    #[test]
    fn lr_monotone_after_warmup() {
        let cfg = TrainCfg::default();
        let mut prev = f32::INFINITY;
        for s in cfg.warmup..cfg.steps {
            let lr = lr_at(&cfg, s);
            assert!(lr <= prev + 1e-6);
            prev = lr;
        }
    }
}
