//! Global metrics registry: counters and timers every subsystem can bump,
//! dumped as JSON for EXPERIMENTS.md and the job service.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

static REGISTRY: Mutex<Option<BTreeMap<String, f64>>> = Mutex::new(None);

fn with<R>(f: impl FnOnce(&mut BTreeMap<String, f64>) -> R) -> R {
    let mut guard = REGISTRY.lock().unwrap();
    f(guard.get_or_insert_with(BTreeMap::new))
}

/// Add `v` to counter `name`.
pub fn add(name: &str, v: f64) {
    with(|m| *m.entry(name.to_string()).or_insert(0.0) += v);
}

/// Increment counter by one.
pub fn inc(name: &str) {
    add(name, 1.0);
}

/// Set a gauge.
pub fn set(name: &str, v: f64) {
    with(|m| {
        m.insert(name.to_string(), v);
    });
}

/// Read a metric (0 if absent).
pub fn get(name: &str) -> f64 {
    with(|m| m.get(name).copied().unwrap_or(0.0))
}

/// Record one latency observation for a serving path: accumulates
/// `<name>_seconds` / `<name>_calls` / `<name>_items` and refreshes the
/// `<name>_last_ms` gauge, so `dump()` exposes mean latency and
/// throughput (`items / seconds`) without a histogram.
pub fn observe(name: &str, seconds: f64, items: usize) {
    with(|m| {
        *m.entry(format!("{name}_seconds")).or_insert(0.0) += seconds;
        *m.entry(format!("{name}_calls")).or_insert(0.0) += 1.0;
        *m.entry(format!("{name}_items")).or_insert(0.0) += items as f64;
        m.insert(format!("{name}_last_ms"), seconds * 1e3);
    });
}

/// Time a closure into `<name>_seconds` (accumulating) and count calls.
pub fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    add(&format!("{name}_seconds"), t0.elapsed().as_secs_f64());
    inc(&format!("{name}_calls"));
    out
}

/// Snapshot as JSON.
pub fn dump() -> Json {
    with(|m| Json::Obj(m.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect()))
}

/// Clear everything (tests).
pub fn reset() {
    with(|m| m.clear());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        reset();
        inc("jobs");
        inc("jobs");
        add("loss", 1.5);
        set("gauge", 7.0);
        assert_eq!(get("jobs"), 2.0);
        assert_eq!(get("loss"), 1.5);
        assert_eq!(get("gauge"), 7.0);
        let j = dump();
        assert_eq!(j.req("jobs").as_f64(), Some(2.0));
        reset();
        assert_eq!(get("jobs"), 0.0);
    }

    #[test]
    fn timed_records() {
        reset();
        let v = timed("op", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(get("op_calls"), 1.0);
        assert!(get("op_seconds") >= 0.0);
        // observe(): latency + throughput counters for the serving paths
        observe("obs_test", 0.5, 128);
        observe("obs_test", 0.25, 64);
        assert_eq!(get("obs_test_calls"), 2.0);
        assert_eq!(get("obs_test_items"), 192.0);
        assert_eq!(get("obs_test_seconds"), 0.75);
        assert_eq!(get("obs_test_last_ms"), 250.0);
    }
}
