//! Global metrics registry: counters, gauges and bounded latency
//! histograms every subsystem can bump, dumped as JSON for
//! EXPERIMENTS.md and the job service.
//!
//! Counters/gauges are plain `name -> f64` entries.  Histograms are
//! bounded rings of the last [`HIST_CAP`] observations; `dump()` folds
//! each one into `<name>_p50` / `<name>_p95` / `<name>_p99` /
//! `<name>_count` entries, so tail latency is visible over the
//! `{"cmd":"metrics"}` endpoint without unbounded memory.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Ring capacity of every histogram (last N observations).
pub const HIST_CAP: usize = 4096;

/// Bounded reservoir of the most recent observations.
struct Ring {
    buf: Vec<f32>,
    next: usize,
    total: u64,
}

impl Ring {
    fn new() -> Ring {
        Ring { buf: Vec::new(), next: 0, total: 0 }
    }

    fn push(&mut self, v: f32) {
        // Non-finite observations would make any percentile meaningless;
        // drop them here so the reservoir only ever holds sortable values.
        if !v.is_finite() {
            return;
        }
        if self.buf.len() < HIST_CAP {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % HIST_CAP;
        self.total += 1;
    }
}

struct Store {
    counters: BTreeMap<String, f64>,
    hists: BTreeMap<String, Ring>,
}

static REGISTRY: Mutex<Option<Store>> = Mutex::new(None);

fn with<R>(f: impl FnOnce(&mut Store) -> R) -> R {
    // Recover from poisoning: a panic elsewhere must not take down every
    // subsequent metrics call process-wide.
    let mut guard = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
    f(guard.get_or_insert_with(|| Store { counters: BTreeMap::new(), hists: BTreeMap::new() }))
}

/// Add `v` to counter `name`.  Non-finite `v` is dropped: `+=` would
/// turn the counter NaN *permanently* (NaN + x == NaN), wrecking every
/// future dump for one bad sample.
pub fn add(name: &str, v: f64) {
    if !v.is_finite() {
        return;
    }
    with(|m| *m.counters.entry(name.to_string()).or_insert(0.0) += v);
}

/// Increment counter by one.
pub fn inc(name: &str) {
    add(name, 1.0);
}

/// Set a gauge.
pub fn set(name: &str, v: f64) {
    with(|m| {
        m.counters.insert(name.to_string(), v);
    });
}

/// Read a metric (0 if absent).
pub fn get(name: &str) -> f64 {
    with(|m| m.counters.get(name).copied().unwrap_or(0.0))
}

/// Record one observation into the bounded histogram `name`.
pub fn record_hist(name: &str, v: f64) {
    with(|m| m.hists.entry(name.to_string()).or_insert_with(Ring::new).push(v as f32));
}

/// One sorted copy serves all three percentile ranks (nearest-rank,
/// matching `stats::percentile`) — a metrics dump must not hold the
/// global mutex for three sorts per histogram.
fn p50_p95_p99(buf: &[f32]) -> (f64, f64, f64) {
    let mut v = buf.to_vec();
    v.sort_by(f32::total_cmp);
    let at = |p: f32| {
        let rank = ((p / 100.0) * (v.len() - 1) as f32).round() as usize;
        v[rank.min(v.len() - 1)] as f64
    };
    (at(50.0), at(95.0), at(99.0))
}

/// (p50, p95, p99) over the histogram's current window, if it has any
/// observations.
pub fn hist_percentiles(name: &str) -> Option<(f64, f64, f64)> {
    with(|m| {
        let r = m.hists.get(name)?;
        if r.buf.is_empty() {
            return None;
        }
        Some(p50_p95_p99(&r.buf))
    })
}

/// Record one latency observation for a serving path: accumulates
/// `<name>_seconds` / `<name>_calls` / `<name>_items`, refreshes the
/// `<name>_last_ms` gauge, and feeds the `<name>_ms` histogram — so
/// `dump()` exposes mean latency, throughput (`items / seconds`) *and*
/// p50/p95/p99 tails.
pub fn observe(name: &str, seconds: f64, items: usize) {
    // A single non-finite duration would poison the accumulating
    // `_seconds` counter for the process lifetime; drop the whole
    // observation instead of recording inconsistent pieces of it.
    if !seconds.is_finite() {
        return;
    }
    with(|m| {
        *m.counters.entry(format!("{name}_seconds")).or_insert(0.0) += seconds;
        *m.counters.entry(format!("{name}_calls")).or_insert(0.0) += 1.0;
        *m.counters.entry(format!("{name}_items")).or_insert(0.0) += items as f64;
        m.counters.insert(format!("{name}_last_ms"), seconds * 1e3);
        m.hists.entry(format!("{name}_ms")).or_insert_with(Ring::new).push((seconds * 1e3) as f32);
    });
}

/// Time a closure into `<name>_seconds` (accumulating) and count calls.
pub fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    add(&format!("{name}_seconds"), t0.elapsed().as_secs_f64());
    inc(&format!("{name}_calls"));
    out
}

/// Snapshot as JSON: every counter/gauge, plus percentile + count
/// entries for every histogram.
pub fn dump() -> Json {
    with(|m| {
        let mut out: BTreeMap<String, Json> =
            m.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        for (name, r) in &m.hists {
            if r.buf.is_empty() {
                continue;
            }
            let (p50, p95, p99) = p50_p95_p99(&r.buf);
            out.insert(format!("{name}_p50"), Json::Num(p50));
            out.insert(format!("{name}_p95"), Json::Num(p95));
            out.insert(format!("{name}_p99"), Json::Num(p99));
            out.insert(format!("{name}_count"), Json::Num(r.total as f64));
        }
        Json::Obj(out)
    })
}

/// Clear everything (tests).
pub fn reset() {
    with(|m| {
        m.counters.clear();
        m.hists.clear();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global and `cargo test` runs tests
    /// concurrently: every test in this module takes this lock so one
    /// test's `reset()` cannot wipe another's in-flight state.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn counters_and_gauges() {
        let _g = serial();
        reset();
        inc("jobs");
        inc("jobs");
        add("loss", 1.5);
        set("gauge", 7.0);
        assert_eq!(get("jobs"), 2.0);
        assert_eq!(get("loss"), 1.5);
        assert_eq!(get("gauge"), 7.0);
        let j = dump();
        assert_eq!(j.req("jobs").as_f64(), Some(2.0));
        reset();
        assert_eq!(get("jobs"), 0.0);
    }

    #[test]
    fn timed_records() {
        let _g = serial();
        reset();
        let v = timed("op", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(get("op_calls"), 1.0);
        assert!(get("op_seconds") >= 0.0);
        // observe(): latency + throughput counters for the serving paths
        observe("obs_test", 0.5, 128);
        observe("obs_test", 0.25, 64);
        assert_eq!(get("obs_test_calls"), 2.0);
        assert_eq!(get("obs_test_items"), 192.0);
        assert_eq!(get("obs_test_seconds"), 0.75);
        assert_eq!(get("obs_test_last_ms"), 250.0);
    }

    #[test]
    fn histogram_percentiles() {
        let _g = serial();
        for i in 1..=100 {
            record_hist("lat", i as f64);
        }
        let (p50, p95, p99) = hist_percentiles("lat").unwrap();
        assert!((45.0..=55.0).contains(&p50), "p50 {p50}");
        assert!((90.0..=100.0).contains(&p95), "p95 {p95}");
        assert!(p99 >= p95, "p99 {p99} < p95 {p95}");
        let j = dump();
        assert!(j.req("lat_p50").as_f64().is_some());
        assert!(j.req("lat_p95").as_f64().is_some());
        assert!(j.req("lat_p99").as_f64().is_some());
        assert_eq!(j.req("lat_count").as_f64(), Some(100.0));
        assert!(hist_percentiles("absent").is_none());
    }

    #[test]
    fn histogram_ring_is_bounded() {
        let _g = serial();
        // 2x the capacity: the window must hold only the most recent CAP
        // samples, and the total must keep counting.
        for i in 0..(2 * HIST_CAP) {
            record_hist("ring", i as f64);
        }
        let (p50, _, _) = hist_percentiles("ring").unwrap();
        // Window is [CAP, 2*CAP): the median must sit inside it.
        assert!(p50 >= HIST_CAP as f64, "p50 {p50} predates the window");
        let j = dump();
        assert_eq!(j.req("ring_count").as_f64(), Some(2.0 * HIST_CAP as f64));
    }

    #[test]
    fn non_finite_observations_are_dropped_not_panicking() {
        let _g = serial();
        record_hist("nan_path", f64::NAN);
        record_hist("nan_path", f64::INFINITY);
        assert!(hist_percentiles("nan_path").is_none(), "only non-finite: empty window");
        record_hist("nan_path", 5.0);
        record_hist("nan_path", f64::NAN);
        let (p50, _, p99) = hist_percentiles("nan_path").unwrap();
        assert_eq!((p50, p99), (5.0, 5.0), "percentiles see only the finite sample");
        // dump() must not panic (and must not poison the registry) either
        let j = dump();
        assert_eq!(j.req("nan_path_count").as_f64(), Some(1.0), "dropped samples not counted");
        // the accumulating counters are guarded at the recording
        // boundary too: one NaN must not make them NaN forever
        add("nan_ctr", 1.0);
        add("nan_ctr", f64::NAN);
        assert_eq!(get("nan_ctr"), 1.0, "NaN add dropped, counter intact");
        observe("nan_obs", f64::NAN, 4);
        observe("nan_obs", 0.5, 4);
        assert_eq!(get("nan_obs_calls"), 1.0, "NaN observation dropped whole");
        assert_eq!(get("nan_obs_seconds"), 0.5);
    }

    #[test]
    fn observe_feeds_histogram() {
        let _g = serial();
        observe("hist_path", 0.010, 1);
        observe("hist_path", 0.020, 1);
        let (p50, _, p99) = hist_percentiles("hist_path_ms").unwrap();
        assert!(p50 >= 10.0 && p99 <= 20.0 + 1e-6, "p50 {p50} p99 {p99}");
    }
}
