//! The pure-Rust CPU reference backend: executes the builtin model zoo
//! natively (no Python, no PJRT, no external crates).
//!
//! State (sessions, registered batches, counters) lives behind one mutex;
//! the coordinator drives the engine sequentially, and heavy kernels
//! parallelize internally across the batch dimension (`ops::par_items`),
//! so a single in-flight execution already uses the machine — the same
//! concurrency contract the PJRT engine documents.

pub mod ops;
pub mod zoo;

use super::backend::{Backend, BatchId, EngineStats, QuantParams, SessionId};
use super::manifest::Manifest;
use crate::tensor::HostTensor;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

struct CpuSession {
    model: String,
    params: Vec<HostTensor>,
    momentum: Vec<Vec<f32>>,
}

#[derive(Default)]
struct State {
    sessions: HashMap<SessionId, CpuSession>,
    batches: HashMap<BatchId, Vec<HostTensor>>,
    next_id: u64,
    stats: EngineStats,
    /// Distinct (model, entry) graphs executed — the CPU analogue of the
    /// PJRT executable cache, reported as `stats.compiled`.
    instantiated: HashSet<(String, &'static str)>,
}

/// Dependency-free CPU execution backend over the builtin model zoo.
pub struct CpuBackend {
    manifest: Manifest,
    state: Mutex<State>,
}

impl CpuBackend {
    pub fn new(manifest: Manifest) -> CpuBackend {
        let state = State { next_id: 1, ..Default::default() };
        CpuBackend { manifest, state: Mutex::new(state) }
    }

    /// Lock the state, recovering from poisoning: a panic inside one
    /// execution (e.g. a shape assert on a malformed batch) must not
    /// brick every other session sharing the handle — sessions/batches
    /// are plain data and stay consistent across such panics except for
    /// the one being mutated.
    fn state(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn validate_params(&self, model: &str, params: &[HostTensor]) -> Result<()> {
        let spec = self.manifest.model(model)?;
        if params.len() != spec.params.len() {
            bail!("expected {} params, got {}", spec.params.len(), params.len());
        }
        for (ts, ps) in params.iter().zip(&spec.params) {
            if ts.shape != ps.shape {
                bail!("param {} shape {:?} != spec {:?}", ps.name, ts.shape, ps.shape);
            }
        }
        Ok(())
    }
}

impl State {
    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn note_exec(&mut self, model: &str, entry: &'static str, seconds: f64) {
        self.stats.executions += 1;
        self.stats.exec_seconds += seconds;
        if self.instantiated.insert((model.to_string(), entry)) {
            self.stats.compiled += 1;
        }
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn create_session(&self, model: &str, params: Vec<HostTensor>) -> Result<SessionId> {
        self.validate_params(model, &params)
            .map_err(|e| e.context(format!("create_session {model}")))?;
        let momentum = params.iter().map(|ts| vec![0.0f32; ts.len()]).collect();
        let mut st = self.state();
        let id = st.fresh_id();
        st.sessions.insert(id, CpuSession { model: model.to_string(), params, momentum });
        Ok(id)
    }

    fn drop_session(&self, sess: SessionId) -> Result<()> {
        self.state().sessions.remove(&sess);
        Ok(())
    }

    fn get_params(&self, sess: SessionId) -> Result<Vec<HostTensor>> {
        let st = self.state();
        Ok(st.sessions.get(&sess).context("unknown session")?.params.clone())
    }

    fn set_params(&self, sess: SessionId, params: Vec<HostTensor>) -> Result<()> {
        let mut st = self.state();
        let s = st.sessions.get_mut(&sess).context("unknown session")?;
        self.validate_params(&s.model.clone(), &params).map_err(|e| e.context("set_params"))?;
        s.params = params;
        Ok(())
    }

    fn register_batch(&self, batch: Vec<HostTensor>) -> Result<BatchId> {
        let mut st = self.state();
        let id = st.fresh_id();
        st.batches.insert(id, batch);
        Ok(id)
    }

    fn drop_batch(&self, batch: BatchId) -> Result<()> {
        self.state().batches.remove(&batch);
        Ok(())
    }

    fn train_step(&self, sess: SessionId, batch: BatchId, lr: f32) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let mut guard = self.state();
        let st = &mut *guard;
        let s = st.sessions.get_mut(&sess).context("unknown session")?;
        let b = st.batches.get(&batch).context("unknown batch")?;
        let spec = self.manifest.model(&s.model)?;
        let loss = zoo::train_step(spec, &mut s.params, &mut s.momentum, b, lr)?;
        let model = s.model.clone();
        st.note_exec(&model, "train_step", t0.elapsed().as_secs_f64());
        Ok(loss)
    }

    fn eval(
        &self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<(f32, f32)> {
        let t0 = std::time::Instant::now();
        let mut guard = self.state();
        let st = &mut *guard;
        let s = st.sessions.get(&sess).context("unknown session")?;
        let b = st.batches.get(&batch).context("unknown batch")?;
        let spec = self.manifest.model(&s.model)?;
        let out = zoo::eval(spec, &s.params, quant.as_ref(), b)?;
        let model = s.model.clone();
        let entry = if quant.is_some() { "fwd_quant" } else { "fwd_fp32" };
        st.note_exec(&model, entry, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    fn hitrate(&self, sess: SessionId, quant: Option<QuantParams>, batch: BatchId) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let mut guard = self.state();
        let st = &mut *guard;
        let s = st.sessions.get(&sess).context("unknown session")?;
        let b = st.batches.get(&batch).context("unknown batch")?;
        let spec = self.manifest.model(&s.model)?;
        let hits = zoo::hitrate(spec, &s.params, quant.as_ref(), b)?;
        let model = s.model.clone();
        let entry = if quant.is_some() { "hitrate_quant" } else { "hitrate" };
        st.note_exec(&model, entry, t0.elapsed().as_secs_f64());
        Ok(hits)
    }

    fn acts(&self, sess: SessionId, batch: BatchId) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let mut guard = self.state();
        let st = &mut *guard;
        let s = st.sessions.get(&sess).context("unknown session")?;
        let b = st.batches.get(&batch).context("unknown batch")?;
        let spec = self.manifest.model(&s.model)?;
        let out = zoo::acts(spec, &s.params, b)?;
        let model = s.model.clone();
        st.note_exec(&model, "acts", t0.elapsed().as_secs_f64());
        Ok(out)
    }

    fn stats(&self) -> Result<EngineStats> {
        let st = self.state();
        let mut stats = st.stats.clone();
        stats.sessions = st.sessions.len() as u64;
        stats.batches = st.batches.len() as u64;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init::init_params;

    fn backend() -> CpuBackend {
        CpuBackend::new(Manifest::builtin())
    }

    #[test]
    fn session_lifecycle_and_errors() {
        let be = backend();
        let spec = be.manifest().model("mlp3").unwrap().clone();
        let params = init_params(&spec.params, 1);
        let sess = be.create_session("mlp3", params.clone()).unwrap();
        assert_eq!(be.get_params(sess).unwrap().len(), params.len());
        assert!(be.create_session("nope", vec![]).is_err());
        assert!(be.create_session("mlp3", vec![]).is_err());
        assert!(be.get_params(999).is_err());
        assert!(be.train_step(999, 999, 0.1).is_err());
        be.drop_session(sess).unwrap();
        assert!(be.get_params(sess).is_err());
    }

    #[test]
    fn stats_track_compiled_entries() {
        let be = backend();
        let spec = be.manifest().model("mlp3").unwrap().clone();
        let sess = be.create_session("mlp3", init_params(&spec.params, 2)).unwrap();
        let data = crate::data::vision::SynthVision::new(1);
        let (x, y) = data.batch_features(0, 32, 64);
        let bid = be.register_batch(vec![x, y]).unwrap();
        be.eval(sess, None, bid).unwrap();
        be.eval(sess, None, bid).unwrap();
        be.train_step(sess, bid, 0.05).unwrap();
        let stats = be.stats().unwrap();
        assert_eq!(stats.executions, 3);
        assert_eq!(stats.compiled, 2); // fwd_fp32 + train_step
        assert_eq!(stats.sessions, 1);
        assert_eq!(stats.batches, 1);
        assert!(stats.exec_seconds >= 0.0);
    }
}
