//! Dense tensor math + a minimal reverse-mode tape for the CPU backend.
//!
//! Everything is f32, row-major, shape-carrying ([`Arr`]).  The op set is
//! exactly what the model zoo needs: matmul, bias broadcast, ReLU,
//! SAME-padded strided/grouped conv (NHWC / HWIO), global average pool,
//! residual add, elementwise mul, last-axis concat, embedding gather,
//! fake-quant (mirroring `quant::quantizer`), softmax cross-entropy and
//! BCE-with-logits.
//!
//! [`Tape`] records the forward graph; [`Tape::backward`] walks it in
//! reverse accumulating gradients — only `train_step` differentiates, so
//! fake-quant (eval-only) uses a straight-through backward.  Inner loops
//! are written scalar-times-contiguous-row so LLVM auto-vectorizes them;
//! batch-parallel sections use scoped threads (no external thread pool).

use crate::quant::quantizer::fake_quant_one;
use crate::quant::GridKind;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Arr {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Arr {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Arr {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Arr { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Arr {
        let n = shape.iter().product();
        Arr { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Arr {
        Arr { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Scalar value of a 1-element tensor.
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Size of the last axis (1 for scalars).
    pub fn last_dim(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }
}

/// Worker-thread budget for batch-parallel sections.
pub fn n_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Run `f(item_index, item_slice)` over consecutive `item`-sized chunks of
/// `data`, splitting the items across scoped threads.  Generic over the
/// element type so the integer kernels (`runtime/int/kernels.rs`) share
/// the same scheduling.
pub(crate) fn par_items<T, F>(data: &mut [T], item: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(item > 0 && data.len() % item == 0);
    let n = data.len() / item;
    let threads = n_threads().min(n.max(1));
    if threads <= 1 {
        for (i, c) in data.chunks_mut(item).enumerate() {
            f(i, c);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, block) in data.chunks_mut(per * item).enumerate() {
            let fr = &f;
            s.spawn(move || {
                for (j, c) in block.chunks_mut(item).enumerate() {
                    fr(t * per + j, c);
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// Matmul kernels
// ---------------------------------------------------------------------------

fn mm_row(a_row: &[f32], b: &[f32], n: usize, out_row: &mut [f32]) {
    for (kk, &av) in a_row.iter().enumerate() {
        if av != 0.0 {
            let b_row = &b[kk * n..kk * n + n];
            for (o, &bv) in out_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// `(M,K) @ (K,N)` — parallel over rows when the work is substantial.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    if m * k * n >= (1 << 21) && n_threads() > 1 {
        par_items(&mut out, n, |row, o| mm_row(&a[row * k..(row + 1) * k], b, n, o));
    } else {
        for (row, o) in out.chunks_mut(n).enumerate() {
            mm_row(&a[row * k..(row + 1) * k], b, n, o);
        }
    }
    out
}

/// `(M,N) @ (K,N)^T -> (M,K)` (gradient w.r.t. the left matmul operand).
fn mat_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for mi in 0..m {
        let a_row = &a[mi * n..(mi + 1) * n];
        let o_row = &mut out[mi * k..(mi + 1) * k];
        for (kk, o) in o_row.iter_mut().enumerate() {
            let b_row = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (x, y) in a_row.iter().zip(b_row) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    out
}

/// `(M,K)^T @ (M,N) -> (K,N)` (gradient w.r.t. the right matmul operand).
fn mat_tn(a: &[f32], g: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for mi in 0..m {
        let g_row = &g[mi * n..(mi + 1) * n];
        for kk in 0..k {
            let av = a[mi * k + kk];
            if av != 0.0 {
                let o_row = &mut out[kk * n..(kk + 1) * n];
                for (o, &gv) in o_row.iter_mut().zip(g_row) {
                    *o += av * gv;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Convolution (NHWC x HWIO, SAME padding, stride, feature groups)
// ---------------------------------------------------------------------------

struct ConvDims {
    n: usize,
    h: usize,
    w: usize,
    ci: usize,
    kh: usize,
    kw: usize,
    cpg: usize,
    co: usize,
    stride: usize,
    groups: usize,
    ho: usize,
    wo: usize,
    pad_t: usize,
    pad_l: usize,
}

fn conv_dims(xs: &[usize], ws: &[usize], stride: usize, groups: usize) -> ConvDims {
    assert_eq!(xs.len(), 4, "conv input must be NHWC, got {xs:?}");
    assert_eq!(ws.len(), 4, "conv weight must be HWIO, got {ws:?}");
    let (n, h, w, ci) = (xs[0], xs[1], xs[2], xs[3]);
    let (kh, kw, cpg, co) = (ws[0], ws[1], ws[2], ws[3]);
    assert_eq!(ci, cpg * groups, "channels {ci} != {cpg}x{groups}");
    assert_eq!(co % groups, 0, "out channels {co} not divisible by groups {groups}");
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let pad_h = ((ho - 1) * stride + kh).saturating_sub(h);
    let pad_w = ((wo - 1) * stride + kw).saturating_sub(w);
    ConvDims {
        n,
        h,
        w,
        ci,
        kh,
        kw,
        cpg,
        co,
        stride,
        groups,
        ho,
        wo,
        pad_t: pad_h / 2,
        pad_l: pad_w / 2,
    }
}

fn conv_fwd_img(xi: &[f32], wd: &[f32], d: &ConvDims, o: &mut [f32]) {
    let copg = d.co / d.groups;
    for oy in 0..d.ho {
        for ox in 0..d.wo {
            let obase = (oy * d.wo + ox) * d.co;
            for ky in 0..d.kh {
                let iy = (oy * d.stride + ky) as isize - d.pad_t as isize;
                if iy < 0 || iy >= d.h as isize {
                    continue;
                }
                for kx in 0..d.kw {
                    let ix = (ox * d.stride + kx) as isize - d.pad_l as isize;
                    if ix < 0 || ix >= d.w as isize {
                        continue;
                    }
                    let xbase = (iy as usize * d.w + ix as usize) * d.ci;
                    let wbase = (ky * d.kw + kx) * d.cpg * d.co;
                    if d.groups == 1 {
                        for ic in 0..d.ci {
                            let xv = xi[xbase + ic];
                            if xv != 0.0 {
                                let w_row = &wd[wbase + ic * d.co..wbase + (ic + 1) * d.co];
                                let o_px = &mut o[obase..obase + d.co];
                                for (ov, &wv) in o_px.iter_mut().zip(w_row) {
                                    *ov += xv * wv;
                                }
                            }
                        }
                    } else {
                        for oc in 0..d.co {
                            let g = oc / copg;
                            let mut acc = 0.0f32;
                            for icg in 0..d.cpg {
                                acc += xi[xbase + g * d.cpg + icg] * wd[wbase + icg * d.co + oc];
                            }
                            o[obase + oc] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// SAME-padded conv forward; `x` NHWC, `w` HWIO.
pub fn conv2d(x: &Arr, w: &Arr, stride: usize, groups: usize) -> Arr {
    let d = conv_dims(&x.shape, &w.shape, stride, groups);
    let mut out = Arr::zeros(vec![d.n, d.ho, d.wo, d.co]);
    let per_x = d.h * d.w * d.ci;
    let per_o = d.ho * d.wo * d.co;
    let (xd, wd, dr) = (&x.data, &w.data, &d);
    par_items(&mut out.data, per_o, |img, o| {
        conv_fwd_img(&xd[img * per_x..(img + 1) * per_x], wd, dr, o);
    });
    out
}

fn conv_bwd_img(
    xi: &[f32],
    wd: &[f32],
    gi: &[f32],
    d: &ConvDims,
    dxi: &mut [f32],
    dwl: &mut [f32],
) {
    let copg = d.co / d.groups;
    for oy in 0..d.ho {
        for ox in 0..d.wo {
            let gbase = (oy * d.wo + ox) * d.co;
            for ky in 0..d.kh {
                let iy = (oy * d.stride + ky) as isize - d.pad_t as isize;
                if iy < 0 || iy >= d.h as isize {
                    continue;
                }
                for kx in 0..d.kw {
                    let ix = (ox * d.stride + kx) as isize - d.pad_l as isize;
                    if ix < 0 || ix >= d.w as isize {
                        continue;
                    }
                    let xbase = (iy as usize * d.w + ix as usize) * d.ci;
                    let wbase = (ky * d.kw + kx) * d.cpg * d.co;
                    if d.groups == 1 {
                        let g_px = &gi[gbase..gbase + d.co];
                        for ic in 0..d.ci {
                            let xv = xi[xbase + ic];
                            let w_row = &wd[wbase + ic * d.co..wbase + (ic + 1) * d.co];
                            let dw_row = &mut dwl[wbase + ic * d.co..wbase + (ic + 1) * d.co];
                            let mut acc_dx = 0.0f32;
                            for oc in 0..d.co {
                                let gv = g_px[oc];
                                acc_dx += gv * w_row[oc];
                                dw_row[oc] += gv * xv;
                            }
                            dxi[xbase + ic] += acc_dx;
                        }
                    } else {
                        for oc in 0..d.co {
                            let gv = gi[gbase + oc];
                            if gv == 0.0 {
                                continue;
                            }
                            let gq = oc / copg;
                            for icg in 0..d.cpg {
                                let ic = gq * d.cpg + icg;
                                dxi[xbase + ic] += gv * wd[wbase + icg * d.co + oc];
                                dwl[wbase + icg * d.co + oc] += gv * xi[xbase + ic];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Conv backward: gradients w.r.t. input and weights.
pub fn conv2d_bwd(x: &Arr, w: &Arr, dy: &Arr, stride: usize, groups: usize) -> (Arr, Arr) {
    let d = conv_dims(&x.shape, &w.shape, stride, groups);
    let per_x = d.h * d.w * d.ci;
    let per_y = d.ho * d.wo * d.co;
    let dw_len = w.data.len();
    let mut dx = Arr::zeros(x.shape.clone());
    let threads = n_threads().min(d.n.max(1));
    let chunk = d.n.div_ceil(threads.max(1)).max(1);
    let (xd, wd, gd, dr) = (&x.data, &w.data, &dy.data, &d);
    let mut partial_dw: Vec<Vec<f32>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (t, dx_block) in dx.data.chunks_mut(chunk * per_x).enumerate() {
            handles.push(s.spawn(move || {
                let mut dwl = vec![0.0f32; dw_len];
                for (j, dxi) in dx_block.chunks_mut(per_x).enumerate() {
                    let img = t * chunk + j;
                    conv_bwd_img(
                        &xd[img * per_x..(img + 1) * per_x],
                        wd,
                        &gd[img * per_y..(img + 1) * per_y],
                        dr,
                        dxi,
                        &mut dwl,
                    );
                }
                dwl
            }));
        }
        for h in handles {
            partial_dw.push(h.join().expect("conv backward worker panicked"));
        }
    });
    let mut dw = Arr::zeros(w.shape.clone());
    for dwl in &partial_dw {
        for (a, b) in dw.data.iter_mut().zip(dwl) {
            *a += b;
        }
    }
    (dx, dw)
}

// ---------------------------------------------------------------------------
// Losses / metrics (forward parts; backward lives in Tape::backward)
// ---------------------------------------------------------------------------

/// Mean softmax cross-entropy of `(B,C)` logits against int labels.
pub fn softmax_xent(logits: &Arr, labels: &[i32]) -> f32 {
    let c = logits.last_dim();
    let b = logits.numel() / c;
    assert_eq!(labels.len(), b);
    let mut acc = 0.0f64;
    for (r, &y) in labels.iter().enumerate() {
        let row = &logits.data[r * c..(r + 1) * c];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        acc += (sum.ln() + mx - row[y as usize]) as f64;
    }
    (acc / b as f64) as f32
}

/// Count of rows whose argmax equals the label (first max wins, like
/// `jnp.argmax`).
pub fn argmax_correct(logits: &Arr, labels: &[i32]) -> f32 {
    let c = logits.last_dim();
    let b = logits.numel() / c;
    let mut good = 0u32;
    for (r, &y) in labels.iter().enumerate() {
        let row = &logits.data[r * c..(r + 1) * c];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == y as usize {
            good += 1;
        }
    }
    good as f32
}

/// Numerically stable mean binary cross-entropy with logits.
pub fn bce_logits(logits: &Arr, labels: &[f32]) -> f32 {
    assert_eq!(logits.numel(), labels.len());
    let mut acc = 0.0f64;
    for (&z, &y) in logits.data.iter().zip(labels) {
        acc += (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64;
    }
    (acc / labels.len().max(1) as f64) as f32
}

/// Count of `(logit > 0) == label` agreements.
pub fn bce_correct(logits: &Arr, labels: &[f32]) -> f32 {
    logits
        .data
        .iter()
        .zip(labels)
        .filter(|(&z, &y)| (z > 0.0) == (y > 0.5))
        .count() as f32
}

// ---------------------------------------------------------------------------
// The tape
// ---------------------------------------------------------------------------

/// Handle to a tape node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Var(pub usize);

enum Op {
    Leaf,
    Matmul(Var, Var),
    AddBias(Var, Var),
    Relu(Var),
    Conv { x: Var, w: Var, stride: usize, groups: usize },
    Gap(Var),
    Add(Var, Var),
    Mul(Var, Var),
    Concat(Var, Var),
    Embed { table: Var, idx: Vec<i32> },
    FakeQuant(Var),
    SoftmaxXent { logits: Var, labels: Vec<i32> },
    BceLogits { logits: Var, labels: Vec<f32> },
}

struct Node {
    val: Arr,
    op: Op,
}

/// Forward-recording tape with reverse-mode gradients.
#[derive(Default)]
pub struct Tape {
    nodes: Vec<Node>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape { nodes: Vec::new() }
    }

    fn push(&mut self, val: Arr, op: Op) -> Var {
        self.nodes.push(Node { val, op });
        Var(self.nodes.len() - 1)
    }

    /// Value of a node.
    pub fn val(&self, v: Var) -> &Arr {
        &self.nodes[v.0].val
    }

    pub fn leaf(&mut self, val: Arr) -> Var {
        self.push(val, Op::Leaf)
    }

    /// `(M,K) @ (K,N)`.
    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
        assert_eq!(av.shape.len(), 2, "matmul lhs {:?}", av.shape);
        assert_eq!(bv.shape.len(), 2, "matmul rhs {:?}", bv.shape);
        assert_eq!(av.shape[1], bv.shape[0], "matmul {:?} x {:?}", av.shape, bv.shape);
        let (m, k, n) = (av.shape[0], av.shape[1], bv.shape[1]);
        let out = Arr::new(vec![m, n], matmul(&av.data, &bv.data, m, k, n));
        self.push(out, Op::Matmul(a, b))
    }

    /// Broadcast-add a `(C,)` bias over the last axis.
    pub fn add_bias(&mut self, x: Var, b: Var) -> Var {
        let (xv, bv) = (&self.nodes[x.0].val, &self.nodes[b.0].val);
        let c = xv.last_dim();
        assert_eq!(bv.numel(), c, "bias {:?} vs x {:?}", bv.shape, xv.shape);
        let mut out = xv.clone();
        for row in out.data.chunks_mut(c) {
            for (o, &add) in row.iter_mut().zip(&bv.data) {
                *o += add;
            }
        }
        self.push(out, Op::AddBias(x, b))
    }

    pub fn relu(&mut self, x: Var) -> Var {
        let xv = &self.nodes[x.0].val;
        let out = Arr::new(xv.shape.clone(), xv.data.iter().map(|&v| v.max(0.0)).collect());
        self.push(out, Op::Relu(x))
    }

    /// SAME-padded NHWC/HWIO conv.
    pub fn conv(&mut self, x: Var, w: Var, stride: usize, groups: usize) -> Var {
        let out = conv2d(&self.nodes[x.0].val, &self.nodes[w.0].val, stride, groups);
        self.push(out, Op::Conv { x, w, stride, groups })
    }

    /// Global average pool `(N,H,W,C) -> (N,C)`.
    pub fn gap(&mut self, x: Var) -> Var {
        let xv = &self.nodes[x.0].val;
        assert_eq!(xv.shape.len(), 4, "gap input {:?}", xv.shape);
        let (n, h, w, c) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let inv = 1.0 / (h * w) as f32;
        let mut out = Arr::zeros(vec![n, c]);
        for img in 0..n {
            let o_row = &mut out.data[img * c..(img + 1) * c];
            for px in xv.data[img * h * w * c..(img + 1) * h * w * c].chunks(c) {
                for (o, &v) in o_row.iter_mut().zip(px) {
                    *o += v * inv;
                }
            }
        }
        self.push(out, Op::Gap(x))
    }

    /// Elementwise sum of same-shape tensors (residual connections).
    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
        assert_eq!(av.shape, bv.shape, "add {:?} vs {:?}", av.shape, bv.shape);
        let data = av.data.iter().zip(&bv.data).map(|(x, y)| x + y).collect();
        let out = Arr::new(av.shape.clone(), data);
        self.push(out, Op::Add(a, b))
    }

    /// Elementwise product of same-shape tensors (GMF interaction).
    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
        assert_eq!(av.shape, bv.shape, "mul {:?} vs {:?}", av.shape, bv.shape);
        let data = av.data.iter().zip(&bv.data).map(|(x, y)| x * y).collect();
        let out = Arr::new(av.shape.clone(), data);
        self.push(out, Op::Mul(a, b))
    }

    /// Concatenate two `(R,Ca)` / `(R,Cb)` tensors along the last axis.
    pub fn concat(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
        let (ca, cb) = (av.last_dim(), bv.last_dim());
        let r = av.numel() / ca;
        assert_eq!(r, bv.numel() / cb, "concat rows {:?} vs {:?}", av.shape, bv.shape);
        let mut data = Vec::with_capacity(r * (ca + cb));
        for row in 0..r {
            data.extend_from_slice(&av.data[row * ca..(row + 1) * ca]);
            data.extend_from_slice(&bv.data[row * cb..(row + 1) * cb]);
        }
        let out = Arr::new(vec![r, ca + cb], data);
        self.push(out, Op::Concat(a, b))
    }

    /// Gather rows of a `(V,D)` table: `out[r] = table[idx[r]]`.
    pub fn embed(&mut self, table: Var, idx: &[i32]) -> Var {
        let tv = &self.nodes[table.0].val;
        assert_eq!(tv.shape.len(), 2, "embed table {:?}", tv.shape);
        let (v, d) = (tv.shape[0], tv.shape[1]);
        let mut data = Vec::with_capacity(idx.len() * d);
        for &i in idx {
            let i = i as usize;
            assert!(i < v, "embedding index {i} out of range {v}");
            data.extend_from_slice(&tv.data[i * d..(i + 1) * d]);
        }
        let out = Arr::new(vec![idx.len(), d], data);
        self.push(out, Op::Embed { table, idx: idx.to_vec() })
    }

    /// Quantize-dequantize (paper Eq. 1); bit-exact with
    /// `quant::quantizer::fake_quant`.  Backward is straight-through.
    pub fn fake_quant(&mut self, x: Var, delta: f32, qmax: f32, kind: GridKind) -> Var {
        let xv = &self.nodes[x.0].val;
        let data = xv.data.iter().map(|&v| fake_quant_one(v, delta, qmax, kind)).collect();
        let out = Arr::new(xv.shape.clone(), data);
        self.push(out, Op::FakeQuant(x))
    }

    /// Mean softmax cross-entropy scalar.
    pub fn softmax_xent(&mut self, logits: Var, labels: &[i32]) -> Var {
        let loss = softmax_xent(&self.nodes[logits.0].val, labels);
        self.push(Arr::scalar(loss), Op::SoftmaxXent { logits, labels: labels.to_vec() })
    }

    /// Mean BCE-with-logits scalar.
    pub fn bce_logits(&mut self, logits: Var, labels: &[f32]) -> Var {
        let loss = bce_logits(&self.nodes[logits.0].val, labels);
        self.push(Arr::scalar(loss), Op::BceLogits { logits, labels: labels.to_vec() })
    }

    /// Reverse-mode sweep from scalar `root`; returns one gradient slot per
    /// node (leaves keep theirs, interior grads are consumed).
    pub fn backward(&self, root: Var) -> Vec<Option<Arr>> {
        let mut grads: Vec<Option<Arr>> = Vec::with_capacity(self.nodes.len());
        grads.resize_with(self.nodes.len(), || None);
        grads[root.0] = Some(Arr::scalar(1.0));
        for i in (0..self.nodes.len()).rev() {
            if matches!(self.nodes[i].op, Op::Leaf) {
                continue;
            }
            let Some(g) = grads[i].take() else { continue };
            match &self.nodes[i].op {
                Op::Leaf => {}
                Op::Matmul(a, b) => {
                    let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
                    let (m, k, n) = (av.shape[0], av.shape[1], bv.shape[1]);
                    let da = mat_nt(&g.data, &bv.data, m, n, k);
                    let db = mat_tn(&av.data, &g.data, m, k, n);
                    acc(&mut grads, *a, Arr::new(av.shape.clone(), da));
                    acc(&mut grads, *b, Arr::new(bv.shape.clone(), db));
                }
                Op::AddBias(x, b) => {
                    let bv = &self.nodes[b.0].val;
                    let c = bv.numel();
                    let mut db = vec![0.0f32; c];
                    for row in g.data.chunks(c) {
                        for (o, &gv) in db.iter_mut().zip(row) {
                            *o += gv;
                        }
                    }
                    acc(&mut grads, *b, Arr::new(bv.shape.clone(), db));
                    acc(&mut grads, *x, g);
                }
                Op::Relu(x) => {
                    let yv = &self.nodes[i].val;
                    let data =
                        g.data.iter().zip(&yv.data).map(|(&gv, &y)| if y > 0.0 { gv } else { 0.0 });
                    acc(&mut grads, *x, Arr::new(yv.shape.clone(), data.collect()));
                }
                Op::Conv { x, w, stride, groups } => {
                    let (xv, wv) = (&self.nodes[x.0].val, &self.nodes[w.0].val);
                    let (dx, dw) = conv2d_bwd(xv, wv, &g, *stride, *groups);
                    acc(&mut grads, *x, dx);
                    acc(&mut grads, *w, dw);
                }
                Op::Gap(x) => {
                    let xv = &self.nodes[x.0].val;
                    let (n, h, w, c) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
                    let inv = 1.0 / (h * w) as f32;
                    let mut dx = Arr::zeros(xv.shape.clone());
                    for img in 0..n {
                        let g_row = &g.data[img * c..(img + 1) * c];
                        for px in dx.data[img * h * w * c..(img + 1) * h * w * c].chunks_mut(c) {
                            for (o, &gv) in px.iter_mut().zip(g_row) {
                                *o += gv * inv;
                            }
                        }
                    }
                    acc(&mut grads, *x, dx);
                }
                Op::Add(a, b) => {
                    acc(&mut grads, *a, g.clone());
                    acc(&mut grads, *b, g);
                }
                Op::Mul(a, b) => {
                    let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
                    let da = g.data.iter().zip(&bv.data).map(|(gv, bvv)| gv * bvv).collect();
                    let db = g.data.iter().zip(&av.data).map(|(gv, avv)| gv * avv).collect();
                    acc(&mut grads, *a, Arr::new(av.shape.clone(), da));
                    acc(&mut grads, *b, Arr::new(bv.shape.clone(), db));
                }
                Op::Concat(a, b) => {
                    let (av, bv) = (&self.nodes[a.0].val, &self.nodes[b.0].val);
                    let (ca, cb) = (av.last_dim(), bv.last_dim());
                    let r = av.numel() / ca;
                    let mut da = Vec::with_capacity(r * ca);
                    let mut db = Vec::with_capacity(r * cb);
                    for row in g.data.chunks(ca + cb) {
                        da.extend_from_slice(&row[..ca]);
                        db.extend_from_slice(&row[ca..]);
                    }
                    acc(&mut grads, *a, Arr::new(av.shape.clone(), da));
                    acc(&mut grads, *b, Arr::new(bv.shape.clone(), db));
                }
                Op::Embed { table, idx } => {
                    let tv = &self.nodes[table.0].val;
                    let d = tv.shape[1];
                    let mut dt = Arr::zeros(tv.shape.clone());
                    for (r, &i) in idx.iter().enumerate() {
                        let dst = &mut dt.data[i as usize * d..(i as usize + 1) * d];
                        for (o, &gv) in dst.iter_mut().zip(&g.data[r * d..(r + 1) * d]) {
                            *o += gv;
                        }
                    }
                    acc(&mut grads, *table, dt);
                }
                Op::FakeQuant(x) => {
                    // Straight-through estimator; only reachable if a
                    // quantized graph is ever differentiated.
                    acc(&mut grads, *x, g);
                }
                Op::SoftmaxXent { logits, labels } => {
                    let lv = &self.nodes[logits.0].val;
                    let c = lv.last_dim();
                    let b = lv.numel() / c;
                    let scale = g.item() / b as f32;
                    let mut dl = Arr::zeros(lv.shape.clone());
                    for (r, &y) in labels.iter().enumerate() {
                        let row = &lv.data[r * c..(r + 1) * c];
                        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
                        let d_row = &mut dl.data[r * c..(r + 1) * c];
                        for (j, o) in d_row.iter_mut().enumerate() {
                            let p = (row[j] - mx).exp() / sum;
                            let onehot = if j == y as usize { 1.0 } else { 0.0 };
                            *o = (p - onehot) * scale;
                        }
                    }
                    acc(&mut grads, *logits, dl);
                }
                Op::BceLogits { logits, labels } => {
                    let lv = &self.nodes[logits.0].val;
                    let scale = g.item() / labels.len().max(1) as f32;
                    let data = lv
                        .data
                        .iter()
                        .zip(labels)
                        .map(|(&z, &y)| (sigmoid(z) - y) * scale)
                        .collect();
                    acc(&mut grads, *logits, Arr::new(lv.shape.clone(), data));
                }
            }
        }
        grads
    }
}

fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

fn acc(grads: &mut [Option<Arr>], v: Var, g: Arr) {
    match &mut grads[v.0] {
        Some(cur) => {
            debug_assert_eq!(cur.shape, g.shape);
            for (a, b) in cur.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
        slot => *slot = Some(g),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff(mut f: impl FnMut(&[f32]) -> f32, x: &[f32], eps: f32) -> Vec<f32> {
        let mut g = Vec::with_capacity(x.len());
        for i in 0..x.len() {
            let mut xp = x.to_vec();
            xp[i] += eps;
            let fp = f(&xp);
            xp[i] -= 2.0 * eps;
            let fm = f(&xp);
            g.push((fp - fm) / (2.0 * eps));
        }
        g
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn matmul_small() {
        // (2,3) x (3,2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn dense_grads_match_finite_diff() {
        let xs = [0.5, -1.0, 2.0, 0.3, -0.7, 1.1];
        let ws = [0.2, -0.4, 0.9, 0.1, -0.3, 0.8];
        let bs = [0.05, -0.02];
        let labels = [1i32, 0];
        let run = |x: &[f32], w: &[f32], b: &[f32]| -> (f32, Vec<Option<Arr>>, Var, Var, Var) {
            let mut t = Tape::new();
            let xv = t.leaf(Arr::new(vec![2, 3], x.to_vec()));
            let wv = t.leaf(Arr::new(vec![3, 2], w.to_vec()));
            let bv = t.leaf(Arr::new(vec![2], b.to_vec()));
            let mm = t.matmul(xv, wv);
            let z = t.add_bias(mm, bv);
            let h = t.relu(z);
            let loss = t.softmax_xent(h, &labels);
            let l = t.val(loss).item();
            let g = t.backward(loss);
            (l, g, xv, wv, bv)
        };
        let (_, g, xv, wv, bv) = run(&xs, &ws, &bs);
        let num_w = finite_diff(|w| run(&xs, w, &bs).0, &ws, 1e-3);
        assert_close(&g[wv.0].as_ref().unwrap().data, &num_w, 2e-2);
        let num_x = finite_diff(|x| run(x, &ws, &bs).0, &xs, 1e-3);
        assert_close(&g[xv.0].as_ref().unwrap().data, &num_x, 2e-2);
        let num_b = finite_diff(|b| run(&xs, &ws, b).0, &bs, 1e-3);
        assert_close(&g[bv.0].as_ref().unwrap().data, &num_b, 2e-2);
    }

    #[test]
    fn conv_grads_match_finite_diff() {
        // 1 image 4x4x2, 3x3 kernel to 3 channels, stride 2
        let mut rngx = crate::util::rng::Pcg32::seeded(1);
        let x: Vec<f32> = (0..32).map(|_| rngx.normal()).collect();
        let w: Vec<f32> = (0..54).map(|_| rngx.normal() * 0.5).collect();
        let labels = [2i32];
        let run = |x: &[f32], w: &[f32]| -> (f32, Vec<Option<Arr>>, Var, Var) {
            let mut t = Tape::new();
            let xv = t.leaf(Arr::new(vec![1, 4, 4, 2], x.to_vec()));
            let wv = t.leaf(Arr::new(vec![3, 3, 2, 3], w.to_vec()));
            let y = t.conv(xv, wv, 2, 1);
            let p = t.gap(y);
            let loss = t.softmax_xent(p, &labels);
            let l = t.val(loss).item();
            let g = t.backward(loss);
            (l, g, xv, wv)
        };
        let (_, g, xv, wv) = run(&x, &w);
        let num_w = finite_diff(|wp| run(&x, wp).0, &w, 1e-3);
        assert_close(&g[wv.0].as_ref().unwrap().data, &num_w, 3e-2);
        let num_x = finite_diff(|xp| run(xp, &w).0, &x, 1e-3);
        assert_close(&g[xv.0].as_ref().unwrap().data, &num_x, 3e-2);
    }

    #[test]
    fn grouped_conv_matches_manual_depthwise() {
        // depthwise 2-channel 1x1-image: out[c] = x[c] * w[c]
        let x = Arr::new(vec![1, 1, 1, 2], vec![3.0, 5.0]);
        let w = Arr::new(vec![1, 1, 1, 2], vec![2.0, -1.0]);
        let y = conv2d(&x, &w, 1, 2);
        assert_eq!(y.shape, vec![1, 1, 1, 2]);
        assert_eq!(y.data, vec![6.0, -5.0]);
    }

    #[test]
    fn same_padding_shapes() {
        let x = Arr::zeros(vec![2, 32, 32, 3]);
        let w = Arr::zeros(vec![3, 3, 3, 16]);
        assert_eq!(conv2d(&x, &w, 1, 1).shape, vec![2, 32, 32, 16]);
        assert_eq!(conv2d(&x, &w, 2, 1).shape, vec![2, 16, 16, 16]);
    }

    #[test]
    fn embed_mul_concat_bce_grads() {
        let table = [0.1f32, -0.2, 0.3, 0.4, -0.5, 0.6];
        let idx = [2i32, 0];
        let labels = [1.0f32, 0.0];
        let run = |tb: &[f32]| -> (f32, Vec<Option<Arr>>, Var) {
            let mut t = Tape::new();
            let tv = t.leaf(Arr::new(vec![3, 2], tb.to_vec()));
            let e1 = t.embed(tv, &idx);
            let e2 = t.embed(tv, &[1, 1]);
            let m = t.mul(e1, e2);
            let cat = t.concat(m, e1);
            let wv = t.leaf(Arr::new(vec![4, 1], vec![0.3, -0.2, 0.5, 0.7]));
            let z = t.matmul(cat, wv);
            let loss = t.bce_logits(z, &labels);
            let l = t.val(loss).item();
            let g = t.backward(loss);
            (l, g, tv)
        };
        let (_, g, tv) = run(&table);
        let num = finite_diff(|tb| run(tb).0, &table, 1e-3);
        assert_close(&g[tv.0].as_ref().unwrap().data, &num, 2e-2);
    }

    #[test]
    fn fake_quant_matches_reference() {
        let mut t = Tape::new();
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.13).collect();
        let x = t.leaf(Arr::new(vec![64], xs.clone()));
        let q = t.fake_quant(x, 0.25, 7.0, GridKind::Signed);
        let reference = crate::quant::quantizer::fake_quant(&xs, 0.25, 7.0, GridKind::Signed);
        assert_eq!(t.val(q).data, reference);
    }

    #[test]
    fn losses_sane() {
        let logits = Arr::new(vec![2, 3], vec![5.0, 0.0, 0.0, 0.0, 5.0, 0.0]);
        let loss = softmax_xent(&logits, &[0, 1]);
        assert!(loss < 0.05, "{loss}");
        assert_eq!(argmax_correct(&logits, &[0, 1]), 2.0);
        assert_eq!(argmax_correct(&logits, &[1, 1]), 1.0);
        let z = Arr::new(vec![2], vec![10.0, -10.0]);
        assert!(bce_logits(&z, &[1.0, 0.0]) < 1e-3);
        assert_eq!(bce_correct(&z, &[1.0, 1.0]), 1.0);
    }
}
