//! The builtin model zoo: Rust-native definitions of the five paper
//! stand-ins (`mlp3`, `cnn6`, `dwsep`, `resmini`, `ncf`) — both their
//! [`ModelSpec`] metadata (mirroring `python/compile/models/*.py` and the
//! manifest fragments `aot.py` emits) and their executable graphs on the
//! CPU tape.
//!
//! Entry-point semantics match the AOT artifacts:
//!
//! * `train_step` — FP32 forward/backward + SGD-with-momentum update
//!   (momentum 0.9, weight decay 1e-4), returns the pre-update loss.
//! * `fwd_quant` / `fwd_fp32` — (mean loss, #correct) under optional
//!   fake-quant with runtime Δ vectors.
//! * `acts` — FP32 input activation of every quant layer.
//! * `hitrate` / `hitrate_quant` — NCF mlperf hit-rate@10 hits.

use super::ops::{argmax_correct, bce_correct, Arr, Tape, Var};
use crate::quant::GridKind;
use crate::runtime::backend::QuantParams;
use crate::runtime::manifest::{
    EntrySpec, ModelSpec, ParamSpec, QuantLayerSpec, TensorSpec, BUILTIN_DIR,
};
use crate::tensor::{Data, HostTensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// SGD hyper-parameters baked into the `train_step` graph (matching
/// `make_train_step` in `python/compile/models/common.py`).
const MOMENTUM: f32 = 0.9;
const WEIGHT_DECAY: f32 = 1e-4;

// ---------------------------------------------------------------------------
// Builtin ModelSpecs
// ---------------------------------------------------------------------------

fn p(name: &str, shape: &[usize], init: &str, fan_in: usize) -> ParamSpec {
    ParamSpec { name: name.into(), shape: shape.to_vec(), init: init.into(), fan_in }
}

fn q(name: &str, weight_param: usize, act_signed: bool, kind: &str) -> QuantLayerSpec {
    QuantLayerSpec { name: name.into(), weight_param, act_signed, kind: kind.into() }
}

fn t(name: &str, shape: &[usize], dtype: &str) -> TensorSpec {
    TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: dtype.into() }
}

/// Assemble a [`ModelSpec`] with the same entry table / argument counts
/// `aot.py` would write for it.
fn finish(
    name: &str,
    task: &str,
    params: Vec<ParamSpec>,
    quant_layers: Vec<QuantLayerSpec>,
    input_spec: BTreeMap<String, Vec<TensorSpec>>,
    act_shapes: Vec<Vec<usize>>,
) -> ModelSpec {
    let n = params.len();
    let scalar = (Vec::new(), "f32".to_string());
    let param_outputs: Vec<(Vec<usize>, String)> =
        params.iter().map(|ps| (ps.shape.clone(), "f32".to_string())).collect();
    let n_in = |entry: &str| input_spec[entry].len();
    let mut entries = BTreeMap::new();
    let mut train_outputs = param_outputs.clone();
    train_outputs.extend(param_outputs.clone());
    train_outputs.push(scalar.clone());
    entries.insert(
        "train_step".to_string(),
        EntrySpec {
            file: BUILTIN_DIR.into(),
            n_args: 2 * n + n_in("train") + 1,
            outputs: train_outputs,
        },
    );
    entries.insert(
        "fwd_quant".to_string(),
        EntrySpec {
            file: BUILTIN_DIR.into(),
            n_args: n + 4 + n_in("eval"),
            outputs: vec![scalar.clone(), scalar.clone()],
        },
    );
    entries.insert(
        "fwd_fp32".to_string(),
        EntrySpec {
            file: BUILTIN_DIR.into(),
            n_args: n + n_in("eval"),
            outputs: vec![scalar.clone(), scalar.clone()],
        },
    );
    let acts_inputs = if task == "ncf" { 2 } else { 1 };
    entries.insert(
        "acts".to_string(),
        EntrySpec {
            file: BUILTIN_DIR.into(),
            n_args: n + acts_inputs,
            outputs: act_shapes.into_iter().map(|s| (s, "f32".to_string())).collect(),
        },
    );
    if task == "ncf" {
        entries.insert(
            "hitrate".to_string(),
            EntrySpec {
                file: BUILTIN_DIR.into(),
                n_args: n + n_in("hitrate"),
                outputs: vec![scalar.clone()],
            },
        );
        entries.insert(
            "hitrate_quant".to_string(),
            EntrySpec {
                file: BUILTIN_DIR.into(),
                n_args: n + 4 + n_in("hitrate"),
                outputs: vec![scalar],
            },
        );
    }
    ModelSpec { name: name.into(), task: task.into(), params, quant_layers, entries, input_spec }
}

fn mlp3() -> ModelSpec {
    let (d_in, h1, h2, classes) = (64, 128, 96, 16);
    let params = vec![
        p("fc1_w", &[d_in, h1], "he", d_in),
        p("fc1_b", &[h1], "zeros", 0),
        p("fc2_w", &[h1, h2], "he", h1),
        p("fc2_b", &[h2], "zeros", 0),
        p("fc3_w", &[h2, classes], "glorot", h2),
        p("fc3_b", &[classes], "zeros", 0),
    ];
    let quant = vec![
        q("fc1", 0, true, "dense"),
        q("fc2", 2, false, "dense"),
        q("fc3", 4, false, "dense"),
    ];
    let mut input_spec = BTreeMap::new();
    input_spec
        .insert("train".into(), vec![t("x", &[128, d_in], "f32"), t("y", &[128], "i32")]);
    input_spec
        .insert("eval".into(), vec![t("x", &[512, d_in], "f32"), t("y", &[512], "i32")]);
    let acts = vec![vec![512, d_in], vec![512, h1], vec![512, h2]];
    finish("mlp3", "vision", params, quant, input_spec, acts)
}

fn cnn6() -> ModelSpec {
    let params = vec![
        p("conv1_w", &[3, 3, 3, 16], "he", 27),
        p("conv1_b", &[16], "zeros", 0),
        p("conv2_w", &[3, 3, 16, 32], "he", 144),
        p("conv2_b", &[32], "zeros", 0),
        p("conv3_w", &[3, 3, 32, 32], "he", 288),
        p("conv3_b", &[32], "zeros", 0),
        p("conv4_w", &[3, 3, 32, 64], "he", 288),
        p("conv4_b", &[64], "zeros", 0),
        p("conv5_w", &[3, 3, 64, 64], "he", 576),
        p("conv5_b", &[64], "zeros", 0),
        p("fc_w", &[64, 10], "glorot", 64),
        p("fc_b", &[10], "zeros", 0),
    ];
    let quant = vec![
        q("conv1", 0, true, "conv"),
        q("conv2", 2, false, "conv"),
        q("conv3", 4, false, "conv"),
        q("conv4", 6, false, "conv"),
        q("conv5", 8, false, "conv"),
        q("fc", 10, false, "dense"),
    ];
    let mut input_spec = BTreeMap::new();
    input_spec
        .insert("train".into(), vec![t("x", &[128, 32, 32, 3], "f32"), t("y", &[128], "i32")]);
    input_spec
        .insert("eval".into(), vec![t("x", &[256, 32, 32, 3], "f32"), t("y", &[256], "i32")]);
    let b = 256;
    let acts = vec![
        vec![b, 32, 32, 3],
        vec![b, 32, 32, 16],
        vec![b, 16, 16, 32],
        vec![b, 16, 16, 32],
        vec![b, 8, 8, 64],
        vec![b, 64],
    ];
    finish("cnn6", "vision", params, quant, input_spec, acts)
}

fn dwsep() -> ModelSpec {
    let params = vec![
        p("stem_w", &[3, 3, 3, 16], "he", 27),
        p("stem_b", &[16], "zeros", 0),
        p("dw1_w", &[3, 3, 1, 16], "he", 9),
        p("dw1_b", &[16], "zeros", 0),
        p("pw1_w", &[1, 1, 16, 32], "he", 16),
        p("pw1_b", &[32], "zeros", 0),
        p("dw2_w", &[3, 3, 1, 32], "he", 9),
        p("dw2_b", &[32], "zeros", 0),
        p("pw2_w", &[1, 1, 32, 64], "he", 32),
        p("pw2_b", &[64], "zeros", 0),
        p("dw3_w", &[3, 3, 1, 64], "he", 9),
        p("dw3_b", &[64], "zeros", 0),
        p("pw3_w", &[1, 1, 64, 64], "he", 64),
        p("pw3_b", &[64], "zeros", 0),
        p("fc_w", &[64, 10], "glorot", 64),
        p("fc_b", &[10], "zeros", 0),
    ];
    let quant = vec![
        q("stem", 0, true, "conv"),
        q("dw1", 2, false, "dwconv"),
        q("pw1", 4, false, "conv"),
        q("dw2", 6, false, "dwconv"),
        q("pw2", 8, false, "conv"),
        q("dw3", 10, false, "dwconv"),
        q("pw3", 12, false, "conv"),
        q("fc", 14, false, "dense"),
    ];
    let mut input_spec = BTreeMap::new();
    input_spec
        .insert("train".into(), vec![t("x", &[128, 32, 32, 3], "f32"), t("y", &[128], "i32")]);
    input_spec
        .insert("eval".into(), vec![t("x", &[256, 32, 32, 3], "f32"), t("y", &[256], "i32")]);
    let b = 256;
    let acts = vec![
        vec![b, 32, 32, 3],
        vec![b, 32, 32, 16],
        vec![b, 16, 16, 16],
        vec![b, 16, 16, 32],
        vec![b, 8, 8, 32],
        vec![b, 8, 8, 64],
        vec![b, 8, 8, 64],
        vec![b, 64],
    ];
    finish("dwsep", "vision", params, quant, input_spec, acts)
}

fn resmini() -> ModelSpec {
    let mut params = vec![p("stem_w", &[3, 3, 3, 16], "he", 27), p("stem_b", &[16], "zeros", 0)];
    for blk in ["s1b1", "s1b2"] {
        for conv in ["c1", "c2"] {
            params.push(p(&format!("{blk}{conv}_w"), &[3, 3, 16, 16], "he", 144));
            params.push(p(&format!("{blk}{conv}_b"), &[16], "zeros", 0));
        }
    }
    params.push(p("down_w", &[3, 3, 16, 32], "he", 144));
    params.push(p("down_b", &[32], "zeros", 0));
    for blk in ["s2b1", "s2b2"] {
        for conv in ["c1", "c2"] {
            params.push(p(&format!("{blk}{conv}_w"), &[3, 3, 32, 32], "he", 288));
            params.push(p(&format!("{blk}{conv}_b"), &[32], "zeros", 0));
        }
    }
    params.push(p("fc_w", &[32, 10], "glorot", 32));
    params.push(p("fc_b", &[10], "zeros", 0));
    let quant = vec![
        q("stem", 0, true, "conv"),
        q("s1b1c1", 2, false, "conv"),
        q("s1b1c2", 4, false, "conv"),
        q("s1b2c1", 6, false, "conv"),
        q("s1b2c2", 8, false, "conv"),
        q("down", 10, false, "conv"),
        q("s2b1c1", 12, false, "conv"),
        q("s2b1c2", 14, false, "conv"),
        q("s2b2c1", 16, false, "conv"),
        q("s2b2c2", 18, false, "conv"),
        q("fc", 20, false, "dense"),
    ];
    let mut input_spec = BTreeMap::new();
    input_spec
        .insert("train".into(), vec![t("x", &[128, 32, 32, 3], "f32"), t("y", &[128], "i32")]);
    input_spec
        .insert("eval".into(), vec![t("x", &[256, 32, 32, 3], "f32"), t("y", &[256], "i32")]);
    let b = 256;
    let mut acts = vec![vec![b, 32, 32, 3]];
    for _ in 0..4 {
        acts.push(vec![b, 32, 32, 16]);
    }
    acts.push(vec![b, 32, 32, 16]); // down input
    for _ in 0..4 {
        acts.push(vec![b, 16, 16, 32]);
    }
    acts.push(vec![b, 32]); // fc input
    finish("resmini", "vision", params, quant, input_spec, acts)
}

fn ncf() -> ModelSpec {
    let (users, items, dim) = (2000, 1000, 16);
    let params = vec![
        p("emb_gmf_u", &[users, dim], "embed", 0),
        p("emb_gmf_i", &[items, dim], "embed", 0),
        p("emb_mlp_u", &[users, dim], "embed", 0),
        p("emb_mlp_i", &[items, dim], "embed", 0),
        p("fc1_w", &[2 * dim, 32], "he", 2 * dim),
        p("fc1_b", &[32], "zeros", 0),
        p("fc2_w", &[32, 16], "he", 32),
        p("fc2_b", &[16], "zeros", 0),
        p("out_w", &[dim + 16, 1], "glorot", dim + 16),
        p("out_b", &[1], "zeros", 0),
    ];
    let quant = vec![
        q("emb_gmf_u", 0, true, "embed"),
        q("emb_gmf_i", 1, true, "embed"),
        q("emb_mlp_u", 2, true, "embed"),
        q("emb_mlp_i", 3, true, "embed"),
        q("fc1", 4, true, "dense"),
        q("fc2", 6, false, "dense"),
        q("out", 8, true, "dense"),
    ];
    let mut input_spec = BTreeMap::new();
    input_spec.insert(
        "train".into(),
        vec![
            t("users", &[2048], "i32"),
            t("items", &[2048], "i32"),
            t("labels", &[2048], "f32"),
        ],
    );
    input_spec.insert(
        "eval".into(),
        vec![
            t("users", &[4096], "i32"),
            t("items", &[4096], "i32"),
            t("labels", &[4096], "f32"),
        ],
    );
    input_spec.insert(
        "hitrate".into(),
        vec![
            t("users", &[256], "i32"),
            t("pos", &[256], "i32"),
            t("negs", &[256, 99], "i32"),
        ],
    );
    let b = 4096;
    let acts = vec![
        vec![b, dim],
        vec![b, dim],
        vec![b, dim],
        vec![b, dim],
        vec![b, 2 * dim],
        vec![b, 32],
        vec![b, dim + 16],
    ];
    finish("ncf", "ncf", params, quant, input_spec, acts)
}

/// All builtin models, keyed by name.
pub fn builtin_models() -> BTreeMap<String, ModelSpec> {
    let mut out = BTreeMap::new();
    for m in [mlp3(), cnn6(), dwsep(), resmini(), ncf()] {
        out.insert(m.name.clone(), m);
    }
    out
}

// ---------------------------------------------------------------------------
// Graph construction
// ---------------------------------------------------------------------------

fn f32_of<'a>(ts: &'a HostTensor, what: &str) -> Result<&'a [f32]> {
    match &ts.data {
        Data::F32(v) => Ok(v),
        Data::I32(_) => bail!("{what}: expected f32 tensor"),
    }
}

fn i32_of<'a>(ts: &'a HostTensor, what: &str) -> Result<&'a [i32]> {
    match &ts.data {
        Data::I32(v) => Ok(v),
        Data::F32(_) => bail!("{what}: expected i32 tensor"),
    }
}

/// Per-run graph context: tape + quantization + activation recording.
struct Ctx<'a> {
    t: Tape,
    spec: &'a ModelSpec,
    quant: Option<&'a QuantParams>,
    record: bool,
    acts: Vec<Option<Arr>>,
}

impl<'a> Ctx<'a> {
    fn new(spec: &'a ModelSpec, quant: Option<&'a QuantParams>, record: bool) -> Ctx<'a> {
        let n = spec.quant_layers.len();
        Ctx { t: Tape::new(), spec, quant, record, acts: vec![None; n] }
    }

    fn leaves(&mut self, params: &[HostTensor]) -> Result<Vec<Var>> {
        params
            .iter()
            .map(|ts| Ok(self.t.leaf(Arr::new(ts.shape.clone(), f32_of(ts, "param")?.to_vec()))))
            .collect()
    }

    fn rec(&mut self, qi: usize, v: Var) {
        if self.record {
            self.acts[qi] = Some(self.t.val(v).clone());
        }
    }

    fn fq_w(&mut self, w: Var, qi: usize) -> Var {
        match self.quant {
            Some(qp) if qp.dw[qi] > 0.0 => {
                self.t.fake_quant(w, qp.dw[qi], qp.qmw[qi], GridKind::Signed)
            }
            _ => w,
        }
    }

    fn fq_a(&mut self, x: Var, qi: usize) -> Var {
        match self.quant {
            Some(qp) if qp.da[qi] > 0.0 => {
                let kind = GridKind::from_signed(self.spec.quant_layers[qi].act_signed);
                self.t.fake_quant(x, qp.da[qi], qp.qma[qi], kind)
            }
            _ => x,
        }
    }

    /// Quantized dense layer: `fq(x) @ fq(w) + b`.
    fn dense(&mut self, x: Var, w: Var, b: Var, qi: usize) -> Var {
        self.rec(qi, x);
        let xq = self.fq_a(x, qi);
        let wq = self.fq_w(w, qi);
        let y = self.t.matmul(xq, wq);
        self.t.add_bias(y, b)
    }

    /// Quantized SAME conv (+ bias).
    fn conv(&mut self, x: Var, w: Var, b: Var, qi: usize, stride: usize, groups: usize) -> Var {
        self.rec(qi, x);
        let xq = self.fq_a(x, qi);
        let wq = self.fq_w(w, qi);
        let y = self.t.conv(xq, wq, stride, groups);
        self.t.add_bias(y, b)
    }

    /// Embedding lookup with weight-grid fake-quant (Δa stays 0).
    fn embed(&mut self, table: Var, idx: &[i32], qi: usize) -> Var {
        let e = self.t.embed(table, idx);
        let eq = self.fq_w(e, qi);
        self.rec(qi, eq);
        eq
    }

    fn relu(&mut self, x: Var) -> Var {
        self.t.relu(x)
    }
}

/// Residual block: `relu(h + conv(relu(conv(h))))` (resmini).
fn res_block(cx: &mut Ctx, h: Var, pv: &[Var], pi: usize, qi: usize) -> Var {
    let y = cx.conv(h, pv[pi], pv[pi + 1], qi, 1, 1);
    let y = cx.relu(y);
    let y = cx.conv(y, pv[pi + 2], pv[pi + 3], qi + 1, 1, 1);
    let s = cx.t.add(h, y);
    cx.relu(s)
}

/// Vision forward: input `x` to logits.
fn vision_logits(cx: &mut Ctx, pv: &[Var], x: Var) -> Result<Var> {
    match cx.spec.name.as_str() {
        "mlp3" => {
            let h = cx.dense(x, pv[0], pv[1], 0);
            let h = cx.relu(h);
            let h = cx.dense(h, pv[2], pv[3], 1);
            let h = cx.relu(h);
            Ok(cx.dense(h, pv[4], pv[5], 2))
        }
        "cnn6" => {
            let strides = [1usize, 2, 1, 2, 1];
            let mut h = x;
            for (i, &s) in strides.iter().enumerate() {
                h = cx.conv(h, pv[2 * i], pv[2 * i + 1], i, s, 1);
                h = cx.relu(h);
            }
            let pooled = cx.t.gap(h);
            Ok(cx.dense(pooled, pv[10], pv[11], 5))
        }
        "dwsep" => {
            // (stride, groups) per conv quant site, mirroring mobile.py.
            let plan = [(1usize, 1usize), (2, 16), (1, 1), (2, 32), (1, 1), (1, 64), (1, 1)];
            let mut h = x;
            for (i, &(s, g)) in plan.iter().enumerate() {
                h = cx.conv(h, pv[2 * i], pv[2 * i + 1], i, s, g);
                h = cx.relu(h);
            }
            let pooled = cx.t.gap(h);
            Ok(cx.dense(pooled, pv[14], pv[15], 7))
        }
        "resmini" => {
            let h = cx.conv(x, pv[0], pv[1], 0, 1, 1);
            let mut h = cx.relu(h);
            h = res_block(cx, h, pv, 2, 1);
            h = res_block(cx, h, pv, 6, 3);
            let d = cx.conv(h, pv[10], pv[11], 5, 2, 1);
            let mut h = cx.relu(d);
            h = res_block(cx, h, pv, 12, 6);
            h = res_block(cx, h, pv, 16, 8);
            let pooled = cx.t.gap(h);
            Ok(cx.dense(pooled, pv[20], pv[21], 10))
        }
        other => bail!("cpu backend: unknown vision model '{other}'"),
    }
}

/// NCF forward: (users, items) to `(B,1)` logits.
fn ncf_logits(cx: &mut Ctx, pv: &[Var], users: &[i32], items: &[i32]) -> Result<Var> {
    if cx.spec.name != "ncf" {
        bail!("cpu backend: unknown ncf model '{}'", cx.spec.name);
    }
    let eg_u = cx.embed(pv[0], users, 0);
    let eg_i = cx.embed(pv[1], items, 1);
    let em_u = cx.embed(pv[2], users, 2);
    let em_i = cx.embed(pv[3], items, 3);
    let gmf = cx.t.mul(eg_u, eg_i);
    let h = cx.t.concat(em_u, em_i);
    let h = cx.dense(h, pv[4], pv[5], 4);
    let h = cx.relu(h);
    let h = cx.dense(h, pv[6], pv[7], 5);
    let h = cx.relu(h);
    let z = cx.t.concat(gmf, h);
    Ok(cx.dense(z, pv[8], pv[9], 6))
}

/// Reject mis-sized Δ vectors up front (the PJRT engine fails the same
/// way via its argument-count check) instead of panicking mid-graph.
fn check_quant(spec: &ModelSpec, quant: Option<&QuantParams>) -> Result<()> {
    if let Some(qp) = quant {
        let n = spec.n_quant_layers();
        let lens = [qp.dw.len(), qp.qmw.len(), qp.da.len(), qp.qma.len()];
        if lens.iter().any(|&l| l != n) {
            bail!("quant params sized {lens:?}, model {} has {n} quant layers", spec.name);
        }
    }
    Ok(())
}

/// Reject vision inputs whose trailing dims disagree with the model's
/// input spec (any batch size is fine) — a shape assert deeper in the
/// graph would panic instead of erroring.  Shared with the integer
/// engine (`runtime/int/session.rs`), which enforces the same contract.
pub(crate) fn check_vision_input(spec: &ModelSpec, x: &HostTensor) -> Result<()> {
    let want = &spec.input_spec["eval"][0].shape[1..];
    if x.shape.len() != want.len() + 1 || x.shape[1..] != *want {
        bail!("input shape {:?} incompatible with {} (want [B, {want:?}])", x.shape, spec.name);
    }
    Ok(())
}

/// Reject out-of-range NCF ids up front (the embed gather asserts).
/// Shared with the integer engine.
pub(crate) fn check_ids(spec: &ModelSpec, users: &[i32], items: &[i32]) -> Result<()> {
    let n_users = spec.params[0].shape[0] as i32;
    let n_items = spec.params[1].shape[0] as i32;
    if users.iter().any(|&u| u < 0 || u >= n_users) {
        bail!("user id out of range 0..{n_users}");
    }
    if items.iter().any(|&i| i < 0 || i >= n_items) {
        bail!("item id out of range 0..{n_items}");
    }
    Ok(())
}

/// Build the loss graph for a full (inputs, labels) batch.  Returns
/// (ctx, loss var, correct count).
fn loss_graph<'a>(
    spec: &'a ModelSpec,
    params: &[HostTensor],
    quant: Option<&'a QuantParams>,
    batch: &[HostTensor],
    record: bool,
) -> Result<(Ctx<'a>, Var, f32)> {
    check_quant(spec, quant)?;
    let mut cx = Ctx::new(spec, quant, record);
    let pv = cx.leaves(params)?;
    if spec.task == "ncf" {
        if batch.len() != 3 {
            bail!("ncf batch needs (users, items, labels), got {} tensors", batch.len());
        }
        let users = i32_of(&batch[0], "users")?;
        let items = i32_of(&batch[1], "items")?;
        let labels = f32_of(&batch[2], "labels")?;
        if users.len() != items.len() || users.len() != labels.len() {
            bail!("ncf batch length mismatch");
        }
        check_ids(spec, users, items)?;
        let logits = ncf_logits(&mut cx, &pv, users, items)?;
        let correct = bce_correct(cx.t.val(logits), labels);
        let loss = cx.t.bce_logits(logits, labels);
        Ok((cx, loss, correct))
    } else {
        if batch.len() != 2 {
            bail!("vision batch needs (x, y), got {} tensors", batch.len());
        }
        let xs = f32_of(&batch[0], "x")?;
        let ys = i32_of(&batch[1], "y")?;
        if batch[0].shape.first().copied().unwrap_or(0) != ys.len() {
            bail!("vision batch length mismatch: x {:?} vs y {:?}", batch[0].shape, batch[1].shape);
        }
        check_vision_input(spec, &batch[0])?;
        let x = cx.t.leaf(Arr::new(batch[0].shape.clone(), xs.to_vec()));
        let logits = vision_logits(&mut cx, &pv, x)?;
        let correct = argmax_correct(cx.t.val(logits), ys);
        let loss = cx.t.softmax_xent(logits, ys);
        Ok((cx, loss, correct))
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// One SGD-with-momentum step; mutates `params`/`momentum` in place and
/// returns the pre-update loss.
pub fn train_step(
    spec: &ModelSpec,
    params: &mut [HostTensor],
    momentum: &mut [Vec<f32>],
    batch: &[HostTensor],
    lr: f32,
) -> Result<f32> {
    let (cx, loss, _) = loss_graph(spec, params, None, batch, false)?;
    let loss_val = cx.t.val(loss).item();
    let grads = cx.t.backward(loss);
    for (i, (ts, mom)) in params.iter_mut().zip(momentum.iter_mut()).enumerate() {
        // Param leaves are the first `n` tape nodes (see Ctx::leaves).
        let g = grads[i].as_ref();
        let pdata = match &mut ts.data {
            Data::F32(v) => v,
            Data::I32(_) => bail!("param {i}: expected f32"),
        };
        for (j, (pw, m)) in pdata.iter_mut().zip(mom.iter_mut()).enumerate() {
            let gv = g.map_or(0.0, |a| a.data[j]);
            *m = MOMENTUM * *m + gv + WEIGHT_DECAY * *pw;
            *pw -= lr * *m;
        }
    }
    Ok(loss_val)
}

/// Quantized (Some) / FP32 (None) forward: (mean loss, #correct).
pub fn eval(
    spec: &ModelSpec,
    params: &[HostTensor],
    quant: Option<&QuantParams>,
    batch: &[HostTensor],
) -> Result<(f32, f32)> {
    let (cx, loss, correct) = loss_graph(spec, params, quant, batch, false)?;
    Ok((cx.t.val(loss).item(), correct))
}

/// FP32 input activations of every quant layer, from an inputs-only batch.
pub fn acts(
    spec: &ModelSpec,
    params: &[HostTensor],
    batch: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    let mut cx = Ctx::new(spec, None, true);
    let pv = cx.leaves(params)?;
    if spec.task == "ncf" {
        if batch.len() != 2 {
            bail!("ncf acts batch needs (users, items), got {} tensors", batch.len());
        }
        let users = i32_of(&batch[0], "users")?;
        let items = i32_of(&batch[1], "items")?;
        check_ids(spec, users, items)?;
        ncf_logits(&mut cx, &pv, users, items)?;
    } else {
        if batch.len() != 1 {
            bail!("vision acts batch needs (x,), got {} tensors", batch.len());
        }
        let xs = f32_of(&batch[0], "x")?;
        check_vision_input(spec, &batch[0])?;
        let x = cx.t.leaf(Arr::new(batch[0].shape.clone(), xs.to_vec()));
        vision_logits(&mut cx, &pv, x)?;
    }
    cx.acts
        .into_iter()
        .enumerate()
        .map(|(i, a)| {
            let a = a.with_context(|| format!("quant layer {i} recorded no activation"))?;
            Ok(HostTensor::f32(a.shape, a.data))
        })
        .collect()
}

/// NCF mlperf hit-rate@10 hits for a (users, pos, negs) batch.
pub fn hitrate(
    spec: &ModelSpec,
    params: &[HostTensor],
    quant: Option<&QuantParams>,
    batch: &[HostTensor],
) -> Result<f32> {
    if spec.task != "ncf" {
        bail!("hitrate: model {} is not an ncf task", spec.name);
    }
    check_quant(spec, quant)?;
    if batch.len() != 3 {
        bail!("hitrate batch needs (users, pos, negs), got {} tensors", batch.len());
    }
    let users = i32_of(&batch[0], "users")?;
    let pos = i32_of(&batch[1], "pos")?;
    let negs = i32_of(&batch[2], "negs")?;
    let b = users.len();
    if b == 0 || pos.len() != b || negs.is_empty() || negs.len() % b != 0 {
        bail!("hitrate batch shape mismatch");
    }
    check_ids(spec, users, pos)?;
    check_ids(spec, &[], negs)?;
    let k = negs.len() / b;
    // Flatten to one (B*(K+1)) scoring pass: per row, positive first.
    let mut users_rep = Vec::with_capacity(b * (k + 1));
    let mut all_items = Vec::with_capacity(b * (k + 1));
    for r in 0..b {
        for _ in 0..=k {
            users_rep.push(users[r]);
        }
        all_items.push(pos[r]);
        all_items.extend_from_slice(&negs[r * k..(r + 1) * k]);
    }
    let mut cx = Ctx::new(spec, quant, false);
    let pv = cx.leaves(params)?;
    let logits = ncf_logits(&mut cx, &pv, &users_rep, &all_items)?;
    let scores = &cx.t.val(logits).data;
    let mut hits = 0.0f32;
    for r in 0..b {
        let row = &scores[r * (k + 1)..(r + 1) * (k + 1)];
        let rank = row[1..].iter().filter(|&&s| s > row[0]).count();
        if rank < 10 {
            hits += 1.0;
        }
    }
    Ok(hits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init::init_params;

    #[test]
    fn builtin_zoo_is_complete() {
        let zoo = builtin_models();
        assert_eq!(
            zoo.keys().cloned().collect::<Vec<_>>(),
            vec!["cnn6", "dwsep", "mlp3", "ncf", "resmini"]
        );
        for spec in zoo.values() {
            assert!(spec.n_quant_layers() >= 3);
            assert_eq!(spec.entry("acts").unwrap().outputs.len(), spec.n_quant_layers());
            for ql in &spec.quant_layers {
                assert!(ql.weight_param < spec.params.len());
            }
        }
    }

    #[test]
    fn mlp3_train_reduces_loss_and_eval_matches() {
        let zoo = builtin_models();
        let spec = &zoo["mlp3"];
        let mut params = init_params(&spec.params, 7);
        let mut momentum: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0.0; p.len()]).collect();
        let data = crate::data::vision::SynthVision::new(3);
        let (x, y) = data.batch_features(0, 64, 64);
        let batch = vec![x, y];
        let l0 = train_step(spec, &mut params, &mut momentum, &batch, 0.1).unwrap();
        for _ in 0..25 {
            train_step(spec, &mut params, &mut momentum, &batch, 0.1).unwrap();
        }
        let (l1, correct) = eval(spec, &params, None, &batch).unwrap();
        assert!(l1 < l0 - 0.05, "loss did not drop: {l0} -> {l1}");
        assert!((0.0..=64.0).contains(&correct));
    }

    #[test]
    fn passthrough_quant_is_exact() {
        let zoo = builtin_models();
        let spec = &zoo["mlp3"];
        let params = init_params(&spec.params, 5);
        let data = crate::data::vision::SynthVision::new(4);
        let (x, y) = data.batch_features(0, 32, 64);
        let batch = vec![x, y];
        let (lf, cf) = eval(spec, &params, None, &batch).unwrap();
        let q = QuantParams::passthrough(spec.n_quant_layers());
        let (lq, cq) = eval(spec, &params, Some(&q), &batch).unwrap();
        assert_eq!(lf, lq);
        assert_eq!(cf, cq);
    }

    #[test]
    fn acts_shapes_follow_quant_layers() {
        let zoo = builtin_models();
        let spec = &zoo["mlp3"];
        let params = init_params(&spec.params, 5);
        let data = crate::data::vision::SynthVision::new(4);
        let (x, _) = data.batch_features(0, 16, 64);
        let out = acts(spec, &params, &[x]).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].shape, vec![16, 64]);
        assert_eq!(out[1].shape, vec![16, 128]);
        assert_eq!(out[2].shape, vec![16, 96]);
    }

    #[test]
    fn ncf_hitrate_bounds() {
        let zoo = builtin_models();
        let spec = &zoo["ncf"];
        let params = init_params(&spec.params, 9);
        let data = crate::data::ncf::SynthNcf::new(2, 2000, 1000, 6);
        let (u, pos, negs) = data.eval_batch(0, 64);
        let hits = hitrate(spec, &params, None, &[u, pos, negs]).unwrap();
        assert!((0.0..=64.0).contains(&hits));
    }

    #[test]
    fn coarse_quant_changes_vision_loss() {
        let zoo = builtin_models();
        let spec = &zoo["mlp3"];
        let params = init_params(&spec.params, 5);
        let data = crate::data::vision::SynthVision::new(4);
        let (x, y) = data.batch_features(0, 32, 64);
        let batch = vec![x, y];
        let (lf, _) = eval(spec, &params, None, &batch).unwrap();
        let n = spec.n_quant_layers();
        let q = QuantParams {
            dw: vec![0.3; n],
            qmw: vec![1.0; n],
            da: vec![0.5; n],
            qma: vec![3.0; n],
        };
        let (lq, _) = eval(spec, &params, Some(&q), &batch).unwrap();
        assert!((lq - lf).abs() > 1e-4, "coarse quant left loss at {lf}");
    }
}
