//! The runtime layer: pluggable execution backends behind one facade.
//!
//! [`backend::Backend`] abstracts the engine operations (`CreateSession`,
//! `RegisterBatch`, `TrainStep`, `Eval`, `Hitrate`, `Acts`, …) the
//! coordinator is written against; [`backend::EngineHandle`] is the
//! cloneable `Send + Sync` facade everything holds.
//!
//! * [`cpu`] — the default backend: a dependency-free pure-Rust executor
//!   that runs the builtin model zoo natively (dense matmul + conv +
//!   fake-quant per the manifest's `QuantParams`), with reverse-mode
//!   gradients for `train_step`.  Works on a clean machine with no Python
//!   or PJRT installed.
//! * [`engine`] / [`handle`] (`--features xla`) — the PJRT engine: loads
//!   the AOT HLO-text artifacts and executes them on a dedicated engine
//!   thread (PJRT wrapper types hold raw pointers and are `!Send`, so all
//!   PJRT state lives on that thread behind an actor/mailbox handle).
//! * [`int`] — the integer inference engine: packs a calibrated session
//!   into i8/i4 weight artifacts and executes them with real integer
//!   kernels (`EngineHandle::pack` + `int::InferSession`), serving the
//!   coordinator's `pack`/`infer` endpoints.

pub mod backend;
pub mod cpu;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod handle;
pub mod int;
pub mod manifest;

pub use backend::{Backend, BatchId, EngineHandle, EngineStats, QuantParams, SessionId};
pub use int::{ExecMode, InferSession, PackOpts, QuantizedModel};
pub use manifest::{Manifest, ModelSpec};
