//! The PJRT runtime layer: loads the AOT HLO-text artifacts and executes
//! them for the Layer-3 coordinator.
//!
//! PJRT wrapper types (`xla::PjRtClient`, `Literal`, …) hold raw pointers
//! and are `!Send`, so all PJRT state lives on a dedicated **engine
//! thread** ([`engine`]); the rest of the system talks to it through the
//! cloneable, `Send` [`handle::EngineHandle`] (an actor/mailbox design —
//! the same shape a serving router uses to own model replicas).

pub mod engine;
pub mod handle;
pub mod manifest;

pub use handle::{BatchId, EngineHandle, QuantParams, SessionId};
pub use manifest::{Manifest, ModelSpec};
