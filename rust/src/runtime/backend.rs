//! The pluggable execution-backend abstraction.
//!
//! A [`Backend`] owns model sessions (parameters + optimizer state) and
//! registered batches, and evaluates the engine operations the
//! coordinator needs: `create_session`, `register_batch`, `train_step`,
//! `eval`, `hitrate`, `acts`, `stats`.  Two implementations exist:
//!
//! * [`super::cpu::CpuBackend`] — the default: a dependency-free pure-Rust
//!   executor that runs the model zoo natively (dense/conv/embedding
//!   forward + reverse-mode gradients, fake-quant per [`QuantParams`]).
//! * The PJRT engine (`--features xla`) — executes the AOT HLO artifacts
//!   through the `xla` bindings on a dedicated engine thread.
//!
//! [`EngineHandle`] is the cloneable, `Send + Sync` facade the rest of the
//! system talks to; it delegates to whichever backend it was started
//! with.

use super::manifest::Manifest;
use crate::tensor::HostTensor;
use anyhow::Result;
use std::sync::Arc;

/// Session identifier (device-resident parameters + momentum).
pub type SessionId = u64;

/// Registered-batch identifier.
pub type BatchId = u64;

/// Per-layer quantization runtime parameters (the graph's dw/qmw/da/qma).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantParams {
    pub dw: Vec<f32>,
    pub qmw: Vec<f32>,
    pub da: Vec<f32>,
    pub qma: Vec<f32>,
}

impl QuantParams {
    /// All-zero steps: every layer passes through (FP32 behaviour).
    pub fn passthrough(n: usize) -> Self {
        QuantParams { dw: vec![0.0; n], qmw: vec![1.0; n], da: vec![0.0; n], qma: vec![1.0; n] }
    }
}

/// Counters for the metrics registry / perf bench.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Entry-point executions (train/eval/hitrate/acts).
    pub executions: u64,
    /// Distinct (model, entry) graphs instantiated/compiled.
    pub compiled: u64,
    pub sessions: u64,
    pub batches: u64,
    /// Total seconds spent executing graphs.
    pub exec_seconds: f64,
}

/// An execution backend: the mailbox-operation surface the coordinator,
/// LAPQ pipeline, analysis and job service are written against.
pub trait Backend: Send + Sync {
    /// Short name for logs and `repro info` ("cpu", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// The model/ABI registry this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Create a model session owning `params` (+ zero momentum).
    fn create_session(&self, model: &str, params: Vec<HostTensor>) -> Result<SessionId>;

    fn drop_session(&self, sess: SessionId) -> Result<()>;

    fn get_params(&self, sess: SessionId) -> Result<Vec<HostTensor>>;

    fn set_params(&self, sess: SessionId, params: Vec<HostTensor>) -> Result<()>;

    /// Register a batch for repeated use (calibration / eval sets).
    fn register_batch(&self, batch: Vec<HostTensor>) -> Result<BatchId>;

    fn drop_batch(&self, batch: BatchId) -> Result<()>;

    /// One SGD-with-momentum step; updates session state, returns loss.
    fn train_step(&self, sess: SessionId, batch: BatchId, lr: f32) -> Result<f32>;

    /// Quantized (Some) or FP32 (None) forward: (mean loss, #correct).
    fn eval(&self, sess: SessionId, quant: Option<QuantParams>, batch: BatchId)
        -> Result<(f32, f32)>;

    /// NCF hit-rate@10 hits for a (users, pos, negs) batch.
    fn hitrate(&self, sess: SessionId, quant: Option<QuantParams>, batch: BatchId) -> Result<f32>;

    /// FP32 input activations of every quant layer for a batch.
    fn acts(&self, sess: SessionId, batch: BatchId) -> Result<Vec<HostTensor>>;

    fn stats(&self) -> Result<EngineStats>;
}

/// Cloneable facade over the active [`Backend`].
#[derive(Clone)]
pub struct EngineHandle {
    inner: Arc<dyn Backend>,
}

impl EngineHandle {
    /// Wrap an explicit backend.
    pub fn from_backend(inner: Arc<dyn Backend>) -> EngineHandle {
        log::info!("engine: backend={}", inner.name());
        EngineHandle { inner }
    }

    /// Boot the pure-Rust CPU backend over the builtin model zoo.
    pub fn cpu() -> Result<EngineHandle> {
        Ok(Self::from_backend(Arc::new(super::cpu::CpuBackend::new(Manifest::builtin()))))
    }

    /// Boot over an artifacts directory.  With the `xla` feature this
    /// starts the PJRT engine on those artifacts; without it the CPU
    /// backend is used (it executes the builtin zoo natively and needs no
    /// artifacts).
    pub fn start(artifacts_dir: impl AsRef<std::path::Path>) -> Result<EngineHandle> {
        Self::start_impl(artifacts_dir.as_ref())
    }

    #[cfg(feature = "xla")]
    fn start_impl(dir: &std::path::Path) -> Result<EngineHandle> {
        let pjrt = super::handle::PjrtEngine::start(dir)?;
        Ok(Self::from_backend(Arc::new(pjrt)))
    }

    #[cfg(not(feature = "xla"))]
    fn start_impl(_dir: &std::path::Path) -> Result<EngineHandle> {
        Self::cpu()
    }

    /// Boot the default backend: PJRT over [`Manifest::default_dir`] when
    /// built with `--features xla` (falling back to CPU if the engine
    /// cannot boot), the CPU backend otherwise.
    pub fn start_default() -> Result<EngineHandle> {
        #[cfg(feature = "xla")]
        {
            match super::handle::PjrtEngine::start(Manifest::default_dir()) {
                Ok(pjrt) => return Ok(Self::from_backend(Arc::new(pjrt))),
                Err(e) => {
                    log::warn!("pjrt engine unavailable ({e:#}); falling back to cpu backend");
                }
            }
        }
        Self::cpu()
    }

    /// Name of the active backend.
    pub fn backend_name(&self) -> &'static str {
        self.inner.name()
    }

    pub fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    /// Create a model session owning `params` (+ zero momentum).
    pub fn create_session(&self, model: &str, params: Vec<HostTensor>) -> Result<SessionId> {
        self.inner.create_session(model, params)
    }

    pub fn drop_session(&self, sess: SessionId) -> Result<()> {
        self.inner.drop_session(sess)
    }

    pub fn get_params(&self, sess: SessionId) -> Result<Vec<HostTensor>> {
        self.inner.get_params(sess)
    }

    pub fn set_params(&self, sess: SessionId, params: Vec<HostTensor>) -> Result<()> {
        self.inner.set_params(sess, params)
    }

    /// Register a batch for repeated use (calibration / eval sets).
    pub fn register_batch(&self, batch: Vec<HostTensor>) -> Result<BatchId> {
        self.inner.register_batch(batch)
    }

    pub fn drop_batch(&self, batch: BatchId) -> Result<()> {
        self.inner.drop_batch(batch)
    }

    /// One SGD-with-momentum step; updates session state, returns loss.
    pub fn train_step(&self, sess: SessionId, batch: BatchId, lr: f32) -> Result<f32> {
        self.inner.train_step(sess, batch, lr)
    }

    /// Quantized (Some) or FP32 (None) forward: (mean loss, #correct).
    pub fn eval(
        &self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<(f32, f32)> {
        self.inner.eval(sess, quant, batch)
    }

    /// NCF hit-rate@10 hits for a (users, pos, negs) batch.
    pub fn hitrate(
        &self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<f32> {
        self.inner.hitrate(sess, quant, batch)
    }

    /// FP32 input activations of every quant layer for a batch.
    pub fn acts(&self, sess: SessionId, batch: BatchId) -> Result<Vec<HostTensor>> {
        self.inner.acts(sess, batch)
    }

    pub fn stats(&self) -> Result<EngineStats> {
        self.inner.stats()
    }

    /// Pack a calibrated session into a deployable integer artifact:
    /// quantize its parameters onto the effective Δ grids (backend-
    /// agnostic — it only needs `get_params` and the manifest spec).
    /// `active` optionally records the calibration's layer mask.
    pub fn pack(
        &self,
        model: &str,
        sess: SessionId,
        quant: &QuantParams,
        active: Option<(&[bool], &[bool])>,
        opts: &super::int::PackOpts,
    ) -> Result<super::int::QuantizedModel> {
        let spec = self.manifest().model(model)?;
        let params = self.get_params(sess)?;
        super::int::model::pack(spec, &params, quant, active, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_shape() {
        let q = QuantParams::passthrough(3);
        assert_eq!(q.dw, vec![0.0; 3]);
        assert_eq!(q.qmw, vec![1.0; 3]);
    }

    #[test]
    fn cpu_handle_boots_and_clones() {
        let eng = EngineHandle::cpu().unwrap();
        let eng2 = eng.clone();
        assert_eq!(eng2.backend_name(), "cpu");
        assert!(eng.manifest().models.len() >= 5);
    }
}
