//! `artifacts/manifest.json` — the ABI contract between `aot.py` and the
//! Rust runtime: parameter specs, quant-layer table, entry-point files and
//! exact argument/output shapes.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub fan_in: usize,
}

#[derive(Clone, Debug)]
pub struct QuantLayerSpec {
    pub name: String,
    /// Index of the weight tensor in `params`.
    pub weight_param: usize,
    /// Input-activation grid sign (images/embeddings signed, ReLU unsigned).
    pub act_signed: bool,
    pub kind: String,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub n_args: usize,
    pub outputs: Vec<(Vec<usize>, String)>,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub task: String,
    pub params: Vec<ParamSpec>,
    pub quant_layers: Vec<QuantLayerSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
    /// Ordered batch-input specs per logical entry ("train", "eval", ...).
    pub input_spec: BTreeMap<String, Vec<TensorSpec>>,
}

impl ModelSpec {
    pub fn n_quant_layers(&self) -> usize {
        self.quant_layers.len()
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries.get(name).with_context(|| format!("model {} has no entry {name}", self.name))
    }

    /// Total parameter count (for reporting).
    pub fn n_weights(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    /// Batch size of the eval entry (leading dim of its first input).
    pub fn eval_batch(&self) -> usize {
        self.input_spec["eval"][0].shape[0]
    }

    pub fn train_batch(&self) -> usize {
        self.input_spec["train"][0].shape[0]
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
}

/// Directory marker used by the builtin (CPU-native) manifest.
pub const BUILTIN_DIR: &str = "<builtin>";

impl Manifest {
    /// The builtin model zoo, constructed in Rust with the same specs and
    /// entry ABI `aot.py` would emit — what the CPU backend executes (no
    /// artifacts on disk required).
    pub fn builtin() -> Manifest {
        let models = crate::runtime::cpu::zoo::builtin_models();
        Manifest { dir: PathBuf::from(BUILTIN_DIR), models }
    }

    /// Load the default artifacts directory when present, else fall back
    /// to the builtin zoo.
    pub fn resolve() -> Manifest {
        let dir = Self::default_dir();
        if dir.join("manifest.json").exists() {
            match Self::load(&dir) {
                Ok(m) => return m,
                Err(e) => {
                    log::warn!("ignoring unreadable artifacts at {dir:?} ({e:#}); using builtin");
                }
            }
        }
        Self::builtin()
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let json = text.parse::<Json>().map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let mut models = BTreeMap::new();
        let Some(model_objs) = json.req("models").as_obj() else {
            bail!("manifest: models is not an object")
        };
        for (name, m) in model_objs {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { dir, models })
    }

    /// Locate the artifacts directory: `$LAPQ_ARTIFACTS`, else
    /// `<crate>/artifacts`, else `./artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("LAPQ_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if here.join("manifest.json").exists() {
            return here;
        }
        PathBuf::from("artifacts")
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| format!("unknown model '{name}'"))
    }

    pub fn hlo_path(&self, model: &str, entry: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.model(model)?.entry(entry)?.file))
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelSpec> {
    let params = m
        .req("params")
        .as_arr()
        .context("params")?
        .iter()
        .map(|p| ParamSpec {
            name: p.req("name").as_str().unwrap_or_default().to_string(),
            shape: p.req("shape").usize_arr(),
            init: p.req("init").as_str().unwrap_or("zeros").to_string(),
            fan_in: p.req("fan_in").as_usize().unwrap_or(0),
        })
        .collect();
    let quant_layers = m
        .req("quant_layers")
        .as_arr()
        .context("quant_layers")?
        .iter()
        .map(|q| QuantLayerSpec {
            name: q.req("name").as_str().unwrap_or_default().to_string(),
            weight_param: q.req("weight_param").as_usize().unwrap_or(0),
            act_signed: q.req("act_signed").as_bool().unwrap_or(true),
            kind: q.req("kind").as_str().unwrap_or("conv").to_string(),
        })
        .collect();
    let mut entries = BTreeMap::new();
    for (ename, e) in m.req("entries").as_obj().context("entries")? {
        let outputs = e
            .req("outputs")
            .as_arr()
            .context("outputs")?
            .iter()
            .map(|o| {
                (o.req("shape").usize_arr(), o.req("dtype").as_str().unwrap_or("f32").to_string())
            })
            .collect();
        entries.insert(
            ename.clone(),
            EntrySpec {
                file: e.req("file").as_str().unwrap_or_default().to_string(),
                n_args: e.req("n_args").as_usize().unwrap_or(0),
                outputs,
            },
        );
    }
    let mut input_spec = BTreeMap::new();
    for (ename, list) in m.req("input_spec").as_obj().context("input_spec")? {
        let specs = list
            .as_arr()
            .context("input list")?
            .iter()
            .map(|t| TensorSpec {
                name: t.req("name").as_str().unwrap_or_default().to_string(),
                shape: t.req("shape").usize_arr(),
                dtype: t.req("dtype").as_str().unwrap_or("f32").to_string(),
            })
            .collect();
        input_spec.insert(ename.clone(), specs);
    }
    Ok(ModelSpec {
        name: name.to_string(),
        task: m.req("task").as_str().unwrap_or("vision").to_string(),
        params,
        quant_layers,
        entries,
        input_spec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_has_the_zoo() {
        let m = Manifest::builtin();
        assert!(m.models.len() >= 5, "{:?}", m.models.keys());
        let cnn = m.model("cnn6").unwrap();
        assert_eq!(cnn.n_quant_layers(), 6);
        assert_eq!(cnn.params.len(), 12);
        assert_eq!(cnn.task, "vision");
        assert!(cnn.n_weights() > 50_000);
    }

    #[test]
    fn arg_count_abi() {
        // The builtin zoo is what the default (CPU) backend executes.
        let m = Manifest::builtin();
        for spec in m.models.values() {
            let n_p = spec.params.len();
            let fq = spec.entry("fwd_quant").unwrap();
            assert_eq!(fq.n_args, n_p + 4 + spec.input_spec["eval"].len(), "{}", spec.name);
            let ts = spec.entry("train_step").unwrap();
            assert_eq!(ts.n_args, 2 * n_p + spec.input_spec["train"].len() + 1);
            // train_step returns params' + mom' + loss
            assert_eq!(ts.outputs.len(), 2 * n_p + 1);
        }
    }

    #[test]
    fn ncf_input_order_preserved() {
        let m = Manifest::builtin();
        let ncf = m.model("ncf").unwrap();
        let names: Vec<&str> =
            ncf.input_spec["train"].iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["users", "items", "labels"]);
    }

    #[test]
    fn entry_files_declared() {
        // Builtin entries carry the marker; on-disk manifests must point
        // at real HLO files.
        let m = Manifest::resolve();
        for (name, spec) in &m.models {
            for (ename, e) in &spec.entries {
                assert!(!e.file.is_empty(), "{name}/{ename} has no file");
                if e.file != BUILTIN_DIR {
                    let p = m.hlo_path(name, ename).unwrap();
                    assert!(p.exists(), "{p:?}");
                }
            }
        }
    }

    #[test]
    fn builtin_matches_default_eval_batches() {
        let m = Manifest::builtin();
        assert_eq!(m.model("mlp3").unwrap().eval_batch(), 512);
        assert_eq!(m.model("cnn6").unwrap().eval_batch(), 256);
        assert_eq!(m.model("ncf").unwrap().train_batch(), 2048);
        assert_eq!(m.dir, PathBuf::from(BUILTIN_DIR));
    }
}
