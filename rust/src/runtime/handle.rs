//! `PjrtEngine` — the `Send + Sync` facade over the PJRT engine thread
//! (`--features xla`), implementing [`Backend`].
//!
//! Spawning boots the engine thread (PJRT client + artifact registry);
//! dropping the last handle shuts it down.  All methods are synchronous
//! request/reply over mpsc channels — the XLA CPU executor is internally
//! multi-threaded, so a single in-flight execution already saturates the
//! machine; concurrency above this layer is about job orchestration (see
//! `coordinator::scheduler`), not parallel PJRT calls.

use super::backend::{Backend, BatchId, EngineStats, QuantParams, SessionId};
use super::engine::{Engine, Request};
use super::manifest::Manifest;
use crate::tensor::HostTensor;
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

/// Cloneable handle to the PJRT engine thread.
#[derive(Clone)]
pub struct PjrtEngine {
    tx: Sender<Request>,
    manifest: Arc<Manifest>,
    _joiner: Arc<Joiner>,
}

struct Joiner {
    tx: Sender<Request>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl PjrtEngine {
    /// Boot an engine over the given artifacts directory.
    pub fn start(artifacts_dir: impl AsRef<std::path::Path>) -> Result<PjrtEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::start_with_manifest(manifest)
    }

    pub fn start_with_manifest(manifest: Manifest) -> Result<PjrtEngine> {
        let (tx, rx) = channel();
        let m2 = manifest.clone();
        let (boot_tx, boot_rx) = channel();
        let thread = std::thread::Builder::new()
            .name("lapq-engine".into())
            .spawn(move || match Engine::new(m2) {
                Ok(engine) => {
                    let _ = boot_tx.send(Ok(()));
                    engine.run(rx);
                }
                Err(e) => {
                    let _ = boot_tx.send(Err(e));
                }
            })
            .context("spawning engine thread")?;
        boot_rx.recv().context("engine boot reply")??;
        Ok(PjrtEngine {
            tx: tx.clone(),
            manifest: Arc::new(manifest),
            _joiner: Arc::new(Joiner { tx, thread: Some(thread) }),
        })
    }

    fn call<T>(&self, make: impl FnOnce(Sender<Result<T>>) -> Request) -> Result<T> {
        let (rtx, rrx) = channel();
        self.tx.send(make(rtx)).ok().context("engine thread gone")?;
        rrx.recv().context("engine dropped reply")?
    }
}

impl Backend for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn create_session(&self, model: &str, params: Vec<HostTensor>) -> Result<SessionId> {
        self.call(|reply| Request::CreateSession { model: model.into(), params, reply })
    }

    fn drop_session(&self, sess: SessionId) -> Result<()> {
        self.call(|reply| Request::DropSession { sess, reply })
    }

    fn get_params(&self, sess: SessionId) -> Result<Vec<HostTensor>> {
        self.call(|reply| Request::GetParams { sess, reply })
    }

    fn set_params(&self, sess: SessionId, params: Vec<HostTensor>) -> Result<()> {
        self.call(|reply| Request::SetParams { sess, params, reply })
    }

    fn register_batch(&self, batch: Vec<HostTensor>) -> Result<BatchId> {
        self.call(|reply| Request::RegisterBatch { batch, reply })
    }

    fn drop_batch(&self, batch: BatchId) -> Result<()> {
        self.call(|reply| Request::DropBatch { batch, reply })
    }

    fn train_step(&self, sess: SessionId, batch: BatchId, lr: f32) -> Result<f32> {
        self.call(|reply| Request::TrainStep { sess, batch, lr, reply })
    }

    fn eval(
        &self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<(f32, f32)> {
        self.call(|reply| Request::Eval { sess, quant, batch, reply })
    }

    fn hitrate(&self, sess: SessionId, quant: Option<QuantParams>, batch: BatchId) -> Result<f32> {
        self.call(|reply| Request::Hitrate { sess, quant, batch, reply })
    }

    fn acts(&self, sess: SessionId, batch: BatchId) -> Result<Vec<HostTensor>> {
        self.call(|reply| Request::Acts { sess, batch, reply })
    }

    fn stats(&self) -> Result<EngineStats> {
        self.call(|reply| Request::Stats { reply })
    }
}
