//! `EngineHandle` — the `Send + Clone` facade over the engine thread.
//!
//! Spawning a handle boots the engine thread (PJRT client + artifact
//! registry); dropping the last handle shuts it down.  All methods are
//! synchronous request/reply over mpsc channels — the XLA CPU executor is
//! internally multi-threaded, so a single in-flight execution already
//! saturates the machine; concurrency above this layer is about job
//! orchestration (see `coordinator::scheduler`), not parallel PJRT calls.

use super::engine::{Engine, EngineStats, Request};
use super::manifest::Manifest;
use crate::tensor::HostTensor;
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

pub use super::engine::{BatchId, QuantParams, SessionId};

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Request>,
    manifest: Arc<Manifest>,
    _joiner: Arc<Joiner>,
}

struct Joiner {
    tx: Sender<Request>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl EngineHandle {
    /// Boot an engine over the given artifacts directory.
    pub fn start(artifacts_dir: impl AsRef<std::path::Path>) -> Result<EngineHandle> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::start_with_manifest(manifest)
    }

    /// Boot an engine over [`Manifest::default_dir`].
    pub fn start_default() -> Result<EngineHandle> {
        Self::start(Manifest::default_dir())
    }

    pub fn start_with_manifest(manifest: Manifest) -> Result<EngineHandle> {
        let (tx, rx) = channel();
        let m2 = manifest.clone();
        let (boot_tx, boot_rx) = channel();
        let thread = std::thread::Builder::new()
            .name("lapq-engine".into())
            .spawn(move || match Engine::new(m2) {
                Ok(engine) => {
                    let _ = boot_tx.send(Ok(()));
                    engine.run(rx);
                }
                Err(e) => {
                    let _ = boot_tx.send(Err(e));
                }
            })
            .context("spawning engine thread")?;
        boot_rx.recv().context("engine boot reply")??;
        Ok(EngineHandle {
            tx: tx.clone(),
            manifest: Arc::new(manifest),
            _joiner: Arc::new(Joiner { tx, thread: Some(thread) }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call<T>(&self, make: impl FnOnce(Sender<Result<T>>) -> Request) -> Result<T> {
        let (rtx, rrx) = channel();
        self.tx.send(make(rtx)).ok().context("engine thread gone")?;
        rrx.recv().context("engine dropped reply")?
    }

    /// Create a model session owning `params` (+ zero momentum).
    pub fn create_session(&self, model: &str, params: Vec<HostTensor>) -> Result<SessionId> {
        self.call(|reply| Request::CreateSession { model: model.into(), params, reply })
    }

    pub fn drop_session(&self, sess: SessionId) -> Result<()> {
        self.call(|reply| Request::DropSession { sess, reply })
    }

    pub fn get_params(&self, sess: SessionId) -> Result<Vec<HostTensor>> {
        self.call(|reply| Request::GetParams { sess, reply })
    }

    pub fn set_params(&self, sess: SessionId, params: Vec<HostTensor>) -> Result<()> {
        self.call(|reply| Request::SetParams { sess, params, reply })
    }

    /// Register a batch for repeated use (calibration / eval sets).
    pub fn register_batch(&self, batch: Vec<HostTensor>) -> Result<BatchId> {
        self.call(|reply| Request::RegisterBatch { batch, reply })
    }

    pub fn drop_batch(&self, batch: BatchId) -> Result<()> {
        self.call(|reply| Request::DropBatch { batch, reply })
    }

    /// One SGD-with-momentum step; updates session state, returns loss.
    pub fn train_step(&self, sess: SessionId, batch: BatchId, lr: f32) -> Result<f32> {
        self.call(|reply| Request::TrainStep { sess, batch, lr, reply })
    }

    /// Quantized (Some) or FP32 (None) forward: (mean loss, #correct).
    pub fn eval(
        &self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<(f32, f32)> {
        self.call(|reply| Request::Eval { sess, quant, batch, reply })
    }

    /// NCF hit-rate@10 hits for a (users, pos, negs) batch.
    pub fn hitrate(
        &self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<f32> {
        self.call(|reply| Request::Hitrate { sess, quant, batch, reply })
    }

    /// FP32 input activations of every quant layer for a batch.
    pub fn acts(&self, sess: SessionId, batch: BatchId) -> Result<Vec<HostTensor>> {
        self.call(|reply| Request::Acts { sess, batch, reply })
    }

    pub fn stats(&self) -> Result<EngineStats> {
        self.call(|reply| Request::Stats { reply })
    }
}
