//! Byte-level codecs for the quantized-model blob: little-endian f32/i8
//! payloads, the 2-per-byte INT4 nibble packing and the 4-per-byte INT2
//! crumb packing.
//!
//! The in-memory representation always holds one `i8` per weight (the
//! kernels index it directly); `pack_i4`/`unpack_i4` and
//! `pack_i2`/`unpack_i2` are the serialization forms for ≤4-bit and
//! ≤2-bit grids, halving resp. quartering the on-disk artifact.  The
//! same densities drive `QuantizedModel::packed_bytes`, so the
//! mixed-precision allocator's byte budget and the serialized size agree.

/// Pack two signed 4-bit values (range −8..=7) into one byte: `lo` in
/// the low nibble, `hi` in the high nibble — the single convention
/// shared by the serialized stream ([`pack_i4`]: even index low) and the
/// GEMM panel layout (`kernels::pack::PackedB4`: even k low).
pub fn i4_pair(lo: i8, hi: i8) -> u8 {
    ((lo as u8) & 0x0f) | (((hi as u8) & 0x0f) << 4)
}

/// Sign-extend the low nibble of an [`i4_pair`] byte.
pub fn i4_lo(b: u8) -> i8 {
    (((b & 0x0f) << 4) as i8) >> 4
}

/// Sign-extend the high nibble of an [`i4_pair`] byte.
pub fn i4_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Pack signed 4-bit values (range −8..=7; LAPQ grids use −7..=7) two per
/// byte: even index in the low nibble, odd index in the high nibble.  An
/// odd-length tail leaves the final high nibble zero.
pub fn pack_i4(q: &[i8]) -> Vec<u8> {
    debug_assert!(q.iter().all(|&v| (-8..=7).contains(&v)), "value outside i4 range");
    let mut out = Vec::with_capacity(q.len().div_ceil(2));
    for pair in q.chunks(2) {
        out.push(i4_pair(pair[0], if pair.len() > 1 { pair[1] } else { 0 }));
    }
    out
}

/// Inverse of [`pack_i4`]: expand `n` sign-extended values.
pub fn unpack_i4(bytes: &[u8], n: usize) -> Vec<i8> {
    assert_eq!(bytes.len(), n.div_ceil(2), "i4 payload is {} bytes for {} values", bytes.len(), n);
    let mut out = Vec::with_capacity(n);
    for &b in bytes {
        out.push(i4_lo(b));
        if out.len() < n {
            out.push(i4_hi(b));
        }
    }
    out
}

/// Pack signed 2-bit values (range −2..=1; ternary LAPQ grids use
/// −1..=1) four per byte, index `i` in bits `2(i mod 4)..2(i mod 4)+2`.
/// A short tail leaves the remaining crumbs zero.
pub fn pack_i2(q: &[i8]) -> Vec<u8> {
    debug_assert!(q.iter().all(|&v| (-2..=1).contains(&v)), "value outside i2 range");
    let mut out = Vec::with_capacity(q.len().div_ceil(4));
    for quad in q.chunks(4) {
        let mut b = 0u8;
        for (k, &v) in quad.iter().enumerate() {
            b |= ((v as u8) & 0x03) << (2 * k);
        }
        out.push(b);
    }
    out
}

/// Inverse of [`pack_i2`]: expand `n` sign-extended values.
pub fn unpack_i2(bytes: &[u8], n: usize) -> Vec<i8> {
    assert_eq!(bytes.len(), n.div_ceil(4), "i2 payload is {} bytes for {} values", bytes.len(), n);
    let mut out = Vec::with_capacity(n);
    for &b in bytes {
        for k in 0..4 {
            if out.len() < n {
                out.push(((((b >> (2 * k)) & 0x03) << 6) as i8) >> 6);
            }
        }
    }
    out
}

/// Append `xs` to `out` as little-endian f32 bytes.
pub fn f32s_to_le(xs: &[f32], out: &mut Vec<u8>) {
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode a little-endian f32 payload (length must be a multiple of 4).
pub fn le_to_f32s(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "f32 payload length {}", bytes.len());
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Append `q` to `out` as raw two's-complement bytes.
pub fn i8s_to_le(q: &[i8], out: &mut Vec<u8>) {
    out.extend(q.iter().map(|&v| v as u8));
}

/// Decode a raw i8 payload.
pub fn le_to_i8s(bytes: &[u8]) -> Vec<i8> {
    bytes.iter().map(|&b| b as i8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn i4_roundtrip_even_and_odd() {
        for n in [0usize, 1, 2, 3, 8, 17] {
            let value = |i: usize| ((i as i64 * 5 - 7).rem_euclid(15) - 7) as i8;
            let q: Vec<i8> = (0..n).map(value).collect();
            let packed = pack_i4(&q);
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(unpack_i4(&packed, n), q);
        }
    }

    #[test]
    fn i4_roundtrip_random() {
        let mut rng = Pcg32::seeded(11);
        for _ in 0..50 {
            let n = rng.below(64) as usize;
            let q: Vec<i8> = (0..n).map(|_| (rng.below(16) as i8) - 8).collect();
            assert_eq!(unpack_i4(&pack_i4(&q), n), q);
        }
    }

    #[test]
    fn i4_extremes() {
        let q = vec![-8i8, 7, -1, 0, 1, -7];
        assert_eq!(unpack_i4(&pack_i4(&q), 6), q);
    }

    #[test]
    fn i4_pair_roundtrips_both_nibbles() {
        for lo in -8i8..=7 {
            for hi in -8i8..=7 {
                let b = i4_pair(lo, hi);
                assert_eq!((i4_lo(b), i4_hi(b)), (lo, hi), "byte {b:#04x}");
            }
        }
    }

    #[test]
    fn i2_roundtrip_even_and_odd() {
        for n in [0usize, 1, 2, 3, 4, 5, 8, 17] {
            let value = |i: usize| ((i as i64 * 3 - 2).rem_euclid(4) - 2) as i8;
            let q: Vec<i8> = (0..n).map(value).collect();
            let packed = pack_i2(&q);
            assert_eq!(packed.len(), n.div_ceil(4));
            assert_eq!(unpack_i2(&packed, n), q);
        }
    }

    #[test]
    fn i2_roundtrip_random() {
        let mut rng = Pcg32::seeded(13);
        for _ in 0..50 {
            let n = rng.below(64) as usize;
            let q: Vec<i8> = (0..n).map(|_| (rng.below(4) as i8) - 2).collect();
            assert_eq!(unpack_i2(&pack_i2(&q), n), q);
        }
    }

    #[test]
    fn i2_extremes() {
        let q = vec![-2i8, 1, -1, 0, 1, -2, 0];
        assert_eq!(unpack_i2(&pack_i2(&q), 7), q);
    }

    #[test]
    fn f32_and_i8_payloads_roundtrip() {
        let xs = [0.0f32, -1.5, 3.25e-7, f32::MAX];
        let mut b = Vec::new();
        f32s_to_le(&xs, &mut b);
        assert_eq!(le_to_f32s(&b), xs.to_vec());
        let qs = [-128i8, -1, 0, 1, 127];
        let mut b2 = Vec::new();
        i8s_to_le(&qs, &mut b2);
        assert_eq!(le_to_i8s(&b2), qs.to_vec());
    }
}
