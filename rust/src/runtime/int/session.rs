//! `InferSession` — executes a packed [`QuantizedModel`] natively.
//!
//! Two modes share one graph walk per model (`mlp3`, `cnn6`, `ncf`,
//! mirroring `runtime/cpu/zoo.rs` layer by layer):
//!
//! * [`ExecMode::Int`] — layers whose weights *and* input activations are
//!   quantized run the integer kernels: quantize the f32 input onto its
//!   grid, i8×i8→i32 GEMM / im2col conv / i8 embedding gather, then the
//!   dequantize+bias epilogue.  GEMM and conv route through the blocked
//!   micro-kernel dispatcher (`kernels::kernel_choice`, overridable with
//!   `LAPQ_KERNEL=scalar|blocked|simd`); ≤4-bit weight payloads take the
//!   nibble-domain INT4 micro-kernel.  Every tier is bit-identical, so
//!   the choice never changes a logit.  Everything else (first/last
//!   layers the paper leaves at FP32, pooling, residual glue) falls back
//!   to the fake-quant f32 path.
//! * [`ExecMode::Simulated`] — the fake-quant reference, computed with
//!   the exact ops (`ops::matmul`, `ops::conv2d`, `fake_quant_one`) and
//!   accumulation order of the CPU backend, so it is bit-identical to
//!   `Backend::eval` under `QuantizedModel::quant`.
//!
//! With the power-of-two scales `pack` emits, the two modes agree
//! bit-for-bit wherever the i32 accumulator stays below 2²⁴ (all of
//! `mlp3`/`ncf`; `cnn6`'s widest conv can differ by one grid step) —
//! asserted by `tests/int_parity.rs`.

use super::kernels;
use super::model::{Payload, QuantizedModel};
use crate::quant::quantizer::fake_quant_one;
use crate::quant::GridKind;
use crate::runtime::cpu::ops::{self, Arr};
use crate::runtime::cpu::zoo::{check_ids, check_vision_input};
use crate::runtime::manifest::ModelSpec;
use crate::tensor::{Data, HostTensor};
use anyhow::{bail, Result};

/// Which kernels execute the quantized layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Packed integer kernels, f32 fallback for uncovered layers.
    Int,
    /// Fake-quant f32 reference (bit-identical to the CPU backend).
    Simulated,
}

/// Per-quant-layer probe recorded when `record_taps` is set.
#[derive(Clone, Debug)]
pub struct LayerTap {
    pub name: String,
    /// Grid indices of the quantized input (empty when the layer's
    /// activations ran f32).
    pub qx: Vec<i32>,
    /// Layer output (bias added, pre-ReLU).
    pub y: Arr,
}

/// Result of one forward pass.
#[derive(Clone, Debug)]
pub struct InferResult {
    pub logits: Arr,
    pub taps: Vec<LayerTap>,
    /// How many quant layers executed with integer kernels.
    pub int_layers: usize,
}

struct Run {
    record: bool,
    taps: Vec<LayerTap>,
    int_layers: usize,
}

impl Run {
    fn tap(&mut self, name: &str, qx: Vec<i32>, y: &Arr) {
        if self.record {
            self.taps.push(LayerTap { name: name.to_string(), qx, y: y.clone() });
        }
    }
}

/// A ready-to-serve view over a packed model.
pub struct InferSession<'a> {
    spec: &'a ModelSpec,
    model: &'a QuantizedModel,
    /// Record per-layer probes (parity tests); off for serving.
    pub record_taps: bool,
}

fn f32s<'a>(ts: &'a HostTensor, what: &str) -> Result<&'a [f32]> {
    match &ts.data {
        Data::F32(v) => Ok(v),
        Data::I32(_) => bail!("{what}: expected f32 tensor"),
    }
}

fn i32s<'a>(ts: &'a HostTensor, what: &str) -> Result<&'a [i32]> {
    match &ts.data {
        Data::I32(v) => Ok(v),
        Data::F32(_) => bail!("{what}: expected i32 tensor"),
    }
}

fn relu(x: &Arr) -> Arr {
    Arr::new(x.shape.clone(), x.data.iter().map(|&v| v.max(0.0)).collect())
}

/// Global average pool `(N,H,W,C) -> (N,C)` — same accumulation order as
/// `Tape::gap`.
fn gap(x: &Arr) -> Arr {
    assert_eq!(x.shape.len(), 4, "gap input {:?}", x.shape);
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let inv = 1.0 / (h * w) as f32;
    let mut out = Arr::zeros(vec![n, c]);
    for img in 0..n {
        let o_row = &mut out.data[img * c..(img + 1) * c];
        for px in x.data[img * h * w * c..(img + 1) * h * w * c].chunks(c) {
            for (o, &v) in o_row.iter_mut().zip(px) {
                *o += v * inv;
            }
        }
    }
    out
}

fn mul(a: &Arr, b: &Arr) -> Arr {
    assert_eq!(a.shape, b.shape, "mul {:?} vs {:?}", a.shape, b.shape);
    Arr::new(a.shape.clone(), a.data.iter().zip(&b.data).map(|(x, y)| x * y).collect())
}

fn concat(a: &Arr, b: &Arr) -> Arr {
    let (ca, cb) = (a.last_dim(), b.last_dim());
    let r = a.numel() / ca;
    assert_eq!(r, b.numel() / cb, "concat rows {:?} vs {:?}", a.shape, b.shape);
    let mut data = Vec::with_capacity(r * (ca + cb));
    for row in 0..r {
        data.extend_from_slice(&a.data[row * ca..(row + 1) * ca]);
        data.extend_from_slice(&b.data[row * cb..(row + 1) * cb]);
    }
    Arr::new(vec![r, ca + cb], data)
}

/// Broadcast-add a bias over the last axis, like `Tape::add_bias`.
fn add_bias(y: &mut Arr, b: &[f32]) {
    let c = y.last_dim();
    assert_eq!(b.len(), c);
    for row in y.data.chunks_mut(c) {
        for (o, &add) in row.iter_mut().zip(b) {
            *o += add;
        }
    }
}

fn qx_ints(x: &[f32], da: f32, qma: f32, signed: bool) -> Vec<i32> {
    if signed {
        kernels::quantize_signed(x, da, qma).iter().map(|&v| v as i32).collect()
    } else {
        kernels::quantize_unsigned(x, da, qma).iter().map(|&v| v as i32).collect()
    }
}

/// Widen a quantized buffer for a tap, only when recording.
fn tap_ints<A: kernels::QAct>(run: &Run, q: &[A]) -> Vec<i32> {
    if run.record {
        q.iter().map(|&v| v.widen()).collect()
    } else {
        Vec::new()
    }
}

impl<'a> InferSession<'a> {
    pub fn new(spec: &'a ModelSpec, model: &'a QuantizedModel) -> Result<InferSession<'a>> {
        if spec.name != model.model {
            bail!("spec is for '{}', packed model is '{}'", spec.name, model.model);
        }
        if model.params.len() != spec.params.len() {
            bail!("packed model has {} params, spec {}", model.params.len(), spec.params.len());
        }
        for (p, ps) in model.params.iter().zip(&spec.params) {
            if p.shape != ps.shape {
                bail!("param {} shape {:?} != spec {:?}", ps.name, p.shape, ps.shape);
            }
        }
        let (have, want) = (model.layers.len(), spec.n_quant_layers());
        if have != want {
            bail!("packed model has {have} layers, spec {want}");
        }
        let q = &model.quant;
        let lens = [q.dw.len(), q.qmw.len(), q.da.len(), q.qma.len()];
        if lens.iter().any(|&l| l != want) {
            bail!("packed model Δ vectors sized {lens:?}, spec has {want} quant layers");
        }
        Ok(InferSession { spec, model, record_taps: false })
    }

    /// Batched forward pass: vision batches are `(x,)`, NCF batches are
    /// `(users, items)`.  Any batch size.
    pub fn infer(&self, batch: &[HostTensor], mode: ExecMode) -> Result<InferResult> {
        let mut run = Run { record: self.record_taps, taps: Vec::new(), int_layers: 0 };
        let logits = if self.spec.task == "ncf" {
            if batch.len() != 2 {
                bail!("ncf infer batch needs (users, items), got {} tensors", batch.len());
            }
            let users = i32s(&batch[0], "users")?;
            let items = i32s(&batch[1], "items")?;
            if users.len() != items.len() {
                bail!("users ({}) vs items ({}) length mismatch", users.len(), items.len());
            }
            check_ids(self.spec, users, items)?;
            self.ncf_logits(users, items, mode, &mut run)?
        } else {
            if batch.len() != 1 {
                bail!("vision infer batch needs (x,), got {} tensors", batch.len());
            }
            check_vision_input(self.spec, &batch[0])?;
            let x = Arr::new(batch[0].shape.clone(), f32s(&batch[0], "x")?.to_vec());
            self.vision_logits(&x, mode, &mut run)?
        };
        Ok(InferResult { logits, taps: run.taps, int_layers: run.int_layers })
    }

    /// Coalesced multi-request forward pass — the micro-batcher's entry
    /// point.  `parts[i]` is one request's input tuple (`(x,)` vision /
    /// `(users, items)` NCF); all parts are concatenated along the batch
    /// axis, executed as **one** kernel invocation, and the logits are
    /// scattered back per part.
    ///
    /// Every row of every kernel (GEMM row, conv image, embedding
    /// gather, requant epilogue) accumulates independently of its batch
    /// neighbours, so the result is bit-for-bit identical to calling
    /// [`InferSession::infer`] per part — the contract the concurrent
    /// server's batched path relies on, pinned by the tests below.
    pub fn infer_many(
        &self,
        parts: &[Vec<HostTensor>],
        mode: ExecMode,
    ) -> Result<Vec<InferResult>> {
        if parts.len() <= 1 {
            return parts.iter().map(|p| self.infer(p, mode)).collect();
        }
        if self.record_taps {
            bail!("infer_many does not support record_taps (probe requests individually)");
        }
        // Concatenate inputs along the batch axis, remembering each
        // part's row count for the scatter.
        let (combined, rows) = if self.spec.task == "ncf" {
            let mut users = Vec::new();
            let mut items = Vec::new();
            let mut rows = Vec::with_capacity(parts.len());
            for (pi, p) in parts.iter().enumerate() {
                if p.len() != 2 {
                    bail!("ncf infer part {pi} needs (users, items), got {} tensors", p.len());
                }
                let u = i32s(&p[0], "users")?;
                let it = i32s(&p[1], "items")?;
                if u.len() != it.len() {
                    bail!("part {pi}: users ({}) vs items ({}) mismatch", u.len(), it.len());
                }
                rows.push(u.len());
                users.extend_from_slice(u);
                items.extend_from_slice(it);
            }
            let ut = HostTensor::i32(vec![users.len()], users);
            let it = HostTensor::i32(vec![items.len()], items);
            (vec![ut, it], rows)
        } else {
            let mut data = Vec::new();
            let mut rows = Vec::with_capacity(parts.len());
            let mut trailing: Option<&[usize]> = None;
            for (pi, p) in parts.iter().enumerate() {
                if p.len() != 1 {
                    bail!("vision infer part {pi} needs (x,), got {} tensors", p.len());
                }
                let x = &p[0];
                if x.shape.is_empty() {
                    bail!("part {pi}: scalar input");
                }
                match trailing {
                    None => trailing = Some(&x.shape[1..]),
                    Some(t) if t == &x.shape[1..] => {}
                    Some(t) => {
                        bail!("part {pi} shape {:?} does not stack onto [B, {t:?}]", x.shape)
                    }
                }
                rows.push(x.shape[0]);
                data.extend_from_slice(f32s(x, "x")?);
            }
            let mut shape = vec![rows.iter().sum::<usize>()];
            shape.extend_from_slice(trailing.unwrap_or(&[]));
            (vec![HostTensor::f32(shape, data)], rows)
        };
        let res = self.infer(&combined, mode)?;
        // Scatter logits rows back to their requests.
        let c = res.logits.last_dim().max(1);
        let mut out = Vec::with_capacity(parts.len());
        let mut off = 0usize;
        for &n in &rows {
            let slice = res.logits.data[off * c..(off + n) * c].to_vec();
            off += n;
            out.push(InferResult {
                logits: Arr::new(vec![n, c], slice),
                taps: Vec::new(),
                int_layers: res.int_layers,
            });
        }
        Ok(out)
    }

    /// Fake-quant of an activation tensor (no-op when Δa = 0).
    fn fq_act(&self, x: &Arr, qi: usize) -> Arr {
        let da = self.model.quant.da[qi];
        if da <= 0.0 {
            return x.clone();
        }
        let qma = self.model.quant.qma[qi];
        let kind = GridKind::from_signed(self.spec.quant_layers[qi].act_signed);
        let data = x.data.iter().map(|&v| fake_quant_one(v, da, qma, kind)).collect();
        Arr::new(x.shape.clone(), data)
    }

    /// Materialize a parameter as f32 (dequantizing Int payloads; the
    /// dequantized values are exactly the fake-quant reference weights).
    fn weight_f32(&self, pi: usize) -> Vec<f32> {
        match &self.model.params[pi].payload {
            Payload::F32(v) => v.clone(),
            Payload::Int { q, scale, .. } => {
                let co = scale.len();
                q.iter().enumerate().map(|(i, &qv)| qv as f32 * scale[i % co]).collect()
            }
        }
    }

    fn bias_vec(&self, qi: usize, co: usize) -> Result<Vec<f32>> {
        let plan = &self.model.layers[qi];
        match plan.bias_param {
            Some(bi) => match &self.model.params[bi].payload {
                Payload::F32(v) => {
                    if v.len() != co {
                        bail!("layer {}: bias len {} != {co}", plan.name, v.len());
                    }
                    Ok(v.clone())
                }
                Payload::Int { .. } => bail!("layer {}: bias unexpectedly quantized", plan.name),
            },
            None => Ok(vec![0.0; co]),
        }
    }

    /// Quantized dense layer `fq(x) @ fq(w) + b`.
    fn dense(&self, x: &Arr, qi: usize, mode: ExecMode, run: &mut Run) -> Result<Arr> {
        let plan = &self.model.layers[qi];
        let wp = &self.model.params[plan.weight_param];
        if wp.shape.len() != 2 {
            bail!("dense {}: weight {:?} is not a matrix", plan.name, wp.shape);
        }
        let (k, n) = (wp.shape[0], wp.shape[1]);
        if x.shape.len() != 2 || x.shape[1] != k {
            bail!("dense {}: input {:?} vs weight {:?}", plan.name, x.shape, wp.shape);
        }
        let m = x.shape[0];
        let bias = self.bias_vec(qi, n)?;
        let da = self.model.quant.da[qi];
        let qma = self.model.quant.qma[qi];
        let signed = self.spec.quant_layers[qi].act_signed;

        if mode == ExecMode::Int && da > 0.0 {
            if let Payload::Int { bits, q, scale } = &wp.payload {
                // ≤4-bit payloads take the nibble-domain micro-kernel;
                // either way the accumulators are bit-identical across
                // tiers (tests/kernel_diff), so the tap contract holds.
                let choice = kernels::kernel_choice();
                let matmul_q = |qxv: &[i8]| {
                    if *bits <= 4 {
                        kernels::gemm_i4_with(choice, qxv, q, m, k, n)
                    } else {
                        kernels::gemm_with(choice, qxv, q, m, k, n)
                    }
                };
                let matmul_qu = |qxv: &[u8]| {
                    if *bits <= 4 {
                        kernels::gemm_i4_with(choice, qxv, q, m, k, n)
                    } else {
                        kernels::gemm_with(choice, qxv, q, m, k, n)
                    }
                };
                let combined: Vec<f32> = scale.iter().map(|&s| s * da).collect();
                let (acc, qx) = if signed {
                    let qxv = kernels::quantize_signed(&x.data, da, qma);
                    let tap = tap_ints(run, &qxv);
                    (matmul_q(&qxv), tap)
                } else {
                    let qxv = kernels::quantize_unsigned(&x.data, da, qma);
                    let tap = tap_ints(run, &qxv);
                    (matmul_qu(&qxv), tap)
                };
                let mut y = Arr::zeros(vec![m, n]);
                kernels::dequant_bias(&acc, n, &combined, &bias, &mut y.data);
                run.int_layers += 1;
                run.tap(&plan.name, qx, &y);
                return Ok(y);
            }
        }
        let xa = self.fq_act(x, qi);
        let wf = self.weight_f32(plan.weight_param);
        let mut y = Arr::new(vec![m, n], ops::matmul(&xa.data, &wf, m, k, n));
        add_bias(&mut y, &bias);
        let qx =
            if run.record && da > 0.0 { qx_ints(&x.data, da, qma, signed) } else { Vec::new() };
        run.tap(&plan.name, qx, &y);
        Ok(y)
    }

    /// Quantized SAME conv (+ bias), groups = 1.
    fn conv(
        &self,
        x: &Arr,
        qi: usize,
        stride: usize,
        mode: ExecMode,
        run: &mut Run,
    ) -> Result<Arr> {
        let plan = &self.model.layers[qi];
        let wp = &self.model.params[plan.weight_param];
        if wp.shape.len() != 4 || x.shape.len() != 4 {
            bail!("conv {}: input {:?} / weight {:?}", plan.name, x.shape, wp.shape);
        }
        let d = kernels::conv_shape(&x.shape, &wp.shape, stride);
        let bias = self.bias_vec(qi, d.co)?;
        let da = self.model.quant.da[qi];
        let qma = self.model.quant.qma[qi];
        let signed = self.spec.quant_layers[qi].act_signed;

        if mode == ExecMode::Int && da > 0.0 {
            if let Payload::Int { bits, q, scale } = &wp.payload {
                let choice = kernels::kernel_choice();
                let conv_q = |qxv: &[i8]| {
                    if *bits <= 4 {
                        kernels::conv_int_i4_with(choice, qxv, q, &d)
                    } else {
                        kernels::conv_int_with(choice, qxv, q, &d)
                    }
                };
                let conv_qu = |qxv: &[u8]| {
                    if *bits <= 4 {
                        kernels::conv_int_i4_with(choice, qxv, q, &d)
                    } else {
                        kernels::conv_int_with(choice, qxv, q, &d)
                    }
                };
                let combined: Vec<f32> = scale.iter().map(|&s| s * da).collect();
                let (acc, qx) = if signed {
                    let qxv = kernels::quantize_signed(&x.data, da, qma);
                    let tap = tap_ints(run, &qxv);
                    (conv_q(&qxv), tap)
                } else {
                    let qxv = kernels::quantize_unsigned(&x.data, da, qma);
                    let tap = tap_ints(run, &qxv);
                    (conv_qu(&qxv), tap)
                };
                let mut y = Arr::zeros(vec![d.n, d.ho, d.wo, d.co]);
                kernels::dequant_bias(&acc, d.co, &combined, &bias, &mut y.data);
                run.int_layers += 1;
                run.tap(&plan.name, qx, &y);
                return Ok(y);
            }
        }
        let xa = self.fq_act(x, qi);
        let wf = Arr::new(wp.shape.clone(), self.weight_f32(plan.weight_param));
        let mut y = ops::conv2d(&xa, &wf, stride, 1);
        add_bias(&mut y, &bias);
        let qx =
            if run.record && da > 0.0 { qx_ints(&x.data, da, qma, signed) } else { Vec::new() };
        run.tap(&plan.name, qx, &y);
        Ok(y)
    }

    /// Embedding gather; the CPU-backend graph fake-quants the *gathered*
    /// rows on the weight grid (Δa stays 0), so gathering i8 rows and
    /// dequantizing per channel is exactly the reference.
    fn embed(&self, idx: &[i32], qi: usize, mode: ExecMode, run: &mut Run) -> Result<Arr> {
        let plan = &self.model.layers[qi];
        let wp = &self.model.params[plan.weight_param];
        if wp.shape.len() != 2 {
            bail!("embed {}: table {:?}", plan.name, wp.shape);
        }
        let dim = wp.shape[1];
        let mut data = Vec::with_capacity(idx.len() * dim);
        let mut qx = Vec::new();
        match &wp.payload {
            Payload::Int { q, scale, .. } => {
                for &i in idx {
                    let row = &q[i as usize * dim..(i as usize + 1) * dim];
                    for (j, &qv) in row.iter().enumerate() {
                        data.push(qv as f32 * scale[j]);
                    }
                    if run.record {
                        qx.extend(row.iter().map(|&v| v as i32));
                    }
                }
                if mode == ExecMode::Int {
                    run.int_layers += 1;
                }
            }
            Payload::F32(v) => {
                for &i in idx {
                    data.extend_from_slice(&v[i as usize * dim..(i as usize + 1) * dim]);
                }
            }
        }
        let y = Arr::new(vec![idx.len(), dim], data);
        run.tap(&plan.name, qx, &y);
        Ok(y)
    }

    fn vision_logits(&self, x: &Arr, mode: ExecMode, run: &mut Run) -> Result<Arr> {
        match self.spec.name.as_str() {
            "mlp3" => {
                let h = self.dense(x, 0, mode, run)?;
                let h = relu(&h);
                let h = self.dense(&h, 1, mode, run)?;
                let h = relu(&h);
                self.dense(&h, 2, mode, run)
            }
            "cnn6" => {
                let strides = [1usize, 2, 1, 2, 1];
                let mut h = x.clone();
                for (i, &s) in strides.iter().enumerate() {
                    h = self.conv(&h, i, s, mode, run)?;
                    h = relu(&h);
                }
                let pooled = gap(&h);
                self.dense(&pooled, 5, mode, run)
            }
            other => bail!("integer engine does not cover vision model '{other}'"),
        }
    }

    fn ncf_logits(
        &self,
        users: &[i32],
        items: &[i32],
        mode: ExecMode,
        run: &mut Run,
    ) -> Result<Arr> {
        if self.spec.name != "ncf" {
            bail!("integer engine does not cover ncf model '{}'", self.spec.name);
        }
        let eg_u = self.embed(users, 0, mode, run)?;
        let eg_i = self.embed(items, 1, mode, run)?;
        let em_u = self.embed(users, 2, mode, run)?;
        let em_i = self.embed(items, 3, mode, run)?;
        let gmf = mul(&eg_u, &eg_i);
        let h = concat(&em_u, &em_i);
        let h = self.dense(&h, 4, mode, run)?;
        let h = relu(&h);
        let h = self.dense(&h, 5, mode, run)?;
        let h = relu(&h);
        let z = concat(&gmf, &h);
        self.dense(&z, 6, mode, run)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::QuantParams;
    use crate::runtime::int::model::{pack, PackOpts};
    use crate::runtime::manifest::Manifest;
    use crate::tensor::init::init_params;

    fn int8_quant(n: usize) -> QuantParams {
        QuantParams {
            dw: vec![0.0625; n],
            qmw: vec![127.0; n],
            da: vec![0.25; n],
            qma: vec![127.0; n],
        }
    }

    #[test]
    fn mlp3_int_forward_shapes_and_counts() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 3);
        let qm = pack(spec, &params, &int8_quant(3), None, &PackOpts::default()).unwrap();
        let sess = InferSession::new(spec, &qm).unwrap();
        let data = crate::data::vision::SynthVision::new(4);
        let (x, _) = data.batch_features(0, 16, 64);
        let res = sess.infer(&[x], ExecMode::Int).unwrap();
        assert_eq!(res.logits.shape, vec![16, 16]);
        assert_eq!(res.int_layers, 3);
        assert!(res.logits.data.iter().all(|v| v.is_finite()));
    }

    /// The micro-batcher's contract: one coalesced execution must be
    /// bit-for-bit identical to serving each part separately.
    #[test]
    fn infer_many_matches_individual_bit_for_bit() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 5);
        let qm = pack(spec, &params, &int8_quant(3), None, &PackOpts::default()).unwrap();
        let sess = InferSession::new(spec, &qm).unwrap();
        let data = crate::data::vision::SynthVision::new(9);
        let (x, _) = data.batch_features(0, 8, 64);
        // uneven split: rows 1 / 2 / 5 of the same batch
        let row = |a: usize, b: usize| {
            HostTensor::f32(vec![b - a, 64], x.f()[a * 64..b * 64].to_vec())
        };
        let parts = vec![vec![row(0, 1)], vec![row(1, 3)], vec![row(3, 8)]];
        for mode in [ExecMode::Int, ExecMode::Simulated] {
            let many = sess.infer_many(&parts, mode).unwrap();
            assert_eq!(many.len(), 3);
            for (part, got) in parts.iter().zip(&many) {
                let solo = sess.infer(part, mode).unwrap();
                assert_eq!(solo.logits.shape, got.logits.shape);
                assert_eq!(got.int_layers, solo.int_layers);
                for (a, b) in solo.logits.data.iter().zip(&got.logits.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{mode:?}: coalesced != solo");
                }
            }
        }
    }

    #[test]
    fn infer_many_rejects_mismatched_parts() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 3);
        let qm = pack(spec, &params, &int8_quant(3), None, &PackOpts::default()).unwrap();
        let sess = InferSession::new(spec, &qm).unwrap();
        let good = vec![HostTensor::zeros(vec![2, 64])];
        let ragged = vec![HostTensor::zeros(vec![2, 32])];
        assert!(sess.infer_many(&[good.clone(), ragged], ExecMode::Int).is_err());
        // a part with the wrong arity fails the whole batch
        assert!(sess.infer_many(&[good, vec![]], ExecMode::Int).is_err());
    }

    #[test]
    fn session_rejects_bad_inputs() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 3);
        let qm = pack(spec, &params, &int8_quant(3), None, &PackOpts::default()).unwrap();
        let sess = InferSession::new(spec, &qm).unwrap();
        // wrong arity
        assert!(sess.infer(&[], ExecMode::Int).is_err());
        // wrong feature width
        let bad = HostTensor::zeros(vec![4, 63]);
        assert!(sess.infer(&[bad], ExecMode::Int).is_err());
        // spec/model mismatch
        let other = m.model("cnn6").unwrap();
        assert!(InferSession::new(other, &qm).is_err());
    }
}
