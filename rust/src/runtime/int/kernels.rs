//! Integer forward kernels: activation quantization, i8×i8→i32 GEMM
//! (batch-parallel on scoped threads, like `runtime/cpu/ops.rs`), im2col
//! convolution, the dequantize+bias epilogue, and a fixed-point
//! requantization multiplier for pure-integer targets.
//!
//! Numerics contract: activation quantization uses the same
//! `round_half_even(x / Δ)` + clamp as `quant::quantizer::fake_quant_one`,
//! and the epilogue computes `acc as f32 * (Δa·Δw[c]) + bias[c]` with
//! plain (non-fused) f32 ops.  With power-of-two scales — the `pack`
//! default — every f32 step is exact while the i32 accumulator stays
//! below 2²⁴, which is what makes the integer engine bit-compatible with
//! the fake-quant reference on the dense models (see `tests/int_parity`).

use crate::quant::quantizer::round_half_even;
use crate::runtime::cpu::ops::{n_threads, par_items};

/// Quantized-activation element: `i8` (signed grids) or `u8` (post-ReLU
/// unsigned grids, qmax ≤ 255).
pub trait QAct: Copy + Default + Send + Sync {
    fn widen(self) -> i32;
}

impl QAct for i8 {
    fn widen(self) -> i32 {
        self as i32
    }
}

impl QAct for u8 {
    fn widen(self) -> i32 {
        self as i32
    }
}

/// Quantize to a signed grid: `clamp(round_half_even(x/Δ), -qmax, qmax)`.
/// The integer returned is exactly the grid index `fake_quant_one` snaps
/// to (it multiplies the same index back by Δ).
pub fn quantize_signed(xs: &[f32], delta: f32, qmax: f32) -> Vec<i8> {
    assert!(delta > 0.0 && qmax <= 127.0, "signed grid Δ={delta} qmax={qmax}");
    xs.iter().map(|&x| round_half_even(x / delta).clamp(-qmax, qmax) as i8).collect()
}

/// Quantize to an unsigned grid: `clamp(round_half_even(x/Δ), 0, qmax)`.
pub fn quantize_unsigned(xs: &[f32], delta: f32, qmax: f32) -> Vec<u8> {
    assert!(delta > 0.0 && qmax <= 255.0, "unsigned grid Δ={delta} qmax={qmax}");
    xs.iter().map(|&x| round_half_even(x / delta).clamp(0.0, qmax) as u8).collect()
}

fn gemm_row<A: QAct>(a_row: &[A], b: &[i8], n: usize, out: &mut [i32]) {
    for (k, &av) in a_row.iter().enumerate() {
        let a = av.widen();
        if a != 0 {
            let b_row = &b[k * n..k * n + n];
            for (o, &bv) in out.iter_mut().zip(b_row) {
                *o += a * bv as i32;
            }
        }
    }
}

/// `(M,K) quantized acts @ (K,N) i8 weights -> (M,N) i32` — row-blocked,
/// parallel over output rows when the work is substantial.  Skips
/// zero-valued activations (common post-ReLU), like the f32 `matmul`.
pub fn gemm<A: QAct>(a: &[A], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0i32; m * n];
    if m * k * n >= (1 << 21) && n_threads() > 1 {
        par_items(&mut out, n, |row, o| gemm_row(&a[row * k..(row + 1) * k], b, n, o));
    } else {
        for (row, o) in out.chunks_mut(n).enumerate() {
            gemm_row(&a[row * k..(row + 1) * k], b, n, o);
        }
    }
    out
}

/// SAME-padding geometry for the integer conv (groups = 1), mirroring
/// `ops::conv_dims` exactly.
#[derive(Clone, Debug)]
pub struct ConvShape {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub ci: usize,
    pub kh: usize,
    pub kw: usize,
    pub co: usize,
    pub stride: usize,
    pub ho: usize,
    pub wo: usize,
    pub pad_t: usize,
    pub pad_l: usize,
}

pub fn conv_shape(xs: &[usize], ws: &[usize], stride: usize) -> ConvShape {
    assert_eq!(xs.len(), 4, "conv input must be NHWC, got {xs:?}");
    assert_eq!(ws.len(), 4, "conv weight must be HWIO, got {ws:?}");
    let (n, h, w, ci) = (xs[0], xs[1], xs[2], xs[3]);
    let (kh, kw, wci, co) = (ws[0], ws[1], ws[2], ws[3]);
    assert_eq!(ci, wci, "channels {ci} != weight {wci} (integer conv has groups=1)");
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let pad_h = ((ho - 1) * stride + kh).saturating_sub(h);
    let pad_w = ((wo - 1) * stride + kw).saturating_sub(w);
    ConvShape { n, h, w, ci, kh, kw, co, stride, ho, wo, pad_t: pad_h / 2, pad_l: pad_w / 2 }
}

/// Gather one image's receptive fields into im2col rows of length
/// `kh*kw*ci`, zero-padded at the borders (the symmetric grid has no
/// zero-point, so padding is exactly `q = 0`).
pub fn im2col<A: QAct>(xq: &[A], d: &ConvShape) -> Vec<A> {
    let kk = d.kh * d.kw * d.ci;
    let mut out = vec![A::default(); d.ho * d.wo * kk];
    for oy in 0..d.ho {
        for ox in 0..d.wo {
            let rbase = (oy * d.wo + ox) * kk;
            for ky in 0..d.kh {
                let iy = (oy * d.stride + ky) as isize - d.pad_t as isize;
                if iy < 0 || iy >= d.h as isize {
                    continue;
                }
                for kx in 0..d.kw {
                    let ix = (ox * d.stride + kx) as isize - d.pad_l as isize;
                    if ix < 0 || ix >= d.w as isize {
                        continue;
                    }
                    let src = (iy as usize * d.w + ix as usize) * d.ci;
                    let dst = rbase + (ky * d.kw + kx) * d.ci;
                    out[dst..dst + d.ci].copy_from_slice(&xq[src..src + d.ci]);
                }
            }
        }
    }
    out
}

/// Integer SAME conv over a quantized NHWC batch: per image, im2col +
/// i8 GEMM against the HWIO weight viewed as `(kh*kw*ci, co)`.  Parallel
/// over images on scoped threads.
pub fn conv_int<A: QAct>(xq: &[A], wq: &[i8], d: &ConvShape) -> Vec<i32> {
    let kk = d.kh * d.kw * d.ci;
    assert_eq!(xq.len(), d.n * d.h * d.w * d.ci);
    assert_eq!(wq.len(), kk * d.co);
    let per_x = d.h * d.w * d.ci;
    let per_o = d.ho * d.wo * d.co;
    let mut out = vec![0i32; d.n * per_o];
    par_items(&mut out, per_o, |img, o| {
        let cols = im2col(&xq[img * per_x..(img + 1) * per_x], d);
        for (row, orow) in o.chunks_mut(d.co).enumerate() {
            gemm_row(&cols[row * kk..(row + 1) * kk], wq, d.co, orow);
        }
    });
    out
}

/// Dequantize+bias epilogue: `out[r,c] = acc[r,c] as f32 * combined[c] +
/// bias[c]`, where `combined[c] = Δa · Δw[c]`.  The multiply and add are
/// deliberately separate (no `mul_add`) so the rounding matches the
/// reference's matmul-then-`add_bias` sequence.
pub fn dequant_bias(acc: &[i32], co: usize, combined: &[f32], bias: &[f32], out: &mut [f32]) {
    assert_eq!(acc.len(), out.len());
    assert!(co > 0 && acc.len() % co == 0);
    assert_eq!(combined.len(), co);
    assert_eq!(bias.len(), co);
    for (arow, orow) in acc.chunks(co).zip(out.chunks_mut(co)) {
        for c in 0..co {
            orow[c] = arow[c] as f32 * combined[c] + bias[c];
        }
    }
}

/// Right-shift with round-half-to-even on the shifted-out bits (the
/// integer mirror of `quantizer::round_half_even`).
pub fn rshift_rhe(x: i64, b: u32) -> i64 {
    if b == 0 {
        return x;
    }
    if b >= 63 {
        // |x| < 2^62 everywhere we call this, so the value is < 0.5.
        return 0;
    }
    let floor = x >> b;
    let rem = x - (floor << b);
    let half = 1i64 << (b - 1);
    floor + if rem > half || (rem == half && (floor & 1) != 0) { 1 } else { 0 }
}

/// A positive real multiplier in fixed-point `mult · 2^-shift` form
/// (`mult` ∈ [2³⁰, 2³¹]): the classic requantization constant for
/// pure-integer targets that cannot afford a float epilogue.  With the
/// power-of-two scales `pack` emits, `apply` is exact (a pure shift).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FixedMult {
    pub mult: i64,
    pub shift: i32,
}

impl FixedMult {
    pub fn from_f32(m: f32) -> FixedMult {
        assert!(m > 0.0 && m.is_finite(), "fixed-point multiplier {m}");
        let mut v = m as f64;
        let mut e = 0i32;
        while v < 0.5 {
            v *= 2.0;
            e -= 1;
        }
        while v >= 1.0 {
            v /= 2.0;
            e += 1;
        }
        let mult = (v * (1u64 << 31) as f64).round() as i64;
        FixedMult { mult, shift: 31 - e }
    }

    /// `round_half_even(acc · m)` computed entirely in integers.
    pub fn apply(&self, acc: i32) -> i64 {
        let p = acc as i64 * self.mult;
        if self.shift >= 0 {
            rshift_rhe(p, self.shift as u32)
        } else {
            p << (-self.shift).min(31)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantizer::fake_quant_one;
    use crate::quant::GridKind;
    use crate::runtime::cpu::ops::matmul;
    use crate::util::rng::Pcg32;

    #[test]
    fn quantize_matches_fake_quant_grid() {
        let mut rng = Pcg32::seeded(3);
        let xs: Vec<f32> = (0..512).map(|_| rng.normal() * 2.0).collect();
        let (d, qmax) = (0.125f32, 127.0f32);
        let qs = quantize_signed(&xs, d, qmax);
        for (&x, &q) in xs.iter().zip(&qs) {
            assert_eq!(q as f32 * d, fake_quant_one(x, d, qmax, GridKind::Signed));
        }
        let qu = quantize_unsigned(&xs, d, 255.0);
        for (&x, &q) in xs.iter().zip(&qu) {
            assert_eq!(q as f32 * d, fake_quant_one(x, d, 255.0, GridKind::Unsigned));
        }
    }

    #[test]
    fn gemm_matches_f32_matmul_on_integer_data() {
        let mut rng = Pcg32::seeded(5);
        let (m, k, n) = (7, 33, 11);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let acc = gemm(&a, &b, m, k, n);
        let reference = matmul(&af, &bf, m, k, n);
        for (x, y) in acc.iter().zip(&reference) {
            assert_eq!(*x as f32, *y);
        }
    }

    #[test]
    fn gemm_unsigned_acts() {
        let a: Vec<u8> = vec![0, 1, 2, 255, 0, 3];
        let b: Vec<i8> = vec![1, -1, 2, -2, 3, -3];
        // (2,3) @ (3,2)
        let acc = gemm(&a, &b, 2, 3, 2);
        // row0 = [0,1,2]·cols, row1 = [255,0,3]·cols
        assert_eq!(acc, vec![8, -8, 264, -264]);
    }

    #[test]
    fn conv_int_matches_f32_conv() {
        use crate::runtime::cpu::ops::{conv2d, Arr};
        let mut rng = Pcg32::seeded(9);
        for stride in [1usize, 2] {
            let (n, h, w, ci, kh, kw, co) = (2, 5, 4, 3, 3, 3, 4);
            let mut draw = |count: usize| -> Vec<i8> {
                (0..count).map(|_| (rng.below(15) as i32 - 7) as i8).collect()
            };
            let xq = draw(n * h * w * ci);
            let wq = draw(kh * kw * ci * co);
            let xf = Arr::new(vec![n, h, w, ci], xq.iter().map(|&v| v as f32).collect());
            let wf = Arr::new(vec![kh, kw, ci, co], wq.iter().map(|&v| v as f32).collect());
            let d = conv_shape(&xf.shape, &wf.shape, stride);
            let acc = conv_int(&xq, &wq, &d);
            let reference = conv2d(&xf, &wf, stride, 1);
            assert_eq!(reference.shape, vec![n, d.ho, d.wo, co]);
            for (x, y) in acc.iter().zip(&reference.data) {
                assert_eq!(*x as f32, *y);
            }
        }
    }

    #[test]
    fn dequant_bias_applies_per_channel() {
        let acc = vec![4i32, -8, 2, 0];
        let mut out = vec![0.0f32; 4];
        dequant_bias(&acc, 2, &[0.5, 0.25], &[1.0, -1.0], &mut out);
        assert_eq!(out, vec![3.0, -3.0, 2.0, -1.0]);
    }

    #[test]
    fn rshift_rhe_ties_to_even() {
        assert_eq!(rshift_rhe(3, 1), 2); // 1.5 -> 2
        assert_eq!(rshift_rhe(5, 1), 2); // 2.5 -> 2
        assert_eq!(rshift_rhe(-3, 1), -2); // -1.5 -> -2
        assert_eq!(rshift_rhe(-5, 1), -2); // -2.5 -> -2
        assert_eq!(rshift_rhe(7, 2), 2); // 1.75 -> 2
        assert_eq!(rshift_rhe(100, 0), 100);
        assert_eq!(rshift_rhe(1, 63), 0);
    }

    #[test]
    fn fixed_mult_exact_for_power_of_two() {
        let fm = FixedMult::from_f32(2.0f32.powi(-7));
        for acc in [-100_000i32, -129, -1, 0, 1, 64, 65, 127, 192, 100_000] {
            let want = round_half_even(acc as f32 * 2.0f32.powi(-7)) as i64;
            assert_eq!(fm.apply(acc), want, "acc={acc}");
        }
        // multiplier above 1 still lands on an exact shift
        let fm2 = FixedMult::from_f32(4.0);
        assert_eq!(fm2.apply(3), 12);
    }

    #[test]
    fn fixed_mult_close_for_arbitrary_scale() {
        let m = 0.0123456f32;
        let fm = FixedMult::from_f32(m);
        for acc in [-10_000i32, -7, 0, 13, 9999] {
            let exact = acc as f64 * m as f64;
            let got = fm.apply(acc) as f64;
            assert!((got - exact).abs() <= 0.5 + exact.abs() * 1e-6, "{got} vs {exact}");
        }
    }

    #[test]
    fn im2col_zero_pads() {
        // 1 image 2x2x1, 3x3 kernel, stride 1 -> 4 rows of 9, corners padded
        let xq: Vec<i8> = vec![1, 2, 3, 4];
        let d = conv_shape(&[1, 2, 2, 1], &[3, 3, 1, 1], 1);
        let cols = im2col(&xq, &d);
        assert_eq!(cols.len(), 4 * 9);
        // first output pixel (0,0): top row and left column are padding
        assert_eq!(&cols[0..9], &[0, 0, 0, 0, 1, 2, 0, 3, 4]);
    }
}
