//! The integer inference engine: *true* quantized execution, closing the
//! loop the fake-quant simulator leaves open.
//!
//! `quant::quantizer::fake_quant` rounds a value to the Δ grid and
//! immediately dequantizes, so a calibrated model still runs at fp32
//! speed.  This subsystem turns a calibrated `lapq::QuantOutcome` into a
//! deployable artifact and executes it with packed integer arithmetic:
//!
//! * [`model`] — [`model::pack`] quantizes a session's fp32 parameters
//!   onto the calibrated grids (i8 in memory, nibble-packed i4 on disk,
//!   per-output-channel scales, i32 bias), producing a
//!   [`model::QuantizedModel`] that serializes to `quantized.json` +
//!   `weights.bin`.
//! * [`kernels`] — the blocked i8×i8→i32 GEMM / im2col conv micro-kernel
//!   architecture: A/B panel packing (`kernels::pack`), runtime-dispatched
//!   scalar / AVX2 / NEON micro-kernels plus a nibble-domain INT4 kernel
//!   (`LAPQ_KERNEL=scalar|blocked|simd` forces a tier), batch-parallel on
//!   scoped threads — every tier bit-identical; activation quantization
//!   and the requantization epilogue are round-half-even, bit-compatible
//!   with `quant::quantizer`.
//! * [`session`] — [`session::InferSession`] walks the zoo graphs
//!   (`mlp3`, `cnn6`, `ncf`) over a packed model, integer kernels where
//!   both sides are quantized, fake-quant f32 fallback elsewhere.
//! * [`packed`] — the little-endian byte codecs.
//!
//! The serving face is `coordinator::service` (`{"cmd":"pack"}` /
//! `{"cmd":"infer"}`) and the `repro pack` / `repro infer` CLI.

pub mod kernels;
pub mod model;
pub mod packed;
pub mod session;

pub use model::{pack, weight_storage_bytes, PackOpts, QuantizedModel};
pub use session::{ExecMode, InferResult, InferSession};
