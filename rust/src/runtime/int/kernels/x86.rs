//! AVX2 micro-kernels (x86-64, runtime-detected).
//!
//! Strategy: widening pair dot products.  Each k-pair of a B panel is
//! one 32-byte load whose halves sign-extend to 16-bit lanes ordered
//! `[b(2t,j), b(2t+1,j)]` per column; each A row broadcasts its widened
//! pair `(a0, a1)` into every 32-bit lane, and `_mm256_madd_epi16`
//! produces `a0·b0 + a1·b1` per column — **exactly**, because the i16
//! products are formed at i32 precision inside `madd` (the
//! `_mm256_maddubs_epi16` shortcut is rejected here: it saturates its
//! i16 pair sums, e.g. `255·127 + 255·127`, silently corrupting u8
//! activations).  All accumulation is i32 adds, so results are
//! bit-identical to the scalar tier.
//!
//! The INT4 kernel computes in the nibble domain: it loads 16
//! pair-bytes, sign-extends both nibbles with the `(x ^ 8) − 8` trick,
//! re-interleaves them into the same pair layout, and reuses the i8
//! inner step — the full-width i8 weight buffer is never materialized.

#![allow(unsafe_code)]

use super::pack::{MR, NR};
#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// Runtime gate for the SIMD tier on this architecture.
pub(crate) fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

/// Accumulate one A panel × one B panel (i8 pair layout) into `acc`.
///
/// # Safety
/// Caller must ensure AVX2 is available ([`avx2_available`]) and that
/// `ap`/`bp` hold at least `kp/2` pair groups (`2·MR` i16 / `2·NR` i8
/// each) — guaranteed by the panel packers.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_i8_avx2(ap: &[i16], bp: &[i8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    debug_assert!(ap.len() >= MR * kp && bp.len() >= NR * kp);
    let mut c = [[_mm256_setzero_si256(); 2]; MR];
    for t in 0..kp / 2 {
        let raw = _mm256_loadu_si256(bp.as_ptr().add(t * 2 * NR) as *const __m256i);
        let b_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(raw)); // columns 0..8
        let b_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(raw)); // columns 8..16
        let a = ap.as_ptr().add(t * 2 * MR);
        for (r, cr) in c.iter_mut().enumerate() {
            let a0 = *a.add(2 * r) as u16 as u32;
            let a1 = *a.add(2 * r + 1) as u16 as u32;
            if (a0 | a1) == 0 {
                continue;
            }
            let av = _mm256_set1_epi32((a0 | (a1 << 16)) as i32);
            cr[0] = _mm256_add_epi32(cr[0], _mm256_madd_epi16(av, b_lo));
            cr[1] = _mm256_add_epi32(cr[1], _mm256_madd_epi16(av, b_hi));
        }
    }
    spill(&c, acc);
}

/// Accumulate one A panel × one nibble-packed B panel into `acc`,
/// decoding i4 pairs in-register.
///
/// # Safety
/// Same contract as [`micro_i8_avx2`]; `bp4` holds `kp/2` groups of `NR`
/// pair-bytes.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_i4_avx2(ap: &[i16], bp4: &[u8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    debug_assert!(ap.len() >= MR * kp && bp4.len() >= NR * kp / 2);
    let mask = _mm_set1_epi8(0x0f);
    let bias = _mm_set1_epi8(8);
    let mut c = [[_mm256_setzero_si256(); 2]; MR];
    for t in 0..kp / 2 {
        let raw = _mm_loadu_si128(bp4.as_ptr().add(t * NR) as *const __m128i);
        // sign-extend both nibbles of every byte: (x & 0xF ^ 8) - 8
        let lo = _mm_sub_epi8(_mm_xor_si128(_mm_and_si128(raw, mask), bias), bias);
        let hi4 = _mm_and_si128(_mm_srli_epi16::<4>(raw), mask);
        let hi = _mm_sub_epi8(_mm_xor_si128(hi4, bias), bias);
        // restore the i8 pair interleave, then the i8 inner step applies
        let b_lo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(lo, hi));
        let b_hi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(lo, hi));
        let a = ap.as_ptr().add(t * 2 * MR);
        for (r, cr) in c.iter_mut().enumerate() {
            let a0 = *a.add(2 * r) as u16 as u32;
            let a1 = *a.add(2 * r + 1) as u16 as u32;
            if (a0 | a1) == 0 {
                continue;
            }
            let av = _mm256_set1_epi32((a0 | (a1 << 16)) as i32);
            cr[0] = _mm256_add_epi32(cr[0], _mm256_madd_epi16(av, b_lo));
            cr[1] = _mm256_add_epi32(cr[1], _mm256_madd_epi16(av, b_hi));
        }
    }
    spill(&c, acc);
}

/// Add the register tile into the caller's accumulator.
#[target_feature(enable = "avx2")]
unsafe fn spill(c: &[[__m256i; 2]; MR], acc: &mut [[i32; NR]; MR]) {
    for (cr, arow) in c.iter().zip(acc.iter_mut()) {
        let mut lanes = [0i32; NR];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, cr[0]);
        _mm256_storeu_si256(lanes.as_mut_ptr().add(8) as *mut __m256i, cr[1]);
        for (o, l) in arow.iter_mut().zip(lanes) {
            *o += l;
        }
    }
}
