//! Integer forward kernels: activation quantization, the blocked
//! i8×i8→i32 GEMM / im2col conv micro-kernel architecture, the
//! dequantize+bias epilogue, and a fixed-point requantization multiplier
//! for pure-integer targets.
//!
//! # Kernel architecture
//!
//! The GEMM/conv hot path is cache-blocked: activations are packed into
//! `MR`-row A panels and weights into `NR`-column B panels ([`pack`]),
//! and an inner micro-kernel accumulates one `MR×NR` register tile over
//! the full k depth.  The micro-kernel is selected at runtime:
//!
//! | tier      | micro-kernel                                    |
//! |-----------|--------------------------------------------------|
//! | `scalar`  | the original unblocked reference loops ([`scalar`]) |
//! | `blocked` | panels + scalar micro-kernel                     |
//! | `simd`    | panels + AVX2 ([`x86`]) / NEON ([`neon`]) micro-kernel, detected at runtime |
//!
//! `LAPQ_KERNEL=scalar|blocked|simd` forces a tier for A/B measurement
//! ([`kernel_choice`]); the default (`Auto`, also any unknown value) is
//! `simd` with silent fallback to `blocked` when no extension is
//! detected.  A fourth micro-kernel computes ≤4-bit layers directly in
//! the nibble domain ([`int4`], AVX2 variant in [`x86`]) on pair-packed
//! bytes, halving the weight bytes streamed per inner loop.
//!
//! # Exactness envelope
//!
//! Every tier is **bit-identical** by construction: integer addition is
//! exactly associative, zero padding contributes zero, and the SIMD
//! lanes form the same i32 products (no saturating shortcuts) — pinned
//! on ~2k generated cases by `tests/kernel_diff`.  Two bounds matter:
//!
//! * **i32 accumulator**: a k-deep dot product of `A::MAX_ABS`-bounded
//!   activations and i8 weights (|q| ≤ 128) is exact iff
//!   `k · MAX_ABS · 128 ≤ i32::MAX` ([`acc_fits_i32`], debug-asserted on
//!   every GEMM/conv call).  For u8/A8 activations that allows
//!   k ≤ 65 807 — three orders of magnitude above the zoo's widest
//!   reduction (`cnn6` conv5: k = 576).
//! * **2²⁴ fake-quant envelope**: the epilogue converts the i32
//!   accumulator to f32, which is integer-exact only below 2²⁴.  With
//!   power-of-two scales the integer path is bit-compatible with the
//!   fake-quant reference *within* that envelope (`mlp3`, `ncf`, and
//!   every ≤4-bit plan: k·7·255 < 2²⁴ up to k ≈ 9 395); an INT8 `cnn6`
//!   conv can cross it, where the f32 reference itself rounds — see
//!   `tests/int_parity`.
//!
//! Numerics contract: activation quantization uses the same
//! `round_half_even(x / Δ)` + clamp as `quant::quantizer::fake_quant_one`,
//! and the epilogue computes `acc as f32 * (Δa·Δw[c]) + bias[c]` with
//! plain (non-fused) f32 ops.

pub mod pack;

mod int4;
#[cfg(target_arch = "aarch64")]
mod neon;
mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

use crate::quant::quantizer::round_half_even;
use crate::runtime::cpu::ops::{n_threads, par_items};
use pack::{PackedA, PackedB, PackedB4, MR, NR};

/// Quantized-activation element: `i8` (signed grids) or `u8` (post-ReLU
/// unsigned grids, qmax ≤ 255).
pub trait QAct: Copy + Default + Send + Sync {
    /// Upper bound on `|widen()|`, for accumulator-overflow accounting.
    const MAX_ABS: i32;
    fn widen(self) -> i32;
}

impl QAct for i8 {
    const MAX_ABS: i32 = 128;
    fn widen(self) -> i32 {
        self as i32
    }
}

impl QAct for u8 {
    const MAX_ABS: i32 = 255;
    fn widen(self) -> i32 {
        self as i32
    }
}

/// True iff a `k`-deep dot product of activations bounded by `a_max`
/// against full-range i8 weights (|q| ≤ 128) cannot overflow the i32
/// accumulator.  Debug-asserted by every GEMM/conv entry point.
pub fn acc_fits_i32(k: usize, a_max: i32) -> bool {
    (k as i64) * (a_max as i64) * 128 <= i32::MAX as i64
}

/// Quantize to a signed grid: `clamp(round_half_even(x/Δ), -qmax, qmax)`.
/// The integer returned is exactly the grid index `fake_quant_one` snaps
/// to (it multiplies the same index back by Δ).
pub fn quantize_signed(xs: &[f32], delta: f32, qmax: f32) -> Vec<i8> {
    assert!(delta > 0.0 && qmax <= 127.0, "signed grid Δ={delta} qmax={qmax}");
    xs.iter().map(|&x| round_half_even(x / delta).clamp(-qmax, qmax) as i8).collect()
}

/// Quantize to an unsigned grid: `clamp(round_half_even(x/Δ), 0, qmax)`.
pub fn quantize_unsigned(xs: &[f32], delta: f32, qmax: f32) -> Vec<u8> {
    assert!(delta > 0.0 && qmax <= 255.0, "unsigned grid Δ={delta} qmax={qmax}");
    xs.iter().map(|&x| round_half_even(x / delta).clamp(0.0, qmax) as u8).collect()
}

// ------------------------------------------------------------- dispatch

/// Which kernel tier executes the GEMM/conv hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// Best available: SIMD when detected, else blocked.  The default.
    Auto,
    /// The unblocked reference loops (the bit-exactness oracle).
    Scalar,
    /// Panel packing + the scalar micro-kernel.
    Blocked,
    /// Panel packing + the SIMD micro-kernel; silently degrades to
    /// `Blocked` when no extension is detected.
    Simd,
}

/// Read the `LAPQ_KERNEL` override (`scalar` / `blocked` / `simd`); any
/// other (or absent) value selects [`KernelChoice::Auto`].  Read per
/// call, so a test or operator can flip tiers without rebuilding.
pub fn kernel_choice() -> KernelChoice {
    match std::env::var("LAPQ_KERNEL").as_deref() {
        Ok("scalar") => KernelChoice::Scalar,
        Ok("blocked") => KernelChoice::Blocked,
        Ok("simd") => KernelChoice::Simd,
        _ => KernelChoice::Auto,
    }
}

/// The resolved micro-kernel for the blocked driver.
#[derive(Clone, Copy)]
enum Micro {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

fn micro_for(choice: KernelChoice) -> Micro {
    match choice {
        KernelChoice::Scalar | KernelChoice::Blocked => Micro::Scalar,
        KernelChoice::Simd | KernelChoice::Auto => {
            #[cfg(target_arch = "x86_64")]
            if x86::avx2_available() {
                return Micro::Avx2;
            }
            #[cfg(target_arch = "aarch64")]
            if neon::neon_available() {
                return Micro::Neon;
            }
            Micro::Scalar
        }
    }
}

/// Human-readable name of the tier [`KernelChoice::Auto`] resolves to on
/// this machine — for bench labels and serve diagnostics.
pub fn active_kernel_name(choice: KernelChoice) -> &'static str {
    match choice {
        KernelChoice::Scalar => "scalar",
        KernelChoice::Blocked => "blocked",
        KernelChoice::Simd | KernelChoice::Auto => match micro_for(choice) {
            Micro::Scalar => "blocked",
            #[cfg(target_arch = "x86_64")]
            Micro::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Micro::Neon => "neon",
        },
    }
}

fn run_micro(m: Micro, ap: &[i16], bp: &[i8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    match m {
        Micro::Scalar => scalar::micro_i8(ap, bp, kp, acc),
        #[cfg(target_arch = "x86_64")]
        Micro::Avx2 => unsafe { x86::micro_i8_avx2(ap, bp, kp, acc) },
        #[cfg(target_arch = "aarch64")]
        Micro::Neon => unsafe { neon::micro_i8_neon(ap, bp, kp, acc) },
    }
}

fn run_micro_i4(m: Micro, ap: &[i16], bp4: &[u8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    match m {
        #[cfg(target_arch = "x86_64")]
        Micro::Avx2 => unsafe { x86::micro_i4_avx2(ap, bp4, kp, acc) },
        _ => int4::micro_i4(ap, bp4, kp, acc),
    }
}

// ------------------------------------------------------- blocked driver

/// A packed B operand: full-width i8 panels or nibble-pair i4 panels.
#[derive(Clone, Copy)]
enum PanelsB<'a> {
    I8(&'a PackedB),
    I4(&'a PackedB4),
}

impl PanelsB<'_> {
    fn panels(&self) -> usize {
        match self {
            PanelsB::I8(b) => b.panels,
            PanelsB::I4(b) => b.panels,
        }
    }
}

/// Compute one A row panel against every B column panel into `slab`
/// (the `rows × n` output block for this panel, row-major).
fn panel_compute(pa: &PackedA, pb: PanelsB, micro: Micro, p: usize, slab: &mut [i32], n: usize) {
    let kp = pa.kp;
    let rows = (pa.m - p * MR).min(MR);
    let ap = &pa.data[p * MR * kp..(p + 1) * MR * kp];
    for cp in 0..pb.panels() {
        let mut acc = [[0i32; NR]; MR];
        match pb {
            PanelsB::I8(b) => {
                run_micro(micro, ap, &b.data[cp * NR * kp..(cp + 1) * NR * kp], kp, &mut acc)
            }
            PanelsB::I4(b) => {
                let half = NR * (kp / 2);
                run_micro_i4(micro, ap, &b.data[cp * half..(cp + 1) * half], kp, &mut acc)
            }
        }
        let col0 = cp * NR;
        let cols = (n - col0).min(NR);
        for (r, arow) in acc.iter().enumerate().take(rows) {
            slab[r * n + col0..r * n + col0 + cols].copy_from_slice(&arow[..cols]);
        }
    }
}

/// The blocked GEMM driver: pack A, then run row panels (in parallel
/// when the work is substantial) against the pre-packed B operand.
fn gemm_blocked<A: QAct>(
    a: &[A],
    pb: PanelsB,
    micro: Micro,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let pa = pack::pack_a(a, m, k);
    let full = m / MR;
    if m * k * n >= (1 << 21) && n_threads() > 1 && full >= 2 {
        let (head, tail) = out.split_at_mut(full * MR * n);
        par_items(head, MR * n, |p, slab| panel_compute(&pa, pb, micro, p, slab, n));
        if !tail.is_empty() {
            panel_compute(&pa, pb, micro, full, tail, n);
        }
    } else {
        for p in 0..pa.panels {
            let lo = p * MR * n;
            let hi = ((p + 1) * MR * n).min(m * n);
            panel_compute(&pa, pb, micro, p, &mut out[lo..hi], n);
        }
    }
    out
}

/// The blocked conv driver: B packed once, then per image (parallel,
/// like the f32 backend) im2col + pack A + row panels.
fn conv_blocked<A: QAct>(xq: &[A], pb: PanelsB, micro: Micro, d: &ConvShape) -> Vec<i32> {
    let kk = d.kh * d.kw * d.ci;
    let per_x = d.h * d.w * d.ci;
    let per_o = d.ho * d.wo * d.co;
    let mut out = vec![0i32; d.n * per_o];
    if per_o == 0 {
        return out;
    }
    par_items(&mut out, per_o, |img, o| {
        let cols = im2col(&xq[img * per_x..(img + 1) * per_x], d);
        let pa = pack::pack_a(&cols, d.ho * d.wo, kk);
        for p in 0..pa.panels {
            let lo = p * MR * d.co;
            let hi = ((p + 1) * MR * d.co).min(o.len());
            panel_compute(&pa, pb, micro, p, &mut o[lo..hi], d.co);
        }
    });
    out
}

// ------------------------------------------------------- public entry points

/// `(M,K) quantized acts @ (K,N) i8 weights -> (M,N) i32`, on the tier
/// selected by [`kernel_choice`].  Every tier returns bit-identical
/// accumulators (see module docs).
pub fn gemm<A: QAct>(a: &[A], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    gemm_with(kernel_choice(), a, b, m, k, n)
}

/// [`gemm`] on an explicit tier — the differential harness's entry point.
pub fn gemm_with<A: QAct>(
    choice: KernelChoice,
    a: &[A],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    debug_assert!(acc_fits_i32(k, A::MAX_ABS), "k={k} can overflow the i32 accumulator");
    match choice {
        KernelChoice::Scalar => scalar::gemm_scalar(a, b, m, k, n),
        _ => {
            let pb = pack::pack_b(b, k, n);
            gemm_blocked(a, PanelsB::I8(&pb), micro_for(choice), m, k, n)
        }
    }
}

/// [`gemm`] for a ≤4-bit weight matrix (values in −8..=7): packs `b`
/// into nibble-pair panels and computes in the nibble domain, never
/// materializing a full-width i8 panel.  `Scalar` routes to the
/// reference loops (which read `b` directly).
pub fn gemm_i4_with<A: QAct>(
    choice: KernelChoice,
    a: &[A],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    debug_assert!(acc_fits_i32(k, A::MAX_ABS), "k={k} can overflow the i32 accumulator");
    match choice {
        KernelChoice::Scalar => scalar::gemm_scalar(a, b, m, k, n),
        _ => {
            let pb4 = pack::pack_b4(b, k, n);
            gemm_blocked(a, PanelsB::I4(&pb4), micro_for(choice), m, k, n)
        }
    }
}

/// SAME-padding geometry for the integer conv (groups = 1), mirroring
/// `ops::conv_dims` exactly.
#[derive(Clone, Debug)]
pub struct ConvShape {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub ci: usize,
    pub kh: usize,
    pub kw: usize,
    pub co: usize,
    pub stride: usize,
    pub ho: usize,
    pub wo: usize,
    pub pad_t: usize,
    pub pad_l: usize,
}

pub fn conv_shape(xs: &[usize], ws: &[usize], stride: usize) -> ConvShape {
    assert_eq!(xs.len(), 4, "conv input must be NHWC, got {xs:?}");
    assert_eq!(ws.len(), 4, "conv weight must be HWIO, got {ws:?}");
    let (n, h, w, ci) = (xs[0], xs[1], xs[2], xs[3]);
    let (kh, kw, wci, co) = (ws[0], ws[1], ws[2], ws[3]);
    assert_eq!(ci, wci, "channels {ci} != weight {wci} (integer conv has groups=1)");
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let pad_h = ((ho - 1) * stride + kh).saturating_sub(h);
    let pad_w = ((wo - 1) * stride + kw).saturating_sub(w);
    ConvShape { n, h, w, ci, kh, kw, co, stride, ho, wo, pad_t: pad_h / 2, pad_l: pad_w / 2 }
}

/// Gather one image's receptive fields into im2col rows of length
/// `kh*kw*ci`, zero-padded at the borders (the symmetric grid has no
/// zero-point, so padding is exactly `q = 0`).
pub fn im2col<A: QAct>(xq: &[A], d: &ConvShape) -> Vec<A> {
    let kk = d.kh * d.kw * d.ci;
    let mut out = vec![A::default(); d.ho * d.wo * kk];
    for oy in 0..d.ho {
        for ox in 0..d.wo {
            let rbase = (oy * d.wo + ox) * kk;
            for ky in 0..d.kh {
                let iy = (oy * d.stride + ky) as isize - d.pad_t as isize;
                if iy < 0 || iy >= d.h as isize {
                    continue;
                }
                for kx in 0..d.kw {
                    let ix = (ox * d.stride + kx) as isize - d.pad_l as isize;
                    if ix < 0 || ix >= d.w as isize {
                        continue;
                    }
                    let src = (iy as usize * d.w + ix as usize) * d.ci;
                    let dst = rbase + (ky * d.kw + kx) * d.ci;
                    out[dst..dst + d.ci].copy_from_slice(&xq[src..src + d.ci]);
                }
            }
        }
    }
    out
}

/// Integer SAME conv over a quantized NHWC batch, on the tier selected
/// by [`kernel_choice`].
pub fn conv_int<A: QAct>(xq: &[A], wq: &[i8], d: &ConvShape) -> Vec<i32> {
    conv_int_with(kernel_choice(), xq, wq, d)
}

/// [`conv_int`] on an explicit tier.
pub fn conv_int_with<A: QAct>(
    choice: KernelChoice,
    xq: &[A],
    wq: &[i8],
    d: &ConvShape,
) -> Vec<i32> {
    let kk = d.kh * d.kw * d.ci;
    assert_eq!(xq.len(), d.n * d.h * d.w * d.ci);
    assert_eq!(wq.len(), kk * d.co);
    debug_assert!(acc_fits_i32(kk, A::MAX_ABS), "kk={kk} can overflow the i32 accumulator");
    match choice {
        KernelChoice::Scalar => scalar::conv_int_scalar(xq, wq, d),
        _ => {
            let pb = pack::pack_b(wq, kk, d.co);
            conv_blocked(xq, PanelsB::I8(&pb), micro_for(choice), d)
        }
    }
}

/// [`conv_int`] for a ≤4-bit weight tensor: nibble-domain B panels.
pub fn conv_int_i4_with<A: QAct>(
    choice: KernelChoice,
    xq: &[A],
    wq: &[i8],
    d: &ConvShape,
) -> Vec<i32> {
    let kk = d.kh * d.kw * d.ci;
    assert_eq!(xq.len(), d.n * d.h * d.w * d.ci);
    assert_eq!(wq.len(), kk * d.co);
    debug_assert!(acc_fits_i32(kk, A::MAX_ABS), "kk={kk} can overflow the i32 accumulator");
    match choice {
        KernelChoice::Scalar => scalar::conv_int_scalar(xq, wq, d),
        _ => {
            let pb4 = pack::pack_b4(wq, kk, d.co);
            conv_blocked(xq, PanelsB::I4(&pb4), micro_for(choice), d)
        }
    }
}

// ------------------------------------------------------------- epilogue

/// Dequantize+bias epilogue: `out[r,c] = acc[r,c] as f32 * combined[c] +
/// bias[c]`, where `combined[c] = Δa · Δw[c]`.  The multiply and add are
/// deliberately separate (no `mul_add`) so the rounding matches the
/// reference's matmul-then-`add_bias` sequence.
pub fn dequant_bias(acc: &[i32], co: usize, combined: &[f32], bias: &[f32], out: &mut [f32]) {
    assert_eq!(acc.len(), out.len());
    assert!(co > 0 && acc.len() % co == 0);
    assert_eq!(combined.len(), co);
    assert_eq!(bias.len(), co);
    for (arow, orow) in acc.chunks(co).zip(out.chunks_mut(co)) {
        for c in 0..co {
            orow[c] = arow[c] as f32 * combined[c] + bias[c];
        }
    }
}

/// Right-shift with round-half-to-even on the shifted-out bits (the
/// integer mirror of `quantizer::round_half_even`).
pub fn rshift_rhe(x: i64, b: u32) -> i64 {
    if b == 0 {
        return x;
    }
    if b >= 63 {
        // |x| < 2^62 everywhere we call this, so the value is < 0.5.
        return 0;
    }
    let floor = x >> b;
    let rem = x - (floor << b);
    let half = 1i64 << (b - 1);
    floor + if rem > half || (rem == half && (floor & 1) != 0) { 1 } else { 0 }
}

/// A positive real multiplier in fixed-point `mult · 2^-shift` form
/// (`mult` ∈ [2³⁰, 2³¹]): the classic requantization constant for
/// pure-integer targets that cannot afford a float epilogue.  With the
/// power-of-two scales `pack` emits, `apply` is exact (a pure shift).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FixedMult {
    pub mult: i64,
    pub shift: i32,
}

impl FixedMult {
    pub fn from_f32(m: f32) -> FixedMult {
        assert!(m > 0.0 && m.is_finite(), "fixed-point multiplier {m}");
        let mut v = m as f64;
        let mut e = 0i32;
        while v < 0.5 {
            v *= 2.0;
            e -= 1;
        }
        while v >= 1.0 {
            v /= 2.0;
            e += 1;
        }
        let mult = (v * (1u64 << 31) as f64).round() as i64;
        FixedMult { mult, shift: 31 - e }
    }

    /// `round_half_even(acc · m)` computed entirely in integers.
    pub fn apply(&self, acc: i32) -> i64 {
        let p = acc as i64 * self.mult;
        if self.shift >= 0 {
            rshift_rhe(p, self.shift as u32)
        } else {
            p << (-self.shift).min(31)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantizer::fake_quant_one;
    use crate::quant::GridKind;
    use crate::runtime::cpu::ops::matmul;
    use crate::util::rng::Pcg32;

    #[test]
    fn quantize_matches_fake_quant_grid() {
        let mut rng = Pcg32::seeded(3);
        let xs: Vec<f32> = (0..512).map(|_| rng.normal() * 2.0).collect();
        let (d, qmax) = (0.125f32, 127.0f32);
        let qs = quantize_signed(&xs, d, qmax);
        for (&x, &q) in xs.iter().zip(&qs) {
            assert_eq!(q as f32 * d, fake_quant_one(x, d, qmax, GridKind::Signed));
        }
        let qu = quantize_unsigned(&xs, d, 255.0);
        for (&x, &q) in xs.iter().zip(&qu) {
            assert_eq!(q as f32 * d, fake_quant_one(x, d, 255.0, GridKind::Unsigned));
        }
    }

    #[test]
    fn gemm_matches_f32_matmul_on_integer_data() {
        let mut rng = Pcg32::seeded(5);
        let (m, k, n) = (7, 33, 11);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let reference = matmul(&af, &bf, m, k, n);
        for choice in
            [KernelChoice::Auto, KernelChoice::Scalar, KernelChoice::Blocked, KernelChoice::Simd]
        {
            let acc = gemm_with(choice, &a, &b, m, k, n);
            for (x, y) in acc.iter().zip(&reference) {
                assert_eq!(*x as f32, *y, "{choice:?}");
            }
        }
    }

    #[test]
    fn gemm_unsigned_acts() {
        let a: Vec<u8> = vec![0, 1, 2, 255, 0, 3];
        let b: Vec<i8> = vec![1, -1, 2, -2, 3, -3];
        // (2,3) @ (3,2)
        for choice in [KernelChoice::Scalar, KernelChoice::Blocked, KernelChoice::Simd] {
            assert_eq!(gemm_with(choice, &a, &b, 2, 3, 2), vec![8, -8, 264, -264], "{choice:?}");
        }
    }

    #[test]
    fn gemm_i4_matches_full_width_tiers() {
        let mut rng = Pcg32::seeded(21);
        let (m, k, n) = (5, 19, 23);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
        let want = gemm_with(KernelChoice::Scalar, &a, &b, m, k, n);
        for choice in [KernelChoice::Auto, KernelChoice::Blocked, KernelChoice::Simd] {
            assert_eq!(gemm_i4_with(choice, &a, &b, m, k, n), want, "{choice:?}");
        }
    }

    #[test]
    fn conv_int_matches_f32_conv() {
        use crate::runtime::cpu::ops::{conv2d, Arr};
        let mut rng = Pcg32::seeded(9);
        for stride in [1usize, 2] {
            let (n, h, w, ci, kh, kw, co) = (2, 5, 4, 3, 3, 3, 4);
            let mut draw = |count: usize| -> Vec<i8> {
                (0..count).map(|_| (rng.below(15) as i32 - 7) as i8).collect()
            };
            let xq = draw(n * h * w * ci);
            let wq = draw(kh * kw * ci * co);
            let xf = Arr::new(vec![n, h, w, ci], xq.iter().map(|&v| v as f32).collect());
            let wf = Arr::new(vec![kh, kw, ci, co], wq.iter().map(|&v| v as f32).collect());
            let d = conv_shape(&xf.shape, &wf.shape, stride);
            let reference = conv2d(&xf, &wf, stride, 1);
            assert_eq!(reference.shape, vec![n, d.ho, d.wo, co]);
            for choice in [KernelChoice::Scalar, KernelChoice::Auto] {
                let acc = conv_int_with(choice, &xq, &wq, &d);
                for (x, y) in acc.iter().zip(&reference.data) {
                    assert_eq!(*x as f32, *y, "{choice:?}");
                }
                let acc4 = conv_int_i4_with(choice, &xq, &wq, &d);
                assert_eq!(acc4, acc, "{choice:?} i4");
            }
        }
    }

    #[test]
    fn accumulator_bound_covers_the_zoo_and_rejects_overflow() {
        // widest zoo reduction: cnn6 conv5, k = 3·3·64 = 576 (u8/A8 acts)
        assert!(acc_fits_i32(576, u8::MAX_ABS));
        assert!(acc_fits_i32(4096, i8::MAX_ABS));
        // the bound is tight: k·MAX_ABS·128 > i32::MAX must be rejected
        assert!(!acc_fits_i32(65808, u8::MAX_ABS));
        assert!(acc_fits_i32(65807, u8::MAX_ABS));
        assert!(!acc_fits_i32(1 << 24, i8::MAX_ABS));
    }

    #[test]
    fn dequant_bias_applies_per_channel() {
        let acc = vec![4i32, -8, 2, 0];
        let mut out = vec![0.0f32; 4];
        dequant_bias(&acc, 2, &[0.5, 0.25], &[1.0, -1.0], &mut out);
        assert_eq!(out, vec![3.0, -3.0, 2.0, -1.0]);
    }

    #[test]
    fn rshift_rhe_ties_to_even() {
        assert_eq!(rshift_rhe(3, 1), 2); // 1.5 -> 2
        assert_eq!(rshift_rhe(5, 1), 2); // 2.5 -> 2
        assert_eq!(rshift_rhe(-3, 1), -2); // -1.5 -> -2
        assert_eq!(rshift_rhe(-5, 1), -2); // -2.5 -> -2
        assert_eq!(rshift_rhe(7, 2), 2); // 1.75 -> 2
        assert_eq!(rshift_rhe(100, 0), 100);
        assert_eq!(rshift_rhe(1, 63), 0);
    }

    #[test]
    fn fixed_mult_exact_for_power_of_two() {
        let fm = FixedMult::from_f32(2.0f32.powi(-7));
        for acc in [-100_000i32, -129, -1, 0, 1, 64, 65, 127, 192, 100_000] {
            let want = round_half_even(acc as f32 * 2.0f32.powi(-7)) as i64;
            assert_eq!(fm.apply(acc), want, "acc={acc}");
        }
        // multiplier above 1 still lands on an exact shift
        let fm2 = FixedMult::from_f32(4.0);
        assert_eq!(fm2.apply(3), 12);
    }

    #[test]
    fn fixed_mult_close_for_arbitrary_scale() {
        let m = 0.0123456f32;
        let fm = FixedMult::from_f32(m);
        for acc in [-10_000i32, -7, 0, 13, 9999] {
            let exact = acc as f64 * m as f64;
            let got = fm.apply(acc) as f64;
            assert!((got - exact).abs() <= 0.5 + exact.abs() * 1e-6, "{got} vs {exact}");
        }
    }

    #[test]
    fn im2col_zero_pads() {
        // 1 image 2x2x1, 3x3 kernel, stride 1 -> 4 rows of 9, corners padded
        let xq: Vec<i8> = vec![1, 2, 3, 4];
        let d = conv_shape(&[1, 2, 2, 1], &[3, 3, 1, 1], 1);
        let cols = im2col(&xq, &d);
        assert_eq!(cols.len(), 4 * 9);
        // first output pixel (0,0): top row and left column are padding
        assert_eq!(&cols[0..9], &[0, 0, 0, 0, 1, 2, 0, 3, 4]);
    }

    #[test]
    fn kernel_names_are_stable() {
        assert_eq!(active_kernel_name(KernelChoice::Scalar), "scalar");
        assert_eq!(active_kernel_name(KernelChoice::Blocked), "blocked");
        // Auto resolves to some real tier on every machine
        assert!(["blocked", "avx2", "neon"].contains(&active_kernel_name(KernelChoice::Auto)));
    }
}
