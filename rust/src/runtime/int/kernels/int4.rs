//! Nibble-domain INT4 micro-kernel (portable scalar form).
//!
//! Computes directly on [`super::pack::PackedB4`] pair-bytes — one byte
//! carries a column's `(k, k+1)` weight pair — so a ≤4-bit layer streams
//! half the weight bytes of the i8 panel through the inner loop and
//! never materializes a full-width i8 weight buffer.  The AVX2
//! counterpart lives in `x86::micro_i4_avx2`; this version serves every
//! other architecture (and the `Blocked` tier) and is bit-identical to
//! decoding the nibbles up front.

use super::pack::{MR, NR};
use crate::runtime::int::packed::{i4_hi, i4_lo};

/// Accumulate one A panel × one nibble-packed B panel into `acc`.
pub(crate) fn micro_i4(ap: &[i16], bp4: &[u8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    for t in 0..kp / 2 {
        let a = &ap[t * 2 * MR..t * 2 * MR + 2 * MR];
        let b = &bp4[t * NR..t * NR + NR];
        for (r, arow) in acc.iter_mut().enumerate() {
            let a0 = a[2 * r] as i32;
            let a1 = a[2 * r + 1] as i32;
            if a0 == 0 && a1 == 0 {
                continue;
            }
            for (j, o) in arow.iter_mut().enumerate() {
                let byte = b[j];
                *o += a0 * i4_lo(byte) as i32 + a1 * i4_hi(byte) as i32;
            }
        }
    }
}
