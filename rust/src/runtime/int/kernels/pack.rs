//! Panel packing for the blocked integer GEMM.
//!
//! Every micro-kernel (scalar, AVX2, NEON, nibble-domain INT4) consumes
//! the same two panel layouts, so the tiers are interchangeable and —
//! because i32 addition is exactly associative — bit-identical:
//!
//! * **A panels** ([`PackedA`]): activations in row panels of [`MR`]
//!   rows, widened once to `i16` (covers both `i8` and `u8 ≤ 255`
//!   grids).  Within a panel, k runs in *pairs*: for each pair index `t`,
//!   the `MR` rows contribute `[a(r, 2t), a(r, 2t+1)]` back to back —
//!   the unit a `pmaddwd`-style pair dot product broadcasts from.
//! * **B panels** ([`PackedB`]): weights in column panels of [`NR`]
//!   columns, k in the same pairs, *interleaved per column*: each pair
//!   index `t` stores `2·NR` bytes `[b(2t, j), b(2t+1, j)]` for
//!   `j = 0..NR` — one aligned 32-byte load per k-pair on AVX2.
//! * **INT4 B panels** ([`PackedB4`]): same geometry, but the k-pair for
//!   column `j` lives in *one byte* (low nibble = even k, high nibble =
//!   odd k, the [`super::super::packed`] serialization convention), so a
//!   4-bit layer streams half the weight bytes through the inner loop.
//!
//! Ragged shapes are zero-padded: a padded row/column/k-slot contributes
//! exactly 0 to every accumulator, so padding never changes a result.

use super::super::packed::i4_pair;
use super::QAct;

/// Micro-kernel row height (A panel rows).
pub const MR: usize = 4;
/// Micro-kernel column width (B panel columns).
pub const NR: usize = 16;

/// Activations packed into `MR`-row panels (see module docs).
pub struct PackedA {
    /// Logical row count (unpadded).
    pub m: usize,
    /// k rounded up to even (pair granularity).
    pub kp: usize,
    /// Number of row panels, `ceil(m / MR)`.
    pub panels: usize,
    /// `panels * MR * kp` widened values; panel `p` occupies
    /// `data[p*MR*kp .. (p+1)*MR*kp]`.
    pub data: Vec<i16>,
}

/// i8 weights packed into `NR`-column panels (see module docs).
pub struct PackedB {
    /// Logical column count (unpadded).
    pub n: usize,
    /// k rounded up to even.
    pub kp: usize,
    /// Number of column panels, `ceil(n / NR)`.
    pub panels: usize,
    /// `panels * NR * kp` bytes; panel `p` occupies
    /// `data[p*NR*kp .. (p+1)*NR*kp]`.
    pub data: Vec<i8>,
}

/// ≤4-bit weights packed nibble-pair-per-byte into `NR`-column panels.
pub struct PackedB4 {
    pub n: usize,
    pub kp: usize,
    pub panels: usize,
    /// `panels * NR * kp/2` bytes; one byte holds one column's k-pair.
    pub data: Vec<u8>,
}

/// Pack an `(m, k)` row-major activation matrix into A panels.
pub fn pack_a<A: QAct>(a: &[A], m: usize, k: usize) -> PackedA {
    assert_eq!(a.len(), m * k);
    let kp = k + (k & 1);
    let panels = m.div_ceil(MR);
    let mut data = vec![0i16; panels * MR * kp];
    for (row, arow) in a.chunks_exact(k.max(1)).enumerate().take(m) {
        let base = (row / MR) * MR * kp;
        let r = row % MR;
        for (kk, &av) in arow.iter().enumerate() {
            data[base + (kk / 2) * 2 * MR + 2 * r + (kk & 1)] = av.widen() as i16;
        }
    }
    PackedA { m, kp, panels, data }
}

/// Pack a `(k, n)` row-major weight matrix into B panels.
pub fn pack_b(b: &[i8], k: usize, n: usize) -> PackedB {
    assert_eq!(b.len(), k * n);
    let kp = k + (k & 1);
    let panels = n.div_ceil(NR);
    let mut data = vec![0i8; panels * NR * kp];
    for kk in 0..k {
        let brow = &b[kk * n..kk * n + n];
        let (t, odd) = (kk / 2, kk & 1);
        for (col, &bv) in brow.iter().enumerate() {
            let base = (col / NR) * NR * kp;
            data[base + t * 2 * NR + 2 * (col % NR) + odd] = bv;
        }
    }
    PackedB { n, kp, panels, data }
}

/// Pack a `(k, n)` row-major ≤4-bit weight matrix (values in −8..=7)
/// into nibble-pair B panels.
pub fn pack_b4(b: &[i8], k: usize, n: usize) -> PackedB4 {
    assert_eq!(b.len(), k * n);
    debug_assert!(b.iter().all(|&v| (-8..=7).contains(&v)), "value outside i4 range");
    let kp = k + (k & 1);
    let panels = n.div_ceil(NR);
    let mut data = vec![0u8; panels * NR * (kp / 2)];
    for t in 0..kp / 2 {
        let k0 = 2 * t;
        for col in 0..n {
            let lo = b[k0 * n + col];
            let hi = if k0 + 1 < k { b[(k0 + 1) * n + col] } else { 0 };
            let base = (col / NR) * NR * (kp / 2);
            data[base + t * NR + (col % NR)] = i4_pair(lo, hi);
        }
    }
    PackedB4 { n, kp, panels, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::int::packed::{i4_hi, i4_lo};

    #[test]
    fn pack_a_pairs_rows_and_zero_pads() {
        // 3 rows (one short of MR), k = 3 (odd)
        let a: Vec<i8> = vec![1, 2, 3, 4, 5, 6, 7, 8, 9];
        let pa = pack_a(&a, 3, 3);
        assert_eq!((pa.m, pa.kp, pa.panels), (3, 4, 1));
        assert_eq!(pa.data.len(), MR * 4);
        // pair t=0: rows contribute [a(r,0), a(r,1)]; padded row 3 is 0
        assert_eq!(&pa.data[..2 * MR], &[1, 2, 4, 5, 7, 8, 0, 0]);
        // pair t=1: [a(r,2), 0] (k padded to 4)
        assert_eq!(&pa.data[2 * MR..], &[3, 0, 6, 0, 9, 0, 0, 0]);
    }

    #[test]
    fn pack_b_interleaves_k_pairs_per_column() {
        // k = 2, n = NR + 1 (ragged second panel)
        let n = NR + 1;
        let b: Vec<i8> = (0..2 * n).map(|i| i as i8).collect();
        let pb = pack_b(&b, 2, n);
        assert_eq!((pb.n, pb.kp, pb.panels), (n, 2, 2));
        // panel 0, pair 0: [b(0,j), b(1,j)] interleaved for j = 0..NR
        for j in 0..NR {
            assert_eq!(pb.data[2 * j], j as i8);
            assert_eq!(pb.data[2 * j + 1], (n + j) as i8);
        }
        // panel 1 holds column NR then zero padding
        let p1 = &pb.data[NR * 2..];
        assert_eq!(p1[0], NR as i8);
        assert_eq!(p1[1], (n + NR) as i8);
        assert!(p1[2..].iter().all(|&v| v == 0));
    }

    #[test]
    fn pack_b4_matches_pack_b_after_nibble_decode() {
        let (k, n) = (5, 19);
        let b: Vec<i8> = (0..k * n).map(|i| ((i * 7) % 15) as i8 - 7).collect();
        let pb = pack_b(&b, k, n);
        let pb4 = pack_b4(&b, k, n);
        assert_eq!((pb4.kp, pb4.panels), (pb.kp, pb.panels));
        for p in 0..pb.panels {
            for t in 0..pb.kp / 2 {
                for j in 0..NR {
                    let byte = pb4.data[p * NR * (pb4.kp / 2) + t * NR + j];
                    let base = p * NR * pb.kp + t * 2 * NR + 2 * j;
                    assert_eq!(i4_lo(byte), pb.data[base]);
                    assert_eq!(i4_hi(byte), pb.data[base + 1]);
                }
            }
        }
    }

    #[test]
    fn empty_shapes_pack_to_empty_panels() {
        let pa = pack_a::<i8>(&[], 0, 5);
        assert_eq!((pa.panels, pa.data.len()), (0, 0));
        let pa0 = pack_a::<i8>(&[], 3, 0);
        assert_eq!((pa0.kp, pa0.data.len()), (0, 0));
        let pb = pack_b(&[], 0, 7);
        assert_eq!((pb.kp, pb.data.len()), (0, 0));
        let pb4 = pack_b4(&[], 4, 0);
        assert_eq!((pb4.panels, pb4.data.len()), (0, 0));
    }
}
