//! Scalar tier: the original triple-loop GEMM/conv (the *reference*
//! every other tier is pinned against by `tests/kernel_diff`) and the
//! scalar micro-kernel that runs the blocked path on machines without a
//! detected SIMD extension.

use super::pack::{MR, NR};
use super::{im2col, ConvShape, QAct};
use crate::runtime::cpu::ops::{n_threads, par_items};

/// One output row of the reference GEMM: `out[j] += Σ_k a[k]·b[k,j]`,
/// skipping zero activations (common post-ReLU).
pub(crate) fn gemm_row<A: QAct>(a_row: &[A], b: &[i8], n: usize, out: &mut [i32]) {
    for (k, &av) in a_row.iter().enumerate() {
        let a = av.widen();
        if a != 0 {
            let b_row = &b[k * n..k * n + n];
            for (o, &bv) in out.iter_mut().zip(b_row) {
                *o += a * bv as i32;
            }
        }
    }
}

/// The unblocked reference GEMM — row-parallel when substantial,
/// otherwise plain loops.  [`super::gemm_with`] routes here for
/// [`super::KernelChoice::Scalar`].
pub(crate) fn gemm_scalar<A: QAct>(a: &[A], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    if m * k * n >= (1 << 21) && n_threads() > 1 {
        par_items(&mut out, n, |row, o| gemm_row(&a[row * k..(row + 1) * k], b, n, o));
    } else {
        for (row, o) in out.chunks_mut(n).enumerate() {
            gemm_row(&a[row * k..(row + 1) * k], b, n, o);
        }
    }
    out
}

/// The unblocked reference conv: per image, im2col + [`gemm_row`],
/// parallel over images.
pub(crate) fn conv_int_scalar<A: QAct>(xq: &[A], wq: &[i8], d: &ConvShape) -> Vec<i32> {
    let kk = d.kh * d.kw * d.ci;
    let per_x = d.h * d.w * d.ci;
    let per_o = d.ho * d.wo * d.co;
    let mut out = vec![0i32; d.n * per_o];
    par_items(&mut out, per_o, |img, o| {
        let cols = im2col(&xq[img * per_x..(img + 1) * per_x], d);
        for (row, orow) in o.chunks_mut(d.co).enumerate() {
            gemm_row(&cols[row * kk..(row + 1) * kk], wq, d.co, orow);
        }
    });
    out
}

/// Scalar micro-kernel over one A panel × one B panel: accumulates the
/// full `kp` depth into the `MR×NR` register tile.  Consumes exactly the
/// pair layout the SIMD tiers read, so it is also their drop-in
/// replacement on ragged tails and unsupported CPUs.
pub(crate) fn micro_i8(ap: &[i16], bp: &[i8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    for t in 0..kp / 2 {
        let a = &ap[t * 2 * MR..t * 2 * MR + 2 * MR];
        let b = &bp[t * 2 * NR..t * 2 * NR + 2 * NR];
        for (r, arow) in acc.iter_mut().enumerate() {
            let a0 = a[2 * r] as i32;
            let a1 = a[2 * r + 1] as i32;
            if a0 == 0 && a1 == 0 {
                continue;
            }
            for (j, o) in arow.iter_mut().enumerate() {
                *o += a0 * b[2 * j] as i32 + a1 * b[2 * j + 1] as i32;
            }
        }
    }
}
