//! NEON micro-kernel (aarch64, runtime-detected).
//!
//! Same pair-dot strategy as the AVX2 tier, spelled with widening
//! multiplies: every i16 product fits (|a| ≤ 255, |b| ≤ 128 ⇒
//! |a·b| ≤ 32640 < 2¹⁵), so `vmulq_s16` is exact, and `vpadalq_s16`
//! widens the adjacent pair sums to i32 *before* adding — no saturation
//! anywhere, hence bit-identical to the scalar tier.  The INT4 path on
//! this architecture falls back to the shared blocked driver with the
//! scalar nibble micro-kernel (`int4::micro_i4`).

#![allow(unsafe_code)]

use super::pack::{MR, NR};
use core::arch::aarch64::*;

/// Runtime gate for the SIMD tier on this architecture.
pub(crate) fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// Accumulate one A panel × one B panel (i8 pair layout) into `acc`.
///
/// # Safety
/// Caller must ensure NEON is available ([`neon_available`]) and that
/// `ap`/`bp` hold at least `kp/2` pair groups — guaranteed by the panel
/// packers.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn micro_i8_neon(ap: &[i16], bp: &[i8], kp: usize, acc: &mut [[i32; NR]; MR]) {
    debug_assert!(ap.len() >= MR * kp && bp.len() >= NR * kp);
    let mut c = [[vdupq_n_s32(0); 4]; MR];
    for t in 0..kp / 2 {
        let b = bp.as_ptr().add(t * 2 * NR);
        let b01 = vld1q_s8(b); // columns 0..8, pairs interleaved
        let b23 = vld1q_s8(b.add(16)); // columns 8..16
        let bw = [
            vmovl_s8(vget_low_s8(b01)),  // columns 0..4 as i16 pairs
            vmovl_s8(vget_high_s8(b01)), // columns 4..8
            vmovl_s8(vget_low_s8(b23)),  // columns 8..12
            vmovl_s8(vget_high_s8(b23)), // columns 12..16
        ];
        let a = ap.as_ptr().add(t * 2 * MR);
        for (r, cr) in c.iter_mut().enumerate() {
            let a0 = *a.add(2 * r) as u16 as u32;
            let a1 = *a.add(2 * r + 1) as u16 as u32;
            if (a0 | a1) == 0 {
                continue;
            }
            // [a0, a1, a0, a1, ...] to line up with the pair interleave
            let av = vreinterpretq_s16_s32(vdupq_n_s32((a0 | (a1 << 16)) as i32));
            for (g, cg) in cr.iter_mut().enumerate() {
                *cg = vpadalq_s16(*cg, vmulq_s16(av, bw[g]));
            }
        }
    }
    for (cr, arow) in c.iter().zip(acc.iter_mut()) {
        let mut lanes = [0i32; NR];
        for (g, &cg) in cr.iter().enumerate() {
            vst1q_s32(lanes.as_mut_ptr().add(4 * g), cg);
        }
        for (o, l) in arow.iter_mut().zip(lanes) {
            *o += l;
        }
    }
}
