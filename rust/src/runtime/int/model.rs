//! The deployable quantized-model artifact.
//!
//! [`pack`] consumes a calibrated session's fp32 parameters plus the
//! effective [`QuantParams`] (a `lapq::QuantOutcome` in practice) and
//! produces a [`QuantizedModel`]: per-layer i8 weight tensors with
//! per-output-channel scales and pre-quantized i32 biases, with fp32
//! passthrough for layers the calibration left unquantized.  The
//! artifact serializes to `<dir>/quantized.json` (metadata, via
//! `util::json`) plus `<dir>/weights.bin` (a little-endian binary blob;
//! ≤4-bit grids are nibble-packed two per byte).
//!
//! By default `pack` snaps every Δ to the nearest power of two
//! (`PackOpts::po2_scales`).  That is a real deployment technique —
//! requantization degenerates to a bit-shift — and it is also what makes
//! the integer engine *bit-compatible* with the fake-quant reference:
//! with power-of-two scales the reference's f32 accumulation is exact
//! wherever the i32 accumulator stays below 2²⁴ (see `int::kernels`).
//! The artifact records the snapped Δ vectors, so the fake-quant
//! reference for a packed model is `eval` with `QuantizedModel::quant`.

use super::packed::{
    f32s_to_le, i8s_to_le, le_to_f32s, le_to_i8s, pack_i2, pack_i4, unpack_i2, unpack_i4,
};
use crate::quant::quantizer::round_half_even;
use crate::quant::GridKind;
use crate::runtime::backend::QuantParams;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::HostTensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Models the integer engine executes natively; `pack` refuses others
/// (their graphs — grouped conv, residual adds — fall back to the
/// fake-quant backend until covered).
pub const SUPPORTED_MODELS: [&str; 3] = ["mlp3", "cnn6", "ncf"];

/// Packing options.
#[derive(Clone, Debug)]
pub struct PackOpts {
    /// Snap every Δ to the nearest power of two (default).  Disable to
    /// keep the raw calibrated scales; the integer path then matches the
    /// fake-quant reference only to within accumulation rounding.
    pub po2_scales: bool,
}

impl Default for PackOpts {
    fn default() -> Self {
        PackOpts { po2_scales: true }
    }
}

/// One stored parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    /// Symmetric signed integer weights, one i8 per value in memory
    /// (`bits` ≤ 4 payloads serialize nibble-packed).  `scale` has one
    /// entry per output channel (the tensor's last axis).
    Int { bits: u32, q: Vec<i8>, scale: Vec<f32> },
}

#[derive(Clone, Debug, PartialEq)]
pub struct PackedParam {
    pub name: String,
    pub shape: Vec<usize>,
    pub payload: Payload,
}

impl PackedParam {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-quant-layer execution metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    pub name: String,
    pub kind: String,
    pub weight_param: usize,
    pub bias_param: Option<usize>,
    /// Bias pre-quantized to accumulator units (`round_half_even(b /
    /// (Δw·Δa))`), for pure-integer targets whose epilogue is a
    /// [`super::kernels::FixedMult`] shift.  The CPU engine's epilogue
    /// uses the exact f32 bias instead, to stay bit-compatible with the
    /// fake-quant reference (which never quantizes biases).
    pub bias_q: Option<Vec<i32>>,
}

/// A packed, deployable model: what `pack` emits, what `infer` serves.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedModel {
    pub model: String,
    /// Effective quantization parameters (post power-of-two snapping,
    /// masked layers zeroed) — the fake-quant reference grid.
    pub quant: QuantParams,
    pub active_w: Vec<bool>,
    pub active_a: Vec<bool>,
    pub params: Vec<PackedParam>,
    pub layers: Vec<LayerPlan>,
}

/// Nearest power of two (0 stays 0, i.e. "not quantized").
pub fn snap_po2(d: f32) -> f32 {
    if d <= 0.0 {
        return 0.0;
    }
    2.0f32.powi(d.log2().round() as i32)
}

fn bits_for(qmax: f32) -> Result<u32> {
    for b in 2..=8u32 {
        if GridKind::Signed.qmax(b) == qmax {
            return Ok(b);
        }
    }
    bail!("weight grid qmax {qmax} is not a supported ≤8-bit signed grid")
}

/// Serialized weight bytes for `n` values at `bits` (32 = FP32).  One
/// definition shared by `packed_bytes`, the blob codecs and the
/// mixed-precision allocator's budget, so "equal packed size" in a bench
/// comparison means equal bytes on disk.
pub fn weight_storage_bytes(n: usize, bits: u32) -> usize {
    match bits {
        0..=2 => n.div_ceil(4),
        3..=4 => n.div_ceil(2),
        5..=8 => n,
        _ => n * 4,
    }
}

/// Quantize fp32 parameters onto the calibrated grids.  `active`
/// overrides the per-layer weight/activation flags (defaults to Δ > 0);
/// pass the calibration's `LayerMask` vectors so the artifact records
/// which layers the joint phase actually optimized.
pub fn pack(
    spec: &ModelSpec,
    params: &[HostTensor],
    quant: &QuantParams,
    active: Option<(&[bool], &[bool])>,
    opts: &PackOpts,
) -> Result<QuantizedModel> {
    if !SUPPORTED_MODELS.contains(&spec.name.as_str()) {
        bail!(
            "integer engine does not cover '{}' yet (supported: {})",
            spec.name,
            SUPPORTED_MODELS.join(", ")
        );
    }
    if params.len() != spec.params.len() {
        bail!("expected {} params, got {}", spec.params.len(), params.len());
    }
    for (ts, ps) in params.iter().zip(&spec.params) {
        if ts.shape != ps.shape {
            bail!("param {} shape {:?} != spec {:?}", ps.name, ts.shape, ps.shape);
        }
    }
    let n = spec.n_quant_layers();
    let lens = [quant.dw.len(), quant.qmw.len(), quant.da.len(), quant.qma.len()];
    if lens.iter().any(|&l| l != n) {
        bail!("quant params sized {lens:?}, model {} has {n} quant layers", spec.name);
    }

    let mut eff = quant.clone();
    if opts.po2_scales {
        for d in eff.dw.iter_mut() {
            *d = snap_po2(*d);
        }
        for d in eff.da.iter_mut() {
            *d = snap_po2(*d);
        }
    }
    let active_w: Vec<bool> = match active {
        Some((w, _)) => w.to_vec(),
        None => eff.dw.iter().map(|&d| d > 0.0).collect(),
    };
    let active_a: Vec<bool> = match active {
        Some((_, a)) => a.to_vec(),
        None => eff.da.iter().map(|&d| d > 0.0).collect(),
    };
    if active_w.len() != n || active_a.len() != n {
        bail!("active flags sized {}/{}, want {n}", active_w.len(), active_a.len());
    }
    for i in 0..n {
        if !active_w[i] {
            eff.dw[i] = 0.0;
        }
        if !active_a[i] {
            eff.da[i] = 0.0;
        }
        if eff.da[i] > 0.0 {
            let kind = GridKind::from_signed(spec.quant_layers[i].act_signed);
            if eff.qma[i] > kind.qmax(8) {
                bail!(
                    "layer {}: activation qmax {} exceeds the 8-bit grid",
                    spec.quant_layers[i].name,
                    eff.qma[i]
                );
            }
        }
    }

    // Which quant layer owns each weight param.
    let mut owner: Vec<Option<usize>> = vec![None; params.len()];
    for (qi, ql) in spec.quant_layers.iter().enumerate() {
        owner[ql.weight_param] = Some(qi);
    }

    let mut packed = Vec::with_capacity(params.len());
    for (i, (ts, ps)) in params.iter().zip(&spec.params).enumerate() {
        let payload = match owner[i] {
            Some(qi) if eff.dw[qi] > 0.0 => {
                let d = eff.dw[qi];
                let qmax = eff.qmw[qi];
                let bits = bits_for(qmax)
                    .with_context(|| format!("packing layer {}", spec.quant_layers[qi].name))?;
                let quantize = |&w: &f32| round_half_even(w / d).clamp(-qmax, qmax) as i8;
                let q: Vec<i8> = ts.f().iter().map(quantize).collect();
                let co = *ts.shape.last().unwrap_or(&1);
                Payload::Int { bits, q, scale: vec![d; co] }
            }
            _ => Payload::F32(ts.f().to_vec()),
        };
        packed.push(PackedParam { name: ps.name.clone(), shape: ts.shape.clone(), payload });
    }

    let mut layers = Vec::with_capacity(n);
    for (qi, ql) in spec.quant_layers.iter().enumerate() {
        let bias_param = if ql.kind == "embed" {
            None
        } else {
            let bi = ql.weight_param + 1;
            (bi < params.len() && params[bi].shape.len() == 1).then_some(bi)
        };
        let bias_q = match bias_param {
            Some(bi) if eff.dw[qi] > 0.0 && eff.da[qi] > 0.0 => {
                let s = eff.dw[qi] * eff.da[qi];
                Some(
                    params[bi]
                        .f()
                        .iter()
                        .map(|&b| {
                            let v = round_half_even(b / s) as i64;
                            v.clamp(i32::MIN as i64, i32::MAX as i64) as i32
                        })
                        .collect(),
                )
            }
            _ => None,
        };
        layers.push(LayerPlan {
            name: ql.name.clone(),
            kind: ql.kind.clone(),
            weight_param: ql.weight_param,
            bias_param,
            bias_q,
        });
    }

    Ok(QuantizedModel {
        model: spec.name.clone(),
        quant: eff,
        active_w,
        active_a,
        params: packed,
        layers,
    })
}

impl QuantizedModel {
    /// Serialized payload size (i4 nibble-packed, i2 crumb-packed), for
    /// compression stats.
    pub fn packed_bytes(&self) -> usize {
        self.params
            .iter()
            .map(|p| match &p.payload {
                Payload::F32(v) => v.len() * 4,
                Payload::Int { bits, q, .. } => weight_storage_bytes(q.len(), *bits),
            })
            .sum()
    }

    /// Per-quant-layer weight bit-widths as served: the `Payload::Int`
    /// bits for quantized layers, 32 for layers left FP32.  This is the
    /// artifact-truth bit plan echoed by `pack` summaries and
    /// `{"cmd":"models"}`.
    pub fn wbits(&self) -> Vec<u32> {
        self.layers
            .iter()
            .map(|l| match &self.params[l.weight_param].payload {
                Payload::Int { bits, .. } => *bits,
                Payload::F32(_) => 32,
            })
            .collect()
    }

    /// What the same parameters occupy at fp32.
    pub fn f32_bytes(&self) -> usize {
        self.params.iter().map(|p| p.numel() * 4).sum()
    }

    /// Count of integer-packed parameter tensors.
    pub fn int_params(&self) -> usize {
        self.params.iter().filter(|p| matches!(p.payload, Payload::Int { .. })).count()
    }

    /// Write `<dir>/quantized.json` + `<dir>/weights.bin`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        let mut blob: Vec<u8> = Vec::new();
        let mut pjson: Vec<Json> = Vec::new();
        for p in &self.params {
            let offset = blob.len();
            let mut entry = vec![
                ("name", Json::Str(p.name.clone())),
                ("shape", Json::Arr(p.shape.iter().map(|&s| Json::Num(s as f64)).collect())),
            ];
            match &p.payload {
                Payload::F32(v) => {
                    f32s_to_le(v, &mut blob);
                    entry.push(("enc", Json::Str("f32".into())));
                }
                Payload::Int { bits, q, scale } => {
                    if *bits <= 2 {
                        blob.extend_from_slice(&pack_i2(q));
                        entry.push(("enc", Json::Str("i2".into())));
                    } else if *bits <= 4 {
                        blob.extend_from_slice(&pack_i4(q));
                        entry.push(("enc", Json::Str("i4".into())));
                    } else {
                        i8s_to_le(q, &mut blob);
                        entry.push(("enc", Json::Str("i8".into())));
                    }
                    entry.push(("bits", Json::Num(*bits as f64)));
                    entry.push(("scale", Json::arr_f32(scale)));
                }
            }
            entry.push(("offset", Json::Num(offset as f64)));
            entry.push(("bytes", Json::Num((blob.len() - offset) as f64)));
            pjson.push(Json::obj(entry));
        }
        let bools = |v: &[bool]| Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect());
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("name", Json::Str(l.name.clone())),
                    ("kind", Json::Str(l.kind.clone())),
                    ("weight_param", Json::Num(l.weight_param as f64)),
                    ("bias_param", l.bias_param.map_or(Json::Null, |b| Json::Num(b as f64))),
                    (
                        "bias_q",
                        l.bias_q.as_ref().map_or(Json::Null, |b| {
                            Json::Arr(b.iter().map(|&v| Json::Num(v as f64)).collect())
                        }),
                    ),
                ])
            })
            .collect();
        let meta = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("model", Json::Str(self.model.clone())),
            (
                "quant",
                Json::obj(vec![
                    ("dw", Json::arr_f32(&self.quant.dw)),
                    ("qmw", Json::arr_f32(&self.quant.qmw)),
                    ("da", Json::arr_f32(&self.quant.da)),
                    ("qma", Json::arr_f32(&self.quant.qma)),
                ]),
            ),
            ("active_w", bools(&self.active_w)),
            ("active_a", bools(&self.active_a)),
            ("layers", Json::Arr(layers)),
            ("params", Json::Arr(pjson)),
        ]);
        std::fs::write(dir.join("quantized.json"), meta.dump())
            .with_context(|| format!("writing {dir:?}/quantized.json"))?;
        std::fs::write(dir.join("weights.bin"), &blob)
            .with_context(|| format!("writing {dir:?}/weights.bin"))?;
        Ok(())
    }

    /// Load an artifact written by [`QuantizedModel::save`].
    pub fn load(dir: &Path) -> Result<QuantizedModel> {
        let meta_path = dir.join("quantized.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?}"))?;
        let meta = text.parse::<Json>().map_err(|e| anyhow::anyhow!("parse {meta_path:?}: {e}"))?;
        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading {dir:?}/weights.bin"))?;

        // Strict array decoding: a truncated or hand-edited artifact
        // must fail here with a clean error, not index-panic at infer.
        let f32v = |j: &Json, key: &str| -> Result<Vec<f32>> {
            let arr =
                j.get(key).and_then(|v| v.as_arr()).with_context(|| format!("array '{key}'"))?;
            let out: Vec<f32> = arr.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
            if out.len() != arr.len() {
                bail!("non-numeric entries in '{key}'");
            }
            Ok(out)
        };
        let boolv = |j: &Json, key: &str| -> Result<Vec<bool>> {
            let arr =
                j.get(key).and_then(|v| v.as_arr()).with_context(|| format!("array '{key}'"))?;
            let out: Vec<bool> = arr.iter().filter_map(|x| x.as_bool()).collect();
            if out.len() != arr.len() {
                bail!("non-boolean entries in '{key}'");
            }
            Ok(out)
        };

        let q = meta.get("quant").context("missing 'quant'")?;
        let quant = QuantParams {
            dw: f32v(q, "dw")?,
            qmw: f32v(q, "qmw")?,
            da: f32v(q, "da")?,
            qma: f32v(q, "qma")?,
        };

        let mut params = Vec::new();
        for p in meta.get("params").and_then(|v| v.as_arr()).context("missing 'params'")? {
            let name = p.get("name").and_then(|v| v.as_str()).context("param name")?.to_string();
            let shape = p.get("shape").context("param shape")?.usize_arr();
            let numel: usize = shape.iter().product();
            let offset = p.get("offset").and_then(|v| v.as_usize()).context("param offset")?;
            let bytes = p.get("bytes").and_then(|v| v.as_usize()).context("param bytes")?;
            let slice = blob
                .get(offset..offset + bytes)
                .with_context(|| format!("param {name}: blob range {offset}+{bytes}"))?;
            let enc = p.get("enc").and_then(|v| v.as_str()).unwrap_or("f32");
            let payload = match enc {
                "f32" => {
                    let v = le_to_f32s(slice);
                    if v.len() != numel {
                        bail!("param {name}: {} f32 values for shape {shape:?}", v.len());
                    }
                    Payload::F32(v)
                }
                "i8" | "i4" | "i2" => {
                    let q = match enc {
                        "i2" => unpack_i2(slice, numel),
                        "i4" => unpack_i4(slice, numel),
                        _ => le_to_i8s(slice),
                    };
                    if q.len() != numel {
                        bail!("param {name}: {} int values for shape {shape:?}", q.len());
                    }
                    let bits = p.get("bits").and_then(|v| v.as_usize()).unwrap_or(8) as u32;
                    let scale = f32v(p, "scale")?;
                    let co = *shape.last().unwrap_or(&1);
                    if scale.len() != co {
                        bail!("param {name}: {} scales for {co} output channels", scale.len());
                    }
                    Payload::Int { bits, q, scale }
                }
                other => bail!("param {name}: unknown encoding '{other}'"),
            };
            params.push(PackedParam { name, shape, payload });
        }

        let mut layers = Vec::new();
        for l in meta.get("layers").and_then(|v| v.as_arr()).context("missing 'layers'")? {
            let bias_q = match l.get("bias_q") {
                Some(Json::Arr(v)) => {
                    Some(v.iter().filter_map(|x| x.as_f64()).map(|x| x as i32).collect())
                }
                _ => None,
            };
            layers.push(LayerPlan {
                name: l.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                kind: l.get("kind").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                weight_param: l.get("weight_param").and_then(|v| v.as_usize()).context("layer")?,
                bias_param: l.get("bias_param").and_then(|v| v.as_usize()),
                bias_q,
            });
        }

        let qm = QuantizedModel {
            model: meta.get("model").and_then(|v| v.as_str()).context("missing 'model'")?.into(),
            quant,
            active_w: boolv(&meta, "active_w")?,
            active_a: boolv(&meta, "active_a")?,
            params,
            layers,
        };
        let n = qm.layers.len();
        let lens = [
            qm.quant.dw.len(),
            qm.quant.qmw.len(),
            qm.quant.da.len(),
            qm.quant.qma.len(),
            qm.active_w.len(),
            qm.active_a.len(),
        ];
        if lens.iter().any(|&l| l != n) {
            bail!("artifact has {n} layers but per-layer arrays sized {lens:?}");
        }
        for l in &qm.layers {
            if l.weight_param >= qm.params.len()
                || l.bias_param.is_some_and(|b| b >= qm.params.len())
            {
                bail!("layer {} references a missing param", l.name);
            }
        }
        Ok(qm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::tensor::init::init_params;

    fn int8_all(n: usize) -> QuantParams {
        // qma 127 is valid on both signed and unsigned activation grids
        QuantParams {
            dw: vec![0.0625; n],
            qmw: vec![127.0; n],
            da: vec![0.25; n],
            qma: vec![127.0; n],
        }
    }

    #[test]
    fn pack_quantizes_weight_params_only() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 1);
        let qm = pack(spec, &params, &int8_all(3), None, &PackOpts::default()).unwrap();
        assert_eq!(qm.params.len(), 6);
        assert!(matches!(qm.params[0].payload, Payload::Int { bits: 8, .. }));
        assert!(matches!(qm.params[1].payload, Payload::F32(_))); // bias
        assert_eq!(qm.int_params(), 3);
        assert_eq!(qm.layers.len(), 3);
        assert!(qm.layers[0].bias_q.is_some());
        assert!(qm.packed_bytes() < qm.f32_bytes());
    }

    #[test]
    fn pack_respects_masked_layers() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 1);
        let mut q = int8_all(3);
        q.dw[0] = 0.0; // first layer left fp32
        let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
        assert!(matches!(qm.params[0].payload, Payload::F32(_)));
        assert!(matches!(qm.params[2].payload, Payload::Int { .. }));
        assert!(!qm.active_w[0]);
        assert!(qm.layers[0].bias_q.is_none());
    }

    #[test]
    fn pack_rejects_uncovered_models() {
        let m = Manifest::builtin();
        let spec = m.model("dwsep").unwrap();
        let params = init_params(&spec.params, 1);
        let n = spec.n_quant_layers();
        let err = pack(spec, &params, &int8_all(n), None, &PackOpts::default());
        assert!(err.is_err());
    }

    #[test]
    fn mixed_bits_pack_and_accounting() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 3);
        // per-layer W8 / W2 / W4 grids (mixed-precision plan)
        let q = QuantParams {
            dw: vec![0.0625, 0.5, 0.125],
            qmw: vec![127.0, 1.0, 7.0],
            da: vec![0.25; 3],
            qma: vec![127.0; 3],
        };
        let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
        assert_eq!(qm.wbits(), vec![8, 2, 4]);
        let weight_bytes: usize = qm
            .params
            .iter()
            .filter_map(|p| match &p.payload {
                Payload::Int { bits, q, .. } => Some(weight_storage_bytes(q.len(), *bits)),
                Payload::F32(_) => None,
            })
            .sum();
        let f32_weightless: usize = qm
            .params
            .iter()
            .filter_map(|p| match &p.payload {
                Payload::F32(v) => Some(v.len() * 4),
                _ => None,
            })
            .sum();
        assert_eq!(qm.packed_bytes(), weight_bytes + f32_weightless);
        // ternary layer really is ternary
        if let Payload::Int { bits, q, .. } = &qm.params[2].payload {
            assert_eq!(*bits, 2);
            assert!(q.iter().all(|&v| (-1..=1).contains(&v)));
        } else {
            panic!("layer 1 weights should be Int");
        }
    }

    #[test]
    fn weight_storage_bytes_densities() {
        assert_eq!(weight_storage_bytes(9, 2), 3);
        assert_eq!(weight_storage_bytes(9, 4), 5);
        assert_eq!(weight_storage_bytes(9, 8), 9);
        assert_eq!(weight_storage_bytes(9, 32), 36);
    }

    #[test]
    fn snap_po2_hits_nearest_power() {
        assert_eq!(snap_po2(0.0), 0.0);
        assert_eq!(snap_po2(0.25), 0.25);
        assert_eq!(snap_po2(0.3), 0.25);
        assert_eq!(snap_po2(0.4), 0.5);
        assert_eq!(snap_po2(3.0), 4.0); // log2(3)≈1.58 rounds to 2
    }

    #[test]
    fn po2_snapping_recorded_in_effective_quant() {
        let m = Manifest::builtin();
        let spec = m.model("mlp3").unwrap();
        let params = init_params(&spec.params, 2);
        let mut q = int8_all(3);
        q.dw = vec![0.3, 0.3, 0.3];
        q.da = vec![0.7, 0.7, 0.7];
        let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
        assert_eq!(qm.quant.dw, vec![0.25; 3]);
        assert_eq!(qm.quant.da, vec![0.5; 3]);
        let raw = pack(spec, &params, &q, None, &PackOpts { po2_scales: false }).unwrap();
        assert_eq!(raw.quant.dw, vec![0.3; 3]);
    }
}
