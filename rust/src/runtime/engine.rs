//! The PJRT engine thread: owns the client, compiled executables, model
//! sessions (device-resident parameters/optimizer state) and registered
//! calibration batches.  Requests arrive over an mpsc mailbox from
//! [`super::handle::PjrtEngine`].
//!
//! Design notes:
//! * Executables are compiled lazily per (model, entry) and cached — the
//!   Powell hot loop re-executes `fwd_quant` thousands of times against
//!   one compiled artifact.
//! * Calibration batches are registered once and kept as `Literal`s, so
//!   an objective evaluation ships only the 4 tiny Δ vectors.
//! * Sessions own parameters + momentum as `Literal`s; `train_step`
//!   swaps them wholesale from the executable outputs (state never
//!   round-trips through the caller).

use super::backend::{BatchId, EngineStats, QuantParams, SessionId};
use super::manifest::Manifest;
use crate::tensor::{Data, HostTensor};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Mailbox requests.  Every variant carries its own reply channel.
pub enum Request {
    CreateSession { model: String, params: Vec<HostTensor>, reply: Sender<Result<SessionId>> },
    DropSession { sess: SessionId, reply: Sender<Result<()>> },
    GetParams { sess: SessionId, reply: Sender<Result<Vec<HostTensor>>> },
    SetParams { sess: SessionId, params: Vec<HostTensor>, reply: Sender<Result<()>> },
    RegisterBatch { batch: Vec<HostTensor>, reply: Sender<Result<BatchId>> },
    DropBatch { batch: BatchId, reply: Sender<Result<()>> },
    TrainStep { sess: SessionId, batch: BatchId, lr: f32, reply: Sender<Result<f32>> },
    /// fwd_quant / fwd_fp32: returns (loss, correct).
    Eval {
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
        reply: Sender<Result<(f32, f32)>>,
    },
    /// NCF hit-rate entries: returns hit count.
    Hitrate {
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
        reply: Sender<Result<f32>>,
    },
    Acts { sess: SessionId, batch: BatchId, reply: Sender<Result<Vec<HostTensor>>> },
    Stats { reply: Sender<Result<EngineStats>> },
    Shutdown,
}

struct Session {
    model: String,
    params: Vec<Literal>,
    momentum: Vec<Literal>,
}

pub(super) struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    executables: HashMap<(String, String), PjRtLoadedExecutable>,
    sessions: HashMap<SessionId, Session>,
    batches: HashMap<BatchId, Vec<Literal>>,
    next_id: u64,
    stats: EngineStats,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        log::info!(
            "engine: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            manifest,
            executables: HashMap::new(),
            sessions: HashMap::new(),
            batches: HashMap::new(),
            next_id: 1,
            stats: EngineStats::default(),
        })
    }

    /// Main loop; returns when `Shutdown` arrives or all senders drop.
    pub fn run(mut self, rx: Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::Shutdown => break,
                Request::CreateSession { model, params, reply } => {
                    let _ = reply.send(self.create_session(&model, params));
                }
                Request::DropSession { sess, reply } => {
                    self.sessions.remove(&sess);
                    let _ = reply.send(Ok(()));
                }
                Request::GetParams { sess, reply } => {
                    let _ = reply.send(self.get_params(sess));
                }
                Request::SetParams { sess, params, reply } => {
                    let _ = reply.send(self.set_params(sess, params));
                }
                Request::RegisterBatch { batch, reply } => {
                    let _ = reply.send(self.register_batch(batch));
                }
                Request::DropBatch { batch, reply } => {
                    self.batches.remove(&batch);
                    let _ = reply.send(Ok(()));
                }
                Request::TrainStep { sess, batch, lr, reply } => {
                    let _ = reply.send(self.train_step(sess, batch, lr));
                }
                Request::Eval { sess, quant, batch, reply } => {
                    let _ = reply.send(self.eval(sess, quant, batch));
                }
                Request::Hitrate { sess, quant, batch, reply } => {
                    let _ = reply.send(self.hitrate(sess, quant, batch));
                }
                Request::Acts { sess, batch, reply } => {
                    let _ = reply.send(self.acts(sess, batch));
                }
                Request::Stats { reply } => {
                    let mut s = self.stats.clone();
                    s.sessions = self.sessions.len() as u64;
                    s.batches = self.batches.len() as u64;
                    let _ = reply.send(Ok(s));
                }
            }
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn executable(&mut self, model: &str, entry: &str) -> Result<&PjRtLoadedExecutable> {
        let key = (model.to_string(), entry.to_string());
        if !self.executables.contains_key(&key) {
            let path = self.manifest.hlo_path(model, entry)?;
            let t0 = std::time::Instant::now();
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).map_err(|e| anyhow!("compile {model}/{entry}: {e:?}"))?;
            log::info!("compiled {model}/{entry} in {:.2}s", t0.elapsed().as_secs_f64());
            self.stats.compiled += 1;
            self.executables.insert(key.clone(), exe);
        }
        Ok(&self.executables[&key])
    }

    fn create_session(&mut self, model: &str, params: Vec<HostTensor>) -> Result<SessionId> {
        let spec = self.manifest.model(model)?;
        if params.len() != spec.params.len() {
            bail!("session: expected {} params, got {}", spec.params.len(), params.len());
        }
        for (t, p) in params.iter().zip(&spec.params) {
            if t.shape != p.shape {
                bail!("param {} shape {:?} != spec {:?}", p.name, t.shape, p.shape);
            }
        }
        let momentum: Vec<Literal> =
            params.iter().map(|t| literal_of(&HostTensor::zeros(t.shape.clone()))).collect::<Result<_>>()?;
        let params: Vec<Literal> = params.iter().map(literal_of).collect::<Result<_>>()?;
        let id = self.fresh_id();
        self.sessions.insert(id, Session { model: model.to_string(), params, momentum });
        Ok(id)
    }

    fn session(&self, sess: SessionId) -> Result<&Session> {
        self.sessions.get(&sess).context("unknown session")
    }

    fn get_params(&self, sess: SessionId) -> Result<Vec<HostTensor>> {
        self.session(sess)?.params.iter().map(host_of).collect()
    }

    fn set_params(&mut self, sess: SessionId, params: Vec<HostTensor>) -> Result<()> {
        let s = self.sessions.get_mut(&sess).context("unknown session")?;
        if params.len() != s.params.len() {
            bail!("set_params: wrong count");
        }
        s.params = params.iter().map(literal_of).collect::<Result<_>>()?;
        Ok(())
    }

    fn register_batch(&mut self, batch: Vec<HostTensor>) -> Result<BatchId> {
        let lits: Vec<Literal> = batch.iter().map(literal_of).collect::<Result<_>>()?;
        let id = self.fresh_id();
        self.batches.insert(id, lits);
        Ok(id)
    }

    /// Execute `entry` with args = session params ++ extra ++ batch.
    fn execute(
        &mut self,
        sess: SessionId,
        entry: &str,
        extra: &[Literal],
        batch: BatchId,
        include_momentum: bool,
        extra_after_batch: bool,
    ) -> Result<Vec<Literal>> {
        let model = self.session(sess)?.model.clone();
        let n_expected = self.manifest.model(&model)?.entry(entry)?.n_args;
        // ensure the executable is compiled before borrowing session state
        self.executable(&model, entry)?;
        // assemble argument references in ABI order
        let s = &self.sessions[&sess];
        let b = self.batches.get(&batch).context("unknown batch")?;
        let mut args: Vec<&Literal> = Vec::with_capacity(n_expected);
        args.extend(s.params.iter());
        if include_momentum {
            args.extend(s.momentum.iter());
        }
        if extra_after_batch {
            args.extend(b.iter());
            args.extend(extra.iter());
        } else {
            args.extend(extra.iter());
            args.extend(b.iter());
        }
        if args.len() != n_expected {
            bail!("{model}/{entry}: assembled {} args, artifact wants {n_expected}", args.len());
        }
        let exe = &self.executables[&(model.clone(), entry.to_string())];
        let t0 = std::time::Instant::now();
        let mut out = exe
            .execute::<&Literal>(&args)
            .map_err(|e| anyhow!("execute {model}/{entry}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.executions += 1;
        self.stats.exec_seconds += dt;
        // The artifact returns a single tuple (return_tuple=True): fetch,
        // then decompose into leaves.
        let buf = out
            .first_mut()
            .and_then(|v| v.first_mut())
            .context("no output buffer")?;
        let mut lit = buf.to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let leaves = lit.decompose_tuple().map_err(|e| anyhow!("decompose: {e:?}"))?;
        if leaves.is_empty() {
            Ok(vec![lit])
        } else {
            Ok(leaves)
        }
    }

    fn train_step(&mut self, sess: SessionId, batch: BatchId, lr: f32) -> Result<f32> {
        let extra = vec![Literal::scalar(lr)];
        let out = self.execute(sess, "train_step", &extra, batch, true, true)?;
        let n = self.session(sess)?.params.len();
        if out.len() != 2 * n + 1 {
            bail!("train_step returned {} outputs, want {}", out.len(), 2 * n + 1);
        }
        let mut it = out.into_iter();
        let new_params: Vec<Literal> = it.by_ref().take(n).collect();
        let new_mom: Vec<Literal> = it.by_ref().take(n).collect();
        let loss = it.next().unwrap();
        let s = self.sessions.get_mut(&sess).unwrap();
        s.params = new_params;
        s.momentum = new_mom;
        scalar_f32(&loss)
    }

    fn quant_literals(q: &QuantParams) -> Result<Vec<Literal>> {
        Ok(vec![
            literal_of(&HostTensor::f32(vec![q.dw.len()], q.dw.clone()))?,
            literal_of(&HostTensor::f32(vec![q.qmw.len()], q.qmw.clone()))?,
            literal_of(&HostTensor::f32(vec![q.da.len()], q.da.clone()))?,
            literal_of(&HostTensor::f32(vec![q.qma.len()], q.qma.clone()))?,
        ])
    }

    fn eval(
        &mut self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<(f32, f32)> {
        let (entry, extra) = match &quant {
            Some(q) => ("fwd_quant", Self::quant_literals(q)?),
            None => ("fwd_fp32", vec![]),
        };
        let out = self.execute(sess, entry, &extra, batch, false, false)?;
        if out.len() != 2 {
            bail!("eval returned {} outputs", out.len());
        }
        Ok((scalar_f32(&out[0])?, scalar_f32(&out[1])?))
    }

    fn hitrate(
        &mut self,
        sess: SessionId,
        quant: Option<QuantParams>,
        batch: BatchId,
    ) -> Result<f32> {
        let (entry, extra) = match &quant {
            Some(q) => ("hitrate_quant", Self::quant_literals(q)?),
            None => ("hitrate", vec![]),
        };
        let out = self.execute(sess, entry, &extra, batch, false, false)?;
        scalar_f32(&out[0])
    }

    fn acts(&mut self, sess: SessionId, batch: BatchId) -> Result<Vec<HostTensor>> {
        let out = self.execute(sess, "acts", &[], batch, false, false)?;
        out.iter().map(host_of).collect()
    }
}

/// HostTensor -> xla::Literal.
pub(super) fn literal_of(t: &HostTensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => Literal::vec1(v.as_slice()),
        Data::I32(v) => Literal::vec1(v.as_slice()),
    };
    lit.reshape(&dims).map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape))
}

/// xla::Literal -> HostTensor.
pub(super) fn host_of(lit: &Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            Ok(HostTensor::f32(dims, lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?))
        }
        xla::ElementType::S32 => {
            Ok(HostTensor::i32(dims, lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?))
        }
        other => bail!("unsupported element type {other:?}"),
    }
}

fn scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("scalar: {e:?}"))
}
