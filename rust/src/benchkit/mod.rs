//! Bench harness (substrate for the absent `criterion`): warmup + timed
//! iterations with mean/p50/p95, plus the table/CSV formatting every
//! paper-figure bench uses.  Benches are `harness = false` binaries.

use crate::util::stats;
use std::time::Instant;

/// Timing result for one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

/// Time `f` with `warmup` unrecorded and `iters` recorded runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() as f32);
    }
    let t = Timing {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples) as f64,
        p50_s: stats::percentile(&samples, 50.0) as f64,
        p95_s: stats::percentile(&samples, 95.0) as f64,
    };
    println!(
        "[bench] {:<40} mean {:>9.3} ms  p50 {:>9.3} ms  p95 {:>9.3} ms  ({} iters)",
        t.name,
        t.mean_s * 1e3,
        t.p50_s * 1e3,
        t.p95_s * 1e3,
        t.iters
    );
    t
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line: Vec<String> =
            self.headers.iter().zip(&widths).map(|(h, w)| format!("{h:<w$}")).collect();
        println!("| {} |", line.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for r in &self.rows {
            let line: Vec<String> =
                r.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}")).collect();
            println!("| {} |", line.join(" | "));
        }
    }

    /// Write the table as CSV under `bench_results/<file>`.
    pub fn write_csv(&self, file: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(file);
        let mut out = self.headers.join(",") + "\n";
        for r in &self.rows {
            out += &(r.join(",") + "\n");
        }
        std::fs::write(&path, out)?;
        println!("[csv] wrote {path:?}");
        Ok(path)
    }
}

/// `fmt` helpers used across benches.
pub fn pct(x: f32) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let t = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.123), "12.3%");
    }
}
