//! Experiment configuration: typed schema + JSON file loading + `k=v`
//! CLI overrides.  One [`ExperimentConfig`] fully describes a run
//! (model, training budget, quantization setting, method, pipeline knobs),
//! which is what the job scheduler, the CLI and the benches all construct.
//!
//! `to_json`/`from_json` round-trip **losslessly** (including the whole
//! `lapq` sub-config) so service job responses and EXPERIMENTS records
//! can reproduce a run exactly.  The `-s key=value` override surface is a
//! single table ([`OVERRIDES`]) that both `apply_overrides` and the CLI
//! help text derive from, so docs can't drift from behaviour.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Calibration method under test (Table 1 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full LAPQ: layer-wise Lp + quadratic approx + joint optimization.
    Lapq,
    /// Layer-wise MMSE (p=2), no joint phase.
    Mmse,
    /// ACIQ analytic clipping.
    Aciq,
    /// TensorRT-style KL calibration.
    Kld,
    /// Min-max (no clipping).
    MinMax,
}

impl Method {
    pub const ALL: [Method; 5] =
        [Method::Lapq, Method::Mmse, Method::Aciq, Method::Kld, Method::MinMax];

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "lapq" => Method::Lapq,
            "mmse" => Method::Mmse,
            "aciq" => Method::Aciq,
            "kld" => Method::Kld,
            "minmax" | "min-max" => Method::MinMax,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Lapq => "LAPQ",
            Method::Mmse => "MMSE",
            Method::Aciq => "ACIQ",
            Method::Kld => "KLD",
            Method::MinMax => "MinMax",
        }
    }
}

/// W/A bitwidths; 32 means "leave FP32" (Δ = 0 everywhere on that side).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitSpec {
    pub weights: u32,
    pub acts: u32,
}

impl BitSpec {
    pub fn new(weights: u32, acts: u32) -> Self {
        BitSpec { weights, acts }
    }

    pub fn label(&self) -> String {
        format!("{} / {}", self.weights, self.acts)
    }

    pub fn quant_weights(&self) -> bool {
        self.weights < 32
    }

    pub fn quant_acts(&self) -> bool {
        self.acts < 32
    }
}

/// Joint-phase optimizer choice (Alg. 1 lines 13–21).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JointOpt {
    /// Powell's direction-set method (the paper's choice).
    Powell,
    /// Nelder–Mead downhill simplex.
    NelderMead,
    /// Cyclic coordinate descent (the "separable view" ablation).
    CoordinateDescent,
}

impl JointOpt {
    pub const ALL: [JointOpt; 3] =
        [JointOpt::Powell, JointOpt::NelderMead, JointOpt::CoordinateDescent];

    pub fn parse(s: &str) -> Result<JointOpt> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "powell" => JointOpt::Powell,
            "nm" | "nelder-mead" | "neldermead" => JointOpt::NelderMead,
            "cd" | "coordinate" | "coordinate-descent" => JointOpt::CoordinateDescent,
            other => bail!("unknown joint optimizer '{other}' (powell|nm|cd)"),
        })
    }

    /// Canonical wire/override key.
    pub fn key(&self) -> &'static str {
        match self {
            JointOpt::Powell => "powell",
            JointOpt::NelderMead => "nm",
            JointOpt::CoordinateDescent => "cd",
        }
    }

    /// Display name (tables, service responses).
    pub fn name(&self) -> &'static str {
        match self {
            JointOpt::Powell => "Powell",
            JointOpt::NelderMead => "NelderMead",
            JointOpt::CoordinateDescent => "CoordinateDescent",
        }
    }
}

/// Joint-phase configuration: which optimizer and how much budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JointCfg {
    pub optimizer: JointOpt,
    /// Outer iterations (Powell direction sweeps / CD sweeps; unused by
    /// Nelder–Mead, which runs to `max_evals`).
    pub iters: usize,
    /// Hard cap on joint-phase objective evaluations.
    pub max_evals: usize,
}

impl Default for JointCfg {
    fn default() -> Self {
        JointCfg { optimizer: JointOpt::Powell, iters: 2, max_evals: 600 }
    }
}

/// LAPQ pipeline knobs (paper defaults in `Default`).
#[derive(Clone, Debug, PartialEq)]
pub struct LapqCfg {
    /// p grid for phase 1 (paper sweeps ~[2, 4]).
    pub p_grid: Vec<f32>,
    /// Joint phase: optimizer choice + budget.
    pub joint: JointCfg,
    /// Multiplicative search box around the initialization, per layer.
    pub box_lo: f64,
    pub box_hi: f64,
    /// Skip quantizing first/last quant layers (paper convention).
    pub exclude_first_last: bool,
    /// Apply Banner-style per-channel bias correction to weights.
    pub bias_correction: bool,
}

impl Default for LapqCfg {
    fn default() -> Self {
        LapqCfg {
            // Wider than the paper's [2,4]: on small stand-ins the whole
            // [2,4] trajectory can sit inside the low-bit collapse plateau
            // while large p (≈ min-max) survives; the quadratic fit then
            // interpolates in the informative region.
            p_grid: vec![2.0, 2.5, 3.0, 4.0, 6.0, 8.0],
            joint: JointCfg::default(),
            box_lo: 0.3,
            box_hi: 3.0,
            exclude_first_last: true,
            bias_correction: true,
        }
    }
}

/// Default packed-model registry (LRU) capacity — the single default
/// shared by `Runner::new` and [`ServeCfg`], defined here in the leaf
/// module both can depend on.
pub const DEFAULT_REGISTRY_CAP: usize = 4;

/// Default registry hash-shard count for pool deployments (the unit
/// constructor `ModelRegistry::new` stays single-shard).
pub const DEFAULT_REGISTRY_SHARDS: usize = 4;

/// How the pool server owns connection I/O.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// Thread-per-connection: each worker blocks on one socket at a
    /// time, so `workers` caps concurrently-open connections.
    Threads,
    /// Readiness-polled reactor (`serve::event`): one poller thread
    /// owns every socket's reads/writes and only decoded requests hit
    /// the worker pool — idle connections cost ~0 threads.
    Poll,
}

impl IoMode {
    pub fn parse(s: &str) -> Result<IoMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "threads" | "thread" => IoMode::Threads,
            "poll" | "event" => IoMode::Poll,
            other => bail!("unknown serve.io '{other}' (threads|poll)"),
        })
    }

    /// Canonical wire/override key.
    pub fn key(&self) -> &'static str {
        match self {
            IoMode::Threads => "threads",
            IoMode::Poll => "poll",
        }
    }

    /// The default mode, overridable by `LAPQ_SERVE_IO=poll|threads` so
    /// CI can run the whole serve suite under the reactor (mirroring
    /// the `LAPQ_KERNEL=scalar` second pass).
    fn env_default() -> IoMode {
        match std::env::var("LAPQ_SERVE_IO").as_deref() {
            Ok("poll") | Ok("event") => IoMode::Poll,
            _ => IoMode::Threads,
        }
    }
}

/// Concurrent-serving knobs (`rust/src/serve/`): connection I/O mode,
/// worker pool width, micro-batching lanes, admission bound, registry
/// capacity.  Part of the lossless config surface so a deployment is
/// reproducible from its config echo, and overridable with `-s serve.*`
/// keys.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeCfg {
    /// Connection I/O: blocking thread-per-connection or the
    /// readiness-polled reactor.
    pub io: IoMode,
    /// Worker threads; under `io=threads` also the max
    /// concurrently-served (persistent) connections.
    pub workers: usize,
    /// Micro-batch coalescing window in milliseconds (0 disables).
    pub batch_window_ms: f64,
    /// Max requests coalesced into one kernel execution (1 disables).
    pub max_batch: usize,
    /// Bound on queued connections/requests before shedding.
    pub queue_bound: usize,
    /// Packed-model registry (LRU) capacity.
    pub registry_cap: usize,
    /// Max concurrently-open connections under `io=poll` (excess is
    /// shed with the typed `overloaded` response).
    pub max_conns: usize,
    /// Per-connection output-queue cap in KiB under `io=poll`: a client
    /// that never reads gets a typed shed + close once its queued
    /// output would exceed this.
    pub out_queue_kib: usize,
    /// Max per-model batcher lanes; hot keys past the cap hash onto an
    /// existing lane (1 reproduces the single global batcher).
    pub max_lanes: usize,
    /// Registry hash shards under the one `registry_cap` budget
    /// (1 reproduces the single global LRU lock).
    pub registry_shards: usize,
    /// Spill directory for evicted packed models (`None` disables
    /// spill: an evicted model is gone until re-packed).
    pub spill_dir: Option<String>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            io: IoMode::env_default(),
            workers: 8,
            batch_window_ms: 2.0,
            max_batch: 16,
            queue_bound: 64,
            registry_cap: DEFAULT_REGISTRY_CAP,
            max_conns: 4096,
            out_queue_kib: 256,
            max_lanes: 4,
            registry_shards: DEFAULT_REGISTRY_SHARDS,
            spill_dir: None,
        }
    }
}

/// Fleet-tier knobs (`rust/src/serve/fleet/`): the consistent-hash
/// front-tier router over N pool-server replicas.  Part of the lossless
/// config surface with `-s fleet.*` overrides; `repro route` reads it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetCfg {
    /// Pool-server replica addresses (`host:port`).  Empty means "no
    /// fleet": the `route` command requires at least one.
    pub replicas: Vec<String>,
    /// Virtual nodes per replica on the hash ring (more = smoother key
    /// spread).
    pub vnodes: usize,
    /// Health-probe interval in milliseconds.
    pub ping_interval_ms: u64,
    /// Consecutive transport failures before a replica is ejected.
    pub fail_threshold: u32,
    /// Ejection window in milliseconds before probational re-admission.
    pub eject_ms: u64,
}

impl Default for FleetCfg {
    fn default() -> Self {
        FleetCfg {
            replicas: Vec::new(),
            vnodes: 64,
            ping_interval_ms: 500,
            fail_threshold: 3,
            eject_ms: 2000,
        }
    }
}

/// How the mixed-precision profiler estimates per-layer sensitivity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProfilerMode {
    /// Quadratic estimate from `analysis::weight_hessian` (cheap; falls
    /// back to `Direct` when the estimate is degenerate).
    Curvature,
    /// Direct loss evaluations, one layer × bit-width at a time.
    Direct,
}

impl ProfilerMode {
    pub fn parse(s: &str) -> Result<ProfilerMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "curvature" | "curv" => ProfilerMode::Curvature,
            "direct" => ProfilerMode::Direct,
            other => bail!("unknown profiler mode '{other}' (curvature|direct)"),
        })
    }

    /// Canonical wire/override key.
    pub fn key(&self) -> &'static str {
        match self {
            ProfilerMode::Curvature => "curvature",
            ProfilerMode::Direct => "direct",
        }
    }
}

/// Mixed-precision knobs (`rust/src/lapq/mixed/`): sensitivity-driven
/// per-layer weight bit allocation under a model-size budget, plus the
/// sharpness-aware post stage.  Disabled by default; part of the lossless
/// config surface with `-s mixed.*` overrides.
#[derive(Clone, Debug, PartialEq)]
pub struct MixedCfg {
    /// Master switch: profile sensitivities and allocate per-layer bits.
    pub enabled: bool,
    /// Weight-byte budget as a fraction of the uniform `bits_w` packed
    /// size (1.0 = "same size as the uniform baseline").
    pub budget_frac: f64,
    /// Candidate per-layer weight bit-widths the allocator may pick from.
    pub candidate_bits: Vec<u32>,
    /// Sensitivity profiler mode.
    pub profiler: ProfilerMode,
    /// Sharpness-aware post stage: number of sampled Δ-perturbations
    /// (0 disables the stage).
    pub sharpness_k: usize,
    /// Relative radius of the perturbation neighborhood.
    pub sharpness_radius: f64,
}

impl Default for MixedCfg {
    fn default() -> Self {
        MixedCfg {
            enabled: false,
            budget_frac: 1.0,
            candidate_bits: vec![2, 4, 8],
            profiler: ProfilerMode::Curvature,
            sharpness_k: 4,
            sharpness_radius: 0.1,
        }
    }
}

/// A full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub model: String,
    pub seed: u64,
    /// Training budget (steps) for producing the FP32 model.
    pub train_steps: usize,
    pub lr: f32,
    /// Calibration set size in samples (paper: 512 images).
    pub calib_size: usize,
    /// Validation set size in samples.
    pub val_size: usize,
    pub bits: BitSpec,
    pub method: Method,
    pub lapq: LapqCfg,
    pub serve: ServeCfg,
    pub mixed: MixedCfg,
    pub fleet: FleetCfg,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "cnn6".into(),
            seed: 42,
            train_steps: 300,
            lr: 0.02,
            calib_size: 512,
            val_size: 2048,
            bits: BitSpec::new(4, 4),
            method: Method::Lapq,
            lapq: LapqCfg::default(),
            serve: ServeCfg::default(),
            mixed: MixedCfg::default(),
            fleet: FleetCfg::default(),
        }
    }
}

/// One `-s key=value` override: the key, a one-line help string, an
/// example value (exercised by tests so the table can't rot), and the
/// application function.  [`ExperimentConfig::apply_overrides`] and the
/// CLI help text are both driven by this table.
pub struct OverrideSpec {
    pub key: &'static str,
    pub help: &'static str,
    pub example: &'static str,
    pub apply: fn(&mut ExperimentConfig, &str) -> Result<()>,
}

/// The full `-s` override surface.
pub const OVERRIDES: &[OverrideSpec] = &[
    OverrideSpec {
        key: "model",
        help: "model name (mlp3|cnn6|dwsep|resmini|ncf)",
        example: "mlp3",
        apply: |c, v| {
            c.model = v.to_string();
            Ok(())
        },
    },
    OverrideSpec {
        key: "seed",
        help: "training/data RNG seed",
        example: "7",
        apply: |c, v| {
            c.seed = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "train_steps",
        help: "FP32 training steps",
        example: "60",
        apply: |c, v| {
            c.train_steps = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "lr",
        help: "training learning rate",
        example: "0.05",
        apply: |c, v| {
            c.lr = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "calib_size",
        help: "calibration set size (samples)",
        example: "512",
        apply: |c, v| {
            c.calib_size = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "val_size",
        help: "validation set size (samples)",
        example: "1024",
        apply: |c, v| {
            c.val_size = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "bits_w",
        help: "weight bitwidth (32 = FP32)",
        example: "4",
        apply: |c, v| {
            c.bits.weights = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "bits_a",
        help: "activation bitwidth (32 = FP32)",
        example: "4",
        apply: |c, v| {
            c.bits.acts = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "method",
        help: "calibration method (lapq|mmse|aciq|kld|minmax)",
        example: "lapq",
        apply: |c, v| {
            c.method = Method::parse(v)?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "p_grid",
        help: "comma-separated p grid for phase 1 (e.g. 2,3,4)",
        example: "2,3,4",
        apply: |c, v| {
            c.lapq.p_grid = parse_f32_list(v)?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "joint",
        help: "joint optimizer (powell|nm|cd)",
        example: "nm",
        apply: |c, v| {
            c.lapq.joint.optimizer = JointOpt::parse(v)?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "joint_iters",
        help: "joint outer iterations (Powell/CD sweeps)",
        example: "2",
        apply: |c, v| {
            c.lapq.joint.iters = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "powell_iters",
        help: "alias of joint_iters (legacy)",
        example: "2",
        apply: |c, v| {
            c.lapq.joint.iters = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "max_evals",
        help: "joint objective-eval budget",
        example: "120",
        apply: |c, v| {
            c.lapq.joint.max_evals = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "box_lo",
        help: "joint search box lower multiplier",
        example: "0.3",
        apply: |c, v| {
            c.lapq.box_lo = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "box_hi",
        help: "joint search box upper multiplier",
        example: "3.0",
        apply: |c, v| {
            c.lapq.box_hi = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "bias_correction",
        help: "apply Banner-style bias correction (true|false)",
        example: "false",
        apply: |c, v| {
            c.lapq.bias_correction = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "exclude_first_last",
        help: "leave first/last quant layers FP32 (true|false)",
        example: "true",
        apply: |c, v| {
            c.lapq.exclude_first_last = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.workers",
        help: "serving worker threads (= max concurrent connections)",
        example: "8",
        apply: |c, v| {
            c.serve.workers = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.batch_window_ms",
        help: "micro-batch coalescing window in ms (0 disables)",
        example: "2.5",
        apply: |c, v| {
            c.serve.batch_window_ms = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.max_batch",
        help: "max infer requests coalesced per execution (1 disables)",
        example: "16",
        apply: |c, v| {
            c.serve.max_batch = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.queue_bound",
        help: "admission queue bound before shedding 'overloaded'",
        example: "64",
        apply: |c, v| {
            c.serve.queue_bound = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.registry_cap",
        help: "packed-model registry (LRU) capacity",
        example: "4",
        apply: |c, v| {
            c.serve.registry_cap = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.io",
        help: "connection I/O mode (threads|poll)",
        example: "poll",
        apply: |c, v| {
            c.serve.io = IoMode::parse(v)?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.max_conns",
        help: "max open connections under io=poll before shedding",
        example: "4096",
        apply: |c, v| {
            c.serve.max_conns = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.out_queue_kib",
        help: "per-connection output-queue cap in KiB under io=poll",
        example: "256",
        apply: |c, v| {
            c.serve.out_queue_kib = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "serve.max_lanes",
        help: "max per-model batcher lanes (1 = single global batcher)",
        example: "4",
        apply: |c, v| {
            c.serve.max_lanes = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "registry.shards",
        help: "registry hash shards under one capacity budget (1 = single lock)",
        example: "4",
        apply: |c, v| {
            c.serve.registry_shards = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "registry.spill_dir",
        help: "spill directory for evicted packed models (reload on miss)",
        example: "packed/spill",
        apply: |c, v| {
            c.serve.spill_dir = Some(v.to_string());
            Ok(())
        },
    },
    OverrideSpec {
        key: "fleet.replicas",
        help: "comma-separated pool-server replica addresses for the router",
        example: "127.0.0.1:7071,127.0.0.1:7072",
        apply: |c, v| {
            c.fleet.replicas =
                v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
            Ok(())
        },
    },
    OverrideSpec {
        key: "fleet.vnodes",
        help: "virtual nodes per replica on the consistent-hash ring",
        example: "64",
        apply: |c, v| {
            c.fleet.vnodes = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "fleet.ping_interval_ms",
        help: "router health-probe interval in ms",
        example: "500",
        apply: |c, v| {
            c.fleet.ping_interval_ms = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "fleet.fail_threshold",
        help: "consecutive transport failures before replica ejection",
        example: "3",
        apply: |c, v| {
            c.fleet.fail_threshold = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "fleet.eject_ms",
        help: "replica ejection window in ms before probational re-admission",
        example: "2000",
        apply: |c, v| {
            c.fleet.eject_ms = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "mixed.enabled",
        help: "per-layer weight bit allocation under a size budget (true|false)",
        example: "true",
        apply: |c, v| {
            c.mixed.enabled = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "mixed.budget_frac",
        help: "weight-byte budget as a fraction of the uniform bits_w size",
        example: "1.0",
        apply: |c, v| {
            c.mixed.budget_frac = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "mixed.bits",
        help: "comma-separated candidate weight bit-widths (e.g. 2,4,8)",
        example: "2,4,8",
        apply: |c, v| {
            c.mixed.candidate_bits = parse_u32_list(v)?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "mixed.profiler",
        help: "sensitivity profiler (curvature|direct)",
        example: "direct",
        apply: |c, v| {
            c.mixed.profiler = ProfilerMode::parse(v)?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "mixed.sharpness_k",
        help: "sharpness post stage: sampled perturbations (0 disables)",
        example: "4",
        apply: |c, v| {
            c.mixed.sharpness_k = v.parse()?;
            Ok(())
        },
    },
    OverrideSpec {
        key: "mixed.sharpness_radius",
        help: "sharpness post stage: relative perturbation radius",
        example: "0.1",
        apply: |c, v| {
            c.mixed.sharpness_radius = v.parse()?;
            Ok(())
        },
    },
];

fn parse_u32_list(v: &str) -> Result<Vec<u32>> {
    let out: Vec<u32> = v
        .split(',')
        .map(|s| s.trim().parse::<u32>().with_context(|| format!("bad bit-width '{s}'")))
        .collect::<Result<_>>()?;
    if out.is_empty() {
        bail!("empty list");
    }
    Ok(out)
}

fn parse_f32_list(v: &str) -> Result<Vec<f32>> {
    let out: Vec<f32> = v
        .split(',')
        .map(|s| s.trim().parse::<f32>().with_context(|| format!("bad number '{s}'")))
        .collect::<Result<_>>()?;
    if out.is_empty() {
        bail!("empty list");
    }
    Ok(out)
}

impl ExperimentConfig {
    /// Load from a JSON file, then apply `k=v` overrides.
    pub fn load(path: &str, overrides: &[String]) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let json = text.parse::<Json>().map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        let mut cfg = Self::from_json(&json)?;
        cfg.apply_overrides(overrides)?;
        Ok(cfg)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let get_f = |k: &str| j.get(k).and_then(|v| v.as_f64());
        if let Some(m) = j.get("model").and_then(|v| v.as_str()) {
            cfg.model = m.to_string();
        }
        if let Some(v) = get_f("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_f("train_steps") {
            cfg.train_steps = v as usize;
        }
        if let Some(v) = get_f("lr") {
            cfg.lr = v as f32;
        }
        if let Some(v) = get_f("calib_size") {
            cfg.calib_size = v as usize;
        }
        if let Some(v) = get_f("val_size") {
            cfg.val_size = v as usize;
        }
        if let Some(v) = get_f("bits_w") {
            cfg.bits.weights = v as u32;
        }
        if let Some(v) = get_f("bits_a") {
            cfg.bits.acts = v as u32;
        }
        if let Some(m) = j.get("method").and_then(|v| v.as_str()) {
            cfg.method = Method::parse(m)?;
        }
        if let Some(l) = j.get("lapq") {
            if let Some(arr) = l.get("p_grid").and_then(|v| v.as_arr()) {
                cfg.lapq.p_grid =
                    arr.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
            }
            if let Some(v) = l.get("box_lo").and_then(|v| v.as_f64()) {
                cfg.lapq.box_lo = v;
            }
            if let Some(v) = l.get("box_hi").and_then(|v| v.as_f64()) {
                cfg.lapq.box_hi = v;
            }
            if let Some(v) = l.get("bias_correction").and_then(|v| v.as_bool()) {
                cfg.lapq.bias_correction = v;
            }
            if let Some(v) = l.get("exclude_first_last").and_then(|v| v.as_bool()) {
                cfg.lapq.exclude_first_last = v;
            }
            // Legacy flat keys (pre-JointCfg configs keep loading).
            if let Some(v) = l.get("powell_iters").and_then(|v| v.as_f64()) {
                cfg.lapq.joint.iters = v as usize;
            }
            if let Some(v) = l.get("max_evals").and_then(|v| v.as_f64()) {
                cfg.lapq.joint.max_evals = v as usize;
            }
            // `"joint": "nm"` or `"joint": {"optimizer": ..., "iters": ...,
            // "max_evals": ...}`.
            if let Some(jn) = l.get("joint") {
                if let Some(s) = jn.as_str() {
                    cfg.lapq.joint.optimizer = JointOpt::parse(s)?;
                } else {
                    if let Some(s) = jn.get("optimizer").and_then(|v| v.as_str()) {
                        cfg.lapq.joint.optimizer = JointOpt::parse(s)?;
                    }
                    if let Some(v) = jn.get("iters").and_then(|v| v.as_f64()) {
                        cfg.lapq.joint.iters = v as usize;
                    }
                    if let Some(v) = jn.get("max_evals").and_then(|v| v.as_f64()) {
                        cfg.lapq.joint.max_evals = v as usize;
                    }
                }
            }
        }
        if let Some(s) = j.get("serve") {
            if let Some(v) = s.get("workers").and_then(|v| v.as_f64()) {
                cfg.serve.workers = v as usize;
            }
            if let Some(v) = s.get("batch_window_ms").and_then(|v| v.as_f64()) {
                cfg.serve.batch_window_ms = v;
            }
            if let Some(v) = s.get("max_batch").and_then(|v| v.as_f64()) {
                cfg.serve.max_batch = v as usize;
            }
            if let Some(v) = s.get("queue_bound").and_then(|v| v.as_f64()) {
                cfg.serve.queue_bound = v as usize;
            }
            if let Some(v) = s.get("registry_cap").and_then(|v| v.as_f64()) {
                cfg.serve.registry_cap = v as usize;
            }
            if let Some(v) = s.get("io").and_then(|v| v.as_str()) {
                cfg.serve.io = IoMode::parse(v)?;
            }
            if let Some(v) = s.get("max_conns").and_then(|v| v.as_f64()) {
                cfg.serve.max_conns = v as usize;
            }
            if let Some(v) = s.get("out_queue_kib").and_then(|v| v.as_f64()) {
                cfg.serve.out_queue_kib = v as usize;
            }
            if let Some(v) = s.get("max_lanes").and_then(|v| v.as_f64()) {
                cfg.serve.max_lanes = v as usize;
            }
        }
        if let Some(r) = j.get("registry") {
            if let Some(v) = r.get("shards").and_then(|v| v.as_f64()) {
                cfg.serve.registry_shards = v as usize;
            }
            if let Some(v) = r.get("spill_dir").and_then(|v| v.as_str()) {
                cfg.serve.spill_dir = Some(v.to_string());
            }
        }
        if let Some(f) = j.get("fleet") {
            if let Some(arr) = f.get("replicas").and_then(|v| v.as_arr()) {
                cfg.fleet.replicas =
                    arr.iter().filter_map(|x| x.as_str().map(str::to_string)).collect();
            }
            if let Some(v) = f.get("vnodes").and_then(|v| v.as_f64()) {
                cfg.fleet.vnodes = v as usize;
            }
            if let Some(v) = f.get("ping_interval_ms").and_then(|v| v.as_f64()) {
                cfg.fleet.ping_interval_ms = v as u64;
            }
            if let Some(v) = f.get("fail_threshold").and_then(|v| v.as_f64()) {
                cfg.fleet.fail_threshold = v as u32;
            }
            if let Some(v) = f.get("eject_ms").and_then(|v| v.as_f64()) {
                cfg.fleet.eject_ms = v as u64;
            }
        }
        if let Some(m) = j.get("mixed") {
            if let Some(v) = m.get("enabled").and_then(|v| v.as_bool()) {
                cfg.mixed.enabled = v;
            }
            if let Some(v) = m.get("budget_frac").and_then(|v| v.as_f64()) {
                cfg.mixed.budget_frac = v;
            }
            if let Some(arr) = m.get("bits").and_then(|v| v.as_arr()) {
                cfg.mixed.candidate_bits =
                    arr.iter().filter_map(|x| x.as_f64()).map(|x| x as u32).collect();
            }
            if let Some(s) = m.get("profiler").and_then(|v| v.as_str()) {
                cfg.mixed.profiler = ProfilerMode::parse(s)?;
            }
            if let Some(v) = m.get("sharpness_k").and_then(|v| v.as_f64()) {
                cfg.mixed.sharpness_k = v as usize;
            }
            if let Some(v) = m.get("sharpness_radius").and_then(|v| v.as_f64()) {
                cfg.mixed.sharpness_radius = v;
            }
        }
        Ok(cfg)
    }

    /// `key=value` overrides (the CLI's `-s` flags), driven by
    /// [`OVERRIDES`].
    pub fn apply_overrides(&mut self, kvs: &[String]) -> Result<()> {
        for kv in kvs {
            let (k, v) = kv.split_once('=').with_context(|| format!("bad override '{kv}'"))?;
            let spec = OVERRIDES.iter().find(|s| s.key == k).with_context(|| {
                let known: Vec<&str> = OVERRIDES.iter().map(|s| s.key).collect();
                format!("unknown config key '{k}' (known: {})", known.join(" "))
            })?;
            (spec.apply)(self, v).with_context(|| format!("applying {k}={v}"))?;
        }
        Ok(())
    }

    /// Serialize (for job-service responses and EXPERIMENTS.md records).
    /// Lossless: `from_json(to_json())` reproduces the config exactly,
    /// including the whole `lapq` sub-config.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("train_steps", Json::Num(self.train_steps as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("calib_size", Json::Num(self.calib_size as f64)),
            ("val_size", Json::Num(self.val_size as f64)),
            ("bits_w", Json::Num(self.bits.weights as f64)),
            ("bits_a", Json::Num(self.bits.acts as f64)),
            ("method", Json::Str(self.method.name().into())),
            (
                "lapq",
                Json::obj(vec![
                    ("p_grid", Json::arr_f32(&self.lapq.p_grid)),
                    (
                        "joint",
                        Json::obj(vec![
                            ("optimizer", Json::Str(self.lapq.joint.optimizer.key().into())),
                            ("iters", Json::Num(self.lapq.joint.iters as f64)),
                            ("max_evals", Json::Num(self.lapq.joint.max_evals as f64)),
                        ]),
                    ),
                    ("box_lo", Json::Num(self.lapq.box_lo)),
                    ("box_hi", Json::Num(self.lapq.box_hi)),
                    ("exclude_first_last", Json::Bool(self.lapq.exclude_first_last)),
                    ("bias_correction", Json::Bool(self.lapq.bias_correction)),
                ]),
            ),
            (
                "serve",
                Json::obj(vec![
                    // `io` is always serialized so a config echo pins the
                    // mode even when it came from the LAPQ_SERVE_IO env
                    // default.
                    ("io", Json::Str(self.serve.io.key().into())),
                    ("workers", Json::Num(self.serve.workers as f64)),
                    ("batch_window_ms", Json::Num(self.serve.batch_window_ms)),
                    ("max_batch", Json::Num(self.serve.max_batch as f64)),
                    ("queue_bound", Json::Num(self.serve.queue_bound as f64)),
                    ("registry_cap", Json::Num(self.serve.registry_cap as f64)),
                    ("max_conns", Json::Num(self.serve.max_conns as f64)),
                    ("out_queue_kib", Json::Num(self.serve.out_queue_kib as f64)),
                    ("max_lanes", Json::Num(self.serve.max_lanes as f64)),
                ]),
            ),
            (
                "registry",
                Json::obj({
                    let mut kv =
                        vec![("shards", Json::Num(self.serve.registry_shards as f64))];
                    // omitted when None so spill-less configs round-trip
                    if let Some(d) = &self.serve.spill_dir {
                        kv.push(("spill_dir", Json::Str(d.clone())));
                    }
                    kv
                }),
            ),
            (
                "fleet",
                Json::obj(vec![
                    (
                        "replicas",
                        Json::Arr(
                            self.fleet.replicas.iter().map(|r| Json::Str(r.clone())).collect(),
                        ),
                    ),
                    ("vnodes", Json::Num(self.fleet.vnodes as f64)),
                    ("ping_interval_ms", Json::Num(self.fleet.ping_interval_ms as f64)),
                    ("fail_threshold", Json::Num(self.fleet.fail_threshold as f64)),
                    ("eject_ms", Json::Num(self.fleet.eject_ms as f64)),
                ]),
            ),
            (
                "mixed",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.mixed.enabled)),
                    ("budget_frac", Json::Num(self.mixed.budget_frac)),
                    (
                        "bits",
                        Json::Arr(
                            self.mixed
                                .candidate_bits
                                .iter()
                                .map(|&b| Json::Num(b as f64))
                                .collect(),
                        ),
                    ),
                    ("profiler", Json::Str(self.mixed.profiler.key().into())),
                    ("sharpness_k", Json::Num(self.mixed.sharpness_k as f64)),
                    ("sharpness_radius", Json::Num(self.mixed.sharpness_radius)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.bits.label(), "4 / 4");
        assert!(c.lapq.p_grid.len() >= 4);
        assert_eq!(c.lapq.joint.optimizer, JointOpt::Powell);
    }

    #[test]
    fn overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "model=resmini".into(),
            "bits_w=8".into(),
            "bits_a=3".into(),
            "method=aciq".into(),
        ])
        .unwrap();
        assert_eq!(c.model, "resmini");
        assert_eq!(c.bits, BitSpec::new(8, 3));
        assert_eq!(c.method, Method::Aciq);
    }

    #[test]
    fn new_overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "p_grid=2,3,4".into(),
            "joint=cd".into(),
            "joint_iters=5".into(),
            "max_evals=99".into(),
            "box_lo=0.5".into(),
            "box_hi=2.5".into(),
        ])
        .unwrap();
        assert_eq!(c.lapq.p_grid, vec![2.0, 3.0, 4.0]);
        assert_eq!(c.lapq.joint.optimizer, JointOpt::CoordinateDescent);
        assert_eq!(c.lapq.joint.iters, 5);
        assert_eq!(c.lapq.joint.max_evals, 99);
        assert_eq!(c.lapq.box_lo, 0.5);
        assert_eq!(c.lapq.box_hi, 2.5);
        // legacy alias still lands on the typed joint config
        c.apply_overrides(&["powell_iters=9".into()]).unwrap();
        assert_eq!(c.lapq.joint.iters, 9);
    }

    #[test]
    fn bad_override_rejected() {
        let mut c = ExperimentConfig::default();
        assert!(c.apply_overrides(&["nope=1".into()]).is_err());
        assert!(c.apply_overrides(&["noequals".into()]).is_err());
        assert!(c.apply_overrides(&["p_grid=".into()]).is_err());
        assert!(c.apply_overrides(&["joint=sgd".into()]).is_err());
    }

    /// Every table entry must apply cleanly — the guarantee that the help
    /// text (derived from the same table) never advertises a dead key.
    #[test]
    fn override_table_examples_apply() {
        for o in OVERRIDES {
            let mut c = ExperimentConfig::default();
            (o.apply)(&mut c, o.example).unwrap_or_else(|e| {
                panic!("override '{}' rejected its own example '{}': {e}", o.key, o.example)
            });
        }
    }

    #[test]
    fn json_roundtrip_core_fields() {
        let c = ExperimentConfig::default();
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2, c, "default config must round-trip losslessly");
    }

    /// The regression this schema existed to prevent: the `lapq`
    /// sub-config (p_grid, joint, box, flags) must survive the trip.
    #[test]
    fn json_roundtrip_lapq_subconfig() {
        let mut c = ExperimentConfig::default();
        c.model = "ncf".into();
        c.seed = 9;
        c.lapq.p_grid = vec![2.0, 3.25, 4.5];
        c.lapq.joint =
            JointCfg { optimizer: JointOpt::CoordinateDescent, iters: 7, max_evals: 123 };
        c.lapq.box_lo = 0.45;
        c.lapq.box_hi = 2.75;
        c.lapq.exclude_first_last = false;
        c.lapq.bias_correction = false;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c, "lapq sub-config must round-trip losslessly");
    }

    /// The serving sub-config joins the lossless surface.
    #[test]
    fn json_roundtrip_serve_subconfig() {
        let serve = ServeCfg {
            io: IoMode::Poll,
            workers: 3,
            batch_window_ms: 7.5,
            max_batch: 11,
            queue_bound: 17,
            registry_cap: 2,
            max_conns: 123,
            out_queue_kib: 33,
            max_lanes: 2,
            registry_shards: 5,
            spill_dir: Some("packed/spill-test".into()),
        };
        let c = ExperimentConfig { serve, ..Default::default() };
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c, "serve sub-config must round-trip losslessly");
        // spill_dir = None must round-trip too (the key is omitted)
        let c3 = ExperimentConfig::default();
        assert_eq!(ExperimentConfig::from_json(&c3.to_json()).unwrap(), c3);
    }

    /// The fleet sub-config joins the lossless surface.
    #[test]
    fn json_roundtrip_fleet_subconfig() {
        let fleet = FleetCfg {
            replicas: vec!["127.0.0.1:7071".into(), "127.0.0.1:7072".into()],
            vnodes: 17,
            ping_interval_ms: 250,
            fail_threshold: 5,
            eject_ms: 900,
        };
        let c = ExperimentConfig { fleet, ..Default::default() };
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c, "fleet sub-config must round-trip losslessly");
    }

    #[test]
    fn registry_and_fleet_overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "registry.shards=8".into(),
            "registry.spill_dir=/tmp/spill".into(),
            "fleet.replicas=127.0.0.1:7071, 127.0.0.1:7072".into(),
            "fleet.vnodes=32".into(),
            "fleet.ping_interval_ms=100".into(),
            "fleet.fail_threshold=2".into(),
            "fleet.eject_ms=500".into(),
        ])
        .unwrap();
        assert_eq!(c.serve.registry_shards, 8);
        assert_eq!(c.serve.spill_dir.as_deref(), Some("/tmp/spill"));
        assert_eq!(c.fleet.replicas, vec!["127.0.0.1:7071", "127.0.0.1:7072"]);
        assert_eq!(c.fleet.vnodes, 32);
        assert_eq!(c.fleet.ping_interval_ms, 100);
        assert_eq!(c.fleet.fail_threshold, 2);
        assert_eq!(c.fleet.eject_ms, 500);
        assert!(c.apply_overrides(&["registry.shards=x".into()]).is_err());
        assert!(c.apply_overrides(&["fleet.nope=1".into()]).is_err());
    }

    #[test]
    fn serve_overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "serve.workers=2".into(),
            "serve.batch_window_ms=0.5".into(),
            "serve.max_batch=4".into(),
            "serve.queue_bound=9".into(),
            "serve.registry_cap=1".into(),
            "serve.io=poll".into(),
            "serve.max_conns=77".into(),
            "serve.out_queue_kib=16".into(),
            "serve.max_lanes=3".into(),
        ])
        .unwrap();
        assert_eq!(c.serve.workers, 2);
        assert_eq!(c.serve.batch_window_ms, 0.5);
        assert_eq!(c.serve.max_batch, 4);
        assert_eq!(c.serve.queue_bound, 9);
        assert_eq!(c.serve.registry_cap, 1);
        assert_eq!(c.serve.io, IoMode::Poll);
        assert_eq!(c.serve.max_conns, 77);
        assert_eq!(c.serve.out_queue_kib, 16);
        assert_eq!(c.serve.max_lanes, 3);
        assert!(c.apply_overrides(&["serve.workers=x".into()]).is_err());
        assert!(c.apply_overrides(&["serve.io=uring".into()]).is_err());
        c.apply_overrides(&["serve.io=threads".into()]).unwrap();
        assert_eq!(c.serve.io, IoMode::Threads);
    }

    /// The mixed-precision sub-config joins the lossless surface.
    #[test]
    fn json_roundtrip_mixed_subconfig() {
        let mixed = MixedCfg {
            enabled: true,
            budget_frac: 0.75,
            candidate_bits: vec![2, 3, 4, 8],
            profiler: ProfilerMode::Direct,
            sharpness_k: 7,
            sharpness_radius: 0.25,
        };
        let c = ExperimentConfig { mixed, ..Default::default() };
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c, "mixed sub-config must round-trip losslessly");
    }

    #[test]
    fn mixed_overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "mixed.enabled=true".into(),
            "mixed.budget_frac=0.5".into(),
            "mixed.bits=2,4".into(),
            "mixed.profiler=direct".into(),
            "mixed.sharpness_k=9".into(),
            "mixed.sharpness_radius=0.2".into(),
        ])
        .unwrap();
        assert!(c.mixed.enabled);
        assert_eq!(c.mixed.budget_frac, 0.5);
        assert_eq!(c.mixed.candidate_bits, vec![2, 4]);
        assert_eq!(c.mixed.profiler, ProfilerMode::Direct);
        assert_eq!(c.mixed.sharpness_k, 9);
        assert_eq!(c.mixed.sharpness_radius, 0.2);
        // unknown keys under the mixed.* prefix are rejected like any other
        assert!(c.apply_overrides(&["mixed.nope=1".into()]).is_err());
        assert!(c.apply_overrides(&["mixed.profiler=hessian2".into()]).is_err());
        assert!(c.apply_overrides(&["mixed.bits=".into()]).is_err());
    }

    #[test]
    fn from_json_joint_string_form() {
        let j = r#"{"model":"mlp3","lapq":{"joint":"nm","max_evals":40}}"#.parse::<Json>().unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.lapq.joint.optimizer, JointOpt::NelderMead);
        assert_eq!(c.lapq.joint.max_evals, 40);
    }

    #[test]
    fn method_parse_all() {
        for (s, m) in [
            ("lapq", Method::Lapq),
            ("MMSE", Method::Mmse),
            ("aciq", Method::Aciq),
            ("kld", Method::Kld),
            ("minmax", Method::MinMax),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("sgd").is_err());
    }

    #[test]
    fn joint_opt_parse_all() {
        for o in JointOpt::ALL {
            assert_eq!(JointOpt::parse(o.key()).unwrap(), o);
        }
        assert_eq!(JointOpt::parse("nelder-mead").unwrap(), JointOpt::NelderMead);
        assert_eq!(JointOpt::parse("coordinate").unwrap(), JointOpt::CoordinateDescent);
        assert!(JointOpt::parse("adam").is_err());
    }

    #[test]
    fn bitspec_fp32_flags() {
        assert!(!BitSpec::new(32, 8).quant_weights());
        assert!(BitSpec::new(32, 8).quant_acts());
    }
}
