//! Experiment configuration: typed schema + JSON file loading + `k=v`
//! CLI overrides.  One [`ExperimentConfig`] fully describes a run
//! (model, training budget, quantization setting, method, pipeline knobs),
//! which is what the job scheduler, the CLI and the benches all construct.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Calibration method under test (Table 1 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full LAPQ: layer-wise Lp + quadratic approx + Powell joint opt.
    Lapq,
    /// Layer-wise MMSE (p=2), no joint phase.
    Mmse,
    /// ACIQ analytic clipping.
    Aciq,
    /// TensorRT-style KL calibration.
    Kld,
    /// Min-max (no clipping).
    MinMax,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "lapq" => Method::Lapq,
            "mmse" => Method::Mmse,
            "aciq" => Method::Aciq,
            "kld" => Method::Kld,
            "minmax" | "min-max" => Method::MinMax,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Lapq => "LAPQ",
            Method::Mmse => "MMSE",
            Method::Aciq => "ACIQ",
            Method::Kld => "KLD",
            Method::MinMax => "MinMax",
        }
    }
}

/// W/A bitwidths; 32 means "leave FP32" (Δ = 0 everywhere on that side).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitSpec {
    pub weights: u32,
    pub acts: u32,
}

impl BitSpec {
    pub fn new(weights: u32, acts: u32) -> Self {
        BitSpec { weights, acts }
    }

    pub fn label(&self) -> String {
        format!("{} / {}", self.weights, self.acts)
    }

    pub fn quant_weights(&self) -> bool {
        self.weights < 32
    }

    pub fn quant_acts(&self) -> bool {
        self.acts < 32
    }
}

/// LAPQ pipeline knobs (paper defaults in `Default`).
#[derive(Clone, Debug)]
pub struct LapqCfg {
    /// p grid for phase 1 (paper sweeps ~[2, 4]).
    pub p_grid: Vec<f32>,
    /// Powell outer iterations.
    pub powell_iters: usize,
    /// Powell objective-eval budget.
    pub max_evals: usize,
    /// Multiplicative search box around the initialization, per layer.
    pub box_lo: f64,
    pub box_hi: f64,
    /// Skip quantizing first/last quant layers (paper convention).
    pub exclude_first_last: bool,
    /// Apply Banner-style per-channel bias correction to weights.
    pub bias_correction: bool,
}

impl Default for LapqCfg {
    fn default() -> Self {
        LapqCfg {
            // Wider than the paper's [2,4]: on small stand-ins the whole
            // [2,4] trajectory can sit inside the low-bit collapse plateau
            // while large p (≈ min-max) survives; the quadratic fit then
            // interpolates in the informative region.
            p_grid: vec![2.0, 2.5, 3.0, 4.0, 6.0, 8.0],
            powell_iters: 2,
            max_evals: 600,
            box_lo: 0.3,
            box_hi: 3.0,
            exclude_first_last: true,
            bias_correction: true,
        }
    }
}

/// A full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: String,
    pub seed: u64,
    /// Training budget (steps) for producing the FP32 model.
    pub train_steps: usize,
    pub lr: f32,
    /// Calibration set size in samples (paper: 512 images).
    pub calib_size: usize,
    /// Validation set size in samples.
    pub val_size: usize,
    pub bits: BitSpec,
    pub method: Method,
    pub lapq: LapqCfg,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "cnn6".into(),
            seed: 42,
            train_steps: 300,
            lr: 0.02,
            calib_size: 512,
            val_size: 2048,
            bits: BitSpec::new(4, 4),
            method: Method::Lapq,
            lapq: LapqCfg::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file, then apply `k=v` overrides.
    pub fn load(path: &str, overrides: &[String]) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        let mut cfg = Self::from_json(&json)?;
        cfg.apply_overrides(overrides)?;
        Ok(cfg)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let get_f = |k: &str| j.get(k).and_then(|v| v.as_f64());
        if let Some(m) = j.get("model").and_then(|v| v.as_str()) {
            cfg.model = m.to_string();
        }
        if let Some(v) = get_f("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_f("train_steps") {
            cfg.train_steps = v as usize;
        }
        if let Some(v) = get_f("lr") {
            cfg.lr = v as f32;
        }
        if let Some(v) = get_f("calib_size") {
            cfg.calib_size = v as usize;
        }
        if let Some(v) = get_f("val_size") {
            cfg.val_size = v as usize;
        }
        if let Some(v) = get_f("bits_w") {
            cfg.bits.weights = v as u32;
        }
        if let Some(v) = get_f("bits_a") {
            cfg.bits.acts = v as u32;
        }
        if let Some(m) = j.get("method").and_then(|v| v.as_str()) {
            cfg.method = Method::parse(m)?;
        }
        if let Some(l) = j.get("lapq") {
            if let Some(arr) = l.get("p_grid").and_then(|v| v.as_arr()) {
                cfg.lapq.p_grid = arr.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
            }
            if let Some(v) = l.get("powell_iters").and_then(|v| v.as_f64()) {
                cfg.lapq.powell_iters = v as usize;
            }
            if let Some(v) = l.get("max_evals").and_then(|v| v.as_f64()) {
                cfg.lapq.max_evals = v as usize;
            }
            if let Some(v) = l.get("bias_correction").and_then(|v| v.as_bool()) {
                cfg.lapq.bias_correction = v;
            }
            if let Some(v) = l.get("exclude_first_last").and_then(|v| v.as_bool()) {
                cfg.lapq.exclude_first_last = v;
            }
        }
        Ok(cfg)
    }

    /// `key=value` overrides (the CLI's `-s` flags).
    pub fn apply_overrides(&mut self, kvs: &[String]) -> Result<()> {
        for kv in kvs {
            let (k, v) = kv.split_once('=').with_context(|| format!("bad override '{kv}'"))?;
            match k {
                "model" => self.model = v.to_string(),
                "seed" => self.seed = v.parse()?,
                "train_steps" => self.train_steps = v.parse()?,
                "lr" => self.lr = v.parse()?,
                "calib_size" => self.calib_size = v.parse()?,
                "val_size" => self.val_size = v.parse()?,
                "bits_w" => self.bits.weights = v.parse()?,
                "bits_a" => self.bits.acts = v.parse()?,
                "method" => self.method = Method::parse(v)?,
                "powell_iters" => self.lapq.powell_iters = v.parse()?,
                "max_evals" => self.lapq.max_evals = v.parse()?,
                "bias_correction" => self.lapq.bias_correction = v.parse()?,
                "exclude_first_last" => self.lapq.exclude_first_last = v.parse()?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        Ok(())
    }

    /// Serialize (for job-service responses and EXPERIMENTS.md records).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("train_steps", Json::Num(self.train_steps as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("calib_size", Json::Num(self.calib_size as f64)),
            ("val_size", Json::Num(self.val_size as f64)),
            ("bits_w", Json::Num(self.bits.weights as f64)),
            ("bits_a", Json::Num(self.bits.acts as f64)),
            ("method", Json::Str(self.method.name().into())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.bits.label(), "4 / 4");
        assert!(c.lapq.p_grid.len() >= 4);
    }

    #[test]
    fn overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "model=resmini".into(),
            "bits_w=8".into(),
            "bits_a=3".into(),
            "method=aciq".into(),
        ])
        .unwrap();
        assert_eq!(c.model, "resmini");
        assert_eq!(c.bits, BitSpec::new(8, 3));
        assert_eq!(c.method, Method::Aciq);
    }

    #[test]
    fn bad_override_rejected() {
        let mut c = ExperimentConfig::default();
        assert!(c.apply_overrides(&["nope=1".into()]).is_err());
        assert!(c.apply_overrides(&["noequals".into()]).is_err());
    }

    #[test]
    fn json_roundtrip_core_fields() {
        let c = ExperimentConfig::default();
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.bits, c.bits);
        assert_eq!(c2.method, c.method);
    }

    #[test]
    fn method_parse_all() {
        for (s, m) in [
            ("lapq", Method::Lapq),
            ("MMSE", Method::Mmse),
            ("aciq", Method::Aciq),
            ("kld", Method::Kld),
            ("minmax", Method::MinMax),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("sgd").is_err());
    }

    #[test]
    fn bitspec_fp32_flags() {
        assert!(!BitSpec::new(32, 8).quant_weights());
        assert!(BitSpec::new(32, 8).quant_acts());
    }
}
