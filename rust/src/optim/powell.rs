//! Powell's conjugate-direction method [Powell 1964] — the joint optimizer
//! of LAPQ (paper §4.3, Algorithm 1).
//!
//! Each iteration line-minimizes along every direction in the set
//! (Brent inside a trust window), then replaces the direction of largest
//! decrease with the net displacement `t_N - t_0` (Algorithm 1, lines
//! 15–20).  Coordinates are box-bounded: the quantization steps live in a
//! multiplicative window around the initialization.

use super::brent::brent_min;
use super::Counted;

#[derive(Clone, Debug)]
pub struct PowellCfg {
    /// Maximum outer iterations (full direction sweeps).
    pub max_iter: usize,
    /// Stop when a sweep improves the objective by less than `ftol`
    /// (relative).
    pub ftol: f64,
    /// Line-search window half-width as a fraction of the box size.
    pub line_frac: f64,
    /// Brent iterations per line search.
    pub line_iters: usize,
    /// Hard cap on objective evaluations.
    pub max_evals: usize,
}

impl Default for PowellCfg {
    fn default() -> Self {
        PowellCfg { max_iter: 3, ftol: 1e-4, line_frac: 0.5, line_iters: 12, max_evals: 10_000 }
    }
}

/// Result of a Powell run.
#[derive(Clone, Debug)]
pub struct PowellResult {
    pub x: Vec<f64>,
    pub fx: f64,
    pub evals: usize,
    pub iters: usize,
    /// Objective value after each outer iteration (for Fig. 5-style plots).
    pub history: Vec<f64>,
}

/// Minimize `f` from `x0` inside `[lo_i, hi_i]` boxes.
pub fn powell(
    x0: &[f64],
    lo: &[f64],
    hi: &[f64],
    cfg: &PowellCfg,
    f: impl FnMut(&[f64]) -> f64,
) -> PowellResult {
    let n = x0.len();
    assert!(n > 0 && lo.len() == n && hi.len() == n);
    let mut obj = Counted::new(f);
    let mut x: Vec<f64> = x0
        .iter()
        .zip(lo.iter().zip(hi))
        .map(|(&v, (&l, &h))| v.clamp(l, h))
        .collect();
    let mut fx = obj.eval(&x);
    let mut dirs: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            let mut d = vec![0.0; n];
            d[i] = 1.0;
            d
        })
        .collect();
    let mut history = vec![fx];
    let mut iters = 0;

    'outer: for _ in 0..cfg.max_iter {
        iters += 1;
        let f_start = fx;
        let x_start = x.clone();
        let mut biggest_drop = 0.0f64;
        let mut biggest_idx = 0usize;

        for (di, d) in dirs.iter().enumerate() {
            if obj.evals >= cfg.max_evals {
                break 'outer;
            }
            let f_before = fx;
            let (x_new, f_new) = line_min(&x, d, lo, hi, cfg, &mut obj);
            if f_new < fx {
                x = x_new;
                fx = f_new;
            }
            if f_before - fx > biggest_drop {
                biggest_drop = f_before - fx;
                biggest_idx = di;
            }
        }

        // Direction replacement (Alg. 1 lines 15–20): drop the direction of
        // biggest decrease, append the net displacement, and line-minimize
        // along it.
        let disp: Vec<f64> = x.iter().zip(&x_start).map(|(a, b)| a - b).collect();
        let disp_norm = disp.iter().map(|v| v * v).sum::<f64>().sqrt();
        if disp_norm > 1e-12 {
            let disp: Vec<f64> = disp.iter().map(|v| v / disp_norm).collect();
            let (x_new, f_new) = line_min(&x, &disp, lo, hi, cfg, &mut obj);
            if f_new < fx {
                x = x_new;
                fx = f_new;
            }
            dirs.remove(biggest_idx);
            dirs.push(disp);
        }

        history.push(fx);
        let rel = (f_start - fx) / f_start.abs().max(1e-12);
        if rel < cfg.ftol {
            break;
        }
    }

    // `Counted` may have seen a better point mid-line-search.
    if obj.best_f < fx {
        fx = obj.best_f;
        x = obj.best_x.clone();
    }
    PowellResult { x, fx, evals: obj.evals, iters, history }
}

/// Bounded line minimization: find λ range keeping `x + λ d` inside the
/// box, shrink to the trust window, Brent it.
fn line_min(
    x: &[f64],
    d: &[f64],
    lo: &[f64],
    hi: &[f64],
    cfg: &PowellCfg,
    obj: &mut Counted,
) -> (Vec<f64>, f64) {
    let (mut lam_lo, mut lam_hi) = (f64::NEG_INFINITY, f64::INFINITY);
    for i in 0..x.len() {
        if d[i].abs() < 1e-15 {
            continue;
        }
        let a = (lo[i] - x[i]) / d[i];
        let b = (hi[i] - x[i]) / d[i];
        let (a, b) = if a < b { (a, b) } else { (b, a) };
        lam_lo = lam_lo.max(a);
        lam_hi = lam_hi.min(b);
    }
    if !lam_lo.is_finite() || !lam_hi.is_finite() || lam_hi <= lam_lo {
        return (x.to_vec(), obj.eval(x));
    }
    // trust window around 0
    let span = (lam_hi - lam_lo) * cfg.line_frac;
    let w_lo = lam_lo.max(-span);
    let w_hi = lam_hi.min(span);
    if w_hi <= w_lo {
        return (x.to_vec(), obj.eval(x));
    }
    let mut g = |lam: f64| {
        let cand: Vec<f64> = x
            .iter()
            .zip(d)
            .zip(lo.iter().zip(hi))
            .map(|((&xi, &di), (&l, &h))| (xi + lam * di).clamp(l, h))
            .collect();
        obj.eval(&cand)
    };
    let (lam, flam) = brent_min(w_lo, w_hi, 1e-4, cfg.line_iters, &mut g);
    let cand: Vec<f64> = x
        .iter()
        .zip(d)
        .zip(lo.iter().zip(hi))
        .map(|((&xi, &di), (&l, &h))| (xi + lam * di).clamp(l, h))
        .collect();
    (cand, flam)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed(n: usize, lo: f64, hi: f64) -> (Vec<f64>, Vec<f64>) {
        (vec![lo; n], vec![hi; n])
    }

    #[test]
    fn separable_quadratic() {
        let target = [1.0, -2.0, 0.5, 3.0];
        let (lo, hi) = boxed(4, -5.0, 5.0);
        let r = powell(
            &[0.0; 4],
            &lo,
            &hi,
            &PowellCfg { max_iter: 6, ..Default::default() },
            |x| x.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum(),
        );
        for (a, b) in r.x.iter().zip(&target) {
            assert!((a - b).abs() < 1e-2, "{:?}", r.x);
        }
        assert!(r.fx < 1e-3);
    }

    #[test]
    fn coupled_quadratic_rosenbrock_lite() {
        // non-separable: f = (x0-1)^2 + 10(x1 - x0)^2 — coupling is exactly
        // what Powell's direction replacement is for.
        let (lo, hi) = boxed(2, -4.0, 4.0);
        let r = powell(
            &[-2.0, 2.0],
            &lo,
            &hi,
            &PowellCfg { max_iter: 10, ftol: 1e-10, ..Default::default() },
            |x| (x[0] - 1.0).powi(2) + 10.0 * (x[1] - x[0]).powi(2),
        );
        assert!(r.fx < 1e-2, "fx={} x={:?}", r.fx, r.x);
    }

    #[test]
    fn respects_bounds() {
        let (lo, hi) = boxed(3, 0.5, 2.0);
        let r = powell(&[1.0; 3], &lo, &hi, &PowellCfg::default(), |x| {
            x.iter().map(|v| (v + 10.0).powi(2)).sum() // min far below box
        });
        for v in &r.x {
            assert!(*v >= 0.5 - 1e-9 && *v <= 2.0 + 1e-9);
        }
        // optimum inside the box is the lower corner
        assert!(r.x.iter().all(|v| (*v - 0.5).abs() < 1e-2), "{:?}", r.x);
    }

    #[test]
    fn history_monotone_nonincreasing() {
        let (lo, hi) = boxed(5, -3.0, 3.0);
        let r = powell(&[2.0; 5], &lo, &hi, &PowellCfg::default(), |x| {
            x.iter().enumerate().map(|(i, v)| (v - 0.1 * i as f64).powi(2)).sum()
        });
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn eval_budget_respected() {
        let (lo, hi) = boxed(8, -1.0, 1.0);
        let cfg = PowellCfg { max_evals: 120, max_iter: 50, ..Default::default() };
        let r = powell(&[0.9; 8], &lo, &hi, &cfg, |x| x.iter().map(|v| v * v).sum());
        assert!(r.evals <= 140, "{}", r.evals); // small slack for final sweep
    }

    #[test]
    fn noisy_plateau_objective() {
        // quantization-like stairs superimposed on a quadratic
        let (lo, hi) = boxed(3, -2.0, 2.0);
        let r = powell(&[1.5, -1.5, 1.0], &lo, &hi, &PowellCfg::default(), |x| {
            x.iter().map(|v| ((v * 20.0).round() / 20.0).powi(2)).sum()
        });
        assert!(r.fx <= 0.0225 + 1e-9, "fx={}", r.fx);
    }
}
