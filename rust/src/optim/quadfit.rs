//! Least-squares quadratic interpolation (paper §4.2): fit
//! `f(p) ≈ a·p² + b·p + c` to sampled (p, loss) pairs and take the vertex
//! as the predicted-optimal p*.

/// Fitted quadratic with goodness-of-fit.
#[derive(Clone, Copy, Debug)]
pub struct Quad {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub r2: f64,
}

impl Quad {
    pub fn eval(&self, x: f64) -> f64 {
        self.a * x * x + self.b * x + self.c
    }

    /// Vertex (minimum if a > 0).
    pub fn vertex(&self) -> Option<f64> {
        if self.a.abs() < 1e-18 {
            None
        } else {
            Some(-self.b / (2.0 * self.a))
        }
    }
}

/// Fit by solving the 3x3 normal equations.
pub fn fit_quadratic(xs: &[f64], ys: &[f64]) -> Option<Quad> {
    let n = xs.len();
    if n < 3 || ys.len() != n {
        return None;
    }
    // moments
    let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
    let (mut sy, mut sxy, mut sx2y) = (0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        let x2 = x * x;
        s1 += x;
        s2 += x2;
        s3 += x2 * x;
        s4 += x2 * x2;
        sy += y;
        sxy += x * y;
        sx2y += x2 * y;
    }
    let nf = n as f64;
    // solve [s4 s3 s2; s3 s2 s1; s2 s1 n] [a b c]^T = [sx2y sxy sy]^T
    let m = [[s4, s3, s2], [s3, s2, s1], [s2, s1, nf]];
    let rhs = [sx2y, sxy, sy];
    let sol = solve3(m, rhs)?;
    let (a, b, c) = (sol[0], sol[1], sol[2]);
    // R²
    let mean_y = sy / nf;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        let pred = a * x * x + b * x + c;
        ss_res += (y - pred).powi(2);
        ss_tot += (y - mean_y).powi(2);
    }
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    Some(Quad { a, b, c, r2 })
}

/// Gaussian elimination with partial pivoting for 3x3 systems.
fn solve3(mut m: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // pivot
        let piv = (col..3).max_by(|&i, &j| {
            m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap()
        })?;
        if m[piv][col].abs() < 1e-14 {
            return None;
        }
        m.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..3 {
            let f = m[row][col] / m[col][col];
            for k in col..3 {
                m[row][k] -= f * m[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 3];
    for row in (0..3).rev() {
        let mut acc = b[row];
        for k in (row + 1)..3 {
            acc -= m[row][k] * x[k];
        }
        x[row] = acc / m[row][row];
    }
    Some(x)
}

/// §4.2 helper: given the sampled (p, loss(Δ_p)) trajectory, return the
/// p* minimizing the fitted quadratic, clamped to the sampled range.
pub fn interpolate_pstar(ps: &[f64], losses: &[f64]) -> Option<(f64, Quad)> {
    let q = fit_quadratic(ps, losses)?;
    let lo = ps.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = ps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let p = match q.vertex() {
        Some(v) if q.a > 0.0 => v.clamp(lo, hi),
        _ => {
            // concave/degenerate fit: fall back to the best sample
            let i = losses
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())?
                .0;
            ps[i]
        }
    };
    Some((p, q))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_quadratic_recovered() {
        let xs: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x * x - 8.0 * x + 3.0).collect();
        let q = fit_quadratic(&xs, &ys).unwrap();
        assert!((q.a - 2.0).abs() < 1e-9);
        assert!((q.b + 8.0).abs() < 1e-9);
        assert!((q.c - 3.0).abs() < 1e-8);
        assert!((q.vertex().unwrap() - 2.0).abs() < 1e-9);
        assert!(q.r2 > 0.999999);
    }

    #[test]
    fn noisy_fit_reasonable() {
        let mut rng = crate::util::rng::Pcg32::seeded(77);
        let xs: Vec<f64> = (0..20).map(|i| 1.0 + 0.2 * i as f64).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| (x - 3.0) * (x - 3.0) + 0.01 * rng.normal() as f64).collect();
        let (p, q) = interpolate_pstar(&xs, &ys).unwrap();
        assert!((p - 3.0).abs() < 0.2, "{p}");
        assert!(q.r2 > 0.95);
    }

    #[test]
    fn concave_falls_back_to_best_sample() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [1.0, 2.0, 1.5]; // peak in the middle: concave
        let (p, _) = interpolate_pstar(&xs, &ys).unwrap();
        assert_eq!(p, 1.0);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(fit_quadratic(&[1.0, 2.0], &[1.0, 2.0]).is_none());
        // collinear duplicated x's make the system singular
        assert!(fit_quadratic(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]).is_none());
    }

    #[test]
    fn vertex_clamped_to_range() {
        let xs = [2.0, 3.0, 4.0, 5.0];
        let ys: Vec<f64> = xs.iter().map(|x| (x - 10.0) * (x - 10.0)).collect();
        let (p, _) = interpolate_pstar(&xs, &ys).unwrap();
        assert_eq!(p, 5.0); // vertex at 10 clamps to sampled max
    }
}
