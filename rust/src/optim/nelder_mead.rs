//! Nelder–Mead downhill simplex: the alternative joint optimizer used by
//! the ablation benches to show LAPQ's result is not an artifact of
//! Powell's method specifically.

use super::Counted;

#[derive(Clone, Debug)]
pub struct NmCfg {
    pub max_evals: usize,
    pub ftol: f64,
    /// Initial simplex size as a fraction of the box.
    pub init_frac: f64,
}

impl Default for NmCfg {
    fn default() -> Self {
        NmCfg { max_evals: 2000, ftol: 1e-6, init_frac: 0.1 }
    }
}

/// Minimize `f` from `x0` in box `[lo, hi]`; returns (x*, f*, evals).
pub fn nelder_mead(
    x0: &[f64],
    lo: &[f64],
    hi: &[f64],
    cfg: &NmCfg,
    f: impl FnMut(&[f64]) -> f64,
) -> (Vec<f64>, f64, usize) {
    let n = x0.len();
    let mut obj = Counted::new(f);
    let clamp = |x: &mut Vec<f64>| {
        for i in 0..n {
            x[i] = x[i].clamp(lo[i], hi[i]);
        }
    };

    // initial simplex: x0 plus per-axis offsets
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    let mut first = x0.to_vec();
    clamp(&mut first);
    let f0 = obj.eval(&first);
    simplex.push((first.clone(), f0));
    for i in 0..n {
        let mut p = first.clone();
        let span = (hi[i] - lo[i]) * cfg.init_frac;
        p[i] = (p[i] + span).clamp(lo[i], hi[i]);
        if (p[i] - first[i]).abs() < 1e-12 {
            p[i] = (first[i] - span).clamp(lo[i], hi[i]);
        }
        let fp = obj.eval(&p);
        simplex.push((p, fp));
    }

    const ALPHA: f64 = 1.0; // reflect
    const GAMMA: f64 = 2.0; // expand
    const RHO: f64 = 0.5; // contract
    const SIGMA: f64 = 0.5; // shrink

    // NaN-tolerant ordering: a NaN objective value sorts as worst instead
    // of panicking (quantization losses can be NaN on collapsed nets).
    let cmp = |a: &f64, b: &f64| {
        a.partial_cmp(b).unwrap_or_else(|| match (a.is_nan(), b.is_nan()) {
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            _ => std::cmp::Ordering::Equal,
        })
    };
    while obj.evals < cfg.max_evals {
        simplex.sort_by(|a, b| cmp(&a.1, &b.1));
        let best = simplex[0].1;
        let worst = simplex[n].1;
        if (worst - best).abs() <= cfg.ftol * (best.abs() + 1e-12) {
            break;
        }
        // centroid excluding worst
        let mut cen = vec![0.0; n];
        for (p, _) in &simplex[..n] {
            for i in 0..n {
                cen[i] += p[i] / n as f64;
            }
        }
        let refl: Vec<f64> = {
            let mut r: Vec<f64> =
                cen.iter().zip(&simplex[n].0).map(|(c, w)| c + ALPHA * (c - w)).collect();
            clamp(&mut r);
            r
        };
        let f_refl = obj.eval(&refl);
        if f_refl < simplex[0].1 {
            // try expansion
            let mut exp: Vec<f64> =
                cen.iter().zip(&simplex[n].0).map(|(c, w)| c + GAMMA * (c - w)).collect();
            clamp(&mut exp);
            let f_exp = obj.eval(&exp);
            simplex[n] = if f_exp < f_refl { (exp, f_exp) } else { (refl, f_refl) };
        } else if f_refl < simplex[n - 1].1 {
            simplex[n] = (refl, f_refl);
        } else {
            // contraction
            let mut con: Vec<f64> =
                cen.iter().zip(&simplex[n].0).map(|(c, w)| c + RHO * (w - c)).collect();
            clamp(&mut con);
            let f_con = obj.eval(&con);
            if f_con < simplex[n].1 {
                simplex[n] = (con, f_con);
            } else {
                // shrink toward best
                let best_p = simplex[0].0.clone();
                for item in simplex.iter_mut().skip(1) {
                    let mut p: Vec<f64> = item
                        .0
                        .iter()
                        .zip(&best_p)
                        .map(|(x, b)| b + SIGMA * (x - b))
                        .collect();
                    clamp(&mut p);
                    let fp = obj.eval(&p);
                    *item = (p, fp);
                }
            }
        }
    }
    simplex.sort_by(|a, b| cmp(&a.1, &b.1));
    let evals = obj.evals;
    if obj.best_f < simplex[0].1 {
        return (obj.best_x, obj.best_f, evals);
    }
    (simplex[0].0.clone(), simplex[0].1, evals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_bowl() {
        let (x, fx, _) = nelder_mead(
            &[2.0, -2.0],
            &[-5.0, -5.0],
            &[5.0, 5.0],
            &NmCfg::default(),
            |v| (v[0] - 1.0).powi(2) + (v[1] + 0.5).powi(2),
        );
        assert!(fx < 1e-4, "{fx} at {x:?}");
    }

    #[test]
    fn coupled_objective() {
        // analytic minimum of this coupled quadratic is 0.5
        let (_, fx, _) = nelder_mead(
            &[0.0, 0.0, 0.0],
            &[-3.0; 3],
            &[3.0; 3],
            &NmCfg { max_evals: 4000, ftol: 1e-10, ..Default::default() },
            |v| (v[0] + v[1] - 1.0).powi(2) + (v[1] + v[2] - 2.0).powi(2) + (v[0] - v[2]).powi(2),
        );
        assert!(fx < 0.5 + 1e-3, "{fx}");
    }

    #[test]
    fn bounds_hold() {
        let (x, _, _) = nelder_mead(
            &[0.9, 0.9],
            &[0.5, 0.5],
            &[1.0, 1.0],
            &NmCfg::default(),
            |v| v.iter().sum::<f64>(), // pushes toward lower corner
        );
        assert!(x.iter().all(|&v| (0.5..=1.0).contains(&v)), "{x:?}");
        assert!(x.iter().all(|&v| v < 0.55));
    }

    #[test]
    fn survives_nan_objective() {
        // NaN regions must not panic the simplex sort; the minimizer
        // should still find the clean region's optimum.
        let cfg = NmCfg { max_evals: 300, ..Default::default() };
        let (x, fx, _) = nelder_mead(&[1.5, 1.5], &[-2.0; 2], &[2.0; 2], &cfg, |v| {
            if v[0] < 0.0 {
                f64::NAN
            } else {
                (v[0] - 1.0).powi(2) + (v[1] - 1.0).powi(2)
            }
        });
        assert!(fx.is_finite(), "{fx} at {x:?}");
        assert!(fx < 0.5, "{fx} at {x:?}");
    }

    #[test]
    fn respects_eval_budget() {
        let cfg = NmCfg { max_evals: 100, ..Default::default() };
        let (_, _, evals) =
            nelder_mead(&[1.0; 6], &[-2.0; 6], &[2.0; 6], &cfg, |v| v.iter().map(|x| x * x).sum());
        assert!(evals <= 100 + 7, "{evals}");
    }
}
