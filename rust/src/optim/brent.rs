//! Brent's line minimization (parabolic interpolation + golden fallback),
//! the inner loop of Powell's method.  Port of the classic Numerical
//! Recipes formulation with a bounded interval.

const GOLD: f64 = 0.381_966_011_250_105; // 2 - φ

/// Minimize `f` on `[a, b]`; returns (x*, f(x*)).
pub fn brent_min(
    a: f64,
    b: f64,
    tol: f64,
    max_iter: usize,
    f: &mut impl FnMut(f64) -> f64,
) -> (f64, f64) {
    let (mut a, mut b) = if a < b { (a, b) } else { (b, a) };
    let mut x = a + GOLD * (b - a);
    let mut w = x;
    let mut v = x;
    let mut fx = f(x);
    let mut fw = fx;
    let mut fv = fx;
    let mut d: f64 = 0.0;
    let mut e: f64 = 0.0;

    for _ in 0..max_iter {
        let m = 0.5 * (a + b);
        let tol1 = tol * x.abs() + 1e-12;
        let tol2 = 2.0 * tol1;
        if (x - m).abs() <= tol2 - 0.5 * (b - a) {
            break;
        }
        let mut use_golden = true;
        if e.abs() > tol1 {
            // parabolic fit through (v, fv), (w, fw), (x, fx)
            let r = (x - w) * (fx - fv);
            let q0 = (x - v) * (fx - fw);
            let mut p = (x - v) * q0 - (x - w) * r;
            let mut q = 2.0 * (q0 - r);
            if q > 0.0 {
                p = -p;
            }
            q = q.abs();
            let e_old = e;
            e = d;
            if p.abs() < (0.5 * q * e_old).abs() && p > q * (a - x) && p < q * (b - x) {
                d = p / q;
                let u = x + d;
                if (u - a) < tol2 || (b - u) < tol2 {
                    d = if m > x { tol1 } else { -tol1 };
                }
                use_golden = false;
            }
        }
        if use_golden {
            e = if x < m { b - x } else { a - x };
            d = GOLD * e;
        }
        let u = if d.abs() >= tol1 { x + d } else { x + if d > 0.0 { tol1 } else { -tol1 } };
        let fu = f(u);
        if fu <= fx {
            if u < x {
                b = x;
            } else {
                a = x;
            }
            v = w;
            fv = fw;
            w = x;
            fw = fx;
            x = u;
            fx = fu;
        } else {
            if u < x {
                a = u;
            } else {
                b = u;
            }
            if fu <= fw || w == x {
                v = w;
                fv = fw;
                w = u;
                fw = fu;
            } else if fu <= fv || v == x || v == w {
                v = u;
                fv = fu;
            }
        }
    }
    (x, fx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_exact() {
        let mut f = |x: f64| (x - 2.5).powi(2);
        let (x, fx) = brent_min(-10.0, 10.0, 1e-10, 100, &mut f);
        assert!((x - 2.5).abs() < 1e-6);
        assert!(fx < 1e-10);
    }

    #[test]
    fn quartic_with_flat_bottom() {
        let mut f = |x: f64| (x - 1.0).powi(4) + 3.0;
        let (x, fx) = brent_min(-5.0, 5.0, 1e-10, 200, &mut f);
        assert!((x - 1.0).abs() < 1e-2);
        assert!((fx - 3.0).abs() < 1e-6);
    }

    #[test]
    fn min_at_boundary() {
        let mut f = |x: f64| x; // decreasing: min at left bound... min at a
        let (x, _) = brent_min(0.0, 4.0, 1e-9, 100, &mut f);
        assert!(x < 0.01, "{x}");
    }

    #[test]
    fn nonsmooth_objective() {
        let mut f = |x: f64| (x - 0.7).abs() + 0.1 * ((x * 8.0).floor() / 8.0 - x).abs();
        let (x, _) = brent_min(0.0, 2.0, 1e-8, 200, &mut f);
        assert!((x - 0.7).abs() < 0.02, "{x}");
    }

    #[test]
    fn eval_count_bounded() {
        let mut n = 0usize;
        let mut f = |x: f64| {
            n += 1;
            (x + 1.0).powi(2)
        };
        brent_min(-3.0, 3.0, 1e-6, 60, &mut f);
        assert!(n <= 62, "{n}");
    }
}
