//! Gradient-free optimization substrate (re-implements what the paper got
//! from `scipy.optimize`): Brent line minimization, Powell's direction-set
//! method (§4.3 / Algorithm 1), quadratic least-squares interpolation
//! (§4.2), plus Nelder–Mead and cyclic coordinate descent used by the
//! ablation benches.

pub mod brent;
pub mod coordinate;
pub mod nelder_mead;
pub mod powell;
pub mod quadfit;

/// Objective wrapper that counts evaluations and tracks the incumbent.
pub struct Counted<'a> {
    f: Box<dyn FnMut(&[f64]) -> f64 + 'a>,
    pub evals: usize,
    pub best_x: Vec<f64>,
    pub best_f: f64,
}

impl<'a> Counted<'a> {
    pub fn new(f: impl FnMut(&[f64]) -> f64 + 'a) -> Self {
        Counted { f: Box::new(f), evals: 0, best_x: Vec::new(), best_f: f64::INFINITY }
    }

    pub fn eval(&mut self, x: &[f64]) -> f64 {
        self.evals += 1;
        let v = (self.f)(x);
        if v < self.best_f {
            self.best_f = v;
            self.best_x = x.to_vec();
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counted_tracks_best() {
        let mut c = Counted::new(|x: &[f64]| x[0] * x[0]);
        c.eval(&[3.0]);
        c.eval(&[-1.0]);
        c.eval(&[2.0]);
        assert_eq!(c.evals, 3);
        assert_eq!(c.best_x, vec![-1.0]);
        assert_eq!(c.best_f, 1.0);
    }
}
