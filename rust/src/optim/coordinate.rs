//! Cyclic coordinate descent: the "no direction-set update" ablation of
//! Powell's method — equivalent to optimizing each layer's Δ in turn while
//! holding the others fixed, i.e. what a purely separable view of the loss
//! (paper §3.1, Eq. 6) would justify.

use super::brent::brent_min;
use super::Counted;

#[derive(Clone, Debug)]
pub struct CoordCfg {
    pub sweeps: usize,
    pub line_iters: usize,
    pub max_evals: usize,
    pub ftol: f64,
}

impl Default for CoordCfg {
    fn default() -> Self {
        CoordCfg { sweeps: 3, line_iters: 12, max_evals: 10_000, ftol: 1e-4 }
    }
}

/// Minimize `f` by per-coordinate Brent line searches; returns (x, fx, evals).
pub fn coordinate_descent(
    x0: &[f64],
    lo: &[f64],
    hi: &[f64],
    cfg: &CoordCfg,
    f: impl FnMut(&[f64]) -> f64,
) -> (Vec<f64>, f64, usize) {
    let n = x0.len();
    let mut obj = Counted::new(f);
    let mut x: Vec<f64> =
        x0.iter().zip(lo.iter().zip(hi)).map(|(&v, (&l, &h))| v.clamp(l, h)).collect();
    let mut fx = obj.eval(&x);

    'outer: for _ in 0..cfg.sweeps {
        let f_start = fx;
        for i in 0..n {
            if obj.evals >= cfg.max_evals {
                break 'outer;
            }
            let mut g = |xi: f64| {
                let mut cand = x.clone();
                cand[i] = xi.clamp(lo[i], hi[i]);
                obj.eval(&cand)
            };
            let (xi, fxi) = brent_min(lo[i], hi[i], 1e-5, cfg.line_iters, &mut g);
            if fxi < fx {
                x[i] = xi.clamp(lo[i], hi[i]);
                fx = fxi;
            }
        }
        if (f_start - fx) < cfg.ftol * f_start.abs().max(1e-12) {
            break;
        }
    }
    if obj.best_f < fx {
        let evals = obj.evals;
        return (obj.best_x, obj.best_f, evals);
    }
    let evals = obj.evals;
    (x, fx, evals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_separable() {
        let (x, fx, _) = coordinate_descent(
            &[0.0; 3],
            &[-4.0; 3],
            &[4.0; 3],
            &CoordCfg::default(),
            |v| (v[0] - 1.0).powi(2) + (v[1] - 2.0).powi(2) + (v[2] + 1.0).powi(2),
        );
        assert!(fx < 1e-4, "{fx} {x:?}");
    }

    #[test]
    fn struggles_on_strong_coupling_vs_powell() {
        // The Fig.2 story: on a strongly coupled objective, coordinate
        // descent with the same budget stalls above Powell.
        let coupled = |v: &[f64]| {
            let a = v[0] - 1.0;
            let b = v[1] - 1.0;
            a * a + 50.0 * (a - b) * (a - b) + 0.5 * b * b
        };
        let budget = 150usize;
        let (_, f_cd, _) = coordinate_descent(
            &[-1.5, 1.8],
            &[-2.0; 2],
            &[2.0; 2],
            &CoordCfg { sweeps: 2, max_evals: budget, ..Default::default() },
            coupled,
        );
        let r = crate::optim::powell::powell(
            &[-1.5, 1.8],
            &[-2.0; 2],
            &[2.0; 2],
            &crate::optim::powell::PowellCfg { max_iter: 6, max_evals: budget, ..Default::default() },
            coupled,
        );
        assert!(r.fx <= f_cd + 1e-9, "powell {} vs cd {}", r.fx, f_cd);
    }
}
