//! Loss-landscape analysis (paper §3, Figs. 1–2, 5, A.1 and Eq. 8–11):
//! 2-D surfaces, finite-difference Hessians, Gaussian curvature.

pub mod curvature;
pub mod hessian;
pub mod surface;
