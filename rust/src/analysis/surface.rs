//! Loss-surface scans (Figs. 1–2): grid the calibration loss over the
//! quantization steps of two chosen layers while the others stay fixed,
//! and quantify the quantization interaction term (Eq. 7).

use crate::lapq::objective::CalibObjective;
use anyhow::Result;

/// A scanned 2-D loss surface.
#[derive(Clone, Debug)]
pub struct Surface {
    pub d1: Vec<f32>,
    pub d2: Vec<f32>,
    /// loss[i][j] at (d1[i], d2[j])
    pub loss: Vec<Vec<f64>>,
}

/// Scan layers `(l1, l2)`'s **weight** steps over multiplicative ranges of
/// `base` (the Δ vector the other layers keep).
pub fn scan_weight_surface(
    obj: &mut CalibObjective,
    base_dw: &[f32],
    base_da: &[f32],
    l1: usize,
    l2: usize,
    lo: f32,
    hi: f32,
    n: usize,
) -> Result<Surface> {
    let mults: Vec<f32> =
        (0..n).map(|i| lo + (hi - lo) * i as f32 / (n - 1).max(1) as f32).collect();
    let d1: Vec<f32> = mults.iter().map(|m| base_dw[l1] * m).collect();
    let d2: Vec<f32> = mults.iter().map(|m| base_dw[l2] * m).collect();
    let mut loss = vec![vec![0.0f64; n]; n];
    let mut dw = base_dw.to_vec();
    for (i, &a) in d1.iter().enumerate() {
        for (j, &b) in d2.iter().enumerate() {
            dw[l1] = a;
            dw[l2] = b;
            loss[i][j] = obj.loss(&dw, base_da)?;
        }
    }
    Ok(Surface { d1, d2, loss })
}

impl Surface {
    /// Quantization-interaction measure: how far the surface is from
    /// additive separability.  For a separable surface
    /// `L(a,b) = f(a) + g(b)` the quantity
    /// `L(a,b) - L(a,b0) - L(a0,b) + L(a0,b0)` vanishes everywhere; we
    /// report its mean |value| relative to the surface's loss range.
    pub fn interaction_index(&self) -> f64 {
        let n = self.loss.len();
        if n < 2 {
            return 0.0;
        }
        let l00 = self.loss[0][0];
        let mut acc = 0.0f64;
        let mut count = 0usize;
        for i in 1..n {
            for j in 1..n {
                let qit =
                    self.loss[i][j] - self.loss[i][0] - self.loss[0][j] + l00;
                acc += qit.abs();
                count += 1;
            }
        }
        let (lo, hi) = self.min_max();
        let range = (hi - lo).max(1e-12);
        acc / count as f64 / range
    }

    pub fn min_max(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for row in &self.loss {
            for &v in row {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        (lo, hi)
    }

    /// Location of the minimum (i, j).
    pub fn argmin(&self) -> (usize, usize) {
        let mut best = (0, 0);
        let mut bv = f64::INFINITY;
        for (i, row) in self.loss.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                if v < bv {
                    bv = v;
                    best = (i, j);
                }
            }
        }
        best
    }

    /// CSV dump: header d2 values, then one row per d1.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("d1\\d2");
        for v in &self.d2 {
            s += &format!(",{v}");
        }
        s.push('\n');
        for (i, row) in self.loss.iter().enumerate() {
            s += &format!("{}", self.d1[i]);
            for v in row {
                s += &format!(",{v}");
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic(f: impl Fn(f64, f64) -> f64, n: usize) -> Surface {
        let d: Vec<f32> = (0..n).map(|i| 0.1 + i as f32 * 0.1).collect();
        let loss = d
            .iter()
            .map(|&a| d.iter().map(|&b| f(a as f64, b as f64)).collect())
            .collect();
        Surface { d1: d.clone(), d2: d, loss }
    }

    #[test]
    fn separable_surface_has_zero_interaction() {
        let s = synthetic(|a, b| (a - 0.3).powi(2) + (b - 0.4).powi(2), 8);
        assert!(s.interaction_index() < 1e-9, "{}", s.interaction_index());
    }

    #[test]
    fn coupled_surface_has_interaction() {
        let s = synthetic(|a, b| (a - 0.3).powi(2) + (b - 0.4).powi(2) + 3.0 * a * b, 8);
        assert!(s.interaction_index() > 0.05, "{}", s.interaction_index());
    }

    #[test]
    fn argmin_and_csv() {
        let s = synthetic(|a, b| (a - 0.3).powi(2) + (b - 0.5).powi(2), 8);
        let (i, j) = s.argmin();
        assert_eq!((i, j), (2, 4));
        let csv = s.to_csv();
        assert!(csv.lines().count() == 9);
        assert!(csv.starts_with("d1\\d2,"));
    }
}
