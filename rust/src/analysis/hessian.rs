//! Finite-difference Hessian of the calibration loss with respect to the
//! per-layer quantization steps (paper Eq. 8 / Fig. A.1).

use crate::lapq::objective::CalibObjective;
use anyhow::Result;

/// Symmetric Hessian estimate plus the gradient at the same point.
#[derive(Clone, Debug)]
pub struct HessianReport {
    pub h: Vec<Vec<f64>>,
    pub grad: Vec<f64>,
    pub f0: f64,
}

/// Central-difference Hessian of `loss(dw)` over the **active weight**
/// coordinates, activations held at `da`.  Step `rel` is relative to each
/// coordinate's magnitude.
pub fn weight_hessian(
    obj: &mut CalibObjective,
    dw: &[f32],
    da: &[f32],
    rel: f64,
) -> Result<HessianReport> {
    let active = obj.mask.active_w();
    let n = active.len();
    let h_steps: Vec<f64> = active.iter().map(|&i| (dw[i] as f64 * rel).max(1e-6)).collect();
    let mut eval = |offsets: &[(usize, f64)]| -> Result<f64> {
        let mut v = dw.to_vec();
        for &(k, s) in offsets {
            v[active[k]] = (dw[active[k]] as f64 + s) as f32;
        }
        obj.loss(&v, da)
    };
    let f0 = eval(&[])?;
    let mut grad = vec![0.0f64; n];
    let mut h = vec![vec![0.0f64; n]; n];
    // diagonal + gradient
    for k in 0..n {
        let s = h_steps[k];
        let fp = eval(&[(k, s)])?;
        let fm = eval(&[(k, -s)])?;
        grad[k] = (fp - fm) / (2.0 * s);
        h[k][k] = (fp - 2.0 * f0 + fm) / (s * s);
    }
    // off-diagonals
    for a in 0..n {
        for b in (a + 1)..n {
            let (sa, sb) = (h_steps[a], h_steps[b]);
            let fpp = eval(&[(a, sa), (b, sb)])?;
            let fpm = eval(&[(a, sa), (b, -sb)])?;
            let fmp = eval(&[(a, -sa), (b, sb)])?;
            let fmm = eval(&[(a, -sa), (b, -sb)])?;
            let v = (fpp - fpm - fmp + fmm) / (4.0 * sa * sb);
            h[a][b] = v;
            h[b][a] = v;
        }
    }
    Ok(HessianReport { h, grad, f0 })
}

impl HessianReport {
    /// Ratio of off-diagonal mass to total mass — the separability measure
    /// behind Fig. A.1 (0 = perfectly separable loss).
    pub fn coupling_ratio(&self) -> f64 {
        let n = self.h.len();
        let mut diag = 0.0;
        let mut off = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    diag += self.h[i][j].abs();
                } else {
                    off += self.h[i][j].abs();
                }
            }
        }
        off / (off + diag).max(1e-18)
    }

    /// Mean |H_ij| at |i-j| = d — adjacency profile (closer layers couple
    /// more strongly, per the paper's appendix).
    pub fn band_mean(&self, d: usize) -> f64 {
        let n = self.h.len();
        if d >= n {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut cnt = 0usize;
        for i in 0..n - d {
            acc += self.h[i][i + d].abs();
            cnt += 1;
        }
        acc / cnt.max(1) as f64
    }

    pub fn csv(&self) -> String {
        let mut s = String::new();
        for row in &self.h {
            let cells: Vec<String> = row.iter().map(|v| format!("{v:.6e}")).collect();
            s += &cells.join(",");
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coupling_ratio_extremes() {
        let diag = HessianReport {
            h: vec![vec![2.0, 0.0], vec![0.0, 3.0]],
            grad: vec![0.0; 2],
            f0: 0.0,
        };
        assert!(diag.coupling_ratio() < 1e-12);
        let coupled = HessianReport {
            h: vec![vec![1.0, 1.0], vec![1.0, 1.0]],
            grad: vec![0.0; 2],
            f0: 0.0,
        };
        assert!((coupled.coupling_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn band_mean_profile() {
        let r = HessianReport {
            h: vec![
                vec![4.0, 2.0, 1.0],
                vec![2.0, 4.0, 2.0],
                vec![1.0, 2.0, 4.0],
            ],
            grad: vec![0.0; 3],
            f0: 0.0,
        };
        assert_eq!(r.band_mean(0), 4.0);
        assert_eq!(r.band_mean(1), 2.0);
        assert_eq!(r.band_mean(2), 1.0);
        assert!(r.band_mean(1) > r.band_mean(2));
    }
}
