//! Gaussian curvature of the loss surface (paper Eq. 9–11):
//! `K = det(H) / (‖∇L‖² + 1)²`, computed from the finite-difference
//! Hessian.  The paper's headline numbers — K ≈ 6.7e-25 at 4 bits vs
//! K ≈ 0.58 at 2 bits — are reproduced (in shape: many orders of
//! magnitude apart) by the `figa1` bench.

use super::hessian::HessianReport;

/// Determinant by LU decomposition with partial pivoting.
pub fn det(m: &[Vec<f64>]) -> f64 {
    let n = m.len();
    let mut a: Vec<Vec<f64>> = m.to_vec();
    let mut d = 1.0f64;
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col] == 0.0 {
            return 0.0;
        }
        if piv != col {
            a.swap(piv, col);
            d = -d;
        }
        d *= a[col][col];
        for r in col + 1..n {
            let f = a[r][col] / a[col][col];
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
        }
    }
    d
}

/// Eq. 9 Gaussian curvature from a Hessian report.
pub fn gaussian_curvature(rep: &HessianReport) -> f64 {
    let g2: f64 = rep.grad.iter().map(|v| v * v).sum();
    det(&rep.h) / (g2 + 1.0).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn det_reference() {
        let m = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert!((det(&m) + 2.0).abs() < 1e-12);
        let id = vec![
            vec![1.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ];
        assert!((det(&id) - 1.0).abs() < 1e-12);
        let sing = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(det(&sing).abs() < 1e-12);
    }

    #[test]
    fn det_needs_pivoting() {
        let m = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        assert!((det(&m) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn curvature_flat_vs_steep() {
        let flat = HessianReport {
            h: vec![vec![1e-6, 0.0], vec![0.0, 1e-6]],
            grad: vec![0.0, 0.0],
            f0: 0.0,
        };
        let steep = HessianReport {
            h: vec![vec![10.0, 1.0], vec![1.0, 10.0]],
            grad: vec![0.1, 0.1],
            f0: 0.0,
        };
        let kf = gaussian_curvature(&flat);
        let ks = gaussian_curvature(&steep);
        assert!(ks / kf.max(1e-30) > 1e10, "flat {kf} steep {ks}");
    }
}
