//! Tiny argv parser: `command --flag value --switch -s key=value`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    /// `-s key=value` config overrides, in order.
    pub overrides: Vec<String>,
    /// bare positional args after the command
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "-s" {
                let Some(v) = it.next() else { bail!("-s needs key=value") };
                out.overrides.push(v.clone());
            } else if let Some(name) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") && *v != "-s" => {
                        out.flags.insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => {
                        out.flags.insert(name.to_string(), "true".to_string());
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_overrides() {
        let a = Args::parse(&sv(&[
            "quantize", "--model", "cnn6", "--wbits", "4", "-s", "seed=7", "--verbose",
        ]))
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("quantize"));
        assert_eq!(a.flag("model"), Some("cnn6"));
        assert_eq!(a.flag("wbits"), Some("4"));
        assert_eq!(a.overrides, vec!["seed=7"]);
        assert!(a.flag_bool("verbose"));
    }

    #[test]
    fn empty_ok() {
        let a = Args::parse(&[]).unwrap();
        assert!(a.command.is_none());
    }

    #[test]
    fn dangling_s_errors() {
        assert!(Args::parse(&sv(&["x", "-s"])).is_err());
    }
}
