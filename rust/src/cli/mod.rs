//! Command-line interface (substrate for the absent `clap`): subcommands
//! with `--flag value` options and `-s key=value` config overrides.

pub mod parser;

use crate::config::{ExperimentConfig, Method};
use crate::coordinator::jobs::Runner;
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::service::Service;
use crate::runtime::EngineHandle;
use anyhow::{bail, Result};
use parser::Args;

pub const USAGE: &str = "\
repro — Loss Aware Post-training Quantization (LAPQ) coordinator

USAGE: repro <command> [options] [-s key=value ...]

COMMANDS:
  info                          list models and artifacts
  train      --model M [--steps N] [--lr F]
  quantize   --model M [--wbits N] [--abits N] [--method lapq|mmse|aciq|kld|minmax]
  sweep      --model M          run all methods at the config's bitwidths
  serve      [--addr HOST:PORT] start the TCP job service
  metrics                       dump the metrics registry

Config overrides (-s): model seed train_steps lr calib_size val_size
  bits_w bits_a method powell_iters max_evals bias_correction
  exclude_first_last
";

/// Entry point for the `repro` binary.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("info") => info(),
        Some("train") => train(&args),
        Some("quantize") => quantize(&args),
        Some("sweep") => sweep(&args),
        Some("serve") => serve(&args),
        Some("metrics") => {
            println!("{}", crate::coordinator::metrics::dump().dump());
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.flag("config") {
        cfg = ExperimentConfig::load(path, &[])?;
    }
    if let Some(m) = args.flag("model") {
        cfg.model = m.to_string();
    }
    if let Some(s) = args.flag("steps") {
        cfg.train_steps = s.parse()?;
    }
    if let Some(l) = args.flag("lr") {
        cfg.lr = l.parse()?;
    }
    if let Some(w) = args.flag("wbits") {
        cfg.bits.weights = w.parse()?;
    }
    if let Some(a) = args.flag("abits") {
        cfg.bits.acts = a.parse()?;
    }
    if let Some(m) = args.flag("method") {
        cfg.method = Method::parse(m)?;
    }
    cfg.apply_overrides(&args.overrides)?;
    Ok(cfg)
}

fn info() -> Result<()> {
    // Report the manifest of the backend that will actually execute, not
    // whatever happens to sit on disk.
    let eng = EngineHandle::start_default()?;
    let manifest = eng.manifest();
    println!("backend: {}  artifacts: {:?}", eng.backend_name(), manifest.dir);
    for (name, spec) in &manifest.models {
        println!(
            "  {name:<10} task={:<7} params={:<9} quant_layers={:<3} entries={}",
            spec.task,
            spec.n_weights(),
            spec.n_quant_layers(),
            spec.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let (_, report) = runner.trained_params(&cfg)?;
    println!("trained {} for {} steps in {:.1}s", cfg.model, report.steps, report.seconds);
    for (step, loss) in &report.losses {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    Ok(())
}

fn quantize(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let res = runner.run(&cfg)?;
    println!(
        "{} W/A {}  {}: FP32 {:.2}% -> quant {:.2}%  (calib loss {:.4} vs fp32 {:.4}, {} joint evals, {:.1}s)",
        res.model,
        res.bits_label,
        res.method,
        res.fp32_metric * 100.0,
        res.quant_metric * 100.0,
        res.outcome.calib_loss,
        res.outcome.fp32_calib_loss,
        res.outcome.joint_evals,
        res.seconds,
    );
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut sched = Scheduler::new();
    for method in [Method::Lapq, Method::Mmse, Method::Aciq, Method::Kld, Method::MinMax] {
        let mut c = cfg.clone();
        c.method = method;
        sched.push(c);
    }
    sched.run_all(&mut runner)?;
    sched.summary_table(&format!("sweep {} W/A {}", cfg.model, cfg.bits.label())).print();
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7070");
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let service = Service::bind(addr)?;
    println!("serving on {}", service.addr);
    service.serve(&mut runner, usize::MAX)
}
