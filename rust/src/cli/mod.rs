//! Command-line interface (substrate for the absent `clap`): subcommands
//! with `--flag value` options and `-s key=value` config overrides.

pub mod parser;

use crate::config::{ExperimentConfig, IoMode, Method, OVERRIDES};
use crate::coordinator::jobs::Runner;
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::service::Service;
use crate::coordinator::workload::{Split, Workload};
use crate::lapq::events::LogObserver;
use crate::runtime::cpu::ops::{argmax_correct, bce_correct};
use crate::runtime::int::{ExecMode, InferSession, PackOpts, QuantizedModel};
use crate::runtime::{EngineHandle, Manifest};
use crate::serve::{PoolServer, Router};
use anyhow::{bail, Context, Result};
use parser::Args;
use std::path::{Path, PathBuf};

const USAGE_HEAD: &str = "\
repro — Loss Aware Post-training Quantization (LAPQ) coordinator

USAGE: repro <command> [options] [-s key=value ...]

COMMANDS:
  info                          list models and artifacts
  train      --model M [--steps N] [--lr F]
  quantize   --model M [--wbits N] [--abits N] [--method lapq|mmse|aciq|kld|minmax]
             [--mixed] [--size-budget F]
                                --mixed allocates per-layer weight bits by
                                sensitivity under a size budget (F × the
                                uniform pack, default 1.0)
  sweep      --model M          run all methods at the config's bitwidths
  pack       --model M [--wbits N] [--abits N] [--out DIR] [--no-po2]
             [--mixed] [--size-budget F]
                                calibrate, quantize the weights and write a
                                deployable integer artifact (mlp3/cnn6/ncf)
  infer      --packed DIR [--batches N] [--check] [--tol F] [--seed N]
                                run the packed integer engine on synthetic
                                val batches; --check verifies against the
                                fake-quant reference (bit-exact at tol 0)
  serve      [--addr HOST:PORT] [--io threads|poll] [--workers N]
             [--batch-window-ms F] [--max-batch N] [--queue-bound N]
             [--registry-cap N] [--registry-shards N] [--spill-dir DIR]
             [--max-conns N] [--out-queue-kib N]
             [--max-lanes N] [--preload M1,M2] [--seq]
                                start the TCP job service: concurrent
                                worker pool + infer micro-batching by
                                default, strictly sequential with --seq;
                                --io poll serves every connection from one
                                readiness-polled reactor thread (idle
                                connections cost an fd, not a thread);
                                --preload packs models into the registry
                                before taking traffic; --spill-dir keeps
                                evicted packed models on disk for
                                transparent reload
  route      --replicas A1,A2 [--addr HOST:PORT] [--vnodes N]
             [--ping-interval-ms N] [--fail-threshold N] [--eject-ms N]
                                start the fleet front tier: consistent-hash
                                routing of pack keys across pool-server
                                replicas with health checks, ejection and
                                overload-aware retry
  metrics                       dump the metrics registry
";

/// Full help text.  The override list is generated from
/// [`crate::config::OVERRIDES`] — the same table `apply_overrides`
/// dispatches on — so this text cannot drift from behaviour.
pub fn usage() -> String {
    let mut s = String::from(USAGE_HEAD);
    s.push_str("\nConfig overrides (-s key=value):\n");
    for o in OVERRIDES {
        s.push_str(&format!("  {:<20} {}\n", o.key, o.help));
    }
    s
}

/// Entry point for the `repro` binary.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        None | Some("help") => {
            println!("{}", usage());
            Ok(())
        }
        Some("info") => info(),
        Some("train") => train(&args),
        Some("quantize") => quantize(&args),
        Some("sweep") => sweep(&args),
        Some("pack") => pack(&args),
        Some("infer") => infer(&args),
        Some("serve") => serve(&args),
        Some("route") => route(&args),
        Some("metrics") => {
            println!("{}", crate::coordinator::metrics::dump().dump());
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{}", usage()),
    }
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.flag("config") {
        cfg = ExperimentConfig::load(path, &[])?;
    }
    if let Some(m) = args.flag("model") {
        cfg.model = m.to_string();
    }
    if let Some(s) = args.flag("steps") {
        cfg.train_steps = s.parse()?;
    }
    if let Some(l) = args.flag("lr") {
        cfg.lr = l.parse()?;
    }
    if let Some(w) = args.flag("wbits") {
        cfg.bits.weights = w.parse()?;
    }
    if let Some(a) = args.flag("abits") {
        cfg.bits.acts = a.parse()?;
    }
    if let Some(m) = args.flag("method") {
        cfg.method = Method::parse(m)?;
    }
    if args.flag_bool("mixed") {
        cfg.mixed.enabled = true;
    }
    if let Some(b) = args.flag("size-budget") {
        cfg.mixed.budget_frac = b.parse()?;
        cfg.mixed.enabled = true;
    }
    cfg.apply_overrides(&args.overrides)?;
    Ok(cfg)
}

fn info() -> Result<()> {
    // Report the manifest of the backend that will actually execute, not
    // whatever happens to sit on disk.
    let eng = EngineHandle::start_default()?;
    let manifest = eng.manifest();
    println!("backend: {}  artifacts: {:?}", eng.backend_name(), manifest.dir);
    for (name, spec) in &manifest.models {
        println!(
            "  {name:<10} task={:<7} params={:<9} quant_layers={:<3} entries={}",
            spec.task,
            spec.n_weights(),
            spec.n_quant_layers(),
            spec.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let (_, report) = runner.trained_params(&cfg)?;
    println!("trained {} for {} steps in {:.1}s", cfg.model, report.steps, report.seconds);
    for (step, loss) in &report.losses {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    Ok(())
}

fn quantize(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    // Live progress: phase starts/ends and throttled eval lines.
    let res = runner.run_observed(&cfg, &mut LogObserver::default())?;
    for t in &res.outcome.trace {
        println!(
            "  phase {:<24} {:>5} evals  loss {:<10.4} {:>6.1}s",
            t.phase, t.evals, t.loss, t.seconds
        );
    }
    println!(
        "{} W/A {}  {}: FP32 {:.2}% -> quant {:.2}%  (calib loss {:.4} vs fp32 {:.4}, {} joint evals, {:.1}s)",
        res.model,
        res.bits_label,
        res.method,
        res.fp32_metric * 100.0,
        res.quant_metric * 100.0,
        res.outcome.calib_loss,
        res.outcome.fp32_calib_loss,
        res.outcome.joint_evals,
        res.seconds,
    );
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut sched = Scheduler::new();
    for method in [Method::Lapq, Method::Mmse, Method::Aciq, Method::Kld, Method::MinMax] {
        let mut c = cfg.clone();
        c.method = method;
        sched.push(c);
    }
    sched.run_all(&mut runner)?;
    sched.summary_table(&format!("sweep {} W/A {}", cfg.model, cfg.bits.label())).print();
    Ok(())
}

fn pack(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let out = args.flag("out").map(PathBuf::from).unwrap_or_else(|| {
        PathBuf::from(format!("packed/{}_w{}a{}", cfg.model, cfg.bits.weights, cfg.bits.acts))
    });
    let opts = PackOpts { po2_scales: !args.flag_bool("no-po2") };
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let (sum, qm) = runner.pack(&cfg, &opts)?;
    qm.save(&out)?;
    println!("packed {} W/A {} ({}) -> {:?}", sum.model, sum.bits_label, sum.method, out);
    println!(
        "  {} int tensors, {} -> {} weight bytes ({:.2}x), fp32 {:.2}% -> int-grid {:.2}% ({:.1}s)",
        sum.int_params,
        sum.f32_bytes,
        sum.packed_bytes,
        sum.f32_bytes as f64 / sum.packed_bytes.max(1) as f64,
        sum.fp32_metric * 100.0,
        sum.quant_metric * 100.0,
        sum.seconds,
    );
    println!("  serve it: repro infer --packed {:?} --check", out);
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let dir = args.flag("packed").context("--packed DIR is required (see `repro pack`)")?;
    let qm = QuantizedModel::load(Path::new(dir))?;
    let manifest = Manifest::builtin();
    let spec = manifest.model(&qm.model)?;
    let seed: u64 = args.flag("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let n_batches: usize = args.flag("batches").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let tol: f32 = args.flag("tol").map(|s| s.parse()).transpose()?.unwrap_or(0.0);
    let check = args.flag_bool("check");
    let workload = Workload::for_model(spec, seed)?;
    let sess = InferSession::new(spec, &qm)?;

    let mut rows_total = 0usize;
    let mut correct_total = 0.0f32;
    let mut seconds_total = 0.0f64;
    let mut int_layers = 0usize;
    for batch in workload.eval_batches(spec, Split::Val, n_batches) {
        // labels ride last in every eval batch; inputs are the rest
        let inputs = &batch[..batch.len() - 1];
        let labels = &batch[batch.len() - 1];
        let t0 = std::time::Instant::now();
        let res = sess.infer(inputs, ExecMode::Int)?;
        int_layers = res.int_layers;
        seconds_total += t0.elapsed().as_secs_f64();
        let rows = res.logits.shape.first().copied().unwrap_or(0);
        rows_total += rows;
        correct_total += if spec.task == "ncf" {
            bce_correct(&res.logits, labels.f())
        } else {
            argmax_correct(&res.logits, labels.i())
        };
        if check {
            let reference = sess.infer(inputs, ExecMode::Simulated)?;
            let mut max_diff = 0.0f32;
            let mut n_diff = 0usize;
            for (a, b) in res.logits.data.iter().zip(&reference.logits.data) {
                if a.to_bits() != b.to_bits() {
                    n_diff += 1;
                }
                max_diff = max_diff.max((a - b).abs());
            }
            if n_diff == 0 {
                println!("  parity: bit-exact with the fake-quant reference ({rows} rows)");
            } else {
                println!(
                    "  parity: {n_diff}/{} logits differ, max |diff| {max_diff:.3e}",
                    res.logits.numel()
                );
            }
            if max_diff > tol {
                bail!("integer engine diverges from fake-quant reference: {max_diff} > {tol}");
            }
        }
    }
    println!(
        "{}: {} rows in {:.3}s ({:.0} rows/s), metric {:.2}%, int layers {}/{}",
        qm.model,
        rows_total,
        seconds_total,
        rows_total as f64 / seconds_total.max(1e-9),
        100.0 * correct_total / rows_total.max(1) as f32,
        int_layers,
        qm.active_w.len(),
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7070");
    let eng = EngineHandle::start_default()?;
    if args.flag_bool("seq") {
        // The blocking reference server: one connection at a time.
        // Pool-only knobs would be silently dead here — reject both
        // their --flag and `-s serve.*` spellings.
        let pool_flags = [
            "workers",
            "batch-window-ms",
            "max-batch",
            "queue-bound",
            "registry-cap",
            "registry-shards",
            "spill-dir",
            "preload",
            "io",
            "max-conns",
            "out-queue-kib",
            "max-lanes",
        ];
        for f in pool_flags {
            if args.flag(f).is_some() {
                bail!("--{f} has no effect with --seq (the sequential server has no pool)");
            }
        }
        if let Some(kv) = args.overrides.iter().find(|kv| kv.starts_with("serve.")) {
            bail!("-s {kv} has no effect with --seq (the sequential server has no pool)");
        }
        let mut runner = Runner::new(eng);
        let service = Service::bind(addr)?;
        println!("serving sequentially on {}", service.addr);
        return service.serve(&mut runner, usize::MAX);
    }
    // Config file / -s serve.* first, explicit flags win.
    let mut scfg = cfg.serve.clone();
    if let Some(v) = args.flag("workers") {
        scfg.workers = v.parse()?;
    }
    if let Some(v) = args.flag("batch-window-ms") {
        scfg.batch_window_ms = v.parse()?;
    }
    if let Some(v) = args.flag("max-batch") {
        scfg.max_batch = v.parse()?;
    }
    if let Some(v) = args.flag("queue-bound") {
        scfg.queue_bound = v.parse()?;
    }
    if let Some(v) = args.flag("registry-cap") {
        scfg.registry_cap = v.parse()?;
    }
    if let Some(v) = args.flag("registry-shards") {
        scfg.registry_shards = v.parse()?;
    }
    if let Some(v) = args.flag("spill-dir") {
        scfg.spill_dir = Some(v.to_string());
    }
    if let Some(v) = args.flag("io") {
        scfg.io = IoMode::parse(v)?;
    }
    if let Some(v) = args.flag("max-conns") {
        scfg.max_conns = v.parse()?;
    }
    if let Some(v) = args.flag("out-queue-kib") {
        scfg.out_queue_kib = v.parse()?;
    }
    if let Some(v) = args.flag("max-lanes") {
        scfg.max_lanes = v.parse()?;
    }
    let server = PoolServer::bind(addr, eng, scfg.clone())?;
    if let Some(models) = args.flag("preload") {
        let cfgs: Vec<ExperimentConfig> = models
            .split(',')
            .filter(|m| !m.trim().is_empty())
            .map(|m| {
                let mut c = cfg.clone();
                c.model = m.trim().to_string();
                c
            })
            .collect();
        let keys = server.preload(&cfgs)?;
        println!("preloaded: {}", keys.join(", "));
    }
    println!(
        "serving on {} (io {}, {} workers, batch window {} ms, max batch {}, queue bound {}, registry cap {}, max conns {}, max lanes {})",
        server.addr,
        scfg.io.key(),
        scfg.workers,
        scfg.batch_window_ms,
        scfg.max_batch,
        scfg.queue_bound,
        scfg.registry_cap,
        scfg.max_conns,
        scfg.max_lanes,
    );
    server.serve(usize::MAX)
}

/// `repro route`: the fleet front tier.  Consistent-hash routing of
/// pack keys across pool-server replicas (started separately with
/// `repro serve`), with periodic pings, ejection and overload-aware
/// retry.  Config file / `-s fleet.*` first, explicit flags win.
fn route(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7170");
    let mut fcfg = cfg.fleet.clone();
    if let Some(v) = args.flag("replicas") {
        fcfg.replicas = v
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    if let Some(v) = args.flag("vnodes") {
        fcfg.vnodes = v.parse()?;
    }
    if let Some(v) = args.flag("ping-interval-ms") {
        fcfg.ping_interval_ms = v.parse()?;
    }
    if let Some(v) = args.flag("fail-threshold") {
        fcfg.fail_threshold = v.parse()?;
    }
    if let Some(v) = args.flag("eject-ms") {
        fcfg.eject_ms = v.parse()?;
    }
    let router = Router::bind(addr, &fcfg)?;
    println!(
        "routing on {} ({} replicas, {} vnodes, ping {} ms, eject after {} failures for {} ms)",
        router.addr,
        fcfg.replicas.len(),
        fcfg.vnodes,
        fcfg.ping_interval_ms,
        fcfg.fail_threshold,
        fcfg.eject_ms,
    );
    router.serve(usize::MAX)
}
