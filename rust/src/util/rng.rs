//! Deterministic PRNG (PCG-XSH-RR 64/32) with the sampling helpers the
//! data generators and property tests need.  Substitute for the `rand`
//! crate, which is unavailable offline.

/// PCG32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary seed/stream pair (stream selects sequence).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-argument convenience constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as u32
    }

    /// Standard normal via Box–Muller (uses two uniforms per pair).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_scaled(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Fill a vector with standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Laplace(0, b) via inverse CDF.
    pub fn laplace(&mut self, b: f32) -> f32 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).max(1e-12).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k << n assumed).
    pub fn sample_distinct(&mut self, n: u32, k: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(k);
        let mut seen = std::collections::HashSet::new();
        while out.len() < k {
            let v = self.below(n);
            if seen.insert(v) {
                out.push(v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(4);
        let xs = r.normal_vec(200_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Pcg32::seeded(5);
        let b = 0.7f32;
        let xs: Vec<f32> = (0..200_000).map(|_| r.laplace(b)).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let e_abs = xs.iter().map(|x| x.abs()).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02);
        assert!((e_abs - b).abs() < 0.02, "E|x| {e_abs} vs b {b}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Pcg32::seeded(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Pcg32::seeded(9);
        let s = r.sample_distinct(1000, 99);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 99);
    }
}
