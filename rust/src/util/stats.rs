//! Small numeric helpers shared by calibration, analysis and benches.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt() as f32
}

/// Mean absolute value — the MLE of the Laplace scale b.
pub fn mean_abs(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| (x as f64).abs()).sum::<f64>() as f32 / xs.len() as f32
}

/// Maximum absolute value.
pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// Minimum / maximum.
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    xs.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &x| (lo.min(x), hi.max(x)))
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f32).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Pearson correlation of two equal-length series.
pub fn pearson(xs: &[f32], ys: &[f32]) -> f32 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = mean(xs) as f64;
    let my = mean(ys) as f64;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        let dx = x as f64 - mx;
        let dy = y as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    (sxy / (sxx * syy).sqrt()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std_dev(&xs) - 1.118034).abs() < 1e-5);
        assert!((mean_abs(&[-1.0, 1.0, -2.0]) - 4.0 / 3.0).abs() < 1e-6);
        assert_eq!(max_abs(&[-3.0, 2.0]), 3.0);
        assert_eq!(min_max(&[-3.0, 2.0]), (-3.0, 2.0));
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-6);
        let neg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-6);
    }
}
