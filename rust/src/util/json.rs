//! Minimal JSON substrate (for the absent `serde_json`): one borrowing
//! single-pass parser, two front-ends.
//!
//! * [`Reader`] — a pull-parser over `&str` yielding borrowed keys and
//!   strings (`Cow` borrows unless escapes force a copy) and streaming
//!   number parses.  The serving hot path decodes requests straight
//!   into typed structs through it; no intermediate `Value` tree.
//! * [`Json`] — the owned tree for config files, metrics dumps and
//!   tests.  `text.parse::<Json>()` (via [`std::str::FromStr`]) and
//!   [`std::fmt::Display`] route through the same `Reader`/writer code,
//!   so the two front-ends cannot disagree on the dialect.
//!
//! Used for `artifacts/manifest.json`, experiment configs, metrics
//! dumps and the TCP job service wire format (`crate::proto`).

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Containers deeper than this are rejected: the recursive decoders
/// (`value_owned` / `skip_value`) must not let wire input pick the
/// stack depth.
pub const MAX_DEPTH: usize = 64;

/// A JSON value.  Numbers are kept as f64 (the manifest only needs ints
/// that fit exactly in f64).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl std::str::FromStr for Json {
    type Err = String;

    /// Parse a complete JSON document (trailing bytes are an error).
    /// This is the owned front-end over [`Reader`].
    fn from_str(text: &str) -> Result<Json, String> {
        let mut r = Reader::new(text);
        let v = r.value_owned(0)?;
        r.expect_end()?;
        Ok(v)
    }
}

impl std::fmt::Display for Json {
    /// Compact JSON text (the wire form; `to_string()` == `dump()`).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.write_to(f)
    }
}

impl Json {
    #[deprecated(note = "use `text.parse::<Json>()` — same Reader, typed front-end")]
    pub fn parse(text: &str) -> Result<Json, String> {
        text.parse()
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a useful message if absent.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key).unwrap_or_else(|| panic!("missing key '{key}' in {self:.60?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize to compact JSON text (same bytes as `Display`).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        let _ = self.write_to(&mut s);
        s
    }

    fn write_to<W: std::fmt::Write>(&self, out: &mut W) -> std::fmt::Result {
        match self {
            Json::Null => out.write_str("null"),
            Json::Bool(b) => out.write_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.write_char('[')?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.write_char(',')?;
                    }
                    x.write_to(out)?;
                }
                out.write_char(']')
            }
            Json::Obj(m) => {
                out.write_char('{')?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.write_char(',')?;
                    }
                    write_escaped(out, k)?;
                    out.write_char(':')?;
                    v.write_to(out)?;
                }
                out.write_char('}')
            }
        }
    }
}

/// The one number-formatting rule for the whole wire format.  JSON has
/// no inf/NaN, so non-finite values become `null` (degenerate
/// calibrations report non-finite losses and the dump must stay
/// parseable); whole numbers print as integers; everything else uses
/// Rust's shortest-roundtrip `f64` text, so identical text <=>
/// identical bits.
pub fn write_num<W: std::fmt::Write>(out: &mut W, n: f64) -> std::fmt::Result {
    if !n.is_finite() {
        out.write_str("null")
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        write!(out, "{}", n as i64)
    } else {
        write!(out, "{n}")
    }
}

/// Write `s` as a JSON string literal (quotes + escapes).
pub fn write_escaped<W: std::fmt::Write>(out: &mut W, s: &str) -> std::fmt::Result {
    out.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => out.write_str("\\\"")?,
            '\\' => out.write_str("\\\\")?,
            '\n' => out.write_str("\\n")?,
            '\r' => out.write_str("\\r")?,
            '\t' => out.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => out.write_char(c)?,
        }
    }
    out.write_char('"')
}

/// Borrowing single-pass pull-parser.
///
/// The caller drives it with the shape it expects — [`Reader::obj`] /
/// [`Reader::arr`] iterate containers handing the closure each
/// key/element position, [`Reader::string_cow`] yields the string
/// *borrowed from the input* unless escapes force a copy,
/// [`Reader::number`] streams a finite `f64`, and [`Reader::f32_array`]
/// decodes a numeric array straight into a caller-owned buffer.
/// Unknown keys are skipped (validated, not built) with
/// [`Reader::skip_value`].  `value_owned` is the bridge to the [`Json`]
/// tree — one parser implementation, two front-ends.
pub struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub fn new(text: &'a str) -> Reader<'a> {
        Reader { b: text.as_bytes(), i: 0 }
    }

    /// Current byte offset (for error messages).
    pub fn pos(&self) -> usize {
        self.i
    }

    pub fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    pub fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    /// After the document: only trailing whitespace is allowed.
    pub fn expect_end(&mut self) -> Result<(), String> {
        self.skip_ws();
        if self.i != self.b.len() {
            return Err(format!("trailing bytes at {}", self.i));
        }
        Ok(())
    }

    fn lit(&mut self, s: &str) -> Result<(), String> {
        self.skip_ws();
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(())
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    /// `true` / `false`.
    pub fn boolean(&mut self) -> Result<bool, String> {
        match self.peek() {
            Some(b't') => {
                self.lit("true")?;
                Ok(true)
            }
            Some(b'f') => {
                self.lit("false")?;
                Ok(false)
            }
            other => Err(format!("expected bool, got {:?} at {}", other.map(char::from), self.i)),
        }
    }

    /// A finite number.  `1e999`, `NaN` and `Infinity` are rejected —
    /// JSON has no spelling for them and the integer kernels must never
    /// see one smuggled through the wire.
    pub fn number(&mut self) -> Result<f64, String> {
        self.skip_ws();
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while matches!(self.b.get(self.i), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let n = std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| format!("bad number at {start}"))?;
        if !n.is_finite() {
            return Err(format!("non-finite number at {start}"));
        }
        Ok(n)
    }

    /// A string, borrowed from the input when it contains no escapes
    /// (the hot path: keys and model names), copied otherwise.
    pub fn string_cow(&mut self) -> Result<Cow<'a, str>, String> {
        self.eat(b'"')?;
        let b: &'a [u8] = self.b;
        let start = self.i;
        // Fast scan: '"' (0x22) and '\\' (0x5c) can't appear inside a
        // UTF-8 continuation byte, so a byte scan is code-point safe.
        loop {
            match b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    let s = std::str::from_utf8(&b[start..self.i])
                        .map_err(|_| "bad utf8".to_string())?;
                    self.i += 1;
                    return Ok(Cow::Borrowed(s));
                }
                Some(b'\\') => break,
                Some(_) => self.i += 1,
            }
        }
        // Slow path: escapes force an owned copy; keep the prefix.
        let mut s = String::new();
        s.push_str(std::str::from_utf8(&b[start..self.i]).map_err(|_| "bad utf8".to_string())?);
        loop {
            match b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(Cow::Owned(s));
                }
                Some(b'\\') => {
                    self.i += 1;
                    match b.get(self.i) {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    let rest = &b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8")?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    /// Iterate an object: `f` is called at each value position with the
    /// borrowed key and must consume exactly that value (parse it or
    /// [`Reader::skip_value`] it).
    pub fn obj<F>(&mut self, mut f: F) -> Result<(), String>
    where
        F: FnMut(&mut Reader<'a>, &str) -> Result<(), String>,
    {
        self.eat(b'{')?;
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            let key = self.string_cow()?;
            self.eat(b':')?;
            f(self, &key)?;
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("bad object at {}", self.i)),
            }
        }
    }

    /// Iterate an array: `f` is called at each element position and
    /// must consume exactly one value.
    pub fn arr<F>(&mut self, mut f: F) -> Result<(), String>
    where
        F: FnMut(&mut Reader<'a>) -> Result<(), String>,
    {
        self.eat(b'[')?;
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            f(self)?;
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("bad array at {}", self.i)),
            }
        }
    }

    /// Decode a numeric array in one pass into `out` (the tensor hot
    /// path: no `Json` tree, no per-element allocation).  Returns how
    /// many values were appended.
    pub fn f32_array(&mut self, out: &mut Vec<f32>) -> Result<usize, String> {
        let n0 = out.len();
        self.arr(|r| {
            let v = r.number()?;
            out.push(v as f32);
            Ok(())
        })?;
        Ok(out.len() - n0)
    }

    /// Parse one value into the owned [`Json`] tree.  `depth` is the
    /// current container nesting (pass 0 at the top).
    pub fn value_owned(&mut self, depth: usize) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => {
                self.check_depth(depth)?;
                let mut m = BTreeMap::new();
                self.obj(|r, k| {
                    let key = k.to_string();
                    let v = r.value_owned(depth + 1)?;
                    m.insert(key, v);
                    Ok(())
                })?;
                Ok(Json::Obj(m))
            }
            Some(b'[') => {
                self.check_depth(depth)?;
                let mut v = Vec::new();
                self.arr(|r| {
                    v.push(r.value_owned(depth + 1)?);
                    Ok(())
                })?;
                Ok(Json::Arr(v))
            }
            Some(b'"') => Ok(Json::Str(self.string_cow()?.into_owned())),
            Some(b't') | Some(b'f') => Ok(Json::Bool(self.boolean()?)),
            Some(b'n') => {
                self.lit("null")?;
                Ok(Json::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Json::Num(self.number()?)),
            other => {
                Err(format!("unexpected {:?} at {}", other.map(char::from), self.i))
            }
        }
    }

    /// Validate and discard one value (unknown keys on the hot path).
    /// Same grammar as `value_owned`, nothing built.
    pub fn skip_value(&mut self, depth: usize) -> Result<(), String> {
        match self.peek() {
            Some(b'{') => {
                self.check_depth(depth)?;
                self.obj(|r, _k| r.skip_value(depth + 1))
            }
            Some(b'[') => {
                self.check_depth(depth)?;
                self.arr(|r| r.skip_value(depth + 1))
            }
            Some(b'"') => self.string_cow().map(|_| ()),
            Some(b't') | Some(b'f') => self.boolean().map(|_| ()),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(|_| ()),
            other => {
                Err(format!("unexpected {:?} at {}", other.map(char::from), self.i))
            }
        }
    }

    fn check_depth(&self, depth: usize) -> Result<(), String> {
        if depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at {}", self.i));
        }
        Ok(())
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<Json, String> {
        text.parse::<Json>()
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].req("b").as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"q\"uo\\te","t":true,"n":null}}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
        // Display and dump are the same writer
        assert_eq!(j.to_string(), j.dump());
    }

    #[test]
    fn non_finite_numbers_dump_as_null() {
        let j = Json::obj(vec![
            ("inf", Json::Num(f64::INFINITY)),
            ("ninf", Json::Num(f64::NEG_INFINITY)),
            ("nan", Json::Num(f64::NAN)),
            ("ok", Json::Num(1.5)),
        ]);
        let text = j.dump();
        let back = parse(&text).expect("non-finite dump must stay parseable");
        assert_eq!(back.req("inf"), &Json::Null);
        assert_eq!(back.req("ninf"), &Json::Null);
        assert_eq!(back.req("nan"), &Json::Null);
        assert_eq!(back.req("ok").as_f64(), Some(1.5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn rejects_non_finite_and_deep_nesting() {
        // JSON has no inf/NaN spelling; an overflowing literal must not
        // become one either.
        assert!(parse("1e999").is_err());
        assert!(parse("nan").is_err());
        // wire input must not choose the recursion depth
        let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn unicode_roundtrip() {
        let j = parse("\"caf\\u00e9 ↦\"").unwrap();
        assert_eq!(j.as_str(), Some("café ↦"));
        assert_eq!(parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn reader_borrows_unescaped_strings() {
        let mut r = Reader::new(r#""plain""#);
        match r.string_cow().unwrap() {
            Cow::Borrowed(s) => assert_eq!(s, "plain"),
            Cow::Owned(_) => panic!("unescaped string must borrow"),
        }
        let mut r = Reader::new(r#""esc\n""#);
        match r.string_cow().unwrap() {
            Cow::Owned(s) => assert_eq!(s, "esc\n"),
            Cow::Borrowed(_) => panic!("escaped string must copy"),
        }
    }

    #[test]
    fn reader_streams_f32_arrays() {
        let mut buf = Vec::new();
        let mut r = Reader::new("[1, 2.5, -3e2]");
        assert_eq!(r.f32_array(&mut buf).unwrap(), 3);
        assert!(r.expect_end().is_ok());
        assert_eq!(buf, vec![1.0f32, 2.5, -300.0]);
        // appends, never clears: the per-connection buffer is reused
        let mut r = Reader::new("[4]");
        assert_eq!(r.f32_array(&mut buf).unwrap(), 1);
        assert_eq!(buf.len(), 4);
    }

    #[test]
    fn reader_skips_unknown_values() {
        let mut r = Reader::new(r#"{"keep":1,"skip":{"deep":[true,null,"s"]},"b":2}"#);
        let mut keep = 0.0;
        let mut b = 0.0;
        r.obj(|r, k| {
            match k {
                "keep" => keep = r.number()?,
                "b" => b = r.number()?,
                _ => r.skip_value(0)?,
            }
            Ok(())
        })
        .unwrap();
        r.expect_end().unwrap();
        assert_eq!((keep, b), (1.0, 2.0));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = text.parse::<Json>().expect("manifest parses");
            assert!(j.req("models").as_obj().unwrap().contains_key("cnn6"));
        }
    }
}
