//! Minimal JSON parser/serializer (substrate for the absent `serde_json`).
//!
//! Parses the subset emitted by `python/compile/aot.py` (and full JSON in
//! practice): objects, arrays, strings with escapes, numbers, bools, null.
//! Used for `artifacts/manifest.json`, experiment configs, metrics dumps
//! and the TCP job service wire format.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Numbers are kept as f64 (the manifest only needs ints
/// that fit exactly in f64).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a useful message if absent.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key).unwrap_or_else(|| panic!("missing key '{key}' in {self:.60?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize to compact JSON text.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no inf/NaN; `null` keeps the dump parseable
                    // (degenerate calibrations report non-finite losses).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full code point.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8")?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].req("b").as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"q\"uo\\te","t":true,"n":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_numbers_dump_as_null() {
        let j = Json::obj(vec![
            ("inf", Json::Num(f64::INFINITY)),
            ("ninf", Json::Num(f64::NEG_INFINITY)),
            ("nan", Json::Num(f64::NAN)),
            ("ok", Json::Num(1.5)),
        ]);
        let text = j.dump();
        let back = Json::parse(&text).expect("non-finite dump must stay parseable");
        assert_eq!(back.req("inf"), &Json::Null);
        assert_eq!(back.req("ninf"), &Json::Null);
        assert_eq!(back.req("nan"), &Json::Null);
        assert_eq!(back.req("ok").as_f64(), Some(1.5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::parse("\"caf\\u00e9 ↦\"").unwrap();
        assert_eq!(j.as_str(), Some("café ↦"));
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).expect("manifest parses");
            assert!(j.req("models").as_obj().unwrap().contains_key("cnn6"));
        }
    }
}
