//! Wall-clock timing helpers (used by benches and the metrics registry).

use std::time::Instant;

/// A running stopwatch that accumulates labelled laps.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, f64)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, laps: Vec::new() }
    }

    /// Record the time since the previous lap under `label`.
    pub fn lap(&mut self, label: &str) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.laps.push((label.to_string(), dt));
        dt
    }

    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let dt = sw.lap("a");
        assert!(dt >= 0.004);
        assert_eq!(sw.laps.len(), 1);
        assert!(sw.total() >= dt);
    }

    #[test]
    fn timed_returns_value() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
