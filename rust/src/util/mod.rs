//! Host-side substrates: RNG, JSON, statistics, timing, logging.
//!
//! The build environment is fully offline with a fixed crate universe, so
//! the usual suspects (`rand`, `serde_json`, `tracing`, `criterion`) are
//! re-implemented here at the scale this project needs.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;
