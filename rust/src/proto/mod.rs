//! The typed wire protocol: one `Request`/`Response` surface shared by
//! the blocking service (`coordinator::service`) and the concurrent
//! pool (`serve::pool`), so the two paths cannot drift.
//!
//! Requests arrive as JSON lines (the default dialect, one object per
//! line) or — after a `{"cmd":"hello","wire":"bin1"}` handshake — as
//! CRC-checked binary frames ([`frame`]) for the `infer` hot path.
//! Parsing goes through `util::json::Reader` directly into these typed
//! structs: no intermediate `Value` tree on the hot path, f32 payloads
//! decoded in a single pass.  Responses serialize into a reusable
//! per-connection buffer via [`Response::write_json`]; the JSON and
//! binary encodings of an infer reply are bit-identical by construction
//! (JSON text is Rust's shortest-roundtrip float form, bin1 is the raw
//! f32 bits).
//!
//! The connection loop both servers share lives in [`wire`].

pub mod frame;
pub mod wire;

use crate::config::ExperimentConfig;
use crate::coordinator::jobs::{InferReply, JobResult, PackSummary};
use crate::coordinator::metrics;
use crate::runtime::cpu::ops::Arr;
use crate::runtime::EngineHandle;
use crate::tensor::HostTensor;
use crate::util::json::{self, Json, Reader};
use anyhow::{Context, Result};
use std::fmt::Write as _;

/// Hard cap on one JSON-lines request.  A single multi-gigabyte line
/// must not OOM a worker: past this the connection gets a typed
/// `too_large` reply and is closed.
pub const MAX_LINE_BYTES: usize = 8 << 20;

/// Hard cap on one bin1 frame payload (binary tensors are denser than
/// their JSON spelling, so the frame cap is the larger of the two).
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// The wire value of the shed response's `error` field.
pub const OVERLOADED: &str = "overloaded";

/// The wire value of the registry-miss response's `error` field, and
/// the stable prefix token carried by the internal error it is mapped
/// from (the vendored `anyhow` shim has no downcasting, so the typed
/// classification rides on the message prefix).
pub const MODEL_NOT_PACKED: &str = "model_not_packed";

/// Does this error chain bottom out in a registry miss?  Both servers'
/// dispatchers use this to turn the `Runner::infer` failure into the
/// typed [`Response::ModelNotPacked`] instead of a generic error.
pub fn is_model_not_packed(e: &anyhow::Error) -> bool {
    e.root_cause().to_string().starts_with(MODEL_NOT_PACKED)
}

/// Row threshold past which a stream-negotiated connection gets its
/// infer reply as chunked frames instead of one monolithic response.
pub const STREAM_CHUNK_ROWS: usize = 32;

/// An optional client-supplied request id, echoed verbatim on the
/// response so one connection can multiplex pipelined requests.
/// Strings and numbers only (anything else is treated as absent).
#[derive(Debug, Clone, PartialEq)]
pub enum ReqId {
    Num(f64),
    Str(String),
}

impl ReqId {
    pub fn write_json(&self, out: &mut String) {
        match self {
            ReqId::Num(n) => {
                let _ = json::write_num(out, *n);
            }
            ReqId::Str(s) => {
                let _ = json::write_escaped(out, s);
            }
        }
    }
}

/// A parsed request — every command both servers accept.
#[derive(Debug, Clone)]
pub enum Request {
    Ping,
    Models,
    Metrics,
    /// Wire negotiation; handled inside the connection loop.  `stream`
    /// opts in to chunked infer replies for large batches.
    Hello { wire: String, stream: bool },
    Quantize { cfg: Box<ExperimentConfig>, stream: bool },
    Pack { cfg: Box<ExperimentConfig>, po2: bool },
    Infer(InferRequest),
    Shutdown,
    /// Anything else: answered with the typed `unknown_cmd` error.
    Unknown { cmd: String },
}

/// An `infer` request: registry key plus decoded input tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub key: String,
    pub inputs: Vec<HostTensor>,
}

impl Request {
    /// Parse one JSON line (discarding any request id) — see
    /// [`Request::parse_line`] for the id-aware entry point.
    pub fn from_line(line: &str) -> Result<Request> {
        Ok(Request::parse_line(line)?.0)
    }

    /// Parse one JSON line plus its optional `"id"` (string or number;
    /// anything else is treated as absent).  `infer` goes through the
    /// borrowing reader straight into [`InferRequest`] (no `Json`
    /// tree); `quantize` / `pack` build the owned tree because
    /// [`ExperimentConfig`] decodes from one (cold path: those jobs run
    /// for seconds to minutes).
    pub fn parse_line(line: &str) -> Result<(Request, Option<ReqId>)> {
        let mut cmd = String::new();
        let mut hello_wire: Option<String> = None;
        let mut stream_flag = false;
        let mut id: Option<ReqId> = None;
        let mut r = Reader::new(line);
        let scan = r
            .obj(|r, k| match k {
                "cmd" => {
                    cmd = r.string_cow()?.into_owned();
                    Ok(())
                }
                "wire" => {
                    hello_wire = Some(r.string_cow()?.into_owned());
                    Ok(())
                }
                "stream" => {
                    // peek, then skip: `quantize` re-reads it from the
                    // owned tree, `hello` wants just the bool.
                    stream_flag = r.peek() == Some(b't');
                    r.skip_value(0)
                }
                "id" => match r.peek() {
                    Some(b'"') => {
                        id = Some(ReqId::Str(r.string_cow()?.into_owned()));
                        Ok(())
                    }
                    Some(c) if c == b'-' || c.is_ascii_digit() => {
                        id = Some(ReqId::Num(r.number()?));
                        Ok(())
                    }
                    _ => r.skip_value(0),
                },
                _ => r.skip_value(0),
            })
            .and_then(|_| r.expect_end());
        scan.map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        let req = match cmd.as_str() {
            "ping" => Request::Ping,
            "models" => Request::Models,
            "metrics" => Request::Metrics,
            "shutdown" => Request::Shutdown,
            "hello" => Request::Hello {
                wire: hello_wire.unwrap_or_else(|| "json".into()),
                stream: stream_flag,
            },
            "infer" => Request::Infer(parse_infer(line)?),
            "quantize" => {
                let req: Json =
                    line.parse().map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
                let cfg = ExperimentConfig::from_json(&req)?;
                let stream = req.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
                Request::Quantize { cfg: Box::new(cfg), stream }
            }
            "pack" => {
                let req: Json =
                    line.parse().map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
                let cfg = ExperimentConfig::from_json(&req)?;
                let po2 = req.get("po2").and_then(|v| v.as_bool()).unwrap_or(true);
                Request::Pack { cfg: Box::new(cfg), po2 }
            }
            _ => Request::Unknown { cmd },
        };
        Ok((req, id))
    }

    /// Serialize to one JSON line (no trailing newline) — the client
    /// half of the protocol, and the round-trip anchor for tests.
    pub fn write_json(&self, out: &mut String) {
        match self {
            Request::Ping => out.push_str(r#"{"cmd":"ping"}"#),
            Request::Models => out.push_str(r#"{"cmd":"models"}"#),
            Request::Metrics => out.push_str(r#"{"cmd":"metrics"}"#),
            Request::Shutdown => out.push_str(r#"{"cmd":"shutdown"}"#),
            Request::Hello { wire, stream } => {
                // "stream" is omitted when false so pre-streaming hello
                // lines round-trip byte for byte.
                out.push_str(r#"{"cmd":"hello","#);
                if *stream {
                    out.push_str(r#""stream":true,"#);
                }
                out.push_str(r#""wire":"#);
                let _ = json::write_escaped(out, wire);
                out.push('}');
            }
            Request::Unknown { cmd } => {
                out.push_str(r#"{"cmd":"#);
                let _ = json::write_escaped(out, cmd);
                out.push('}');
            }
            Request::Quantize { cfg, stream } => {
                let mut j = cfg.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("cmd".into(), Json::Str("quantize".into()));
                    if *stream {
                        m.insert("stream".into(), Json::Bool(true));
                    }
                }
                out.push_str(&j.dump());
            }
            Request::Pack { cfg, po2 } => {
                let mut j = cfg.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("cmd".into(), Json::Str("pack".into()));
                    m.insert("po2".into(), Json::Bool(*po2));
                }
                out.push_str(&j.dump());
            }
            Request::Infer(ir) => write_infer_request(ir, out),
        }
    }
}

/// Infer request writer (keys alphabetical, matching `Json::Obj` dumps).
fn write_infer_request(ir: &InferRequest, out: &mut String) {
    let ncf = ir.inputs.len() == 2
        && ir.inputs.iter().all(|t| matches!(t.data, crate::tensor::Data::I32(_)));
    out.push_str(r#"{"cmd":"infer""#);
    if ncf {
        out.push_str(r#","items":"#);
        write_i32_arr(ir.inputs[1].i(), out);
        out.push_str(r#","key":"#);
        let _ = json::write_escaped(out, &ir.key);
        out.push_str(r#","users":"#);
        write_i32_arr(ir.inputs[0].i(), out);
    } else {
        out.push_str(r#","key":"#);
        let _ = json::write_escaped(out, &ir.key);
        let t = &ir.inputs[0];
        if t.shape.len() == 2 {
            // nested rows
            out.push_str(r#","x":["#);
            let cols = t.shape[1];
            for (i, row) in t.f().chunks(cols.max(1)).enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_f32_arr(row, out);
            }
            out.push(']');
        } else {
            out.push_str(r#","shape":["#);
            for (i, d) in t.shape.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{d}");
            }
            out.push_str(r#"],"x":"#);
            write_f32_arr(t.f(), out);
        }
    }
    out.push('}');
}

fn write_f32_arr(xs: &[f32], out: &mut String) {
    out.push('[');
    for (i, &v) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = json::write_num(out, v as f64);
    }
    out.push(']');
}

fn write_i32_arr(xs: &[i32], out: &mut String) {
    out.push('[');
    for (i, &v) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
}

/// Decode an infer line in one borrowing pass: `users`+`items` i32
/// arrays (NCF), nested `x` rows (feature models), or flat `x` +
/// `shape` (images).  Tensor data goes straight from the text into its
/// final `Vec<f32>` — no `Json` tree, no per-element boxing.
fn parse_infer(line: &str) -> Result<InferRequest> {
    let mut key: Option<String> = None;
    let mut model: Option<String> = None;
    let mut users: Option<Vec<i32>> = None;
    let mut items: Option<Vec<i32>> = None;
    let mut shape: Option<Vec<usize>> = None;
    let mut saw_x = false;
    let mut x_flat = false;
    let mut x_rows = 0usize;
    let mut x_cols = 0usize;
    let mut data: Vec<f32> = Vec::new();
    let mut r = Reader::new(line);
    let scan = r
        .obj(|r, k| match k {
            "cmd" => r.skip_value(0),
            "key" => {
                key = Some(r.string_cow()?.into_owned());
                Ok(())
            }
            "model" => {
                model = Some(r.string_cow()?.into_owned());
                Ok(())
            }
            "users" => {
                users = Some(parse_i32_arr(r)?);
                Ok(())
            }
            "items" => {
                items = Some(parse_i32_arr(r)?);
                Ok(())
            }
            "shape" => {
                let mut s = Vec::new();
                r.arr(|r| {
                    s.push(r.number()? as usize);
                    Ok(())
                })?;
                shape = Some(s);
                Ok(())
            }
            "x" => {
                saw_x = true;
                r.arr(|r| {
                    if r.peek() == Some(b'[') {
                        if x_flat {
                            return Err("mixed flat and nested 'x'".into());
                        }
                        let n = r.f32_array(&mut data)?;
                        if x_rows == 0 {
                            x_cols = n;
                        } else if n != x_cols {
                            return Err(format!("ragged 'x' rows ({n} vs {x_cols})"));
                        }
                        x_rows += 1;
                    } else {
                        if x_rows > 0 {
                            return Err("mixed flat and nested 'x'".into());
                        }
                        x_flat = true;
                        data.push(r.number()? as f32);
                    }
                    Ok(())
                })
            }
            _ => r.skip_value(0),
        })
        .and_then(|_| r.expect_end());
    scan.map_err(|e| anyhow::anyhow!("bad request: {e}"))?;

    let key = key.or(model).context("infer needs 'key' (from pack) or 'model'")?;
    if let (Some(u), Some(it)) = (users, items) {
        let ut = HostTensor::i32(vec![u.len()], u);
        let it = HostTensor::i32(vec![it.len()], it);
        return Ok(InferRequest { key, inputs: vec![ut, it] });
    }
    if !saw_x {
        anyhow::bail!("infer needs 'x' (vision) or 'users'+'items' (ncf)");
    }
    if x_rows > 0 {
        return Ok(InferRequest { key, inputs: vec![HostTensor::f32(vec![x_rows, x_cols], data)] });
    }
    if !x_flat {
        anyhow::bail!("'x' is empty");
    }
    let shape = shape.context("flat 'x' needs a 'shape' array")?;
    if shape.iter().product::<usize>() != data.len() {
        anyhow::bail!("shape {shape:?} does not cover {} values", data.len());
    }
    Ok(InferRequest { key, inputs: vec![HostTensor::f32(shape, data)] })
}

fn parse_i32_arr(r: &mut Reader) -> Result<Vec<i32>, String> {
    let mut out = Vec::new();
    r.arr(|r| {
        out.push(r.number()? as i32);
        Ok(())
    })?;
    Ok(out)
}

/// The prediction rule both encodings share: argmax (first max wins)
/// for multi-class rows, `v > 0` for single-logit rows.
pub fn predict_row(row: &[f32]) -> i64 {
    if row.len() > 1 {
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        best as i64
    } else if row.first().is_some_and(|&v| v > 0.0) {
        1
    } else {
        0
    }
}

/// A typed response — every reply shape either server can send.
#[derive(Debug, Clone)]
pub enum Response {
    Pong,
    /// `models` is the builtin zoo; `packs` echoes the registry's packed
    /// artifacts as `(key, per-layer weight bits)` so clients can see
    /// which mixed/uniform variants are already servable.
    Models { models: Vec<String>, packs: Vec<(String, Vec<u32>)> },
    Metrics { metrics: Json },
    /// The quantize result subtree (built once per minutes-long job).
    Quantize { result: Json },
    Pack { packed: PackSummary },
    Infer { reply: InferReply },
    Hello { wire: String, stream: bool },
    Stopping,
    Error { msg: String },
    UnknownCmd { cmd: String },
    TooLarge { limit_bytes: usize },
    Overloaded { retry_after_ms: u64 },
    /// `infer` named a key that is neither resident nor spilled —
    /// typed so clients can react (pack it, try another key) without
    /// parsing prose.
    ModelNotPacked { key: String },
}

impl Response {
    pub fn error(msg: impl Into<String>) -> Response {
        Response::Error { msg: msg.into() }
    }

    pub fn models(eng: &EngineHandle, registry: &crate::serve::registry::ModelRegistry) -> Response {
        Response::Models {
            models: eng.manifest().models.keys().cloned().collect(),
            packs: registry.entries_wbits(),
        }
    }

    pub fn metrics() -> Response {
        Response::Metrics { metrics: metrics::dump() }
    }

    /// The quantize result: metrics, calibration trace, layer masks and
    /// a lossless config echo (the run is reproducible from the
    /// response alone).
    pub fn quantize(cfg: &ExperimentConfig, res: &JobResult) -> Response {
        let bools = |v: &[bool]| Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect());
        let trace = Json::Arr(res.outcome.trace.iter().map(|t| t.to_json()).collect());
        let joint = match cfg.method {
            crate::config::Method::Lapq => cfg.lapq.joint.optimizer.name(),
            _ => "none",
        };
        let result = Json::obj(vec![
            ("model", Json::Str(res.model.clone())),
            ("bits", Json::Str(res.bits_label.clone())),
            ("method", Json::Str(res.method.clone())),
            ("joint", Json::Str(joint.into())),
            ("fp32_metric", Json::Num(res.fp32_metric as f64)),
            ("quant_metric", Json::Num(res.quant_metric as f64)),
            ("calib_loss", Json::Num(res.outcome.calib_loss)),
            ("init_loss", Json::Num(res.outcome.init_loss)),
            ("fp32_calib_loss", Json::Num(res.outcome.fp32_calib_loss)),
            ("joint_evals", Json::Num(res.outcome.joint_evals as f64)),
            ("active_w", bools(&res.outcome.mask.weights)),
            ("active_a", bools(&res.outcome.mask.acts)),
            ("trace", trace),
            ("config", cfg.to_json()),
            ("seconds", Json::Num(res.seconds)),
        ]);
        Response::Quantize { result }
    }

    /// Serialize as one JSON line (no trailing newline) into a
    /// caller-reused buffer.  Object keys are alphabetical, matching
    /// the `Json::Obj` (BTreeMap) dumps this replaces byte for byte.
    pub fn write_json(&self, out: &mut String) {
        self.write_json_id(None, out);
    }

    /// Like [`Response::write_json`] but echoing the client's request
    /// id (`"id"` stays in alphabetical key position; with `None` the
    /// output is byte-identical to the id-less wire format).
    pub fn write_json_id(&self, id: Option<&ReqId>, out: &mut String) {
        // "id" sorts after "cmd"/"error" and before every other key the
        // ok-responses emit, so it lands right after `{` on the ok arms
        // and right after the error discriminant on the error arms.
        let put_id_lead = |out: &mut String, id: Option<&ReqId>| {
            if let Some(id) = id {
                out.push_str(r#""id":"#);
                id.write_json(out);
                out.push(',');
            }
        };
        let put_id_mid = |out: &mut String, id: Option<&ReqId>| {
            if let Some(id) = id {
                out.push_str(r#","id":"#);
                id.write_json(out);
            }
        };
        match self {
            Response::Pong => {
                out.push('{');
                put_id_lead(out, id);
                out.push_str(r#""ok":true,"pong":true}"#);
            }
            Response::Stopping => {
                out.push('{');
                put_id_lead(out, id);
                out.push_str(r#""ok":true,"stopping":true}"#);
            }
            Response::Hello { wire, stream } => {
                out.push('{');
                put_id_lead(out, id);
                out.push_str(r#""ok":true,"#);
                if *stream {
                    out.push_str(r#""stream":true,"#);
                }
                out.push_str(r#""wire":"#);
                let _ = json::write_escaped(out, wire);
                out.push('}');
            }
            Response::Models { models, packs } => {
                out.push('{');
                put_id_lead(out, id);
                out.push_str(r#""models":["#);
                for (i, m) in models.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = json::write_escaped(out, m);
                }
                out.push_str(r#"],"ok":true"#);
                if !packs.is_empty() {
                    out.push_str(r#","packs":["#);
                    for (i, (key, wbits)) in packs.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push_str(r#"{"key":"#);
                        let _ = json::write_escaped(out, key);
                        out.push_str(r#","wbits":["#);
                        for (k, b) in wbits.iter().enumerate() {
                            if k > 0 {
                                out.push(',');
                            }
                            let _ = write!(out, "{b}");
                        }
                        out.push_str("]}");
                    }
                    out.push(']');
                }
                out.push('}');
            }
            Response::Metrics { metrics } => {
                out.push('{');
                put_id_lead(out, id);
                let _ = write!(out, r#""metrics":{metrics},"ok":true}}"#);
            }
            Response::Quantize { result } => {
                out.push('{');
                put_id_lead(out, id);
                let _ = write!(out, r#""ok":true,"result":{result}}}"#);
            }
            Response::Pack { packed } => {
                out.push('{');
                put_id_lead(out, id);
                write_pack(packed, out);
            }
            Response::Infer { reply } => {
                out.push('{');
                put_id_lead(out, id);
                write_infer_reply(reply, out);
            }
            Response::Error { msg } => {
                out.push_str(r#"{"error":"#);
                let _ = json::write_escaped(out, msg);
                put_id_mid(out, id);
                out.push_str(r#","ok":false}"#);
            }
            Response::UnknownCmd { cmd } => {
                out.push_str(r#"{"cmd":"#);
                let _ = json::write_escaped(out, cmd);
                out.push_str(r#","error":"unknown_cmd""#);
                put_id_mid(out, id);
                out.push_str(r#","ok":false}"#);
            }
            Response::TooLarge { limit_bytes } => {
                out.push_str(r#"{"error":"too_large""#);
                put_id_mid(out, id);
                let _ = write!(out, r#","limit_bytes":{limit_bytes},"ok":false}}"#);
            }
            Response::Overloaded { retry_after_ms } => {
                out.push_str(r#"{"error":"overloaded""#);
                put_id_mid(out, id);
                let _ = write!(out, r#","ok":false,"retry_after_ms":{retry_after_ms}}}"#);
            }
            Response::ModelNotPacked { key } => {
                out.push_str(r#"{"error":"model_not_packed""#);
                put_id_mid(out, id);
                out.push_str(r#","key":"#);
                let _ = json::write_escaped(out, key);
                out.push_str(r#","ok":false}"#);
            }
        }
    }

    /// Parse a response line back into its typed form (clients, tests).
    pub fn from_line(line: &str) -> Result<Response, String> {
        let j: Json = line.parse()?;
        let ok = j.get("ok").and_then(|v| v.as_bool()).ok_or("response missing 'ok'")?;
        let str_of = |j: &Json, k: &str| {
            j.get(k).and_then(|v| v.as_str()).map(str::to_string).unwrap_or_default()
        };
        if !ok {
            let err = str_of(&j, "error");
            return Ok(match err.as_str() {
                "unknown_cmd" => Response::UnknownCmd { cmd: str_of(&j, "cmd") },
                "too_large" => Response::TooLarge {
                    limit_bytes: j.get("limit_bytes").and_then(|v| v.as_usize()).unwrap_or(0),
                },
                OVERLOADED => Response::Overloaded {
                    retry_after_ms: j
                        .get("retry_after_ms")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0) as u64,
                },
                MODEL_NOT_PACKED => Response::ModelNotPacked { key: str_of(&j, "key") },
                _ => Response::Error { msg: err },
            });
        }
        if j.get("pong").is_some() {
            Ok(Response::Pong)
        } else if j.get("stopping").is_some() {
            Ok(Response::Stopping)
        } else if let Some(w) = j.get("wire") {
            Ok(Response::Hello {
                wire: w.as_str().unwrap_or_default().to_string(),
                stream: j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false),
            })
        } else if let Some(m) = j.get("models") {
            let models = m
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect())
                .unwrap_or_default();
            let packs = j
                .get("packs")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .map(|p| {
                            let key = p
                                .get("key")
                                .and_then(|v| v.as_str())
                                .unwrap_or_default()
                                .to_string();
                            let wbits = p
                                .get("wbits")
                                .and_then(|v| v.as_arr())
                                .map(|b| {
                                    b.iter()
                                        .filter_map(|v| v.as_f64().map(|n| n as u32))
                                        .collect()
                                })
                                .unwrap_or_default();
                            (key, wbits)
                        })
                        .collect()
                })
                .unwrap_or_default();
            Ok(Response::Models { models, packs })
        } else if let Some(m) = j.get("metrics") {
            Ok(Response::Metrics { metrics: m.clone() })
        } else if let Some(p) = j.get("packed") {
            Ok(Response::Pack { packed: pack_from_json(p) })
        } else if let Some(r) = j.get("result") {
            if r.get("logits").is_some() {
                Ok(Response::Infer { reply: infer_reply_from_json(r)? })
            } else {
                Ok(Response::Quantize { result: r.clone() })
            }
        } else {
            Err("unrecognized response shape".into())
        }
    }
}

/// `"ok":true,"packed":{...}}` — keys alphabetical; the caller has
/// already opened the object (and possibly written `"id"`).
fn write_pack(s: &PackSummary, out: &mut String) {
    out.push_str(r#""ok":true,"packed":{"bits":"#);
    let _ = json::write_escaped(out, &s.bits_label);
    let _ = write!(out, r#","f32_bytes":{}"#, s.f32_bytes);
    out.push_str(r#","fp32_metric":"#);
    let _ = json::write_num(out, s.fp32_metric as f64);
    let _ = write!(out, r#","int_params":{}"#, s.int_params);
    out.push_str(r#","key":"#);
    let _ = json::write_escaped(out, &s.key);
    out.push_str(r#","method":"#);
    let _ = json::write_escaped(out, &s.method);
    out.push_str(r#","model":"#);
    let _ = json::write_escaped(out, &s.model);
    let _ = write!(out, r#","packed_bytes":{}"#, s.packed_bytes);
    out.push_str(r#","quant_metric":"#);
    let _ = json::write_num(out, s.quant_metric as f64);
    out.push_str(r#","seconds":"#);
    let _ = json::write_num(out, s.seconds);
    // "wbits" sorts last; omitted when empty so pre-mixed lines round-trip
    if !s.wbits.is_empty() {
        out.push_str(r#","wbits":["#);
        for (i, b) in s.wbits.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{b}");
        }
        out.push(']');
    }
    out.push_str("}}");
}

/// `"ok":true,"result":{...}}` for infer — keys alphabetical
/// (`int_layers`, `key`, `logits`, `predictions`, `rows`, `seconds`),
/// written straight into the reusable buffer: no `Json` tree per reply.
/// The caller has already opened the object.
fn write_infer_reply(reply: &InferReply, out: &mut String) {
    let c = reply.logits.last_dim().max(1);
    let _ = write!(out, r#""ok":true,"result":{{"int_layers":{}"#, reply.int_layers);
    out.push_str(r#","key":"#);
    let _ = json::write_escaped(out, &reply.key);
    out.push_str(r#","logits":["#);
    let mut preds: Vec<i64> = Vec::with_capacity(reply.logits.data.len() / c.max(1) + 1);
    for (i, row) in reply.logits.data.chunks(c).enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_f32_arr(row, out);
        preds.push(predict_row(row));
    }
    out.push_str(r#"],"predictions":["#);
    for (i, p) in preds.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{p}");
    }
    let _ = write!(out, r#"],"rows":{},"seconds":"#, reply.rows);
    let _ = json::write_num(out, reply.seconds);
    out.push_str("}}");
}

/// One chunk of a streamed infer reply, mirroring the quantize
/// `{"event":...}` stream: no `"ok"` key (the final frame carries it),
/// keys alphabetical (`chunk`, `chunks`, `id?`, `key`, `logits`,
/// `predictions`).  `rows` holds `nrows * cols` row-major logits.
pub fn write_infer_chunk_json(
    key: &str,
    chunk: usize,
    chunks: usize,
    rows: &[f32],
    cols: usize,
    id: Option<&ReqId>,
    out: &mut String,
) {
    let c = cols.max(1);
    let _ = write!(out, r#"{{"chunk":{chunk},"chunks":{chunks}"#);
    if let Some(id) = id {
        out.push_str(r#","id":"#);
        id.write_json(out);
    }
    out.push_str(r#","key":"#);
    let _ = json::write_escaped(out, key);
    out.push_str(r#","logits":["#);
    let mut preds: Vec<i64> = Vec::with_capacity(rows.len() / c + 1);
    for (i, row) in rows.chunks(c).enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_f32_arr(row, out);
        preds.push(predict_row(row));
    }
    out.push_str(r#"],"predictions":["#);
    for (i, p) in preds.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{p}");
    }
    out.push_str("]}");
}

/// The terminal frame of a streamed infer reply: the usual
/// `{"ok":true,"result":{...}}` envelope minus the logits (already
/// streamed), with `"streamed":true` marking the shape.
pub fn write_infer_final_json(reply: &InferReply, id: Option<&ReqId>, out: &mut String) {
    out.push('{');
    if let Some(id) = id {
        out.push_str(r#""id":"#);
        id.write_json(out);
        out.push(',');
    }
    let _ = write!(out, r#""ok":true,"result":{{"int_layers":{}"#, reply.int_layers);
    out.push_str(r#","key":"#);
    let _ = json::write_escaped(out, &reply.key);
    let _ = write!(out, r#","rows":{},"seconds":"#, reply.rows);
    let _ = json::write_num(out, reply.seconds);
    out.push_str(r#","streamed":true}}"#);
}

fn pack_from_json(p: &Json) -> PackSummary {
    let s = |k: &str| p.get(k).and_then(|v| v.as_str()).unwrap_or_default().to_string();
    let n = |k: &str| p.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
    let f = |k: &str| p.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    PackSummary {
        key: s("key"),
        model: s("model"),
        bits_label: s("bits"),
        method: s("method"),
        int_params: n("int_params"),
        f32_bytes: n("f32_bytes"),
        packed_bytes: n("packed_bytes"),
        fp32_metric: f("fp32_metric") as f32,
        quant_metric: f("quant_metric") as f32,
        seconds: f("seconds"),
        wbits: p
            .get("wbits")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|n| n as u32)).collect())
            .unwrap_or_default(),
    }
}

fn infer_reply_from_json(r: &Json) -> Result<InferReply, String> {
    let rows_json = r.get("logits").and_then(|v| v.as_arr()).ok_or("missing logits")?;
    let cols = rows_json.first().and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0);
    let mut data = Vec::with_capacity(rows_json.len() * cols);
    for row in rows_json {
        let row = row.as_arr().ok_or("logits rows must be arrays")?;
        if row.len() != cols {
            return Err("ragged logits".into());
        }
        data.extend(row.iter().map(|v| v.as_f64().unwrap_or(f64::NAN) as f32));
    }
    Ok(InferReply {
        key: r.get("key").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
        logits: Arr::new(vec![rows_json.len(), cols], data),
        rows: r.get("rows").and_then(|v| v.as_usize()).unwrap_or(0),
        int_layers: r.get("int_layers").and_then(|v| v.as_usize()).unwrap_or(0),
        seconds: r.get("seconds").and_then(|v| v.as_f64()).unwrap_or(0.0),
    })
}
