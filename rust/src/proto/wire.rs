//! The connection loop both servers share: bounded reads, per-connection
//! wire negotiation, panic containment, and reusable response buffers.
//!
//! A connection starts in JSON-lines mode.  The reader peeks one byte:
//! `0xBF` (invalid as a UTF-8 start) means a bin1 frame, anything else
//! a JSON line.  `{"cmd":"hello","wire":"bin1"}` switches the
//! connection to binary infer replies; every other response — and every
//! error, in either mode — stays a JSON line, so clients can always
//! fall back to the line parser.
//!
//! Read bounds: a line longer than [`MAX_LINE_BYTES`] or a frame larger
//! than [`MAX_FRAME_BYTES`] gets the typed `too_large` reply and the
//! connection is closed (a line that long cannot be resynchronized
//! without reading it, which is exactly the OOM this cap prevents).

use super::frame;
use super::{ReqId, Request, Response, MAX_FRAME_BYTES, MAX_LINE_BYTES};
use crate::coordinator::jobs::InferReply;
use crate::coordinator::metrics;
use crate::runtime::cpu::ops::Arr;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Per-connection encoding, negotiated by `hello`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    Json,
    Bin1,
}

/// One unit of input from the wire.
pub enum Incoming {
    /// A complete JSON line (no terminator, `\r` stripped).
    Line,
    /// A verified bin1 frame of this kind; payload in the reader's buffer.
    Frame(u8),
    /// Clean end of stream (or a read error — either way, stop).
    Eof,
    /// The line/frame exceeded its cap; reply `too_large`, then close.
    TooLarge { limit_bytes: usize },
    /// Undecodable input (bad magic, CRC mismatch, invalid UTF-8):
    /// reply with the error, then close — the stream cannot be resynced.
    Corrupt(String),
}

/// Bounded reader over a stream: JSON lines and bin1 frames through one
/// reusable buffer.
pub struct WireReader<R: Read> {
    r: BufReader<R>,
    buf: Vec<u8>,
}

impl<R: Read> WireReader<R> {
    pub fn new(inner: R) -> WireReader<R> {
        WireReader { r: BufReader::new(inner), buf: Vec::new() }
    }

    /// The bytes of the last `Line`/`Frame` result.
    pub fn payload(&self) -> &[u8] {
        &self.buf
    }

    /// The last `Line` as text (always valid: `next` checks UTF-8).
    pub fn line(&self) -> &str {
        std::str::from_utf8(&self.buf).unwrap_or("")
    }

    /// Read the next line or frame into the internal buffer.
    pub fn next(&mut self) -> Incoming {
        self.buf.clear();
        let first = loop {
            match self.r.fill_buf() {
                Ok([]) => return Incoming::Eof,
                Ok(avail) => break avail[0],
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Incoming::Eof,
            }
        };
        if first == frame::MARKER {
            self.next_frame()
        } else {
            self.next_line()
        }
    }

    fn next_line(&mut self) -> Incoming {
        loop {
            let (consumed, done) = {
                let avail = match self.r.fill_buf() {
                    Ok(a) => a,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => return Incoming::Eof,
                };
                if avail.is_empty() {
                    // EOF mid-line: surface what we have (mirrors
                    // BufRead::read_line).
                    if self.buf.is_empty() {
                        return Incoming::Eof;
                    }
                    (0, true)
                } else {
                    match avail.iter().position(|&b| b == b'\n') {
                        Some(p) => {
                            self.buf.extend_from_slice(&avail[..p]);
                            (p + 1, true)
                        }
                        None => {
                            self.buf.extend_from_slice(avail);
                            (avail.len(), false)
                        }
                    }
                }
            };
            self.r.consume(consumed);
            if self.buf.len() > MAX_LINE_BYTES {
                return Incoming::TooLarge { limit_bytes: MAX_LINE_BYTES };
            }
            if done {
                if self.buf.last() == Some(&b'\r') {
                    self.buf.pop();
                }
                if std::str::from_utf8(&self.buf).is_err() {
                    return Incoming::Corrupt("request line is not UTF-8".into());
                }
                return Incoming::Line;
            }
        }
    }

    fn next_frame(&mut self) -> Incoming {
        let mut header = [0u8; frame::HEADER_LEN];
        if let Err(e) = self.r.read_exact(&mut header) {
            return Incoming::Corrupt(format!("truncated frame header: {e}"));
        }
        if header[0] != frame::MARKER || header[1] != frame::MAGIC2 {
            return Incoming::Corrupt("bad frame magic".into());
        }
        if header[2] != frame::VERSION {
            return Incoming::Corrupt(format!("unsupported frame version {}", header[2]));
        }
        let kind = header[3];
        let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
        if len > MAX_FRAME_BYTES {
            return Incoming::TooLarge { limit_bytes: MAX_FRAME_BYTES };
        }
        self.buf.resize(len, 0);
        if let Err(e) = self.r.read_exact(&mut self.buf) {
            return Incoming::Corrupt(format!("truncated frame payload: {e}"));
        }
        let mut crc = [0u8; frame::CRC_LEN];
        if let Err(e) = self.r.read_exact(&mut crc) {
            return Incoming::Corrupt(format!("truncated frame crc: {e}"));
        }
        if u32::from_le_bytes(crc) != frame::crc32(&self.buf) {
            return Incoming::Corrupt("frame crc mismatch".into());
        }
        Incoming::Frame(kind)
    }
}

/// One unit decoded by [`FeedDecoder::next`].
pub enum Feed {
    /// A complete JSON line (no terminator, `\r` stripped).
    Line(String),
    /// A CRC-verified bin1 frame.
    Frame { kind: u8, payload: Vec<u8> },
    /// The line/frame exceeded its cap; reply `too_large`, then close.
    TooLarge { limit_bytes: usize },
    /// Undecodable input — reply, then close (no resync possible).
    Corrupt(String),
    /// Nothing complete buffered yet; push more bytes.
    More,
}

/// Push-based twin of [`WireReader`] for the nonblocking reactor: the
/// event loop feeds whatever bytes the socket had, and pulls complete
/// lines/frames out — same grammar, same caps, same corruption rules as
/// the blocking path, so the two I/O modes cannot drift on framing.
#[derive(Default)]
pub struct FeedDecoder {
    buf: Vec<u8>,
    pos: usize,
}

impl FeedDecoder {
    pub fn new() -> FeedDecoder {
        FeedDecoder::default()
    }

    /// Append bytes read from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet decoded.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn compact(&mut self) {
        // Reclaim consumed prefix once it dominates the buffer; amortized
        // O(1) per byte.
        if self.pos > 64 * 1024 && self.pos * 2 >= self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Decode the next complete unit, or report why there is none.
    /// After `TooLarge`/`Corrupt` the stream cannot be resynchronized —
    /// the caller replies and closes, exactly like the blocking path.
    pub fn next(&mut self) -> Feed {
        self.compact();
        let avail = &self.buf[self.pos..];
        let Some(&first) = avail.first() else {
            return Feed::More;
        };
        if first == frame::MARKER {
            self.next_frame()
        } else {
            self.next_line()
        }
    }

    fn next_line(&mut self) -> Feed {
        let avail = &self.buf[self.pos..];
        let Some(p) = avail.iter().position(|&b| b == b'\n') else {
            if avail.len() > MAX_LINE_BYTES {
                return Feed::TooLarge { limit_bytes: MAX_LINE_BYTES };
            }
            return Feed::More;
        };
        let mut line = &avail[..p];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        if line.len() > MAX_LINE_BYTES {
            return Feed::TooLarge { limit_bytes: MAX_LINE_BYTES };
        }
        let Ok(text) = std::str::from_utf8(line) else {
            return Feed::Corrupt("request line is not UTF-8".into());
        };
        let text = text.to_string();
        self.pos += p + 1;
        Feed::Line(text)
    }

    fn next_frame(&mut self) -> Feed {
        let avail = &self.buf[self.pos..];
        if avail.len() < frame::HEADER_LEN {
            return Feed::More;
        }
        if avail[0] != frame::MARKER || avail[1] != frame::MAGIC2 {
            return Feed::Corrupt("bad frame magic".into());
        }
        if avail[2] != frame::VERSION {
            return Feed::Corrupt(format!("unsupported frame version {}", avail[2]));
        }
        let kind = avail[3];
        let len = u32::from_le_bytes(avail[4..8].try_into().unwrap()) as usize;
        // The cap is enforced from the header alone, before buffering
        // the body — an attacker cannot make the reactor hold 64 MB.
        if len > MAX_FRAME_BYTES {
            return Feed::TooLarge { limit_bytes: MAX_FRAME_BYTES };
        }
        let total = frame::HEADER_LEN + len + frame::CRC_LEN;
        if avail.len() < total {
            return Feed::More;
        }
        let payload = &avail[frame::HEADER_LEN..frame::HEADER_LEN + len];
        let crc = u32::from_le_bytes(avail[frame::HEADER_LEN + len..total].try_into().unwrap());
        if crc != frame::crc32(payload) {
            return Feed::Corrupt("frame crc mismatch".into());
        }
        let payload = payload.to_vec();
        self.pos += total;
        Feed::Frame { kind, payload }
    }
}

/// Serve one connection to EOF (or `budget` requests): the loop both
/// servers run.  `handle` turns a parsed [`Request`] into a
/// [`Response`]; the raw writer it also receives is for mid-request
/// `{"event":...}` stream frames.  Panics inside parse or handle become
/// structured `internal panic` errors; I/O errors end the connection,
/// never the server.  Returns how many requests were handled.
pub fn serve_conn<F>(stream: TcpStream, budget: usize, mut handle: F) -> usize
where
    F: FnMut(Request, &mut dyn Write) -> Response,
{
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "<unknown>".into());
    log::info!("conn from {peer}");
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            log::warn!("conn {peer}: clone failed: {e}");
            return 0;
        }
    };
    let mut reader = WireReader::new(stream);
    let mut mode = WireMode::Json;
    let mut stream_replies = false;
    // Reused across the connection: the JSON response text and the bin1
    // frame bytes — zero steady-state allocation on the reply path.
    let mut out = String::new();
    let mut bin: Vec<u8> = Vec::new();
    let mut handled = 0usize;
    while handled < budget {
        let (resp, id, fatal) = match reader.next() {
            Incoming::Eof => break,
            Incoming::TooLarge { limit_bytes } => (Response::TooLarge { limit_bytes }, None, true),
            Incoming::Corrupt(msg) => (Response::error(msg), None, true),
            Incoming::Line => {
                if reader.line().trim().is_empty() {
                    continue;
                }
                metrics::inc("service_requests");
                let (resp, id) = dispatch_caught(
                    reader.line(),
                    None,
                    &mut mode,
                    &mut stream_replies,
                    &mut handle,
                    &mut writer,
                );
                (resp, id, false)
            }
            Incoming::Frame(kind) => {
                metrics::inc("service_requests");
                let (resp, id) = if mode != WireMode::Bin1 {
                    (Response::error("binary frame before a successful hello/bin1 handshake"), None)
                } else if kind != frame::KIND_INFER_REQ {
                    (Response::error(format!("unexpected frame kind {kind}")), None)
                } else {
                    dispatch_caught(
                        "",
                        Some(reader.payload()),
                        &mut mode,
                        &mut stream_replies,
                        &mut handle,
                        &mut writer,
                    )
                };
                (resp, id, false)
            }
        };
        if matches!(
            resp,
            Response::Error { .. } | Response::UnknownCmd { .. } | Response::TooLarge { .. }
        ) {
            metrics::inc("service_errors");
        }
        let wrote = write_response_ex(
            &mut writer,
            &resp,
            mode,
            stream_replies,
            id.as_ref(),
            &mut out,
            &mut bin,
        );
        if let Err(e) = wrote {
            log::warn!("conn {peer}: write failed: {e}");
            break;
        }
        handled += 1;
        if fatal {
            break;
        }
    }
    handled
}

/// The `hello` handshake both I/O paths share: mutates the negotiated
/// mode/stream flags and answers with the matching [`Response::Hello`].
pub(crate) fn negotiate(
    wire: &str,
    want_stream: bool,
    mode: &mut WireMode,
    stream: &mut bool,
) -> Response {
    match wire {
        "bin1" => {
            *mode = WireMode::Bin1;
            *stream = want_stream;
            Response::Hello { wire: "bin1".into(), stream: want_stream }
        }
        "json" => {
            *mode = WireMode::Json;
            *stream = want_stream;
            Response::Hello { wire: "json".into(), stream: want_stream }
        }
        other => Response::error(format!("unknown wire '{other}' (want json or bin1)")),
    }
}

/// Parse + handle under one `catch_unwind`: a panic anywhere in the
/// request path becomes a structured error, and the connection (and
/// server) keep going.
fn dispatch_caught<F>(
    line: &str,
    frame_payload: Option<&[u8]>,
    mode: &mut WireMode,
    stream: &mut bool,
    handle: &mut F,
    writer: &mut TcpStream,
) -> (Response, Option<ReqId>)
where
    F: FnMut(Request, &mut dyn Write) -> Response,
{
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let (req, id) = match frame_payload {
            Some(payload) => match frame::decode_infer_request_id(payload) {
                Ok((ir, id)) => (Request::Infer(ir), id),
                Err(e) => return (Response::error(format!("bad frame: {e}")), None),
            },
            None => match Request::parse_line(line) {
                Ok(pair) => pair,
                Err(e) => return (Response::error(format!("{e:#}")), None),
            },
        };
        if let Request::Hello { wire, stream: want_stream } = &req {
            return (negotiate(wire, *want_stream, mode, stream), id);
        }
        (handle(req, writer), id)
    }));
    match caught {
        Ok(pair) => pair,
        Err(p) => (Response::error(format!("internal panic: {}", panic_text(p.as_ref()))), None),
    }
}

/// Write one response in the negotiated encoding, echoing the request
/// id.  Only a successful infer reply is ever framed; everything else
/// (including every error) is a JSON line in both modes.  With `stream`
/// negotiated, an infer reply larger than
/// [`super::STREAM_CHUNK_ROWS`] rows goes out as chunk frames (JSON
/// lines or `KIND_INFER_CHUNK`) followed by a logits-free terminal
/// response — chunk contents are bit-identical to the monolithic reply
/// by construction (same floats, same writers).
pub fn write_response_ex(
    w: &mut dyn Write,
    resp: &Response,
    mode: WireMode,
    stream: bool,
    id: Option<&ReqId>,
    out: &mut String,
    bin: &mut Vec<u8>,
) -> std::io::Result<()> {
    if let Response::Infer { reply } = resp {
        let cols = reply.logits.last_dim().max(1);
        let nrows = reply.logits.data.len() / cols;
        if stream && nrows > super::STREAM_CHUNK_ROWS {
            let per = cols * super::STREAM_CHUNK_ROWS;
            let chunks = nrows.div_ceil(super::STREAM_CHUNK_ROWS);
            for (i, rows) in reply.logits.data.chunks(per).enumerate() {
                if mode == WireMode::Bin1 {
                    frame::encode_infer_chunk(&reply.key, i, chunks, rows, cols, id, bin);
                    w.write_all(bin)?;
                } else {
                    out.clear();
                    super::write_infer_chunk_json(&reply.key, i, chunks, rows, cols, id, out);
                    out.push('\n');
                    w.write_all(out.as_bytes())?;
                }
                // flush per chunk: the point of streaming is that early
                // rows reach the client before late rows are serialized
                w.flush()?;
            }
            if mode == WireMode::Bin1 {
                let fin = InferReply {
                    key: reply.key.clone(),
                    logits: Arr::new(vec![0, cols], Vec::new()),
                    rows: reply.rows,
                    int_layers: reply.int_layers,
                    seconds: reply.seconds,
                };
                frame::encode_infer_reply_id(&fin, id, bin);
                w.write_all(bin)?;
            } else {
                out.clear();
                super::write_infer_final_json(reply, id, out);
                out.push('\n');
                w.write_all(out.as_bytes())?;
            }
            return w.flush();
        }
        if mode == WireMode::Bin1 {
            frame::encode_infer_reply_id(reply, id, bin);
            w.write_all(bin)?;
            return w.flush();
        }
    }
    out.clear();
    resp.write_json_id(id, out);
    out.push('\n');
    w.write_all(out.as_bytes())?;
    w.flush()
}

/// Human text out of a panic payload (for the structured error reply).
pub fn panic_text(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Minimal protocol client for tests, benches and scripting: speaks
/// JSON lines by default, upgrades to bin1 via [`Client::hello_bin1`].
pub struct Client {
    writer: TcpStream,
    reader: WireReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let writer = stream.try_clone().context("clone stream")?;
        Ok(Client { writer, reader: WireReader::new(stream) })
    }

    /// Send one request, read one JSON-line response as a `Json` tree.
    pub fn call(&mut self, req: &Request) -> Result<Json> {
        let mut line = String::new();
        req.write_json(&mut line);
        self.call_raw(&line)
    }

    /// Send a raw line (tests exercise malformed input through this).
    pub fn call_raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        match self.reader.next() {
            Incoming::Line => {
                self.reader.line().parse().map_err(|e| anyhow::anyhow!("bad response: {e}"))
            }
            Incoming::Frame(_) => anyhow::bail!("unexpected binary frame"),
            Incoming::Eof => anyhow::bail!("connection closed"),
            Incoming::TooLarge { .. } => anyhow::bail!("oversized response"),
            Incoming::Corrupt(e) => anyhow::bail!("corrupt response: {e}"),
        }
    }

    /// Negotiate bin1 on this connection.
    pub fn hello_bin1(&mut self) -> Result<()> {
        self.hello_opts("bin1", false)
    }

    /// Negotiate wire + streaming on this connection.
    pub fn hello_opts(&mut self, wire: &str, stream: bool) -> Result<()> {
        let resp = self.call(&Request::Hello { wire: wire.into(), stream })?;
        if resp.get("wire").and_then(|v| v.as_str()) != Some(wire) {
            anyhow::bail!("handshake refused: {resp:?}");
        }
        if stream && resp.get("stream").and_then(|v| v.as_bool()) != Some(true) {
            anyhow::bail!("stream negotiation refused: {resp:?}");
        }
        Ok(())
    }

    /// Send an infer request as a bin1 frame; the reply is either a
    /// framed [`InferReply`] (plus server-computed predictions) or a
    /// JSON error line.
    pub fn infer_bin(
        &mut self,
        req: &super::InferRequest,
    ) -> Result<(InferReply, Vec<i32>)> {
        let mut buf = Vec::new();
        frame::encode_infer_request(req, &mut buf);
        self.writer.write_all(&buf)?;
        self.writer.flush()?;
        match self.reader.next() {
            Incoming::Frame(frame::KIND_INFER_REP) => frame::decode_infer_reply(self.reader.payload())
                .map_err(|e| anyhow::anyhow!("bad reply frame: {e}")),
            Incoming::Frame(k) => anyhow::bail!("unexpected frame kind {k}"),
            Incoming::Line => {
                let j: Json = self
                    .reader
                    .line()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
                anyhow::bail!(
                    "infer failed: {}",
                    j.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error")
                )
            }
            Incoming::Eof => anyhow::bail!("connection closed"),
            Incoming::TooLarge { .. } => anyhow::bail!("oversized response"),
            Incoming::Corrupt(e) => anyhow::bail!("corrupt response: {e}"),
        }
    }

    /// Streamed framed infer: send one request (optionally with a
    /// multiplexing id), collect `KIND_INFER_CHUNK` frames until the
    /// terminal `KIND_INFER_REP`, and reassemble the full reply.
    /// Returns the reply, the concatenated predictions, and the raw
    /// chunks (so tests can pin the chunking itself).
    #[allow(clippy::type_complexity)]
    pub fn infer_bin_stream(
        &mut self,
        req: &super::InferRequest,
        id: Option<&ReqId>,
    ) -> Result<(InferReply, Vec<i32>, Vec<frame::InferChunk>)> {
        let mut buf = Vec::new();
        frame::encode_infer_request_id(req, id, &mut buf);
        self.writer.write_all(&buf)?;
        self.writer.flush()?;
        let mut chunks: Vec<frame::InferChunk> = Vec::new();
        loop {
            match self.reader.next() {
                Incoming::Frame(frame::KIND_INFER_CHUNK) => {
                    let c = frame::decode_infer_chunk(self.reader.payload())
                        .map_err(|e| anyhow::anyhow!("bad chunk frame: {e}"))?;
                    chunks.push(c);
                }
                Incoming::Frame(frame::KIND_INFER_REP) => {
                    let (mut reply, mut preds, _id) =
                        frame::decode_infer_reply_id(self.reader.payload())
                            .map_err(|e| anyhow::anyhow!("bad reply frame: {e}"))?;
                    if !chunks.is_empty() {
                        let cols = chunks[0].logits.last_dim().max(1);
                        let mut data = Vec::new();
                        let mut all = Vec::new();
                        for c in &chunks {
                            data.extend_from_slice(&c.logits.data);
                            all.extend_from_slice(&c.preds);
                        }
                        reply.logits = Arr::new(vec![data.len() / cols, cols], data);
                        preds = all;
                    }
                    return Ok((reply, preds, chunks));
                }
                Incoming::Frame(k) => anyhow::bail!("unexpected frame kind {k}"),
                Incoming::Line => {
                    let j: Json = self
                        .reader
                        .line()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
                    anyhow::bail!(
                        "infer failed: {}",
                        j.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error")
                    )
                }
                Incoming::Eof => anyhow::bail!("connection closed"),
                Incoming::TooLarge { .. } => anyhow::bail!("oversized response"),
                Incoming::Corrupt(e) => anyhow::bail!("corrupt response: {e}"),
            }
        }
    }
}
