//! The `bin1` binary frame: length-prefixed, CRC-checked tensor payloads
//! for the serving hot path.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       1     marker 0xBF   (invalid as a UTF-8 first byte, so a
//!                              frame can never be confused with a
//!                              JSON-lines request; the reader peeks
//!                              one byte to pick the decoder)
//! 1       1     magic  'Q'
//! 2       1     version (1)
//! 3       1     kind    (1 = infer request, 2 = infer reply)
//! 4       4     payload length N
//! 8       N     payload
//! 8+N     4     CRC32 (IEEE) of the payload bytes
//! ```
//!
//! Payloads carry tensors as `u8 dtype (0 = f32, 1 = i32), u8 ndim,
//! ndim x u32 dims, little-endian body`.  An f32 travels as its raw
//! bits, so the bin1 reply is bit-identical to the JSON reply by
//! construction (JSON text is shortest-roundtrip; bin1 is the bits
//! themselves).  Errors are never framed: every failure is a JSON line
//! regardless of the negotiated mode, so a client can always fall back
//! to the line parser on a non-0xBF first byte.

use crate::coordinator::jobs::InferReply;
use crate::runtime::cpu::ops::Arr;
use crate::tensor::{Data, HostTensor};
use super::InferRequest;

/// First byte of every frame; invalid as a UTF-8 start byte.
pub const MARKER: u8 = 0xBF;
/// Second magic byte.
pub const MAGIC2: u8 = b'Q';
/// Frame format version.
pub const VERSION: u8 = 1;
/// Header bytes before the payload (marker, magic, version, kind, len).
pub const HEADER_LEN: usize = 8;
/// Trailing CRC bytes.
pub const CRC_LEN: usize = 4;

/// Frame kinds.
pub const KIND_INFER_REQ: u8 = 1;
pub const KIND_INFER_REP: u8 = 2;
/// One chunk of a streamed infer reply (negotiated by
/// `{"cmd":"hello","wire":"bin1","stream":true}`); the terminal frame
/// is a regular `KIND_INFER_REP` with empty logits.
pub const KIND_INFER_CHUNK: u8 = 3;

const DTYPE_F32: u8 = 0;
const DTYPE_I32: u8 = 1;
const MAX_NDIM: usize = 8;

/// Tags for the optional trailing request id (absent entirely on
/// id-less frames, so pre-multiplex payloads decode unchanged).
const ID_NUM: u8 = 0;
const ID_STR: u8 = 1;

// -- CRC32 (IEEE 802.3, poly 0xEDB88320) ------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0usize;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// -- frame assembly ----------------------------------------------------------

/// Start a frame in `out` (cleared): header with a length placeholder.
/// Append the payload, then call [`finish`].
pub fn begin(out: &mut Vec<u8>, kind: u8) {
    out.clear();
    out.extend_from_slice(&[MARKER, MAGIC2, VERSION, kind, 0, 0, 0, 0]);
}

/// Patch the payload length and append the CRC.
pub fn finish(out: &mut Vec<u8>) {
    let len = (out.len() - HEADER_LEN) as u32;
    out[4..8].copy_from_slice(&len.to_le_bytes());
    let crc = crc32(&out[HEADER_LEN..]);
    out.extend_from_slice(&crc.to_le_bytes());
}

// -- payload writers ---------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
}

fn put_tensor_header(out: &mut Vec<u8>, dtype: u8, shape: &[usize]) {
    out.push(dtype);
    out.push(shape.len() as u8);
    for &d in shape {
        put_u32(out, d as u32);
    }
}

fn put_tensor(out: &mut Vec<u8>, shape: &[usize], data: &Data) {
    match data {
        Data::F32(v) => {
            put_tensor_header(out, DTYPE_F32, shape);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Data::I32(v) => {
            put_tensor_header(out, DTYPE_I32, shape);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

fn put_id(out: &mut Vec<u8>, id: Option<&super::ReqId>) {
    match id {
        None => {}
        Some(super::ReqId::Num(n)) => {
            out.push(ID_NUM);
            out.extend_from_slice(&n.to_le_bytes());
        }
        Some(super::ReqId::Str(s)) => {
            out.push(ID_STR);
            put_str(out, s);
        }
    }
}

/// Read the optional trailing id: only present if payload bytes remain.
fn read_opt_id(r: &mut ByteReader) -> Result<Option<super::ReqId>, String> {
    if r.remaining() == 0 {
        return Ok(None);
    }
    match r.u8()? {
        ID_NUM => Ok(Some(super::ReqId::Num(r.f64()?))),
        ID_STR => Ok(Some(super::ReqId::Str(r.str()?.to_string()))),
        other => Err(format!("unknown id tag {other}")),
    }
}

/// Encode a complete infer-request frame into `out` (cleared first).
pub fn encode_infer_request(req: &InferRequest, out: &mut Vec<u8>) {
    encode_infer_request_id(req, None, out);
}

/// Infer-request frame with an optional multiplexing id appended.
pub fn encode_infer_request_id(
    req: &InferRequest,
    id: Option<&super::ReqId>,
    out: &mut Vec<u8>,
) {
    begin(out, KIND_INFER_REQ);
    put_str(out, &req.key);
    out.push(req.inputs.len() as u8);
    for t in &req.inputs {
        put_tensor(out, &t.shape, &t.data);
    }
    put_id(out, id);
    finish(out);
}

/// Encode a complete infer-reply frame into `out` (cleared first).
pub fn encode_infer_reply(reply: &InferReply, out: &mut Vec<u8>) {
    encode_infer_reply_id(reply, None, out);
}

/// Infer-reply frame with the echoed request id appended (absent when
/// the request carried none, keeping pre-multiplex frames byte-stable).
pub fn encode_infer_reply_id(reply: &InferReply, id: Option<&super::ReqId>, out: &mut Vec<u8>) {
    begin(out, KIND_INFER_REP);
    put_str(out, &reply.key);
    put_u32(out, reply.rows as u32);
    put_u32(out, reply.int_layers as u32);
    out.extend_from_slice(&reply.seconds.to_le_bytes());
    put_tensor_header(out, DTYPE_F32, &reply.logits.shape);
    for x in &reply.logits.data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    let c = reply.logits.last_dim().max(1);
    let preds: Vec<i32> =
        reply.logits.data.chunks(c).map(|row| super::predict_row(row) as i32).collect();
    put_u32(out, preds.len() as u32);
    for p in &preds {
        out.extend_from_slice(&p.to_le_bytes());
    }
    put_id(out, id);
    finish(out);
}

/// One decoded streamed-reply chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct InferChunk {
    pub key: String,
    pub chunk: usize,
    pub chunks: usize,
    /// Row-major logits, `[nrows, cols]`.
    pub logits: Arr,
    pub preds: Vec<i32>,
    pub id: Option<super::ReqId>,
}

/// Encode one streamed-reply chunk: `rows` holds `nrows * cols`
/// row-major logits of this chunk.
pub fn encode_infer_chunk(
    key: &str,
    chunk: usize,
    chunks: usize,
    rows: &[f32],
    cols: usize,
    id: Option<&super::ReqId>,
    out: &mut Vec<u8>,
) {
    let c = cols.max(1);
    begin(out, KIND_INFER_CHUNK);
    put_str(out, key);
    put_u32(out, chunk as u32);
    put_u32(out, chunks as u32);
    put_tensor_header(out, DTYPE_F32, &[rows.len() / c, c]);
    for x in rows {
        out.extend_from_slice(&x.to_le_bytes());
    }
    let preds: Vec<i32> = rows.chunks(c).map(|row| super::predict_row(row) as i32).collect();
    put_u32(out, preds.len() as u32);
    for p in &preds {
        out.extend_from_slice(&p.to_le_bytes());
    }
    put_id(out, id);
    finish(out);
}

/// Decode a streamed-reply chunk payload.
pub fn decode_infer_chunk(payload: &[u8]) -> Result<InferChunk, String> {
    let mut r = ByteReader::new(payload);
    let key = r.str()?.to_string();
    let chunk = r.u32()? as usize;
    let chunks = r.u32()? as usize;
    let (dtype, shape, n) = read_shape(&mut r)?;
    if dtype != DTYPE_F32 {
        return Err("chunk logits must be f32".into());
    }
    let logits = Arr::new(shape, r.f32s(n)?);
    let npred = r.u32()? as usize;
    let preds = r.i32s(npred)?;
    let id = read_opt_id(&mut r)?;
    r.expect_end()?;
    Ok(InferChunk { key, chunk, chunks, logits, preds, id })
}

// -- payload readers ---------------------------------------------------------

/// Bounds-checked little-endian cursor over a frame payload.
pub struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(b: &'a [u8]) -> ByteReader<'a> {
        ByteReader { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let s = self
            .b
            .get(self.i..self.i + n)
            .ok_or_else(|| format!("truncated payload at {}", self.i))?;
        self.i += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<&'a str, String> {
        let n = self.u16()? as usize;
        std::str::from_utf8(self.take(n)?).map_err(|_| "bad utf8 in payload".into())
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let raw = self.take(n.checked_mul(4).ok_or("tensor size overflow")?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn i32s(&mut self, n: usize) -> Result<Vec<i32>, String> {
        let raw = self.take(n.checked_mul(4).ok_or("tensor size overflow")?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Unconsumed payload bytes (the optional trailing id is present
    /// iff this is nonzero after the fixed fields).
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Every payload byte must be consumed: trailing garbage is corruption.
    pub fn expect_end(&self) -> Result<(), String> {
        if self.i != self.b.len() {
            return Err(format!("{} trailing payload bytes", self.b.len() - self.i));
        }
        Ok(())
    }
}

fn read_shape(r: &mut ByteReader) -> Result<(u8, Vec<usize>, usize), String> {
    let dtype = r.u8()?;
    let ndim = r.u8()? as usize;
    if ndim > MAX_NDIM {
        return Err(format!("tensor rank {ndim} exceeds {MAX_NDIM}"));
    }
    let mut shape = Vec::with_capacity(ndim);
    let mut n = 1usize;
    for _ in 0..ndim {
        let d = r.u32()? as usize;
        n = n.checked_mul(d).ok_or("tensor size overflow")?;
        shape.push(d);
    }
    Ok((dtype, shape, n))
}

fn read_tensor(r: &mut ByteReader) -> Result<HostTensor, String> {
    let (dtype, shape, n) = read_shape(r)?;
    match dtype {
        DTYPE_F32 => Ok(HostTensor::f32(shape, r.f32s(n)?)),
        DTYPE_I32 => Ok(HostTensor::i32(shape, r.i32s(n)?)),
        other => Err(format!("unknown dtype {other}")),
    }
}

/// Decode an infer-request payload (the bytes between header and CRC),
/// dropping any multiplexing id.
pub fn decode_infer_request(payload: &[u8]) -> Result<InferRequest, String> {
    Ok(decode_infer_request_id(payload)?.0)
}

/// Decode an infer-request payload plus its optional trailing id.
pub fn decode_infer_request_id(
    payload: &[u8],
) -> Result<(InferRequest, Option<super::ReqId>), String> {
    let mut r = ByteReader::new(payload);
    let key = r.str()?.to_string();
    let ntensors = r.u8()? as usize;
    let mut inputs = Vec::with_capacity(ntensors);
    for _ in 0..ntensors {
        inputs.push(read_tensor(&mut r)?);
    }
    let id = read_opt_id(&mut r)?;
    r.expect_end()?;
    Ok((InferRequest { key, inputs }, id))
}

/// Decode an infer-reply payload; returns the reply plus the
/// server-computed predictions (the JSON path derives them from the
/// logits, so clients get the same values either way).  Any echoed id
/// is dropped — see [`decode_infer_reply_id`].
pub fn decode_infer_reply(payload: &[u8]) -> Result<(InferReply, Vec<i32>), String> {
    let (reply, preds, _id) = decode_infer_reply_id(payload)?;
    Ok((reply, preds))
}

/// Decode an infer-reply payload plus its optional echoed id.
#[allow(clippy::type_complexity)]
pub fn decode_infer_reply_id(
    payload: &[u8],
) -> Result<(InferReply, Vec<i32>, Option<super::ReqId>), String> {
    let mut r = ByteReader::new(payload);
    let key = r.str()?.to_string();
    let rows = r.u32()? as usize;
    let int_layers = r.u32()? as usize;
    let seconds = r.f64()?;
    let (dtype, shape, n) = read_shape(&mut r)?;
    if dtype != DTYPE_F32 {
        return Err("logits must be f32".into());
    }
    let logits = Arr::new(shape, r.f32s(n)?);
    let npred = r.u32()? as usize;
    let preds = r.i32s(npred)?;
    let id = read_opt_id(&mut r)?;
    r.expect_end()?;
    Ok((InferReply { key, logits, rows, int_layers, seconds }, preds, id))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_test_vector() {
        // the canonical IEEE CRC32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn infer_request_roundtrip() {
        let req = InferRequest {
            key: "mlp3-int8".into(),
            inputs: vec![
                HostTensor::f32(vec![2, 3], vec![1.0, -2.5, 0.0, f32::MIN, f32::MAX, 3.25]),
                HostTensor::i32(vec![2], vec![-7, 40]),
            ],
        };
        let mut buf = Vec::new();
        encode_infer_request(&req, &mut buf);
        assert_eq!(buf[0], MARKER);
        assert_eq!(buf[3], KIND_INFER_REQ);
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        assert_eq!(buf.len(), HEADER_LEN + len + CRC_LEN);
        let payload = &buf[HEADER_LEN..HEADER_LEN + len];
        let crc = u32::from_le_bytes(buf[HEADER_LEN + len..].try_into().unwrap());
        assert_eq!(crc, crc32(payload));
        let back = decode_infer_request(payload).unwrap();
        assert_eq!(back.key, req.key);
        assert_eq!(back.inputs, req.inputs);
    }

    #[test]
    fn infer_reply_roundtrip_is_bit_exact() {
        let reply = InferReply {
            key: "k".into(),
            logits: Arr::new(vec![2, 2], vec![0.1, 0.7, -0.3, f32::EPSILON]),
            rows: 2,
            int_layers: 3,
            seconds: 0.125,
        };
        let mut buf = Vec::new();
        encode_infer_reply(&reply, &mut buf);
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        let (back, preds) = decode_infer_reply(&buf[HEADER_LEN..HEADER_LEN + len]).unwrap();
        assert_eq!(back.key, reply.key);
        assert_eq!(back.rows, 2);
        assert_eq!(back.int_layers, 3);
        assert_eq!(back.seconds.to_bits(), reply.seconds.to_bits());
        assert_eq!(back.logits.shape, reply.logits.shape);
        let bits: Vec<u32> = back.logits.data.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = reply.logits.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want);
        assert_eq!(preds, vec![1, 1], "argmax per row");
    }

    #[test]
    fn request_and_reply_ids_roundtrip() {
        use crate::proto::ReqId;
        let req = InferRequest { key: "k".into(), inputs: vec![HostTensor::f32(vec![1], vec![1.0])] };
        for id in [ReqId::Num(42.0), ReqId::Str("abc-7".into())] {
            let mut buf = Vec::new();
            encode_infer_request_id(&req, Some(&id), &mut buf);
            let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
            let (back, got) = decode_infer_request_id(&buf[HEADER_LEN..HEADER_LEN + len]).unwrap();
            assert_eq!(back.key, req.key);
            assert_eq!(got.as_ref(), Some(&id));
        }
        let reply = InferReply {
            key: "k".into(),
            logits: Arr::new(vec![1, 2], vec![0.5, -0.5]),
            rows: 1,
            int_layers: 1,
            seconds: 0.25,
        };
        let mut buf = Vec::new();
        encode_infer_reply_id(&reply, Some(&ReqId::Str("r1".into())), &mut buf);
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        let (_, _, id) = decode_infer_reply_id(&buf[HEADER_LEN..HEADER_LEN + len]).unwrap();
        assert_eq!(id, Some(ReqId::Str("r1".into())));
        // id-less frames still decode through the tolerant wrappers
        let mut plain = Vec::new();
        encode_infer_reply(&reply, &mut plain);
        let len = u32::from_le_bytes(plain[4..8].try_into().unwrap()) as usize;
        let (_, _, id) = decode_infer_reply_id(&plain[HEADER_LEN..HEADER_LEN + len]).unwrap();
        assert_eq!(id, None);
    }

    #[test]
    fn chunk_frame_roundtrip() {
        use crate::proto::ReqId;
        let rows = vec![0.1f32, 0.9, -1.0, 2.0, 0.0, 0.5];
        let mut buf = Vec::new();
        encode_infer_chunk("k", 1, 3, &rows, 2, Some(&ReqId::Num(5.0)), &mut buf);
        assert_eq!(buf[3], KIND_INFER_CHUNK);
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        let c = decode_infer_chunk(&buf[HEADER_LEN..HEADER_LEN + len]).unwrap();
        assert_eq!(c.key, "k");
        assert_eq!((c.chunk, c.chunks), (1, 3));
        assert_eq!(c.logits.shape, vec![3, 2]);
        let bits: Vec<u32> = c.logits.data.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = rows.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want, "chunk logits are bit-exact");
        assert_eq!(c.preds, vec![1, 1, 1], "argmax per chunk row");
        assert_eq!(c.id, Some(ReqId::Num(5.0)));
    }

    #[test]
    fn corrupt_payload_is_rejected() {
        let req = InferRequest { key: "k".into(), inputs: vec![HostTensor::f32(vec![1], vec![1.0])] };
        let mut buf = Vec::new();
        encode_infer_request(&req, &mut buf);
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        // truncated payload
        assert!(decode_infer_request(&buf[HEADER_LEN..HEADER_LEN + len - 2]).is_err());
        // trailing garbage
        let mut long = buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        long.push(0);
        assert!(decode_infer_request(&long).is_err());
    }
}
