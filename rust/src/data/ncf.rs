//! Synthetic implicit-feedback dataset for NCF (MovieLens-1B stand-in).
//!
//! Generative model: latent user/item factors `u, v ~ N(0, I_8/√8)` plus an
//! item popularity bias; the affinity is `2.5·u·v + pop`.  For each user we
//! sample `k` positive items by affinity-weighted softmax sampling.
//! Evaluation follows the mlperf NCF protocol: per user, one held-out
//! positive is ranked against 99 sampled negatives (hit-rate@10).

use crate::tensor::HostTensor;
use crate::util::rng::Pcg32;

pub const DIM: usize = 8;

pub struct SynthNcf {
    pub n_users: usize,
    pub n_items: usize,
    user_f: Vec<f32>,
    item_f: Vec<f32>,
    pop: Vec<f32>,
    /// positives per user: [user][k]
    pub positives: Vec<Vec<u32>>,
    /// last positive per user, held out for eval
    pub holdout: Vec<u32>,
    seed: u64,
}

impl SynthNcf {
    pub fn new(seed: u64, n_users: usize, n_items: usize, pos_per_user: usize) -> Self {
        let mut rng = Pcg32::new(seed, 0x4ecf);
        let scale = (1.0 / DIM as f32).sqrt();
        let user_f: Vec<f32> = (0..n_users * DIM).map(|_| rng.normal() * scale).collect();
        let item_f: Vec<f32> = (0..n_items * DIM).map(|_| rng.normal() * scale).collect();
        let pop: Vec<f32> = (0..n_items).map(|_| rng.normal() * 0.5).collect();

        let mut positives = Vec::with_capacity(n_users);
        let mut holdout = Vec::with_capacity(n_users);
        for u in 0..n_users {
            // affinity-weighted sampling without replacement via Gumbel-top-k
            let uf = &user_f[u * DIM..(u + 1) * DIM];
            let mut keyed: Vec<(f32, u32)> = (0..n_items)
                .map(|i| {
                    let vf = &item_f[i * DIM..(i + 1) * DIM];
                    let aff: f32 = uf.iter().zip(vf).map(|(a, b)| a * b).sum::<f32>() * 2.5
                        + pop[i];
                    let gumbel = -(-rng.uniform().max(1e-9).ln()).ln();
                    (aff + 0.8 * gumbel, i as u32)
                })
                .collect();
            keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            let mut pos: Vec<u32> = keyed[..pos_per_user + 1].iter().map(|k| k.1).collect();
            holdout.push(pos.pop().unwrap());
            positives.push(pos);
        }
        SynthNcf { n_users, n_items, user_f, item_f, pop, positives, holdout, seed }
    }

    /// Training batch of (users, items, labels) with `neg_ratio` sampled
    /// negatives per positive.  Deterministic in `epoch_index`.
    pub fn train_batch(
        &self,
        epoch_index: u64,
        n: usize,
        neg_ratio: usize,
    ) -> (HostTensor, HostTensor, HostTensor) {
        let mut rng = Pcg32::new(self.seed ^ epoch_index.wrapping_mul(0x2545f491), 0x7ea1);
        let mut users = Vec::with_capacity(n);
        let mut items = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let u = rng.below(self.n_users as u32);
            let pos_list = &self.positives[u as usize];
            if rng.below((neg_ratio + 1) as u32) == 0 {
                // positive
                let p = pos_list[rng.below(pos_list.len() as u32) as usize];
                users.push(u as i32);
                items.push(p as i32);
                labels.push(1.0);
            } else {
                // negative: rejection-sample an item not in the positives
                let mut it = rng.below(self.n_items as u32);
                let mut guard = 0;
                while (pos_list.contains(&it) || self.holdout[u as usize] == it) && guard < 16 {
                    it = rng.below(self.n_items as u32);
                    guard += 1;
                }
                users.push(u as i32);
                items.push(it as i32);
                labels.push(0.0);
            }
        }
        (
            HostTensor::i32(vec![n], users),
            HostTensor::i32(vec![n], items),
            HostTensor::f32(vec![n], labels),
        )
    }

    /// mlperf eval batch: `n` users starting at `start`, each with the
    /// held-out positive and 99 negatives.  Returns (users, pos, negs).
    pub fn eval_batch(&self, start: usize, n: usize) -> (HostTensor, HostTensor, HostTensor) {
        let mut rng = Pcg32::new(self.seed ^ 0xeba1, 0x99);
        let mut users = Vec::with_capacity(n);
        let mut pos = Vec::with_capacity(n);
        let mut negs = Vec::with_capacity(n * 99);
        for k in 0..n {
            let u = (start + k) % self.n_users;
            users.push(u as i32);
            pos.push(self.holdout[u] as i32);
            let pos_list = &self.positives[u];
            let mut count = 0;
            while count < 99 {
                let it = rng.below(self.n_items as u32);
                if it != self.holdout[u] && !pos_list.contains(&it) {
                    negs.push(it as i32);
                    count += 1;
                }
            }
        }
        (
            HostTensor::i32(vec![n], users),
            HostTensor::i32(vec![n], pos),
            HostTensor::i32(vec![n, 99], negs),
        )
    }

    /// Oracle hit-rate@10 using the true latent factors — the ceiling any
    /// learned model can approach (used to sanity-check training).
    pub fn oracle_hitrate(&self, n_users: usize) -> f32 {
        let (users, pos, negs) = self.eval_batch(0, n_users);
        let mut hits = 0;
        for k in 0..n_users {
            let u = users.i()[k] as usize;
            let uf = &self.user_f[u * DIM..(u + 1) * DIM];
            let score = |i: usize| -> f32 {
                let vf = &self.item_f[i * DIM..(i + 1) * DIM];
                uf.iter().zip(vf).map(|(a, b)| a * b).sum::<f32>() * 2.5 + self.pop[i]
            };
            let sp = score(pos.i()[k] as usize);
            let rank = (0..99)
                .filter(|&j| score(negs.i()[k * 99 + j] as usize) > sp)
                .count();
            if rank < 10 {
                hits += 1;
            }
        }
        hits as f32 / n_users as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SynthNcf {
        SynthNcf::new(3, 200, 100, 8)
    }

    #[test]
    fn shapes_and_ranges() {
        let d = small();
        let (u, i, l) = d.train_batch(0, 256, 4);
        assert_eq!(u.shape, vec![256]);
        assert!(u.i().iter().all(|&x| (0..200).contains(&x)));
        assert!(i.i().iter().all(|&x| (0..100).contains(&x)));
        assert!(l.f().iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn label_balance_matches_neg_ratio() {
        let d = small();
        let (_, _, l) = d.train_batch(1, 8192, 4);
        let pos_frac = l.f().iter().sum::<f32>() / 8192.0;
        assert!((pos_frac - 0.2).abs() < 0.03, "{pos_frac}");
    }

    #[test]
    fn eval_batch_protocol() {
        let d = small();
        let (u, p, n) = d.eval_batch(0, 32);
        assert_eq!(n.shape, vec![32, 99]);
        for k in 0..32 {
            let user = u.i()[k] as usize;
            assert_eq!(p.i()[k] as u32, d.holdout[user]);
            for j in 0..99 {
                let neg = n.i()[k * 99 + j] as u32;
                assert_ne!(neg, d.holdout[user]);
                assert!(!d.positives[user].contains(&neg));
            }
        }
    }

    #[test]
    fn oracle_beats_chance() {
        let d = small();
        let hr = d.oracle_hitrate(200);
        // chance = 10/100 = 0.1; the latent model must be far above it
        assert!(hr > 0.4, "oracle hitrate {hr}");
    }

    #[test]
    fn deterministic() {
        let a = SynthNcf::new(5, 100, 80, 4);
        let b = SynthNcf::new(5, 100, 80, 4);
        assert_eq!(a.holdout, b.holdout);
        assert_eq!(a.train_batch(3, 64, 4), b.train_batch(3, 64, 4));
    }
}
