//! Procedural image-classification dataset ("synthnet").
//!
//! Each of the 10 classes is a fixed low-frequency template (a sum of
//! random 2-D sinusoid planes per channel); a sample is
//! `amp · template + smooth noise + white noise`, per-image standardized.
//! CNNs reach high accuracy after a few hundred SGD steps while staying
//! sensitive to weight perturbation — the property the quantization
//! experiments need.  Deterministic in (seed, index): train/val/calib
//! splits are index ranges, and regeneration is cheap enough that nothing
//! is stored.

use crate::tensor::HostTensor;
use crate::util::rng::Pcg32;

pub const H: usize = 32;
pub const W: usize = 32;
pub const C: usize = 3;
pub const N_CLASSES: usize = 10;

/// Dataset generator (cheap to clone; templates are precomputed).
#[derive(Clone)]
pub struct SynthVision {
    seed: u64,
    templates: Vec<Vec<f32>>, // per class, H*W*C
    /// Template mixing amplitude range.
    pub amp: (f32, f32),
    /// Smooth-noise and white-noise scales.
    pub smooth_noise: f32,
    pub white_noise: f32,
}

impl SynthVision {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0x5e_ed);
        let templates = (0..N_CLASSES).map(|_| Self::template(&mut rng)).collect();
        // Noise scales tuned so a few-hundred-step CNN lands in the high
        // 80s/low 90s — leaving visible headroom for quantization damage
        // (the paper's models sit at 69–77% on ImageNet).
        SynthVision { seed, templates, amp: (0.35, 0.9), smooth_noise: 0.9, white_noise: 0.9 }
    }

    /// Low-frequency template: sum of 6 random sinusoid planes per channel.
    fn template(rng: &mut Pcg32) -> Vec<f32> {
        let mut t = vec![0.0f32; H * W * C];
        for c in 0..C {
            for _ in 0..6 {
                let fx = rng.range(0.3, 2.5);
                let fy = rng.range(0.3, 2.5);
                let phase = rng.range(0.0, std::f32::consts::TAU);
                let amp = rng.range(0.4, 1.0);
                for y in 0..H {
                    for x in 0..W {
                        let v = (fx * x as f32 / W as f32 * std::f32::consts::TAU
                            + fy * y as f32 / H as f32 * std::f32::consts::TAU
                            + phase)
                            .sin();
                        t[(y * W + x) * C + c] += amp * v;
                    }
                }
            }
        }
        // standardize the template
        let m = crate::util::stats::mean(&t);
        let s = crate::util::stats::std_dev(&t).max(1e-6);
        for v in &mut t {
            *v = (*v - m) / s;
        }
        t
    }

    /// Deterministic (image, label) for a global sample index.
    pub fn sample(&self, index: u64) -> (Vec<f32>, i32) {
        let mut rng = Pcg32::new(self.seed ^ (index.wrapping_mul(0x9e3779b97f4a7c15)), 0xda7a);
        let label = rng.below(N_CLASSES as u32) as usize;
        let tmpl = &self.templates[label];
        let amp = rng.range(self.amp.0, self.amp.1);
        // smooth noise: one random sinusoid plane shared across channels
        let fx = rng.range(0.5, 3.0);
        let fy = rng.range(0.5, 3.0);
        let phase = rng.range(0.0, std::f32::consts::TAU);
        let mut img = vec![0.0f32; H * W * C];
        for y in 0..H {
            for x in 0..W {
                let sm = (fx * x as f32 / W as f32 * std::f32::consts::TAU
                    + fy * y as f32 / H as f32 * std::f32::consts::TAU
                    + phase)
                    .sin();
                for c in 0..C {
                    let i = (y * W + x) * C + c;
                    img[i] = amp * tmpl[i] + self.smooth_noise * sm + self.white_noise * rng.normal();
                }
            }
        }
        (img, label as i32)
    }

    /// Batch of `n` samples starting at `start` as (x, y) host tensors
    /// shaped `(n, H, W, C)` / `(n,)`.
    pub fn batch(&self, start: u64, n: usize) -> (HostTensor, HostTensor) {
        let mut xs = Vec::with_capacity(n * H * W * C);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let (img, label) = self.sample(start + i as u64);
            xs.extend_from_slice(&img);
            ys.push(label);
        }
        (HostTensor::f32(vec![n, H, W, C], xs), HostTensor::i32(vec![n], ys))
    }

    /// Flattened-feature batch for the MLP model: `(n, d)` where `d` is a
    /// random-projection of the image to `dim` features (deterministic).
    pub fn batch_features(&self, start: u64, n: usize, dim: usize) -> (HostTensor, HostTensor) {
        let mut proj_rng = Pcg32::new(self.seed ^ 0xfeed, 0x11);
        let d_in = H * W * C;
        let scale = (1.0 / d_in as f32).sqrt();
        let proj: Vec<f32> = (0..d_in * dim).map(|_| proj_rng.normal() * scale).collect();
        let mut xs = Vec::with_capacity(n * dim);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let (img, label) = self.sample(start + i as u64);
            for j in 0..dim {
                let mut acc = 0.0f32;
                for k in 0..d_in {
                    acc += img[k] * proj[k * dim + j];
                }
                xs.push(acc);
            }
            ys.push(label);
        }
        (HostTensor::f32(vec![n, dim], xs), HostTensor::i32(vec![n], ys))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d1 = SynthVision::new(5);
        let d2 = SynthVision::new(5);
        assert_eq!(d1.sample(123), d2.sample(123));
        assert_ne!(d1.sample(1).0, d1.sample(2).0);
    }

    #[test]
    fn seeds_change_templates() {
        let a = SynthVision::new(1).sample(0);
        let b = SynthVision::new(2).sample(0);
        assert_ne!(a.0, b.0);
    }

    #[test]
    fn batch_shapes() {
        let d = SynthVision::new(7);
        let (x, y) = d.batch(0, 16);
        assert_eq!(x.shape, vec![16, H, W, C]);
        assert_eq!(y.shape, vec![16]);
        assert!(y.i().iter().all(|&l| (0..N_CLASSES as i32).contains(&l)));
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = SynthVision::new(9);
        let (_, y) = d.batch(0, 2000);
        let mut counts = [0usize; N_CLASSES];
        for &l in y.i() {
            counts[l as usize] += 1;
        }
        for c in counts {
            assert!(c > 100, "{counts:?}");
        }
    }

    #[test]
    fn class_signal_dominates_noise() {
        // nearest-template classification on raw pixels should beat chance
        // by a wide margin — the dataset is learnable.
        let d = SynthVision::new(11);
        let mut correct = 0;
        let n = 200;
        for i in 0..n {
            let (img, label) = d.sample(i);
            let mut best = (f32::INFINITY, 0usize);
            for (c, t) in d.templates.iter().enumerate() {
                let dist: f32 = img.iter().zip(t).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == label as usize {
                correct += 1;
            }
        }
        assert!(correct as f32 / n as f32 > 0.6, "{correct}/{n}");
    }

    #[test]
    fn feature_batch_shape() {
        let d = SynthVision::new(13);
        let (x, y) = d.batch_features(0, 8, 64);
        assert_eq!(x.shape, vec![8, 64]);
        assert_eq!(y.shape, vec![8]);
    }
}
