//! Synthetic data substrates standing in for the paper's datasets
//! (ImageNet → procedural textures; MovieLens-1B → latent-factor implicit
//! feedback).  See DESIGN.md §Substitutions for why these preserve the
//! behaviour the paper measures.

pub mod batcher;
pub mod ncf;
pub mod vision;
