//! Index-space batching: split a sample range into train/val/calibration
//! streams with deterministic per-epoch shuffling.  Works for any
//! generator addressed by global sample index (both data substrates are).

use crate::util::rng::Pcg32;

/// A named contiguous split of the global index space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Split {
    pub start: u64,
    pub len: u64,
}

impl Split {
    pub fn indices(&self) -> std::ops::Range<u64> {
        self.start..self.start + self.len
    }
}

/// Standard layout: disjoint train / val / calibration ranges.
#[derive(Clone, Copy, Debug)]
pub struct Splits {
    pub train: Split,
    pub val: Split,
    pub calib: Split,
}

impl Splits {
    /// `calib_len` samples are carved from *held-back* space after val —
    /// the paper's calibration set is disjoint from both.
    pub fn new(train_len: u64, val_len: u64, calib_len: u64) -> Self {
        Splits {
            train: Split { start: 0, len: train_len },
            val: Split { start: train_len, len: val_len },
            calib: Split { start: train_len + val_len, len: calib_len },
        }
    }
}

/// Deterministic shuffled batch iterator over a split.
pub struct Batcher {
    order: Vec<u64>,
    batch: usize,
    cursor: usize,
    epoch: u64,
    split: Split,
    seed: u64,
}

impl Batcher {
    pub fn new(split: Split, batch: usize, seed: u64) -> Self {
        let mut b = Batcher { order: Vec::new(), batch, cursor: 0, epoch: 0, split, seed };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.order = self.split.indices().collect();
        let mut rng = Pcg32::new(self.seed ^ self.epoch.wrapping_mul(0x9e37), 0xba7c);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next batch of indices; rolls into a new shuffled epoch when the
    /// split is exhausted (batches never straddle epochs).
    pub fn next_indices(&mut self) -> &[u64] {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let out = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_disjoint() {
        let s = Splits::new(100, 50, 25);
        assert_eq!(s.train.indices().end, s.val.indices().start);
        assert_eq!(s.val.indices().end, s.calib.indices().start);
        assert_eq!(s.calib.len, 25);
    }

    #[test]
    fn batches_cover_epoch_exactly() {
        let mut b = Batcher::new(Split { start: 10, len: 64 }, 16, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            for &i in b.next_indices() {
                assert!((10..74).contains(&i));
                assert!(seen.insert(i), "dup {i}");
            }
        }
        assert_eq!(seen.len(), 64);
        assert_eq!(b.epoch(), 0);
        b.next_indices();
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let collect = || {
            let mut b = Batcher::new(Split { start: 0, len: 32 }, 8, 7);
            let mut all = Vec::new();
            for _ in 0..8 {
                all.extend_from_slice(b.next_indices());
            }
            all
        };
        let a = collect();
        let b = collect();
        assert_eq!(a, b);
        // epoch 0 and epoch 1 orders differ
        assert_ne!(a[..32], a[32..]);
    }
}
