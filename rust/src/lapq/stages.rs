//! The pluggable stage vocabulary of the calibration API: initialization
//! strategies (paper §4.1–4.2), joint optimizers (§4.3) and post stages
//! (bias correction), each behind a trait so `Calibrator` can compose
//! them freely.
//!
//! Mapping to paper Algorithm 1:
//! * lines 1–8 (layer-wise L_p per p in the grid)  → [`LayerwiseLp`]
//! * lines 9–12 (quadratic interpolation over p)   → [`QuadraticPStar`]
//! * ablation inits (Table 3)                      → [`RandomInit`]
//! * small-model collapse guard                    → [`MinMaxFallback`]
//! * lines 13–21 (joint minimization)              → [`JointOptimizer`]
//!   ([`PowellJoint`], [`NelderMeadJoint`], [`CoordinateDescentJoint`])
//! * Banner-style weight correction                → [`BiasCorrection`]

use super::calibration::CalibData;
use super::calibrator::QuantOutcome;
use super::events::{CalibEvent, CalibObserver};
use super::objective::{CalibObjective, LayerMask};
use crate::config::{BitSpec, ExperimentConfig, JointCfg, JointOpt, LapqCfg, Method};
use crate::optim::coordinate::{coordinate_descent, CoordCfg};
use crate::optim::nelder_mead::{nelder_mead, NmCfg};
use crate::optim::powell::{powell, PowellCfg};
use crate::optim::quadfit;
use crate::quant::{aciq, bias_correction, kld, minmax, mmse, GridKind};
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{EngineHandle, SessionId};
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Phase label for the whole init stage (candidates from every strategy
/// compete under one phase).
pub const PHASE_INIT: &str = "init";

// ---------------------------------------------------------------------------
// per-layer Δ construction primitives (shared by strategies and benches)
// ---------------------------------------------------------------------------

/// Per-layer Δ for a given p (Alg. 1 phase 1), for weights and activations.
pub fn layerwise_deltas(
    calib: &CalibData,
    mask: &LayerMask,
    qmw: &[f32],
    qma: &[f32],
    p: f32,
) -> (Vec<f32>, Vec<f32>) {
    let n = mask.weights.len();
    let mut dw = vec![0.0f32; n];
    let mut da = vec![0.0f32; n];
    let search = mmse::LpSearch::default();
    for i in 0..n {
        if mask.weights[i] {
            dw[i] =
                mmse::lp_optimal_delta(calib.weights[i].f(), qmw[i], p, GridKind::Signed, search).0;
        }
        if mask.acts[i] {
            da[i] =
                mmse::lp_optimal_delta(&calib.act_samples[i], qma[i], p, calib.act_kind[i], search)
                    .0;
        }
    }
    (dw, da)
}

/// Baseline per-layer calibrators (Table 1 competitors).  `method` must
/// not be [`Method::Lapq`] — LAPQ is a composition of init strategies
/// plus a joint optimizer, not a per-layer rule.
pub fn baseline_deltas(
    method: Method,
    calib: &CalibData,
    mask: &LayerMask,
    qmw: &[f32],
    qma: &[f32],
    bits: BitSpec,
) -> (Vec<f32>, Vec<f32>) {
    let n = mask.weights.len();
    let mut dw = vec![0.0f32; n];
    let mut da = vec![0.0f32; n];
    for i in 0..n {
        if mask.weights[i] {
            let w = calib.weights[i].f();
            dw[i] = match method {
                Method::Mmse => mmse::mmse_delta(w, qmw[i], GridKind::Signed),
                Method::Aciq => aciq::aciq_delta(w, bits.weights, GridKind::Signed),
                Method::Kld => kld::kld_delta(w, bits.weights, GridKind::Signed),
                Method::MinMax => minmax::minmax_delta(w, qmw[i], GridKind::Signed),
                Method::Lapq => unreachable!("baseline_deltas has no LAPQ rule"),
            };
        }
        if mask.acts[i] {
            let a = &calib.act_samples[i];
            let kind = calib.act_kind[i];
            da[i] = match method {
                Method::Mmse => mmse::mmse_delta(a, qma[i], kind),
                Method::Aciq => aciq::aciq_delta(a, bits.acts, kind),
                Method::Kld => kld::kld_delta(a, bits.acts, kind),
                Method::MinMax => minmax::minmax_delta(a, qma[i], kind),
                Method::Lapq => unreachable!("baseline_deltas has no LAPQ rule"),
            };
        }
    }
    (dw, da)
}

/// Random initialization for the Table-3 ablation: log-uniform multiple of
/// the min-max step.
pub fn random_deltas(
    calib: &CalibData,
    mask: &LayerMask,
    qmw: &[f32],
    qma: &[f32],
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::seeded(seed);
    let n = mask.weights.len();
    let mut dw = vec![0.0f32; n];
    let mut da = vec![0.0f32; n];
    let mut draw = |base: f32| -> f32 {
        let log_mult = rng.range(-2.3, 1.4); // e^-2.3≈0.1 .. e^1.4≈4
        base * log_mult.exp()
    };
    for i in 0..n {
        if mask.weights[i] {
            dw[i] = draw(minmax::minmax_delta(calib.weights[i].f(), qmw[i], GridKind::Signed));
        }
        if mask.acts[i] {
            da[i] = draw(minmax::minmax_delta(&calib.act_samples[i], qma[i], calib.act_kind[i]));
        }
    }
    (dw, da)
}

// ---------------------------------------------------------------------------
// init strategies
// ---------------------------------------------------------------------------

/// What strategies see while proposing candidates.
pub struct StageCtx<'r, 'e> {
    pub calib: &'r CalibData,
    pub obj: &'r mut CalibObjective<'e>,
    pub lapq: &'r LapqCfg,
    /// Quadratic-interpolation diagnostics (filled by [`QuadraticPStar`],
    /// copied onto `QuantOutcome` by the calibrator).
    pub notes: &'r mut InitNotes,
    pub obs: &'r mut dyn CalibObserver,
    /// Memo of `layerwise_deltas` results keyed by `p.to_bits()`, shared
    /// across strategies: [`LayerwiseLp`] and [`QuadraticPStar`] walk the
    /// same p grid, and the per-layer Lp search is the expensive part
    /// (the loss itself is already memoized inside the objective).
    pub lp_memo: &'r mut std::collections::HashMap<u32, (Vec<f32>, Vec<f32>)>,
}

impl StageCtx<'_, '_> {
    /// Memoized [`layerwise_deltas`] over this run's mask and grids.
    pub fn layerwise(&mut self, p: f32) -> (Vec<f32>, Vec<f32>) {
        if let Some(hit) = self.lp_memo.get(&p.to_bits()) {
            return hit.clone();
        }
        let (dw, da) =
            layerwise_deltas(self.calib, &self.obj.mask, &self.obj.qmw, &self.obj.qma, p);
        self.lp_memo.insert(p.to_bits(), (dw.clone(), da.clone()));
        (dw, da)
    }
}

/// Diagnostics produced by init strategies.
#[derive(Clone, Copy, Debug, Default)]
pub struct InitNotes {
    pub p_star: Option<f64>,
    pub quad_r2: Option<f64>,
}

/// One proposed starting point for the joint phase.
#[derive(Clone, Debug)]
pub struct InitCandidate {
    pub label: String,
    pub dw: Vec<f32>,
    pub da: Vec<f32>,
}

/// An initialization strategy proposes zero or more candidate Δ vectors;
/// the calibrator's best-of selector evaluates the calibration loss of
/// every candidate from every strategy and keeps the winner.
pub trait InitStrategy {
    fn name(&self) -> &'static str;
    fn candidates(&self, ctx: &mut StageCtx<'_, '_>) -> Result<Vec<InitCandidate>>;
}

/// Random steps (paper Table 3 "Random").
pub struct RandomInit {
    pub seed: u64,
}

impl InitStrategy for RandomInit {
    fn name(&self) -> &'static str {
        "random"
    }

    fn candidates(&self, ctx: &mut StageCtx<'_, '_>) -> Result<Vec<InitCandidate>> {
        let (dw, da) =
            random_deltas(ctx.calib, &ctx.obj.mask, &ctx.obj.qmw, &ctx.obj.qma, self.seed);
        Ok(vec![InitCandidate { label: format!("random({})", self.seed), dw, da }])
    }
}

/// Layer-wise L_p minimization, one candidate per p.  `ps: None` means
/// "use the config's `p_grid`" (resolved at run time).
pub struct LayerwiseLp {
    pub ps: Option<Vec<f32>>,
}

impl LayerwiseLp {
    /// The paper's phase-1 sweep over the configured p grid.
    pub fn grid() -> Self {
        LayerwiseLp { ps: None }
    }

    /// Fixed p values (e.g. `[2.0]` for the MMSE-init ablation).
    pub fn fixed(ps: Vec<f32>) -> Self {
        LayerwiseLp { ps: Some(ps) }
    }
}

impl InitStrategy for LayerwiseLp {
    fn name(&self) -> &'static str {
        "layerwise-lp"
    }

    fn candidates(&self, ctx: &mut StageCtx<'_, '_>) -> Result<Vec<InitCandidate>> {
        let ps = self.ps.clone().unwrap_or_else(|| ctx.lapq.p_grid.clone());
        Ok(ps
            .iter()
            .map(|&p| {
                let (dw, da) = ctx.layerwise(p);
                InitCandidate { label: format!("p={p}"), dw, da }
            })
            .collect())
    }
}

/// Quadratic interpolation over the p trajectory (Alg. 1 phase 2): fit
/// L(Δ_p) over p, propose Δ at the vertex p*.  Emits a
/// [`CalibEvent::Degenerate`] warning (and proposes nothing) when the
/// whole trajectory is non-finite — the low-bit collapse plateau on small
/// stand-ins.
pub struct QuadraticPStar {
    pub ps: Option<Vec<f32>>,
}

impl QuadraticPStar {
    pub fn grid() -> Self {
        QuadraticPStar { ps: None }
    }
}

impl InitStrategy for QuadraticPStar {
    fn name(&self) -> &'static str {
        "quadratic-p*"
    }

    fn candidates(&self, ctx: &mut StageCtx<'_, '_>) -> Result<Vec<InitCandidate>> {
        let ps = self.ps.clone().unwrap_or_else(|| ctx.lapq.p_grid.clone());
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &p in &ps {
            let (dw, da) = ctx.layerwise(p);
            let l = ctx.obj.loss(&dw, &da)?;
            if l.is_finite() {
                xs.push(p as f64);
                ys.push(l);
            }
        }
        if xs.is_empty() {
            ctx.obs.on_event(&CalibEvent::Degenerate {
                phase: PHASE_INIT,
                detail: format!(
                    "p-trajectory loss non-finite at all {} grid points; quadratic fit skipped",
                    ps.len()
                ),
            });
            return Ok(Vec::new());
        }
        let Some((pstar, quad)) = quadfit::interpolate_pstar(&xs, &ys) else {
            return Ok(Vec::new());
        };
        ctx.notes.p_star = Some(pstar);
        ctx.notes.quad_r2 = Some(quad.r2);
        let (dw, da) = ctx.layerwise(pstar as f32);
        Ok(vec![InitCandidate { label: format!("p*={pstar:.3}"), dw, da }])
    }
}

/// Min-max (p → ∞) fallback candidate: on small stand-ins the whole
/// finite-p trajectory can sit inside the low-bit collapse plateau while
/// the un-clipped grid survives.
pub struct MinMaxFallback;

impl InitStrategy for MinMaxFallback {
    fn name(&self) -> &'static str {
        "minmax-fallback"
    }

    fn candidates(&self, ctx: &mut StageCtx<'_, '_>) -> Result<Vec<InitCandidate>> {
        // The min-max rule needs no bitwidth, so compute it directly
        // rather than routing through `baseline_deltas`' bits parameter.
        let mask = &ctx.obj.mask;
        let n = mask.weights.len();
        let mut dw = vec![0.0f32; n];
        let mut da = vec![0.0f32; n];
        for i in 0..n {
            if mask.weights[i] {
                dw[i] = minmax::minmax_delta(
                    ctx.calib.weights[i].f(),
                    ctx.obj.qmw[i],
                    GridKind::Signed,
                );
            }
            if mask.acts[i] {
                da[i] = minmax::minmax_delta(
                    &ctx.calib.act_samples[i],
                    ctx.obj.qma[i],
                    ctx.calib.act_kind[i],
                );
            }
        }
        Ok(vec![InitCandidate { label: "minmax".into(), dw, da }])
    }
}

/// A Table-1 baseline (MMSE / ACIQ / KLD / min-max) as a single-candidate
/// init strategy — how `Calibrator::from_config` expresses the non-LAPQ
/// methods (no joint phase).
pub struct BaselineInit {
    pub method: Method,
    pub bits: BitSpec,
}

impl InitStrategy for BaselineInit {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn candidates(&self, ctx: &mut StageCtx<'_, '_>) -> Result<Vec<InitCandidate>> {
        if self.method == Method::Lapq {
            anyhow::bail!(
                "BaselineInit cannot express LAPQ — compose init strategies \
                 (LayerwiseLp/QuadraticPStar/...) plus a joint optimizer instead"
            );
        }
        let (dw, da) = baseline_deltas(
            self.method,
            ctx.calib,
            &ctx.obj.mask,
            &ctx.obj.qmw,
            &ctx.obj.qma,
            self.bits,
        );
        Ok(vec![InitCandidate { label: self.method.name().to_string(), dw, da }])
    }
}

// ---------------------------------------------------------------------------
// joint optimizers
// ---------------------------------------------------------------------------

/// Result of a joint minimization.
#[derive(Clone, Debug)]
pub struct JointResult {
    pub x: Vec<f64>,
    pub fx: f64,
    pub evals: usize,
}

/// A derivative-free box-bounded minimizer with a *fallible* objective:
/// engine errors propagate out of `minimize` instead of being trapped in
/// interior-mutability cells at every call site.
pub trait JointOptimizer {
    fn name(&self) -> &'static str;
    /// Phase label for events/traces ("joint:powell", ...).
    fn phase(&self) -> &'static str;
    fn minimize(
        &self,
        x0: &[f64],
        lo: &[f64],
        hi: &[f64],
        f: &mut dyn FnMut(&[f64]) -> Result<f64>,
    ) -> Result<JointResult>;
}

/// Adapt a fallible objective to the infallible `optim::*` substrate: the
/// first error is stashed and `minimize` returns it afterwards.  After an
/// error the objective is never called again — the optimizer spins down
/// on cheap `+inf` instead of hammering a broken engine for the rest of
/// its eval budget.  `NaN` losses (collapsed nets) are mapped to `+inf`
/// so comparison-based optimizers never see them.
fn with_error_trap<R>(
    f: &mut dyn FnMut(&[f64]) -> Result<f64>,
    run: impl FnOnce(&mut dyn FnMut(&[f64]) -> f64) -> R,
) -> Result<R> {
    let mut err: Option<anyhow::Error> = None;
    let result = {
        let mut g = |x: &[f64]| {
            if err.is_some() {
                return f64::INFINITY;
            }
            match f(x) {
                Ok(v) if v.is_nan() => f64::INFINITY,
                Ok(v) => v,
                Err(e) => {
                    err = Some(e);
                    f64::INFINITY
                }
            }
        };
        run(&mut g)
    };
    match err {
        Some(e) => Err(e),
        None => Ok(result),
    }
}

/// Powell's conjugate-direction method — the paper's joint optimizer.
pub struct PowellJoint {
    pub iters: usize,
    pub max_evals: usize,
}

impl JointOptimizer for PowellJoint {
    fn name(&self) -> &'static str {
        "powell"
    }

    fn phase(&self) -> &'static str {
        "joint:powell"
    }

    fn minimize(
        &self,
        x0: &[f64],
        lo: &[f64],
        hi: &[f64],
        f: &mut dyn FnMut(&[f64]) -> Result<f64>,
    ) -> Result<JointResult> {
        let cfg =
            PowellCfg { max_iter: self.iters, max_evals: self.max_evals, ..Default::default() };
        let r = with_error_trap(f, |g| powell(x0, lo, hi, &cfg, g))?;
        Ok(JointResult { x: r.x, fx: r.fx, evals: r.evals })
    }
}

/// Nelder–Mead downhill simplex (`joint=nm`).
pub struct NelderMeadJoint {
    pub max_evals: usize,
}

impl JointOptimizer for NelderMeadJoint {
    fn name(&self) -> &'static str {
        "nelder-mead"
    }

    fn phase(&self) -> &'static str {
        "joint:nelder-mead"
    }

    fn minimize(
        &self,
        x0: &[f64],
        lo: &[f64],
        hi: &[f64],
        f: &mut dyn FnMut(&[f64]) -> Result<f64>,
    ) -> Result<JointResult> {
        let cfg = NmCfg { max_evals: self.max_evals, ..Default::default() };
        let (x, fx, evals) = with_error_trap(f, |g| nelder_mead(x0, lo, hi, &cfg, g))?;
        Ok(JointResult { x, fx, evals })
    }
}

/// Cyclic coordinate descent (`joint=cd`) — the "purely separable view"
/// ablation of Powell.
pub struct CoordinateDescentJoint {
    pub sweeps: usize,
    pub max_evals: usize,
}

impl JointOptimizer for CoordinateDescentJoint {
    fn name(&self) -> &'static str {
        "coordinate-descent"
    }

    fn phase(&self) -> &'static str {
        "joint:coordinate-descent"
    }

    fn minimize(
        &self,
        x0: &[f64],
        lo: &[f64],
        hi: &[f64],
        f: &mut dyn FnMut(&[f64]) -> Result<f64>,
    ) -> Result<JointResult> {
        let cfg = CoordCfg { sweeps: self.sweeps, max_evals: self.max_evals, ..Default::default() };
        let (x, fx, evals) = with_error_trap(f, |g| coordinate_descent(x0, lo, hi, &cfg, g))?;
        Ok(JointResult { x, fx, evals })
    }
}

/// Instantiate the configured joint optimizer.
pub fn joint_optimizer(cfg: &JointCfg) -> Box<dyn JointOptimizer> {
    match cfg.optimizer {
        JointOpt::Powell => Box::new(PowellJoint { iters: cfg.iters, max_evals: cfg.max_evals }),
        JointOpt::NelderMead => Box::new(NelderMeadJoint { max_evals: cfg.max_evals }),
        JointOpt::CoordinateDescent => {
            Box::new(CoordinateDescentJoint { sweeps: cfg.iters, max_evals: cfg.max_evals })
        }
    }
}

// ---------------------------------------------------------------------------
// post stages
// ---------------------------------------------------------------------------

/// A stage that runs after the Δ search, mutating session params and/or
/// the outcome (bias correction, sharpness-aware re-optimization).  The
/// calibration data is passed so stages can rebuild a loss objective on
/// the same batches the search used.
pub trait PostStage {
    fn name(&self) -> &'static str;
    fn phase(&self) -> &'static str;
    fn apply(
        &self,
        eng: &EngineHandle,
        sess: SessionId,
        spec: &ModelSpec,
        cfg: &ExperimentConfig,
        calib: &CalibData,
        outcome: &mut QuantOutcome,
    ) -> Result<()>;
}

/// Banner-style per-channel bias correction of the session weights for
/// the final Δw (no-op unless weights are quantized).
pub struct BiasCorrection;

impl PostStage for BiasCorrection {
    fn name(&self) -> &'static str {
        "bias-correction"
    }

    fn phase(&self) -> &'static str {
        "post:bias-correction"
    }

    fn apply(
        &self,
        eng: &EngineHandle,
        sess: SessionId,
        spec: &ModelSpec,
        cfg: &ExperimentConfig,
        _calib: &CalibData,
        outcome: &mut QuantOutcome,
    ) -> Result<()> {
        if !cfg.bits.quant_weights() {
            return Ok(());
        }
        let params = eng.get_params(sess)?;
        let mut corrected = params.clone();
        for (i, q) in spec.quant_layers.iter().enumerate() {
            let d = outcome.quant.dw[i];
            if d > 0.0 {
                corrected[q.weight_param] = bias_correction::bias_corrected_weights(
                    &params[q.weight_param],
                    d,
                    outcome.quant.qmw[i],
                );
            }
        }
        eng.set_params(sess, corrected)?;
        outcome.original_params = Some(params);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_obj(target: &[f64]) -> impl FnMut(&[f64]) -> Result<f64> + '_ {
        move |x: &[f64]| Ok(x.iter().zip(target).map(|(a, b)| (a - b) * (a - b)).sum())
    }

    #[test]
    fn optimizers_interchangeable_through_trait() {
        let target = [0.7, 1.6, 0.9];
        let lo = [0.3; 3];
        let hi = [3.0; 3];
        for cfg in [
            JointCfg { optimizer: JointOpt::Powell, iters: 6, max_evals: 4000 },
            JointCfg { optimizer: JointOpt::NelderMead, iters: 6, max_evals: 4000 },
            JointCfg { optimizer: JointOpt::CoordinateDescent, iters: 6, max_evals: 4000 },
        ] {
            let opt = joint_optimizer(&cfg);
            let mut f = quadratic_obj(&target);
            let r = opt.minimize(&[1.0; 3], &lo, &hi, &mut f).unwrap();
            assert!(r.fx < 1e-2, "{} stalled at {}", opt.name(), r.fx);
            for (a, b) in r.x.iter().zip(&target) {
                assert!((a - b).abs() < 0.1, "{}: {:?}", opt.name(), r.x);
            }
        }
    }

    #[test]
    fn objective_errors_propagate() {
        for cfg in [
            JointCfg { optimizer: JointOpt::Powell, ..Default::default() },
            JointCfg { optimizer: JointOpt::NelderMead, ..Default::default() },
            JointCfg { optimizer: JointOpt::CoordinateDescent, ..Default::default() },
        ] {
            let opt = joint_optimizer(&cfg);
            let mut calls = 0usize;
            let mut f = |_x: &[f64]| -> Result<f64> {
                calls += 1;
                anyhow::bail!("engine down")
            };
            let err = opt.minimize(&[1.0; 2], &[0.0; 2], &[2.0; 2], &mut f).unwrap_err();
            assert!(format!("{err}").contains("engine down"), "{}", opt.name());
            // fail-fast: the broken objective is never called again
            assert_eq!(calls, 1, "{} kept hammering a failed objective", opt.name());
        }
    }
}
