//! The LAPQ calibration pipeline (paper §4, Algorithm 1) and the baseline
//! calibrators it is compared against.
//!
//! Phases:
//!   1. **Layer-wise**: for each p in the grid, per-layer Δ_p minimizing
//!      the L_p quantization error (Eq. 12) of weights and activations.
//!   2. **Quadratic approximation**: fit L(Δ_p) over p, take p*.
//!   3. **Joint optimization**: Powell's method over all active layer
//!      steps (multiplicative parameterization around the init), driven by
//!      the compiled `fwd_quant` calibration loss.

use super::calibration::CalibData;
use super::objective::{grids, CalibObjective, LayerMask};
use crate::config::{BitSpec, ExperimentConfig, LapqCfg, Method};
use crate::optim::powell::{powell, PowellCfg};
use crate::optim::quadfit;
use crate::quant::{aciq, bias_correction, kld, minmax, mmse, GridKind};
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{EngineHandle, QuantParams, SessionId};
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Everything a calibration run produces.
#[derive(Clone, Debug)]
pub struct QuantOutcome {
    pub method: Method,
    pub bits: BitSpec,
    pub quant: QuantParams,
    /// Which layers were active in the joint phase (weights/activations),
    /// so `pack` and downstream tooling can tell "masked off" apart from
    /// "calibrated to Δ=0" without re-deriving the config's mask.
    pub mask: LayerMask,
    /// Calibration loss of the final Δ.
    pub calib_loss: f64,
    /// FP32 loss on the same calibration batches.
    pub fp32_calib_loss: f64,
    /// Loss at the initialization (before the joint phase, when run).
    pub init_loss: f64,
    /// Quadratic-interpolation diagnostics (LAPQ only).
    pub p_star: Option<f64>,
    pub quad_r2: Option<f64>,
    /// Joint-phase objective evaluations.
    pub joint_evals: usize,
    pub seconds: f64,
    /// Original (pre-bias-correction) session params, for restoration.
    pub original_params: Option<Vec<crate::tensor::HostTensor>>,
}

/// Initialization strategy for the joint phase (Table 3 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    /// Random steps (paper Table 3 "Random").
    Random(u64),
    /// Layer-wise p=2 (MMSE) only — "LW".
    Layerwise,
    /// Layer-wise + quadratic approximation — "LW + QA" (full LAPQ init).
    LapqQuadratic,
}

/// Which layers count as "first" beyond index 0 (NCF's parallel embedding
/// tables all feed the first dense layer).
fn extra_first_layers(spec: &ModelSpec) -> Vec<usize> {
    spec.quant_layers
        .iter()
        .enumerate()
        .filter(|(_, q)| q.kind == "embed")
        .map(|(i, _)| i)
        .collect()
}

fn build_mask(spec: &ModelSpec, cfg: &ExperimentConfig) -> LayerMask {
    let n = spec.n_quant_layers();
    let mask = LayerMask::all(n, cfg.bits);
    if cfg.lapq.exclude_first_last {
        mask.exclude_first_last(&extra_first_layers(spec))
    } else {
        mask
    }
}

/// Per-layer Δ for a given p (phase 1), for weights and activations.
pub fn layerwise_deltas(calib: &CalibData, mask: &LayerMask, qmw: &[f32], qma: &[f32], p: f32) -> (Vec<f32>, Vec<f32>) {
    let n = mask.weights.len();
    let mut dw = vec![0.0f32; n];
    let mut da = vec![0.0f32; n];
    let search = mmse::LpSearch::default();
    for i in 0..n {
        if mask.weights[i] {
            dw[i] =
                mmse::lp_optimal_delta(calib.weights[i].f(), qmw[i], p, GridKind::Signed, search).0;
        }
        if mask.acts[i] {
            da[i] =
                mmse::lp_optimal_delta(&calib.act_samples[i], qma[i], p, calib.act_kind[i], search)
                    .0;
        }
    }
    (dw, da)
}

/// Baseline per-layer calibrators (Table 1 competitors).
fn baseline_deltas(
    method: Method,
    calib: &CalibData,
    mask: &LayerMask,
    qmw: &[f32],
    qma: &[f32],
    bits: BitSpec,
) -> (Vec<f32>, Vec<f32>) {
    let n = mask.weights.len();
    let mut dw = vec![0.0f32; n];
    let mut da = vec![0.0f32; n];
    for i in 0..n {
        if mask.weights[i] {
            let w = calib.weights[i].f();
            dw[i] = match method {
                Method::Mmse => mmse::mmse_delta(w, qmw[i], GridKind::Signed),
                Method::Aciq => aciq::aciq_delta(w, bits.weights, GridKind::Signed),
                Method::Kld => kld::kld_delta(w, bits.weights, GridKind::Signed),
                Method::MinMax => minmax::minmax_delta(w, qmw[i], GridKind::Signed),
                Method::Lapq => unreachable!(),
            };
        }
        if mask.acts[i] {
            let a = &calib.act_samples[i];
            let kind = calib.act_kind[i];
            da[i] = match method {
                Method::Mmse => mmse::mmse_delta(a, qma[i], kind),
                Method::Aciq => aciq::aciq_delta(a, bits.acts, kind),
                Method::Kld => kld::kld_delta(a, bits.acts, kind),
                Method::MinMax => minmax::minmax_delta(a, qma[i], kind),
                Method::Lapq => unreachable!(),
            };
        }
    }
    (dw, da)
}

/// Random initialization for the Table-3 ablation: log-uniform multiple of
/// the min-max step.
pub fn random_deltas(
    calib: &CalibData,
    mask: &LayerMask,
    qmw: &[f32],
    qma: &[f32],
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::seeded(seed);
    let n = mask.weights.len();
    let mut dw = vec![0.0f32; n];
    let mut da = vec![0.0f32; n];
    let mut draw = |base: f32| -> f32 {
        let log_mult = rng.range(-2.3, 1.4); // e^-2.3≈0.1 .. e^1.4≈4
        base * log_mult.exp()
    };
    for i in 0..n {
        if mask.weights[i] {
            dw[i] = draw(minmax::minmax_delta(calib.weights[i].f(), qmw[i], GridKind::Signed));
        }
        if mask.acts[i] {
            da[i] =
                draw(minmax::minmax_delta(&calib.act_samples[i], qma[i], calib.act_kind[i]));
        }
    }
    (dw, da)
}

/// Phase 3: Powell over multiplicative scalings of the active steps.
pub fn joint_optimize(
    obj: &mut CalibObjective,
    dw0: &[f32],
    da0: &[f32],
    lapq_cfg: &LapqCfg,
) -> Result<(Vec<f32>, Vec<f32>, f64, usize)> {
    let aw = obj.mask.active_w();
    let aa = obj.mask.active_a();
    let dim = aw.len() + aa.len();
    if dim == 0 {
        let l = obj.loss(dw0, da0)?;
        return Ok((dw0.to_vec(), da0.to_vec(), l, 0));
    }
    let dw0v = dw0.to_vec();
    let da0v = da0.to_vec();
    let expand = |x: &[f64]| -> (Vec<f32>, Vec<f32>) {
        let mut dw = dw0v.clone();
        let mut da = da0v.clone();
        for (k, &i) in aw.iter().enumerate() {
            dw[i] = dw0v[i] * x[k] as f32;
        }
        for (k, &i) in aa.iter().enumerate() {
            da[i] = da0v[i] * x[aw.len() + k] as f32;
        }
        (dw, da)
    };

    // Powell body cannot return Result: trap errors and report +inf.
    let mut err: Option<anyhow::Error> = None;
    let result = {
        let obj_cell = std::cell::RefCell::new(&mut *obj);
        let x0 = vec![1.0f64; dim];
        let lo = vec![lapq_cfg.box_lo; dim];
        let hi = vec![lapq_cfg.box_hi; dim];
        let pcfg = PowellCfg {
            max_iter: lapq_cfg.powell_iters,
            max_evals: lapq_cfg.max_evals,
            ..Default::default()
        };
        powell(&x0, &lo, &hi, &pcfg, |x| {
            let (dw, da) = expand(x);
            match obj_cell.borrow_mut().loss(&dw, &da) {
                Ok(v) => v,
                Err(e) => {
                    err = Some(e);
                    f64::INFINITY
                }
            }
        })
    };
    if let Some(e) = err {
        return Err(e);
    }
    let (dw, da) = expand(&result.x);
    Ok((dw, da, result.fx, result.evals))
}

/// Full calibration with an explicit initialization (Table 3 entry point).
pub fn calibrate_with_init(
    eng: &EngineHandle,
    sess: SessionId,
    spec: &ModelSpec,
    cfg: &ExperimentConfig,
    calib: &CalibData,
    init: InitKind,
    run_joint: bool,
) -> Result<QuantOutcome> {
    let t0 = std::time::Instant::now();
    let mask = build_mask(spec, cfg);
    let (qmw, qma) = grids(spec, cfg.bits);
    let mut obj = CalibObjective::new(
        eng,
        sess,
        calib.loss_batches.clone(),
        mask.clone(),
        qmw.clone(),
        qma.clone(),
    );
    let fp32_calib_loss = obj.fp32_loss()?;

    let mut p_star = None;
    let mut quad_r2 = None;
    let (dw0, da0) = match init {
        InitKind::Random(seed) => random_deltas(calib, &mask, &qmw, &qma, seed),
        InitKind::Layerwise => layerwise_deltas(calib, &mask, &qmw, &qma, 2.0),
        InitKind::LapqQuadratic => {
            // phase 1: sample the p trajectory
            let mut ps = Vec::new();
            let mut losses = Vec::new();
            let mut best: Option<(f64, Vec<f32>, Vec<f32>)> = None;
            for &p in &cfg.lapq.p_grid {
                let (dw, da) = layerwise_deltas(calib, &mask, &qmw, &qma, p);
                let l = obj.loss(&dw, &da)?;
                ps.push(p as f64);
                losses.push(l);
                if best.as_ref().map_or(true, |(b, _, _)| l < *b) {
                    best = Some((l, dw, da));
                }
            }
            // min-max (p -> inf) candidate: on small stand-ins the whole
            // finite-p trajectory can sit inside the low-bit collapse
            // plateau while the un-clipped grid survives.
            {
                let (dw, da) =
                    baseline_deltas(Method::MinMax, calib, &mask, &qmw, &qma, cfg.bits);
                let l = obj.loss(&dw, &da)?;
                if best.as_ref().map_or(true, |(b, _, _)| l < *b) {
                    best = Some((l, dw, da));
                }
            }
            // phase 2: quadratic interpolation over p
            if let Some((pstar, quad)) = quadfit::interpolate_pstar(&ps, &losses) {
                p_star = Some(pstar);
                quad_r2 = Some(quad.r2);
                let (dw, da) = layerwise_deltas(calib, &mask, &qmw, &qma, pstar as f32);
                let l = obj.loss(&dw, &da)?;
                if best.as_ref().map_or(true, |(b, _, _)| l < *b) {
                    best = Some((l, dw, da));
                }
            }
            let (_, dw, da) = best.unwrap();
            (dw, da)
        }
    };
    let init_loss = obj.loss(&dw0, &da0)?;

    let (dw, da, calib_loss, joint_evals) = if run_joint {
        joint_optimize(&mut obj, &dw0, &da0, &cfg.lapq)?
    } else {
        (dw0, da0, init_loss, 0)
    };

    let mut outcome = QuantOutcome {
        method: Method::Lapq,
        bits: cfg.bits,
        quant: obj.quant_params(&dw, &da),
        mask: mask.clone(),
        calib_loss,
        fp32_calib_loss,
        init_loss,
        p_star,
        quad_r2,
        joint_evals,
        seconds: t0.elapsed().as_secs_f64(),
        original_params: None,
    };
    maybe_bias_correct(eng, sess, spec, cfg, &mut outcome)?;
    Ok(outcome)
}

/// Calibrate `sess` with the configured method.  On return the session
/// params may be bias-corrected; `outcome.original_params` holds the
/// pristine weights for restoration by the caller.
pub fn calibrate(
    eng: &EngineHandle,
    sess: SessionId,
    spec: &ModelSpec,
    cfg: &ExperimentConfig,
    calib: &CalibData,
) -> Result<QuantOutcome> {
    match cfg.method {
        Method::Lapq => {
            calibrate_with_init(eng, sess, spec, cfg, calib, InitKind::LapqQuadratic, true)
        }
        m => {
            let t0 = std::time::Instant::now();
            let mask = build_mask(spec, cfg);
            let (qmw, qma) = grids(spec, cfg.bits);
            let mut obj = CalibObjective::new(
                eng,
                sess,
                calib.loss_batches.clone(),
                mask.clone(),
                qmw.clone(),
                qma.clone(),
            );
            let fp32_calib_loss = obj.fp32_loss()?;
            let (dw, da) = baseline_deltas(m, calib, &mask, &qmw, &qma, cfg.bits);
            let calib_loss = obj.loss(&dw, &da)?;
            let mut outcome = QuantOutcome {
                method: m,
                bits: cfg.bits,
                quant: obj.quant_params(&dw, &da),
                mask: mask.clone(),
                calib_loss,
                fp32_calib_loss,
                init_loss: calib_loss,
                p_star: None,
                quad_r2: None,
                joint_evals: 0,
                seconds: t0.elapsed().as_secs_f64(),
                original_params: None,
            };
            maybe_bias_correct(eng, sess, spec, cfg, &mut outcome)?;
            Ok(outcome)
        }
    }
}

/// Apply Banner-style per-channel bias correction to the session weights
/// for the final Δw (no-op unless enabled and weights are quantized).
fn maybe_bias_correct(
    eng: &EngineHandle,
    sess: SessionId,
    spec: &ModelSpec,
    cfg: &ExperimentConfig,
    outcome: &mut QuantOutcome,
) -> Result<()> {
    if !cfg.lapq.bias_correction || !cfg.bits.quant_weights() {
        return Ok(());
    }
    let params = eng.get_params(sess)?;
    let mut corrected = params.clone();
    for (i, q) in spec.quant_layers.iter().enumerate() {
        let d = outcome.quant.dw[i];
        if d > 0.0 {
            corrected[q.weight_param] = bias_correction::bias_corrected_weights(
                &params[q.weight_param],
                d,
                outcome.quant.qmw[i],
            );
        }
    }
    eng.set_params(sess, corrected)?;
    outcome.original_params = Some(params);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_kind_eq() {
        assert_eq!(InitKind::Layerwise, InitKind::Layerwise);
        assert_ne!(InitKind::Random(1), InitKind::Layerwise);
    }
}
