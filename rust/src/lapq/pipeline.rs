//! Compatibility wrappers over the [`Calibrator`] API (paper §4,
//! Algorithm 1).  The pipeline used to be hard-wired here; it now lives
//! in three composable pieces:
//!
//! * [`super::stages`] — init strategies, joint optimizers, post stages
//! * [`super::calibrator`] — the [`Calibrator`] builder + runner
//! * [`super::events`] — the observer/event stream
//!
//! `calibrate` / `calibrate_with_init` survive as thin entry points so
//! existing callers (and muscle memory) keep working.

use super::calibration::CalibData;
use super::calibrator::Calibrator;
use super::events::NullObserver;
use crate::config::ExperimentConfig;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{EngineHandle, SessionId};
use anyhow::Result;

pub use super::calibrator::{build_mask, joint_optimize, InitKind, QuantOutcome};
pub use super::stages::{baseline_deltas, layerwise_deltas, random_deltas};

/// Calibrate `sess` with the configured method (the standard composition
/// from [`Calibrator::from_config`]).  On return the session params may
/// be bias-corrected; `outcome.original_params` holds the pristine
/// weights for restoration by the caller.
pub fn calibrate(
    eng: &EngineHandle,
    sess: SessionId,
    spec: &ModelSpec,
    cfg: &ExperimentConfig,
    calib: &CalibData,
) -> Result<QuantOutcome> {
    Calibrator::from_config(cfg).run(eng, sess, spec, cfg, calib, &mut NullObserver)
}

/// Full calibration with an explicit initialization (Table 3 entry point).
pub fn calibrate_with_init(
    eng: &EngineHandle,
    sess: SessionId,
    spec: &ModelSpec,
    cfg: &ExperimentConfig,
    calib: &CalibData,
    init: InitKind,
    run_joint: bool,
) -> Result<QuantOutcome> {
    Calibrator::from_init(cfg, init, run_joint).run(eng, sess, spec, cfg, calib, &mut NullObserver)
}
