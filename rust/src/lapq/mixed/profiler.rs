//! Per-layer sensitivity profiling: how much calibration loss does each
//! layer lose at each candidate bit-width, all other layers held FP32?
//!
//! Two estimators share one output shape:
//!
//! * **Direct** — one objective per candidate width, one loss eval per
//!   (layer, width) pair with `dw` zero everywhere except the probed
//!   layer.  Exact but `layers × widths` forward passes.
//! * **Curvature** — a single finite-difference Hessian at a near-FP32
//!   probe point (`analysis::weight_hessian`), then a second-order
//!   Taylor estimate per (layer, width).  One Hessian amortizes over
//!   all widths; [`plan_bits`](super::plan_bits) falls back to direct
//!   probes when [`SensitivityProfile::degenerate`] says the quadratic
//!   model can't be trusted.

use crate::analysis::curvature::gaussian_curvature;
use crate::analysis::hessian::weight_hessian;
use crate::config::ProfilerMode;
use crate::lapq::calibration::CalibData;
use crate::lapq::objective::{CalibObjective, LayerMask};
use crate::quant::minmax::minmax_delta;
use crate::quant::GridKind;
use crate::runtime::{EngineHandle, SessionId};
use anyhow::Result;

/// Sensitivity table: `sens[k][j]` is the estimated calibration-loss
/// degradation of active layer `layers[k]` quantized to `bits[j]` with
/// every other layer left FP32.  Rows follow [`LayerMask::active_w`]
/// order; columns follow ascending candidate bits.
#[derive(Clone, Debug)]
pub struct SensitivityProfile {
    /// Quant-layer indices of the rows (the mask's active weight layers).
    pub layers: Vec<usize>,
    /// Candidate bit-widths of the columns, ascending.
    pub bits: Vec<u32>,
    /// Loss degradation estimates, clamped at 0.
    pub sens: Vec<Vec<f64>>,
    /// FP32 (direct) or near-FP32 probe-point (curvature) reference loss.
    pub base_loss: f64,
    /// `analysis::gaussian_curvature` at the probe point (curvature mode).
    pub curvature: Option<f64>,
    /// Which estimator actually produced `sens`.
    pub mode_used: ProfilerMode,
    /// Objective evaluations spent.
    pub evals: usize,
}

impl SensitivityProfile {
    /// Profile of an empty active set (nothing to allocate).
    pub fn empty() -> Self {
        SensitivityProfile {
            layers: Vec::new(),
            bits: Vec::new(),
            sens: Vec::new(),
            base_loss: 0.0,
            curvature: None,
            mode_used: ProfilerMode::Direct,
            evals: 0,
        }
    }

    /// Is this estimate structurally untrustworthy?  True when any entry
    /// is non-finite, any row says *more* bits hurt (sensitivity must be
    /// non-increasing in bit-width), or every entry is zero (a flat table
    /// gives the allocator nothing to trade on).
    pub fn degenerate(&self) -> bool {
        if self.sens.is_empty() {
            return true;
        }
        let mut max_s = 0.0f64;
        for row in &self.sens {
            for (j, &s) in row.iter().enumerate() {
                if !s.is_finite() {
                    return true;
                }
                if j > 0 && s > row[j - 1] + 1e-9 {
                    return true;
                }
                max_s = max_s.max(s);
            }
        }
        max_s <= 0.0
    }
}

/// Direct probing: for each candidate width `b`, quantize one layer at a
/// time to its minmax Δ on the `b`-bit signed grid (`dw` zero elsewhere —
/// a zero step leaves a layer FP32) and measure the loss excess over the
/// FP32 reference.  Activations stay FP32 throughout (`da = 0`).
pub fn profile_direct(
    eng: &EngineHandle,
    sess: SessionId,
    calib: &CalibData,
    mask: &LayerMask,
    bits: &[u32],
) -> Result<SensitivityProfile> {
    let n = mask.weights.len();
    let active = mask.active_w();
    let da = vec![0.0f32; n];
    let mut sens = vec![vec![0.0f64; bits.len()]; active.len()];
    let mut base = 0.0f64;
    let mut evals = 0usize;
    for (j, &b) in bits.iter().enumerate() {
        let qmax = GridKind::Signed.qmax(b);
        let mut obj = CalibObjective::new(
            eng,
            sess,
            calib.loss_batches.clone(),
            mask.clone(),
            vec![qmax; n],
            vec![1.0; n],
        );
        if j == 0 {
            base = obj.fp32_loss()?;
            evals += 1;
        }
        for (k, &l) in active.iter().enumerate() {
            let mut dw = vec![0.0f32; n];
            dw[l] = minmax_delta(calib.weights[l].f(), qmax, GridKind::Signed);
            sens[k][j] = (obj.loss(&dw, &da)? - base).max(0.0);
        }
        evals += obj.evals;
    }
    Ok(SensitivityProfile {
        layers: active,
        bits: bits.to_vec(),
        sens,
        base_loss: base,
        curvature: None,
        mode_used: ProfilerMode::Direct,
        evals,
    })
}

/// Curvature estimate: one central-difference Hessian at the mildest
/// probe point (every active layer at its minmax Δ for the *largest*
/// candidate width, where the paper finds the landscape flat and
/// separable), then per-layer second-order extrapolation to the other
/// widths: `sens ≈ g_k·(Δ_b − Δ_0) + ½·H_kk·(Δ_b − Δ_0)²`.
pub fn profile_curvature(
    eng: &EngineHandle,
    sess: SessionId,
    calib: &CalibData,
    mask: &LayerMask,
    bits: &[u32],
) -> Result<SensitivityProfile> {
    let n = mask.weights.len();
    let active = mask.active_w();
    let max_bit = *bits.iter().max().expect("candidate bits are non-empty");
    let qmax_hi = GridKind::Signed.qmax(max_bit);
    let da = vec![0.0f32; n];
    let mut dw0 = vec![0.0f32; n];
    for &l in &active {
        dw0[l] = minmax_delta(calib.weights[l].f(), qmax_hi, GridKind::Signed);
    }
    let mut obj = CalibObjective::new(
        eng,
        sess,
        calib.loss_batches.clone(),
        mask.clone(),
        vec![qmax_hi; n],
        vec![1.0; n],
    );
    let rep = weight_hessian(&mut obj, &dw0, &da, 0.25)?;
    let curvature = gaussian_curvature(&rep);

    let mut sens = vec![vec![0.0f64; bits.len()]; active.len()];
    for (k, &l) in active.iter().enumerate() {
        let d0 = dw0[l] as f64;
        for (j, &b) in bits.iter().enumerate() {
            let db = minmax_delta(calib.weights[l].f(), GridKind::Signed.qmax(b), GridKind::Signed)
                as f64;
            let d = db - d0;
            sens[k][j] = (rep.grad[k] * d + 0.5 * rep.h[k][k] * d * d).max(0.0);
        }
    }
    Ok(SensitivityProfile {
        layers: active,
        bits: bits.to_vec(),
        sens,
        base_loss: rep.f0,
        curvature: Some(curvature),
        mode_used: ProfilerMode::Curvature,
        evals: obj.evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(sens: Vec<Vec<f64>>) -> SensitivityProfile {
        SensitivityProfile {
            layers: (0..sens.len()).collect(),
            bits: vec![2, 4, 8],
            sens,
            base_loss: 0.1,
            curvature: Some(1.0),
            mode_used: ProfilerMode::Curvature,
            evals: 0,
        }
    }

    #[test]
    fn monotone_positive_table_is_sound() {
        let p = profile(vec![vec![3.0, 1.0, 0.1], vec![0.5, 0.5, 0.0]]);
        assert!(!p.degenerate());
    }

    #[test]
    fn degenerate_on_nonfinite() {
        let p = profile(vec![vec![f64::INFINITY, 1.0, 0.1]]);
        assert!(p.degenerate(), "inf entries are tolerated only as a flag");
        let p = profile(vec![vec![f64::NAN, 1.0, 0.1]]);
        assert!(p.degenerate());
    }

    #[test]
    fn degenerate_on_inverted_row() {
        // more bits must not hurt: 1.0 → 2.0 with rising width is nonsense
        let p = profile(vec![vec![3.0, 1.0, 2.0]]);
        assert!(p.degenerate());
    }

    #[test]
    fn degenerate_on_flat_zero_table() {
        let p = profile(vec![vec![0.0, 0.0, 0.0], vec![0.0, 0.0, 0.0]]);
        assert!(p.degenerate());
        assert!(SensitivityProfile::empty().degenerate());
    }
}
