//! Mixed-precision subsystem: sensitivity-driven per-layer weight
//! bit allocation under a model-size budget, wired into the Calibrator
//! as an extra phase plus a sharpness-aware post stage.
//!
//! The paper's landscape finding (flat and separable at mild bit-widths,
//! steep and coupled at 4 bits) means bits are not equally valuable in
//! every layer.  This module turns that into an allocation:
//!
//! * [`profiler`] — measure per-layer loss degradation at each candidate
//!   bit-width, either from one finite-difference Hessian
//!   (`analysis::hessian` + `analysis::curvature`, Hubara-style cheap
//!   estimate) or by direct loss probes, one layer × bit at a time,
//!   with automatic fallback to direct when the quadratic model is
//!   degenerate.
//! * [`alloc`] — solve the resulting multi-choice knapsack exactly by
//!   DP over byte budgets and emit a [`BitPlan`].
//! * [`sharpness`] — a [`PostStage`](super::stages::PostStage) that
//!   re-optimizes the joint scale vector against the worst of K sampled
//!   Δ-perturbations (Liu-style sharpness-aware objective).
//!
//! The plan flows through the whole stack: `Calibrator::run` builds the
//! objective on per-layer grids, `QuantOutcome::wbits` records the plan,
//! `runtime::int::pack` packs each layer at its own width (i8/i4/i2
//! payloads), and the pack key embeds the plan so mixed and uniform
//! artifacts never collide in the model registry.

pub mod alloc;
pub mod profiler;
pub mod sharpness;

pub use alloc::{allocate, BitPlan};
pub use profiler::SensitivityProfile;
pub use sharpness::SharpnessAware;

use super::calibration::CalibData;
use super::events::{CalibEvent, CalibObserver};
use super::objective::LayerMask;
use crate::config::{ExperimentConfig, ProfilerMode};
use crate::runtime::int::weight_storage_bytes;
use crate::runtime::{EngineHandle, SessionId};
use anyhow::{bail, Result};

/// Phase label of the allocation phase (events, traces).
pub const PHASE_ALLOC: &str = "alloc";

/// Profile per-layer sensitivities and allocate bits under the byte
/// budget.  The budget is `mixed.budget_frac` × the bytes the **active**
/// weight layers would occupy at the uniform `bits_w` width, using the
/// same [`weight_storage_bytes`] density as the packed artifact — so
/// "budget_frac = 1.0" means "no larger on disk than the uniform pack".
/// Masked-out layers stay FP32 (bits 32) and join neither the budget nor
/// the baseline.
pub fn plan_bits(
    eng: &EngineHandle,
    sess: SessionId,
    cfg: &ExperimentConfig,
    calib: &CalibData,
    mask: &LayerMask,
    obs: &mut dyn CalibObserver,
) -> Result<(BitPlan, SensitivityProfile)> {
    let n = mask.weights.len();
    let mut bits: Vec<u32> =
        cfg.mixed.candidate_bits.iter().copied().filter(|b| (2..=8).contains(b)).collect();
    bits.sort_unstable();
    bits.dedup();
    if bits.is_empty() {
        bail!("mixed.bits has no usable candidates (signed weight grids cover 2..=8)");
    }
    let active = mask.active_w();
    if active.is_empty() {
        return Ok((
            BitPlan { wbits: vec![32; n], budget_bytes: 0, spent_bytes: 0 },
            SensitivityProfile::empty(),
        ));
    }

    let mut profile = match cfg.mixed.profiler {
        ProfilerMode::Curvature => profiler::profile_curvature(eng, sess, calib, mask, &bits)?,
        ProfilerMode::Direct => profiler::profile_direct(eng, sess, calib, mask, &bits)?,
    };
    if profile.mode_used == ProfilerMode::Curvature && profile.degenerate() {
        obs.on_event(&CalibEvent::Degenerate {
            phase: PHASE_ALLOC,
            detail: "curvature sensitivity estimate is degenerate (non-finite, \
                     non-monotone or flat); falling back to direct loss probes"
                .into(),
        });
        let prior_evals = profile.evals;
        let curvature = profile.curvature;
        profile = profiler::profile_direct(eng, sess, calib, mask, &bits)?;
        profile.evals += prior_evals;
        profile.curvature = curvature;
    }

    let sizes: Vec<usize> = active.iter().map(|&l| calib.weights[l].f().len()).collect();
    let costs: Vec<Vec<usize>> = sizes
        .iter()
        .map(|&m| bits.iter().map(|&b| weight_storage_bytes(m, b)).collect())
        .collect();
    let uniform: usize =
        sizes.iter().map(|&m| weight_storage_bytes(m, cfg.bits.weights)).sum();
    let budget = (cfg.mixed.budget_frac * uniform as f64).floor() as usize;
    let (pick, spent) = allocate(&costs, &profile.sens, budget)?;

    let mut wbits = vec![32u32; n];
    for (k, &l) in active.iter().enumerate() {
        wbits[l] = bits[pick[k]];
    }
    log::info!(
        "[mixed] allocated bits {:?} ({} of {} budget bytes, uniform-w{} baseline {} B, {})",
        wbits,
        spent,
        budget,
        cfg.bits.weights,
        uniform,
        profile.mode_used.key(),
    );
    Ok((BitPlan { wbits, budget_bytes: budget, spent_bytes: spent }, profile))
}
