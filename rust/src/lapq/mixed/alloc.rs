//! Exact bit allocation: a multi-choice knapsack solved by dynamic
//! programming over byte budgets.  Each active layer picks exactly one
//! candidate width; minimize total estimated loss degradation subject to
//! the packed weight bytes fitting the budget.  No external solver — the
//! builtin zoo's budgets are a few hundred KB, so the DP table is tiny.

use anyhow::{bail, Result};

/// The allocator's verdict, in full-quant-layer coordinates: `wbits[i]`
/// is the chosen weight width for quant layer `i`, with `32` marking
/// layers the mask left at FP32 (they join neither budget nor spend).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitPlan {
    pub wbits: Vec<u32>,
    /// Byte budget the allocation was solved under.
    pub budget_bytes: usize,
    /// Packed weight bytes the chosen plan actually uses.
    pub spent_bytes: usize,
}

/// Solve the allocation.  `costs[k][j]` / `sens[k][j]` are the packed
/// byte cost and loss degradation of layer `k` at candidate `j`
/// (candidates ascending in bit-width).  Returns the chosen candidate
/// index per layer and the total unscaled byte spend.
///
/// Ties between equal-value plans resolve toward **higher** bits: with a
/// flat sensitivity table and an ample budget the result degrades to
/// uniform max-width, never to a gratuitously aggressive plan.
///
/// Exactness: when `budget` exceeds ~4M cells the byte unit is doubled
/// until the table fits, with per-item costs rounded **up** to the unit —
/// scaled solutions therefore never overshoot the true budget, and for
/// the builtin zoo (unit = 1) the DP is exact.
pub fn allocate(
    costs: &[Vec<usize>],
    sens: &[Vec<f64>],
    budget: usize,
) -> Result<(Vec<usize>, usize)> {
    if costs.len() != sens.len() {
        bail!("allocator shape mismatch: {} cost rows vs {} sensitivity rows", costs.len(), sens.len());
    }
    if costs.is_empty() {
        return Ok((Vec::new(), 0));
    }
    let m = costs[0].len();
    if m == 0 || m > u8::MAX as usize {
        bail!("allocator needs 1..=255 candidates per layer, got {m}");
    }
    for (k, (c, s)) in costs.iter().zip(sens).enumerate() {
        if c.len() != m || s.len() != m {
            bail!("allocator row {k} is ragged ({} costs, {} sensitivities, want {m})", c.len(), s.len());
        }
    }
    let min_total: usize = costs.iter().map(|c| *c.iter().min().expect("non-empty row")).sum();
    if min_total > budget {
        bail!("size budget {budget} B infeasible: cheapest plan needs {min_total} B");
    }

    // Scale the byte unit up until the table is tractable; round costs up
    // so a scaled-feasible plan is always unscaled-feasible.
    let mut unit = 1usize;
    while budget / unit > 4_000_000 {
        unit *= 2;
    }
    let sb = budget / unit;
    let scaled: Vec<Vec<usize>> =
        costs.iter().map(|c| c.iter().map(|&b| b.div_ceil(unit)).collect()).collect();
    let scaled_min: usize = scaled.iter().map(|c| *c.iter().min().expect("non-empty row")).sum();
    if scaled_min > sb {
        bail!(
            "size budget {budget} B infeasible at {unit}-byte granularity: cheapest plan rounds to {} units over {sb}",
            scaled_min
        );
    }

    // dp[j] = best total sensitivity using at most j units; feas[j] marks
    // states actually reachable (sensitivities may legitimately be +inf —
    // a collapsed 2-bit layer — so the value can't double as the flag).
    let mut dp = vec![0.0f64; sb + 1];
    let mut feas = vec![true; sb + 1];
    let mut choice: Vec<Vec<u8>> = Vec::with_capacity(costs.len());
    for (k, c) in scaled.iter().enumerate() {
        let mut nd = vec![0.0f64; sb + 1];
        let mut nf = vec![false; sb + 1];
        let mut ch = vec![u8::MAX; sb + 1];
        for j in 0..=sb {
            for (cand, &cb) in c.iter().enumerate() {
                if cb <= j && feas[j - cb] {
                    let v = dp[j - cb] + sens[k][cand];
                    // `<=` + ascending candidate order: ties prefer the
                    // later (higher-bit) candidate.
                    if !nf[j] || v <= nd[j] {
                        nd[j] = v;
                        nf[j] = true;
                        ch[j] = cand as u8;
                    }
                }
            }
        }
        dp = nd;
        feas = nf;
        choice.push(ch);
    }

    // dp has "cost at most j" semantics, so dp[sb] is the optimum;
    // reconstruct from the full budget (ties already resolved upward).
    debug_assert!(feas[sb], "feasibility was checked up front");
    let mut at = sb;
    let mut pick = vec![0usize; costs.len()];
    for k in (0..costs.len()).rev() {
        let cand = choice[k][at] as usize;
        debug_assert_ne!(choice[k][at], u8::MAX, "reconstruction hit an infeasible state");
        pick[k] = cand;
        at -= scaled[k][cand];
    }
    let spent: usize = pick.iter().enumerate().map(|(k, &cand)| costs[k][cand]).sum();
    debug_assert!(spent <= budget, "plan spends {spent} B over budget {budget} B");
    Ok((pick, spent))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn hand_checked_optimum() {
        // layer 0: costs 1/2/4, sens 10/4/1; layer 1: costs 2/4/8, sens 3/1/0.2
        let costs = vec![vec![1, 2, 4], vec![2, 4, 8]];
        let sens = vec![vec![10.0, 4.0, 1.0], vec![3.0, 1.0, 0.2]];
        // budget 8: best is layer0@2 (cost 2) + layer1@1 (cost 4) = 6 ≤ 8,
        // value 4+1=5; alternatives: [0,2]=10.2 cost 10 infeasible,
        // [2,1]=2.0 cost 8 feasible and better!  4+4=8 ≤ 8 value 1+1=2.
        let (pick, spent) = allocate(&costs, &sens, 8).unwrap();
        assert_eq!(pick, vec![2, 1]);
        assert_eq!(spent, 8);
        // budget 12: [2,2] costs 12, value 1.2 — now feasible and optimal.
        let (pick, spent) = allocate(&costs, &sens, 12).unwrap();
        assert_eq!(pick, vec![2, 2]);
        assert_eq!(spent, 12);
    }

    #[test]
    fn budget_is_respected_exactly() {
        let costs = vec![vec![1, 2, 4]; 3];
        let sens = vec![vec![9.0, 3.0, 1.0]; 3];
        for budget in 3..=12 {
            let (pick, spent) = allocate(&costs, &sens, budget).unwrap();
            assert!(spent <= budget, "budget {budget}: spent {spent}");
            assert_eq!(spent, pick.iter().map(|&c| costs[0][c]).sum::<usize>());
        }
    }

    #[test]
    fn ample_budget_degrades_to_uniform_max_bits() {
        // flat sensitivities: nothing to trade, ties must resolve upward
        let costs = vec![vec![1, 2, 4]; 4];
        let sens = vec![vec![0.0, 0.0, 0.0]; 4];
        let (pick, spent) = allocate(&costs, &sens, 16).unwrap();
        assert_eq!(pick, vec![2; 4], "ample budget + flat sens → max width everywhere");
        assert_eq!(spent, 16);
    }

    #[test]
    fn infeasible_budget_bails() {
        let costs = vec![vec![4, 8], vec![4, 8]];
        let sens = vec![vec![1.0, 0.0]; 2];
        let err = allocate(&costs, &sens, 7).unwrap_err().to_string();
        assert!(err.contains("infeasible"), "got: {err}");
        // exactly the cheapest plan fits
        let (pick, spent) = allocate(&costs, &sens, 8).unwrap();
        assert_eq!(pick, vec![0, 0]);
        assert_eq!(spent, 8);
    }

    #[test]
    fn infinite_sensitivity_is_feasible_but_avoided() {
        // a collapsed 2-bit layer reports +inf; the allocator must route
        // around it when the budget allows and still terminate when not.
        let costs = vec![vec![1, 2], vec![1, 2]];
        let sens = vec![vec![f64::INFINITY, 0.5], vec![0.1, 0.0]];
        let (pick, _) = allocate(&costs, &sens, 3).unwrap();
        assert_eq!(pick[0], 1, "must pay bytes to escape the inf row");
        // budget forces the inf choice: still returns a plan, not a hang
        let (pick, spent) = allocate(&costs, &sens, 2).unwrap();
        assert_eq!(pick, vec![0, 0]);
        assert_eq!(spent, 2);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = Pcg32::seeded(77);
        for trial in 0..60 {
            let layers = 1 + rng.below(4) as usize;
            let cands = 2 + rng.below(3) as usize;
            let costs: Vec<Vec<usize>> = (0..layers)
                .map(|_| {
                    let mut c: Vec<usize> = (0..cands).map(|_| 1 + rng.below(6) as usize).collect();
                    c.sort_unstable();
                    c
                })
                .collect();
            let sens: Vec<Vec<f64>> = (0..layers)
                .map(|_| {
                    let mut s: Vec<f64> = (0..cands).map(|_| rng.below(1000) as f64 / 100.0).collect();
                    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
                    s
                })
                .collect();
            let budget = layers + rng.below(16) as usize;
            let min_total: usize = costs.iter().map(|c| c[0]).sum();
            if min_total > budget {
                assert!(allocate(&costs, &sens, budget).is_err());
                continue;
            }
            let (pick, spent) = allocate(&costs, &sens, budget).unwrap();
            let value: f64 = pick.iter().enumerate().map(|(k, &c)| sens[k][c]).sum();
            // brute force over cands^layers
            let mut best = f64::INFINITY;
            let mut idx = vec![0usize; layers];
            loop {
                let cost: usize = idx.iter().enumerate().map(|(k, &c)| costs[k][c]).sum();
                if cost <= budget {
                    let v: f64 = idx.iter().enumerate().map(|(k, &c)| sens[k][c]).sum();
                    best = best.min(v);
                }
                let mut k = 0;
                loop {
                    idx[k] += 1;
                    if idx[k] < cands {
                        break;
                    }
                    idx[k] = 0;
                    k += 1;
                    if k == layers {
                        break;
                    }
                }
                if k == layers {
                    break;
                }
            }
            assert!(
                (value - best).abs() < 1e-9,
                "trial {trial}: DP value {value} vs brute force {best} (spent {spent}/{budget})"
            );
        }
    }

    #[test]
    fn shape_errors_bail() {
        assert!(allocate(&[vec![1, 2]], &[], 10).is_err());
        assert!(allocate(&[vec![1, 2]], &[vec![1.0]], 10).is_err());
        assert!(allocate(&[vec![]], &[vec![]], 10).is_err());
        let (pick, spent) = allocate(&[], &[], 10).unwrap();
        assert!(pick.is_empty());
        assert_eq!(spent, 0);
    }
}
