//! Sharpness-aware re-optimization of the joint scale vector: a
//! [`PostStage`] that minimizes the **worst** calibration loss over K
//! sampled multiplicative Δ-perturbations instead of the nominal loss.
//!
//! The paper's premise is that 4-bit minima are steep — a Δ vector that
//! is optimal on the calibration batch can sit on a knife edge where any
//! step-size drift (packing rounding, per-channel bias correction, a
//! different batch) blows the loss up.  One cheap coordinate-descent pass
//! on `max_k L(x ⊙ pert_k)` trades a little nominal loss for a flatter
//! neighborhood; the stage only commits when the worst-case strictly
//! improves, so it can never regress the nominal outcome silently.

use crate::lapq::calibration::CalibData;
use crate::lapq::calibrator::QuantOutcome;
use crate::lapq::objective::CalibObjective;
use crate::lapq::stages::{CoordinateDescentJoint, JointOptimizer, PostStage};
use crate::config::ExperimentConfig;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{EngineHandle, SessionId};
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Worst loss over the nominal point and all perturbations of `x`.
fn worst_loss(
    obj: &mut CalibObjective,
    aw: &[usize],
    aa: &[usize],
    dw0: &[f32],
    da0: &[f32],
    x: &[f64],
    perts: &[Vec<f64>],
) -> Result<f64> {
    let nominal: Vec<f64> = vec![1.0; x.len()];
    let mut worst = f64::NEG_INFINITY;
    for pert in std::iter::once(&nominal).chain(perts) {
        let mut dw = dw0.to_vec();
        let mut da = da0.to_vec();
        for (k, &i) in aw.iter().enumerate() {
            dw[i] = dw0[i] * (x[k] * pert[k]) as f32;
        }
        for (k, &i) in aa.iter().enumerate() {
            let j = aw.len() + k;
            da[i] = da0[i] * (x[j] * pert[j]) as f32;
        }
        worst = worst.max(obj.loss(&dw, &da)?);
    }
    Ok(worst)
}

/// The sharpness-aware post stage.  `k` perturbation vectors are drawn
/// once (seeded from `cfg.seed`, so runs reproduce); each scales every
/// active coordinate by a factor in `[1−radius, 1+radius]`.
pub struct SharpnessAware {
    /// Number of sampled perturbations (0 disables the stage).
    pub k: usize,
    /// Relative perturbation radius (≤ 0 disables the stage).
    pub radius: f64,
}

impl PostStage for SharpnessAware {
    fn name(&self) -> &'static str {
        "sharpness"
    }

    fn phase(&self) -> &'static str {
        "post:sharpness"
    }

    fn apply(
        &self,
        eng: &EngineHandle,
        sess: SessionId,
        _spec: &ModelSpec,
        cfg: &ExperimentConfig,
        calib: &CalibData,
        outcome: &mut QuantOutcome,
    ) -> Result<()> {
        if self.k == 0 || self.radius <= 0.0 {
            return Ok(());
        }
        let aw = outcome.mask.active_w();
        let aa = outcome.mask.active_a();
        let dim = aw.len() + aa.len();
        if dim == 0 {
            return Ok(());
        }
        let mut obj = CalibObjective::new(
            eng,
            sess,
            calib.loss_batches.clone(),
            outcome.mask.clone(),
            outcome.quant.qmw.clone(),
            outcome.quant.qma.clone(),
        );
        let dw0 = outcome.quant.dw.clone();
        let da0 = outcome.quant.da.clone();
        let mut rng = Pcg32::seeded(cfg.seed ^ 0x5AFE_D00D);
        let r = self.radius as f32;
        let perts: Vec<Vec<f64>> = (0..self.k)
            .map(|_| (0..dim).map(|_| 1.0 + rng.range(-r, r) as f64).collect())
            .collect();

        let x0 = vec![1.0f64; dim];
        let lo = vec![(1.0 - self.radius).max(0.25); dim];
        let hi = vec![1.0 + self.radius; dim];
        let mut f = |x: &[f64]| worst_loss(&mut obj, &aw, &aa, &dw0, &da0, x, &perts);
        let f0 = f(&x0)?;
        if !f0.is_finite() {
            return Ok(()); // collapsed net: nothing sane to flatten
        }
        let opt = CoordinateDescentJoint { sweeps: 1, max_evals: (8 * dim).min(64) };
        let res = opt.minimize(&x0, &lo, &hi, &mut f)?;
        if res.fx + 1e-12 >= f0 {
            return Ok(()); // no strict worst-case improvement: keep nominal
        }
        let mut dw = dw0.clone();
        let mut da = da0.clone();
        for (k, &i) in aw.iter().enumerate() {
            dw[i] = dw0[i] * res.x[k] as f32;
        }
        for (k, &i) in aa.iter().enumerate() {
            da[i] = da0[i] * res.x[aw.len() + k] as f32;
        }
        outcome.calib_loss = obj.loss(&dw, &da)?;
        outcome.quant = obj.quant_params(&dw, &da);
        log::info!(
            "[mixed] sharpness: worst-case {f0:.5} → {:.5} ({} evals), nominal now {:.5}",
            res.fx,
            res.evals,
            outcome.calib_loss,
        );
        Ok(())
    }
}
