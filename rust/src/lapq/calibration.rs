//! Calibration data collection: weight tensors, activation samples and
//! registered calibration-loss batches — everything phases 1–3 of LAPQ
//! (and every baseline) consume.

use crate::coordinator::workload::{Split, Workload};
use crate::quant::GridKind;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{BatchId, EngineHandle, SessionId};
use crate::tensor::HostTensor;
use anyhow::Result;

/// Cap on retained activation samples per layer (deterministic stride
/// subsampling keeps the Δ search fast without biasing the distribution).
pub const MAX_ACT_SAMPLES: usize = 32_768;

pub struct CalibData {
    /// Per quant layer: the (FP32) weight tensor, cloned from the session.
    pub weights: Vec<HostTensor>,
    /// Per quant layer: subsampled input-activation values.
    pub act_samples: Vec<Vec<f32>>,
    /// Per quant layer: activation grid kind.
    pub act_kind: Vec<GridKind>,
    /// Registered calibration-loss batches (drive `fwd_quant`).
    pub loss_batches: Vec<BatchId>,
}

/// Gather calibration data for `sess`.
///
/// `calib_size` samples are split into `ceil(size / eval_batch)` batches;
/// the same batches serve the loss objective, while `acts` executions on
/// inputs-only variants provide the activation populations.
pub fn collect(
    eng: &EngineHandle,
    sess: SessionId,
    spec: &ModelSpec,
    workload: &Workload,
    calib_size: usize,
) -> Result<CalibData> {
    let per = spec.eval_batch();
    let n_batches = calib_size.div_ceil(per).max(1);

    // weights
    let params = eng.get_params(sess)?;
    let weights: Vec<HostTensor> =
        spec.quant_layers.iter().map(|q| params[q.weight_param].clone()).collect();
    let act_kind: Vec<GridKind> =
        spec.quant_layers.iter().map(|q| GridKind::from_signed(q.act_signed)).collect();

    // loss batches
    let raw = workload.eval_batches(spec, Split::Calib, n_batches);
    let loss_batches: Vec<BatchId> =
        raw.into_iter().map(|b| eng.register_batch(b)).collect::<Result<_>>()?;

    // activation samples
    let n_layers = spec.quant_layers.len();
    let mut act_samples: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
    for batch in workload.acts_batches(spec, n_batches) {
        let bid = eng.register_batch(batch)?;
        let acts = eng.acts(sess, bid)?;
        eng.drop_batch(bid)?;
        for (i, a) in acts.into_iter().enumerate() {
            act_samples[i].extend_from_slice(a.f());
        }
    }
    for s in &mut act_samples {
        subsample(s, MAX_ACT_SAMPLES);
    }

    Ok(CalibData { weights, act_samples, act_kind, loss_batches })
}

impl CalibData {
    /// Release the registered loss batches.
    pub fn release(&self, eng: &EngineHandle) {
        for &b in &self.loss_batches {
            let _ = eng.drop_batch(b);
        }
    }
}

/// Deterministic stride subsampling in place.
pub fn subsample(xs: &mut Vec<f32>, cap: usize) {
    if xs.len() <= cap {
        return;
    }
    let stride = xs.len() as f64 / cap as f64;
    let picked: Vec<f32> = (0..cap).map(|i| xs[(i as f64 * stride) as usize]).collect();
    *xs = picked;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsample_cap_and_determinism() {
        let mut a: Vec<f32> = (0..100_000).map(|i| i as f32).collect();
        let mut b = a.clone();
        subsample(&mut a, 1000);
        subsample(&mut b, 1000);
        assert_eq!(a.len(), 1000);
        assert_eq!(a, b);
        // spans the full range
        assert!(a[0] < 200.0 && *a.last().unwrap() > 98_000.0);
    }

    #[test]
    fn subsample_noop_below_cap() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        subsample(&mut a, 10);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
    }
}
