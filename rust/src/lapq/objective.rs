//! The joint objective L(Δ): mean cross-entropy (or BCE) over the
//! calibration batches, evaluated by executing the compiled `fwd_quant`
//! artifact.  This is the hot path of LAPQ phase 3 — Powell calls it
//! hundreds of times — so results are memoized on the quantized bit
//! pattern of the Δ vectors.

use crate::config::BitSpec;
use crate::quant::GridKind;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{BatchId, EngineHandle, QuantParams, SessionId};
use anyhow::Result;
use std::collections::HashMap;

/// Which layers are quantized (the paper leaves first/last at FP32).
#[derive(Clone, Debug)]
pub struct LayerMask {
    pub weights: Vec<bool>,
    pub acts: Vec<bool>,
}

impl LayerMask {
    pub fn all(n: usize, bits: BitSpec) -> Self {
        LayerMask { weights: vec![bits.quant_weights(); n], acts: vec![bits.quant_acts(); n] }
    }

    /// Paper convention: exclude the first and last quant layer.
    pub fn exclude_first_last(mut self, embeds_are_first: &[usize]) -> Self {
        let n = self.weights.len();
        if n == 0 {
            return self;
        }
        for v in [&mut self.weights, &mut self.acts] {
            v[0] = false;
            v[n - 1] = false;
            // embedding layers listed as "first" (NCF has 4 parallel ones)
            for &i in embeds_are_first {
                if i < v.len() {
                    v[i] = false;
                }
            }
        }
        self
    }

    pub fn active_w(&self) -> Vec<usize> {
        self.weights.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect()
    }

    pub fn active_a(&self) -> Vec<usize> {
        self.acts.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect()
    }
}

/// Grid bounds per layer for a bit spec.
pub fn grids(spec: &ModelSpec, bits: BitSpec) -> (Vec<f32>, Vec<f32>) {
    let qmw = spec
        .quant_layers
        .iter()
        .map(|_| if bits.quant_weights() { GridKind::Signed.qmax(bits.weights) } else { 1.0 })
        .collect();
    let qma = spec
        .quant_layers
        .iter()
        .map(|q| {
            if bits.quant_acts() {
                GridKind::from_signed(q.act_signed).qmax(bits.acts)
            } else {
                1.0
            }
        })
        .collect();
    (qmw, qma)
}

/// Memoizing calibration-loss objective.
pub struct CalibObjective<'a> {
    pub eng: &'a EngineHandle,
    pub sess: SessionId,
    pub batches: Vec<BatchId>,
    pub mask: LayerMask,
    pub qmw: Vec<f32>,
    pub qma: Vec<f32>,
    pub evals: usize,
    pub cache_hits: usize,
    cache: HashMap<Vec<u32>, f64>,
}

impl<'a> CalibObjective<'a> {
    pub fn new(
        eng: &'a EngineHandle,
        sess: SessionId,
        batches: Vec<BatchId>,
        mask: LayerMask,
        qmw: Vec<f32>,
        qma: Vec<f32>,
    ) -> Self {
        CalibObjective { eng, sess, batches, mask, qmw, qma, evals: 0, cache_hits: 0, cache: HashMap::new() }
    }

    /// Build the graph-side QuantParams from full-length Δ vectors,
    /// zeroing masked-out layers.
    pub fn quant_params(&self, dw: &[f32], da: &[f32]) -> QuantParams {
        let n = self.mask.weights.len();
        assert_eq!(dw.len(), n);
        assert_eq!(da.len(), n);
        QuantParams {
            dw: dw.iter().zip(&self.mask.weights).map(|(&d, &m)| if m { d } else { 0.0 }).collect(),
            qmw: self.qmw.clone(),
            da: da.iter().zip(&self.mask.acts).map(|(&d, &m)| if m { d } else { 0.0 }).collect(),
            qma: self.qma.clone(),
        }
    }

    /// Mean calibration loss under (dw, da); memoized.
    pub fn loss(&mut self, dw: &[f32], da: &[f32]) -> Result<f64> {
        let q = self.quant_params(dw, da);
        let key: Vec<u32> =
            q.dw.iter().chain(q.da.iter()).map(|f| f.to_bits()).collect();
        if let Some(&v) = self.cache.get(&key) {
            self.cache_hits += 1;
            return Ok(v);
        }
        self.evals += 1;
        let mut acc = 0.0f64;
        for &b in &self.batches {
            acc += self.eng.eval(self.sess, Some(q.clone()), b)?.0 as f64;
        }
        let v = acc / self.batches.len().max(1) as f64;
        self.cache.insert(key, v);
        Ok(v)
    }

    /// FP32 reference loss on the same batches.
    pub fn fp32_loss(&self) -> Result<f64> {
        let mut acc = 0.0f64;
        for &b in &self.batches {
            acc += self.eng.eval(self.sess, None, b)?.0 as f64;
        }
        Ok(acc / self.batches.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_excludes_first_last() {
        let m = LayerMask::all(6, BitSpec::new(4, 4)).exclude_first_last(&[]);
        assert_eq!(m.weights, vec![false, true, true, true, true, false]);
        assert_eq!(m.active_w(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn mask_fp32_sides() {
        let m = LayerMask::all(4, BitSpec::new(32, 4));
        assert!(m.weights.iter().all(|&b| !b));
        assert!(m.acts.iter().all(|&b| b));
    }

    #[test]
    fn mask_embeds() {
        let m = LayerMask::all(7, BitSpec::new(8, 8)).exclude_first_last(&[1, 2, 3]);
        assert_eq!(m.weights, vec![false, false, false, false, true, true, false]);
    }
}
