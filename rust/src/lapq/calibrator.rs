//! The [`Calibrator`]: a composable, observable calibration engine built
//! from pluggable [`InitStrategy`] / [`JointOptimizer`] / [`PostStage`]
//! stages (paper §4, Algorithm 1).
//!
//! ```text
//! Calibrator::builder()
//!     .init(LayerwiseLp::grid())        // Alg. 1 lines 1–8
//!     .init(MinMaxFallback)             // collapse guard
//!     .init(QuadraticPStar::grid())     // Alg. 1 lines 9–12
//!     .joint_cfg(&cfg.lapq.joint)       // Alg. 1 lines 13–21
//!     .post(BiasCorrection)
//!     .build()
//!     .run(&eng, sess, &spec, &cfg, &calib, &mut observer)
//! ```
//!
//! Every run streams [`CalibEvent`]s into the supplied observer and
//! records a per-phase [`PhaseTrace`] on the returned [`QuantOutcome`].

use super::calibration::CalibData;
use super::events::{CalibEvent, CalibObserver, NullObserver, PhaseTrace};
use super::objective::{grids, CalibObjective, LayerMask};
use super::stages::{
    joint_optimizer, BaselineInit, BiasCorrection, InitCandidate, InitNotes, InitStrategy,
    JointOptimizer, LayerwiseLp, MinMaxFallback, PostStage, QuadraticPStar, RandomInit, StageCtx,
    PHASE_INIT,
};
use crate::config::{BitSpec, ExperimentConfig, JointCfg, LapqCfg, Method};
use crate::quant::GridKind;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::{EngineHandle, QuantParams, SessionId};
use anyhow::{bail, Result};

/// Everything a calibration run produces.
#[derive(Clone, Debug)]
pub struct QuantOutcome {
    pub method: Method,
    pub bits: BitSpec,
    pub quant: QuantParams,
    /// Which layers were active in the joint phase (weights/activations),
    /// so `pack` and downstream tooling can tell "masked off" apart from
    /// "calibrated to Δ=0" without re-deriving the config's mask.
    pub mask: LayerMask,
    /// Calibration loss of the final Δ.
    pub calib_loss: f64,
    /// FP32 loss on the same calibration batches.
    pub fp32_calib_loss: f64,
    /// Loss at the initialization (before the joint phase, when run).
    pub init_loss: f64,
    /// Quadratic-interpolation diagnostics (LAPQ only).
    pub p_star: Option<f64>,
    pub quad_r2: Option<f64>,
    /// Joint-phase objective evaluations.
    pub joint_evals: usize,
    pub seconds: f64,
    /// Per-phase summary of the run (init / joint / post stages in order).
    pub trace: Vec<PhaseTrace>,
    /// Original (pre-bias-correction) session params, for restoration.
    pub original_params: Option<Vec<crate::tensor::HostTensor>>,
    /// Mixed-precision weight bit plan, when `mixed.enabled` allocated
    /// one (`wbits[i] == 32` marks a masked-off FP32 layer).  `None`
    /// means uniform `bits.weights` everywhere — the pre-mixed contract.
    pub wbits: Option<Vec<u32>>,
}

/// Initialization strategy shorthand for the Table-3 ablation entry
/// points ([`Calibrator::from_init`], `Runner::run_with_init`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    /// Random steps (paper Table 3 "Random").
    Random(u64),
    /// Layer-wise p=2 (MMSE) only — "LW".
    Layerwise,
    /// Layer-wise + quadratic approximation — "LW + QA" (full LAPQ init).
    LapqQuadratic,
}

/// Which layers count as "first" beyond index 0 (NCF's parallel embedding
/// tables all feed the first dense layer).
fn extra_first_layers(spec: &ModelSpec) -> Vec<usize> {
    spec.quant_layers
        .iter()
        .enumerate()
        .filter(|(_, q)| q.kind == "embed")
        .map(|(i, _)| i)
        .collect()
}

/// The config's layer mask (optionally excluding first/last layers).
pub fn build_mask(spec: &ModelSpec, cfg: &ExperimentConfig) -> LayerMask {
    let n = spec.n_quant_layers();
    let mask = LayerMask::all(n, cfg.bits);
    if cfg.lapq.exclude_first_last {
        mask.exclude_first_last(&extra_first_layers(spec))
    } else {
        mask
    }
}

/// A composed calibration: init candidates → best-of → optional joint
/// optimization → post stages.  Build one with [`Calibrator::builder`],
/// or let [`Calibrator::from_config`] assemble the standard composition
/// for a config's method.
pub struct Calibrator {
    init: Vec<Box<dyn InitStrategy>>,
    joint: Option<Box<dyn JointOptimizer>>,
    post: Vec<Box<dyn PostStage>>,
}

#[derive(Default)]
pub struct CalibratorBuilder {
    init: Vec<Box<dyn InitStrategy>>,
    joint: Option<Box<dyn JointOptimizer>>,
    post: Vec<Box<dyn PostStage>>,
}

impl CalibratorBuilder {
    /// Add an init strategy (candidates from all strategies compete).
    pub fn init(mut self, s: impl InitStrategy + 'static) -> Self {
        self.init.push(Box::new(s));
        self
    }

    /// Set the joint optimizer (replaces any previous choice).
    pub fn joint(mut self, j: impl JointOptimizer + 'static) -> Self {
        self.joint = Some(Box::new(j));
        self
    }

    /// Set the joint optimizer from a typed config (optimizer + budget).
    pub fn joint_cfg(mut self, cfg: &JointCfg) -> Self {
        self.joint = Some(joint_optimizer(cfg));
        self
    }

    /// Append a post stage (runs after the Δ search, in order).
    pub fn post(mut self, p: impl PostStage + 'static) -> Self {
        self.post.push(Box::new(p));
        self
    }

    pub fn build(self) -> Calibrator {
        Calibrator { init: self.init, joint: self.joint, post: self.post }
    }
}

impl Calibrator {
    pub fn builder() -> CalibratorBuilder {
        CalibratorBuilder::default()
    }

    /// The standard composition for a config: full LAPQ (layer-wise grid +
    /// min-max fallback + quadratic p*, joint phase per `cfg.lapq.joint`)
    /// when `method == Lapq`, otherwise the single-candidate baseline;
    /// bias correction when enabled.
    pub fn from_config(cfg: &ExperimentConfig) -> Calibrator {
        let mut b = Calibrator::builder();
        match cfg.method {
            Method::Lapq => {
                b = b
                    .init(LayerwiseLp::grid())
                    .init(MinMaxFallback)
                    .init(QuadraticPStar::grid())
                    .joint_cfg(&cfg.lapq.joint);
            }
            m => {
                b = b.init(BaselineInit { method: m, bits: cfg.bits });
            }
        }
        if cfg.mixed.enabled && cfg.mixed.sharpness_k > 0 {
            // before bias correction: the sharpness pass re-evaluates the
            // loss objective, which must see the pristine session weights
            b = b.post(super::mixed::SharpnessAware {
                k: cfg.mixed.sharpness_k,
                radius: cfg.mixed.sharpness_radius,
            });
        }
        if cfg.lapq.bias_correction {
            b = b.post(BiasCorrection);
        }
        b.build()
    }

    /// Table-3 ablation composition: an explicit [`InitKind`], joint phase
    /// optional, bias correction per config.
    pub fn from_init(cfg: &ExperimentConfig, init: InitKind, run_joint: bool) -> Calibrator {
        let mut b = Calibrator::builder();
        b = match init {
            InitKind::Random(seed) => b.init(RandomInit { seed }),
            InitKind::Layerwise => b.init(LayerwiseLp::fixed(vec![2.0])),
            InitKind::LapqQuadratic => b
                .init(LayerwiseLp::grid())
                .init(MinMaxFallback)
                .init(QuadraticPStar::grid()),
        };
        if run_joint {
            b = b.joint_cfg(&cfg.lapq.joint);
        }
        if cfg.lapq.bias_correction {
            b = b.post(BiasCorrection);
        }
        b.build()
    }

    /// Run the composed calibration against a live session.  Emits
    /// [`CalibEvent`]s into `obs` throughout; on return the session params
    /// may have been rewritten by post stages (`outcome.original_params`
    /// holds the pristine weights for restoration by the caller).
    pub fn run(
        &self,
        eng: &EngineHandle,
        sess: SessionId,
        spec: &ModelSpec,
        cfg: &ExperimentConfig,
        calib: &CalibData,
        obs: &mut dyn CalibObserver,
    ) -> Result<QuantOutcome> {
        let t0 = std::time::Instant::now();
        let mask = build_mask(spec, cfg);
        let (mut qmw, qma) = grids(spec, cfg.bits);
        let mut trace: Vec<PhaseTrace> = Vec::new();

        // ---- mixed-precision allocation phase (optional): profile
        // sensitivities, solve the size-budget knapsack, and rewrite the
        // per-layer weight grids before any Δ is ever searched.
        let mut wbits: Option<Vec<u32>> = None;
        if cfg.mixed.enabled && cfg.bits.quant_weights() {
            let phase = super::mixed::PHASE_ALLOC;
            obs.on_event(&CalibEvent::PhaseStart { phase });
            let ta = std::time::Instant::now();
            let (plan, profile) = super::mixed::plan_bits(eng, sess, cfg, calib, &mask, obs)?;
            for (i, &b) in plan.wbits.iter().enumerate() {
                if mask.weights[i] && b < 32 {
                    qmw[i] = GridKind::Signed.qmax(b);
                }
            }
            obs.on_event(&CalibEvent::Alloc {
                phase,
                wbits: plan.wbits.clone(),
                budget_bytes: plan.budget_bytes,
                spent_bytes: plan.spent_bytes,
            });
            let secs = ta.elapsed().as_secs_f64();
            obs.on_event(&CalibEvent::PhaseEnd {
                phase,
                evals: profile.evals,
                seconds: secs,
                loss: profile.base_loss,
            });
            trace.push(PhaseTrace {
                phase,
                evals: profile.evals,
                seconds: secs,
                loss: profile.base_loss,
            });
            wbits = Some(plan.wbits);
        }

        let mut obj = CalibObjective::new(
            eng,
            sess,
            calib.loss_batches.clone(),
            mask.clone(),
            qmw.clone(),
            qma.clone(),
        );
        let fp32_calib_loss = obj.fp32_loss()?;
        let mut notes = InitNotes::default();

        // ---- init phase: gather candidates from every strategy, best-of.
        obs.on_event(&CalibEvent::PhaseStart { phase: PHASE_INIT });
        let ti = std::time::Instant::now();
        let evals_at_start = obj.evals;
        let mut candidates: Vec<InitCandidate> = Vec::new();
        let mut lp_memo = std::collections::HashMap::new();
        for s in &self.init {
            let mut ctx = StageCtx {
                calib,
                obj: &mut obj,
                lapq: &cfg.lapq,
                notes: &mut notes,
                obs: &mut *obs,
                lp_memo: &mut lp_memo,
            };
            candidates.extend(s.candidates(&mut ctx)?);
        }
        if candidates.is_empty() {
            bail!("calibrator has no init candidates (add an InitStrategy)");
        }
        let mut losses = Vec::with_capacity(candidates.len());
        let mut best: Option<(f64, usize)> = None;
        for (i, c) in candidates.iter().enumerate() {
            let l = obj.loss(&c.dw, &c.da)?;
            losses.push(l);
            if l.is_finite() && best.map_or(true, |(b, _)| l < b) {
                best = Some((l, i));
            }
            let incumbent = best.map_or(l, |(b, _)| b);
            // `evals` is the phase's objective cache-miss count so far —
            // consistent with the PhaseEnd/trace totals (strategies may
            // evaluate internally; candidate re-evals are cache hits).
            obs.on_event(&CalibEvent::Eval {
                phase: PHASE_INIT,
                evals: obj.evals - evals_at_start,
                loss: l,
                best: incumbent,
            });
        }
        let (init_loss, best_idx) = match best {
            Some(b) => b,
            None => {
                // Every candidate is non-finite: the quantized net has
                // collapsed at this bitwidth.  Warn instead of silently
                // proceeding, then keep the first candidate.
                obs.on_event(&CalibEvent::Degenerate {
                    phase: PHASE_INIT,
                    detail: format!(
                        "all {} init candidates have non-finite calibration loss; \
                         keeping '{}'",
                        candidates.len(),
                        candidates[0].label
                    ),
                });
                (losses[0], 0)
            }
        };
        let init_evals = obj.evals - evals_at_start;
        let init_secs = ti.elapsed().as_secs_f64();
        obs.on_event(&CalibEvent::PhaseEnd {
            phase: PHASE_INIT,
            evals: init_evals,
            seconds: init_secs,
            loss: init_loss,
        });
        trace.push(PhaseTrace {
            phase: PHASE_INIT,
            evals: init_evals,
            seconds: init_secs,
            loss: init_loss,
        });
        let chosen = candidates.swap_remove(best_idx);
        let (dw0, da0) = (chosen.dw, chosen.da);

        // ---- joint phase (optional).
        let (dw, da, calib_loss, joint_evals) = match &self.joint {
            Some(joint) => {
                let phase = joint.phase();
                obs.on_event(&CalibEvent::PhaseStart { phase });
                let tj = std::time::Instant::now();
                let r = run_joint(joint.as_ref(), &mut obj, &dw0, &da0, &cfg.lapq, obs)?;
                let secs = tj.elapsed().as_secs_f64();
                obs.on_event(&CalibEvent::PhaseEnd { phase, evals: r.3, seconds: secs, loss: r.2 });
                trace.push(PhaseTrace { phase, evals: r.3, seconds: secs, loss: r.2 });
                r
            }
            None => (dw0, da0, init_loss, 0),
        };

        let mut outcome = QuantOutcome {
            method: cfg.method,
            bits: cfg.bits,
            quant: obj.quant_params(&dw, &da),
            mask: mask.clone(),
            calib_loss,
            fp32_calib_loss,
            init_loss,
            p_star: notes.p_star,
            quad_r2: notes.quad_r2,
            joint_evals,
            seconds: 0.0,
            trace: Vec::new(),
            original_params: None,
            wbits,
        };

        // ---- post stages.
        for p in &self.post {
            let phase = p.phase();
            obs.on_event(&CalibEvent::PhaseStart { phase });
            let tp = std::time::Instant::now();
            p.apply(eng, sess, spec, cfg, calib, &mut outcome)?;
            let secs = tp.elapsed().as_secs_f64();
            // re-read from the outcome: a stage may have improved the loss
            obs.on_event(&CalibEvent::PhaseEnd {
                phase,
                evals: 0,
                seconds: secs,
                loss: outcome.calib_loss,
            });
            trace.push(PhaseTrace { phase, evals: 0, seconds: secs, loss: outcome.calib_loss });
        }

        outcome.seconds = t0.elapsed().as_secs_f64();
        outcome.trace = trace;
        Ok(outcome)
    }
}

/// Drive a [`JointOptimizer`] over multiplicative scalings of the active
/// steps (Alg. 1 lines 13–21), emitting a [`CalibEvent::Eval`] per
/// objective evaluation.  Returns `(dw, da, loss, evals)`.
pub fn run_joint(
    joint: &dyn JointOptimizer,
    obj: &mut CalibObjective,
    dw0: &[f32],
    da0: &[f32],
    lapq: &LapqCfg,
    obs: &mut dyn CalibObserver,
) -> Result<(Vec<f32>, Vec<f32>, f64, usize)> {
    let aw = obj.mask.active_w();
    let aa = obj.mask.active_a();
    let dim = aw.len() + aa.len();
    if dim == 0 {
        let l = obj.loss(dw0, da0)?;
        return Ok((dw0.to_vec(), da0.to_vec(), l, 0));
    }
    let dw0v = dw0.to_vec();
    let da0v = da0.to_vec();
    let expand = |x: &[f64]| -> (Vec<f32>, Vec<f32>) {
        let mut dw = dw0v.clone();
        let mut da = da0v.clone();
        for (k, &i) in aw.iter().enumerate() {
            dw[i] = dw0v[i] * x[k] as f32;
        }
        for (k, &i) in aa.iter().enumerate() {
            da[i] = da0v[i] * x[aw.len() + k] as f32;
        }
        (dw, da)
    };

    let x0 = vec![1.0f64; dim];
    let lo = vec![lapq.box_lo; dim];
    let hi = vec![lapq.box_hi; dim];
    let phase = joint.phase();
    let mut n = 0usize;
    let mut best = f64::INFINITY;
    let mut f = |x: &[f64]| -> Result<f64> {
        let (dw, da) = expand(x);
        let v = obj.loss(&dw, &da)?;
        n += 1;
        if v < best {
            best = v;
        }
        obs.on_event(&CalibEvent::Eval { phase, evals: n, loss: v, best });
        Ok(v)
    };
    let r = joint.minimize(&x0, &lo, &hi, &mut f)?;
    let (dw, da) = expand(&r.x);
    Ok((dw, da, r.fx, r.evals))
}

/// Compatibility form of the joint phase for analysis benches: run the
/// *configured* optimizer with no observer attached.
pub fn joint_optimize(
    obj: &mut CalibObjective,
    dw0: &[f32],
    da0: &[f32],
    lapq: &LapqCfg,
) -> Result<(Vec<f32>, Vec<f32>, f64, usize)> {
    let joint = joint_optimizer(&lapq.joint);
    run_joint(joint.as_ref(), obj, dw0, da0, lapq, &mut NullObserver)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_kind_eq() {
        assert_eq!(InitKind::Layerwise, InitKind::Layerwise);
        assert_ne!(InitKind::Random(1), InitKind::Layerwise);
    }

    #[test]
    fn from_config_shapes() {
        let mut cfg = ExperimentConfig::default();
        cfg.method = Method::Lapq;
        let c = Calibrator::from_config(&cfg);
        assert_eq!(c.init.len(), 3);
        assert!(c.joint.is_some());
        assert_eq!(c.post.len(), 1);

        cfg.method = Method::Mmse;
        cfg.lapq.bias_correction = false;
        let c = Calibrator::from_config(&cfg);
        assert_eq!(c.init.len(), 1);
        assert!(c.joint.is_none());
        assert!(c.post.is_empty());

        // mixed adds the sharpness stage ahead of bias correction
        cfg.method = Method::Lapq;
        cfg.lapq.bias_correction = true;
        cfg.mixed.enabled = true;
        let c = Calibrator::from_config(&cfg);
        assert_eq!(c.post.len(), 2);
        assert_eq!(c.post[0].name(), "sharpness");
        assert_eq!(c.post[1].name(), "bias-correction");
    }

    #[test]
    fn builder_composes() {
        let c = Calibrator::builder()
            .init(RandomInit { seed: 7 })
            .joint_cfg(&JointCfg::default())
            .post(BiasCorrection)
            .build();
        assert_eq!(c.init.len(), 1);
        assert!(c.joint.is_some());
        assert_eq!(c.post.len(), 1);
    }
}
