//! Calibration observability: typed events emitted by the [`Calibrator`]
//! while it runs, the [`CalibObserver`] sink they flow into, and the
//! per-phase [`PhaseTrace`] records that end up on `QuantOutcome`.
//!
//! Every consumer picks its own fidelity: the CLI logs throttled progress
//! lines ([`LogObserver`]), benches collect full eval traces for free
//! ([`EventLog`]), and the TCP service streams `{"event":...}` frames to
//! the client so minutes-long calibrations are never silent.
//!
//! [`Calibrator`]: super::calibrator::Calibrator

use crate::util::json::Json;

/// One step of a calibration run.  Phase names are `&'static str` because
/// every stage type has a fixed name ("init", "joint:powell", ...).
#[derive(Clone, Debug)]
pub enum CalibEvent {
    /// A phase (init / joint / post stage) began.
    PhaseStart { phase: &'static str },
    /// One objective evaluation inside a phase.  `evals` counts within the
    /// phase; `best` is the incumbent loss so far.
    Eval { phase: &'static str, evals: usize, loss: f64, best: f64 },
    /// A phase finished: how many evaluations it spent and where it ended.
    PhaseEnd { phase: &'static str, evals: usize, seconds: f64, loss: f64 },
    /// Something structurally wrong that the run survives but the operator
    /// should know about (e.g. an all-non-finite init trajectory).
    Degenerate { phase: &'static str, detail: String },
    /// A mixed-precision bit plan was chosen (`wbits[i] == 32` marks a
    /// layer the mask left at FP32).  Streamed so a `quantize --mixed`
    /// client sees the allocation as soon as it is decided.
    Alloc { phase: &'static str, wbits: Vec<u32>, budget_bytes: usize, spent_bytes: usize },
}

impl CalibEvent {
    /// Wire form for the TCP service's streamed frames.
    pub fn to_json(&self) -> Json {
        match self {
            CalibEvent::PhaseStart { phase } => Json::obj(vec![
                ("event", Json::Str("phase_start".into())),
                ("phase", Json::Str((*phase).into())),
            ]),
            CalibEvent::Eval { phase, evals, loss, best } => Json::obj(vec![
                ("event", Json::Str("eval".into())),
                ("phase", Json::Str((*phase).into())),
                ("evals", Json::Num(*evals as f64)),
                ("loss", Json::Num(*loss)),
                ("best", Json::Num(*best)),
            ]),
            CalibEvent::PhaseEnd { phase, evals, seconds, loss } => Json::obj(vec![
                ("event", Json::Str("phase_end".into())),
                ("phase", Json::Str((*phase).into())),
                ("evals", Json::Num(*evals as f64)),
                ("seconds", Json::Num(*seconds)),
                ("loss", Json::Num(*loss)),
            ]),
            CalibEvent::Degenerate { phase, detail } => Json::obj(vec![
                ("event", Json::Str("degenerate".into())),
                ("phase", Json::Str((*phase).into())),
                ("detail", Json::Str(detail.clone())),
            ]),
            CalibEvent::Alloc { phase, wbits, budget_bytes, spent_bytes } => Json::obj(vec![
                ("event", Json::Str("alloc".into())),
                ("phase", Json::Str((*phase).into())),
                ("wbits", Json::Arr(wbits.iter().map(|&b| Json::Num(b as f64)).collect())),
                ("budget_bytes", Json::Num(*budget_bytes as f64)),
                ("spent_bytes", Json::Num(*spent_bytes as f64)),
            ]),
        }
    }

    pub fn phase(&self) -> &'static str {
        match self {
            CalibEvent::PhaseStart { phase }
            | CalibEvent::Eval { phase, .. }
            | CalibEvent::PhaseEnd { phase, .. }
            | CalibEvent::Degenerate { phase, .. }
            | CalibEvent::Alloc { phase, .. } => phase,
        }
    }
}

/// Event sink for a calibration run.
pub trait CalibObserver {
    fn on_event(&mut self, ev: &CalibEvent);
}

/// Discards everything (the default for batch jobs and tests).
#[derive(Default)]
pub struct NullObserver;

impl CalibObserver for NullObserver {
    fn on_event(&mut self, _ev: &CalibEvent) {}
}

/// Records every event (benches and tests read the trace afterwards).
#[derive(Default)]
pub struct EventLog {
    pub events: Vec<CalibEvent>,
}

impl CalibObserver for EventLog {
    fn on_event(&mut self, ev: &CalibEvent) {
        self.events.push(ev.clone());
    }
}

impl EventLog {
    pub fn evals(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, CalibEvent::Eval { .. })).count()
    }

    pub fn phases(&self) -> Vec<&'static str> {
        self.events
            .iter()
            .filter_map(|e| match e {
                CalibEvent::PhaseStart { phase } => Some(*phase),
                _ => None,
            })
            .collect()
    }

    pub fn degenerate(&self) -> bool {
        self.events.iter().any(|e| matches!(e, CalibEvent::Degenerate { .. }))
    }
}

/// Shared 1-in-N eval throttle with improvement passthrough — the one
/// policy both [`LogObserver`] and the service's stream observer apply,
/// so they can't drift apart.  Phase boundaries and degenerate warnings
/// always pass; a [`CalibEvent::Eval`] passes when it *strictly* improves
/// the throttle's own incumbent or lands on every `every`-th observed
/// eval.  Ties are suppressed (a plateaued or all-`inf` run must not
/// flood the sink), and counting observed events — not the event's own
/// `evals` field — keeps the cadence correct for the init phase, whose
/// cache-miss counter can plateau.
pub struct EvalThrottle {
    pub every: usize,
    seen: usize,
    incumbent: f64,
}

impl EvalThrottle {
    pub fn new(every: usize) -> Self {
        EvalThrottle { every, seen: 0, incumbent: f64::INFINITY }
    }

    /// Should `ev` be emitted downstream?
    pub fn admit(&mut self, ev: &CalibEvent) -> bool {
        match ev {
            CalibEvent::Eval { loss, .. } => {
                self.seen += 1;
                let improved = *loss < self.incumbent;
                if improved {
                    self.incumbent = *loss;
                }
                improved || (self.every > 0 && self.seen % self.every == 0)
            }
            _ => true,
        }
    }
}

/// Throttled `log::info!` progress lines (what `repro quantize` shows).
pub struct LogObserver {
    throttle: EvalThrottle,
}

impl LogObserver {
    /// Log improving evals plus every `every`-th one.
    pub fn every(every: usize) -> Self {
        LogObserver { throttle: EvalThrottle::new(every) }
    }
}

impl Default for LogObserver {
    fn default() -> Self {
        LogObserver::every(25)
    }
}

impl CalibObserver for LogObserver {
    fn on_event(&mut self, ev: &CalibEvent) {
        if !self.throttle.admit(ev) {
            return;
        }
        match ev {
            CalibEvent::PhaseStart { phase } => log::info!("[calib] {phase}: start"),
            CalibEvent::Eval { phase, evals, loss, best } => {
                log::info!("[calib] {phase}: eval {evals}  loss {loss:.5}  best {best:.5}")
            }
            CalibEvent::PhaseEnd { phase, evals, seconds, loss } => {
                log::info!("[calib] {phase}: done, {evals} evals, loss {loss:.5} ({seconds:.1}s)")
            }
            CalibEvent::Degenerate { phase, detail } => {
                log::warn!("[calib] {phase}: degenerate — {detail}")
            }
            CalibEvent::Alloc { phase, wbits, budget_bytes, spent_bytes } => {
                log::info!(
                    "[calib] {phase}: bits {wbits:?} ({spent_bytes} of {budget_bytes} B budget)"
                )
            }
        }
    }
}

/// Adapter: any `FnMut(&CalibEvent)` is an observer.
pub struct FnObserver<F: FnMut(&CalibEvent)>(pub F);

impl<F: FnMut(&CalibEvent)> CalibObserver for FnObserver<F> {
    fn on_event(&mut self, ev: &CalibEvent) {
        (self.0)(ev)
    }
}

/// One phase's summary on `QuantOutcome::trace` — the durable form of the
/// PhaseStart/PhaseEnd event pair.
#[derive(Clone, Debug)]
pub struct PhaseTrace {
    pub phase: &'static str,
    pub evals: usize,
    pub seconds: f64,
    /// Best calibration loss at the end of the phase.  Post stages don't
    /// evaluate the objective; their rows repeat the incumbent loss with
    /// `evals == 0`.
    pub loss: f64,
}

impl PhaseTrace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(self.phase.into())),
            ("evals", Json::Num(self.evals as f64)),
            ("seconds", Json::Num(self.seconds)),
            ("loss", Json::Num(self.loss)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_shapes() {
        let j = CalibEvent::PhaseStart { phase: "init" }.to_json();
        assert_eq!(j.req("event").as_str(), Some("phase_start"));
        assert_eq!(j.req("phase").as_str(), Some("init"));
        let j = CalibEvent::Eval { phase: "joint:powell", evals: 3, loss: 0.5, best: 0.4 }
            .to_json();
        assert_eq!(j.req("evals").as_f64(), Some(3.0));
        let j = CalibEvent::Degenerate { phase: "init", detail: "all inf".into() }.to_json();
        assert_eq!(j.req("event").as_str(), Some("degenerate"));
        let j = CalibEvent::Alloc {
            phase: "alloc",
            wbits: vec![32, 8, 2, 32],
            budget_bytes: 100,
            spent_bytes: 96,
        }
        .to_json();
        assert_eq!(j.req("event").as_str(), Some("alloc"));
        assert_eq!(j.req("wbits").as_arr().map(|a| a.len()), Some(4));
        assert_eq!(j.req("spent_bytes").as_f64(), Some(96.0));
    }

    #[test]
    fn event_log_collects() {
        let mut log = EventLog::default();
        log.on_event(&CalibEvent::PhaseStart { phase: "init" });
        log.on_event(&CalibEvent::Eval { phase: "init", evals: 1, loss: 1.0, best: 1.0 });
        log.on_event(&CalibEvent::PhaseEnd { phase: "init", evals: 1, seconds: 0.1, loss: 1.0 });
        assert_eq!(log.evals(), 1);
        assert_eq!(log.phases(), vec!["init"]);
        assert!(!log.degenerate());
    }

    #[test]
    fn throttle_admits_improvements_and_every_nth() {
        let ev = |loss: f64| CalibEvent::Eval { phase: "init", evals: 1, loss, best: loss };
        let mut t = EvalThrottle::new(3);
        // phase events always pass
        assert!(t.admit(&CalibEvent::PhaseStart { phase: "init" }));
        // strictly improving evals pass regardless of position
        assert!(t.admit(&ev(1.0)));
        // ties and regressions off-cadence are suppressed...
        assert!(!t.admit(&ev(1.0)));
        // ...but the 3rd observed eval passes on cadence
        assert!(t.admit(&ev(2.0)));
        assert!(!t.admit(&ev(2.0)));
        // a genuine improvement still cuts through immediately
        assert!(t.admit(&ev(0.5)));
        assert!(t.admit(&CalibEvent::Degenerate { phase: "init", detail: "x".into() }));
    }

    #[test]
    fn throttle_suppresses_inf_plateau() {
        // all-inf collapse: nothing "improves", only the 1-in-N cadence
        let inf = f64::INFINITY;
        let ev = || CalibEvent::Eval { phase: "j", evals: 1, loss: inf, best: inf };
        let mut t = EvalThrottle::new(5);
        let admitted = (0..20).filter(|_| t.admit(&ev())).count();
        assert_eq!(admitted, 4, "only every 5th of 20 inf evals may pass");
    }

    #[test]
    fn fn_observer_forwards() {
        let mut n = 0usize;
        {
            let mut obs = FnObserver(|_ev: &CalibEvent| n += 1);
            obs.on_event(&CalibEvent::PhaseStart { phase: "init" });
            let end = CalibEvent::PhaseEnd { phase: "init", evals: 0, seconds: 0.0, loss: 0.0 };
            obs.on_event(&end);
        }
        assert_eq!(n, 2);
    }
}
