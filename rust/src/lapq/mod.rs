//! LAPQ — the paper's contribution: loss-aware post-training calibration
//! of per-layer quantization steps (layer-wise Lp → quadratic
//! approximation over p → Powell joint optimization).

pub mod calibration;
pub mod objective;
pub mod pipeline;

pub use pipeline::{calibrate, calibrate_with_init, InitKind, QuantOutcome};
