//! LAPQ — the paper's contribution: loss-aware post-training calibration
//! of per-layer quantization steps, exposed as a composable, observable
//! [`Calibrator`] built from pluggable stages.
//!
//! # Paper Algorithm 1 ↔ stage types
//!
//! | Alg. 1 phase                         | Stage type                          |
//! |--------------------------------------|-------------------------------------|
//! | lines 1–8: layer-wise L_p per p      | [`stages::LayerwiseLp`]             |
//! | lines 9–12: quadratic fit over p, p* | [`stages::QuadraticPStar`]          |
//! | (small-model collapse guard)         | [`stages::MinMaxFallback`]          |
//! | lines 13–21: joint minimization      | [`stages::JointOptimizer`] — [`stages::PowellJoint`] (paper), [`stages::NelderMeadJoint`], [`stages::CoordinateDescentJoint`] |
//! | Table 1 baselines (no joint phase)   | [`stages::BaselineInit`]            |
//! | Table 3 "Random" init ablation       | [`stages::RandomInit`]              |
//! | Banner-style weight correction       | [`stages::BiasCorrection`] ([`stages::PostStage`]) |
//! | mixed-precision bit allocation       | [`mixed`] (profiler + knapsack DP)  |
//! | sharpness-aware Δ re-optimization    | [`mixed::SharpnessAware`] ([`stages::PostStage`]) |
//!
//! The init strategies are *composable candidates*: every strategy
//! proposes Δ vectors, the calibrator's best-of selector evaluates all of
//! them on the calibration loss and the winner seeds the joint phase —
//! exactly how Alg. 1 picks its starting point, but open to new
//! strategies (per-channel, integer-programming, alternating scalar
//! minimization, ...) without touching the pipeline.
//!
//! Runs are observable: the calibrator streams [`CalibEvent`]s into a
//! [`CalibObserver`] (CLI progress lines, bench eval traces, the TCP
//! service's `{"event":...}` frames) and records a per-phase
//! [`events::PhaseTrace`] on [`QuantOutcome::trace`].
//!
//! ```no_run
//! # use lapq::lapq::{Calibrator, stages::*, events::LogObserver};
//! # fn demo(eng: &lapq::runtime::EngineHandle, sess: lapq::runtime::SessionId,
//! #         spec: &lapq::runtime::manifest::ModelSpec,
//! #         cfg: &lapq::config::ExperimentConfig,
//! #         calib: &lapq::lapq::calibration::CalibData) -> anyhow::Result<()> {
//! let outcome = Calibrator::builder()
//!     .init(LayerwiseLp::grid())
//!     .init(MinMaxFallback)
//!     .init(QuadraticPStar::grid())
//!     .joint_cfg(&cfg.lapq.joint)
//!     .post(BiasCorrection)
//!     .build()
//!     .run(eng, sess, spec, cfg, calib, &mut LogObserver::default())?;
//! # Ok(()) }
//! ```

pub mod calibration;
pub mod calibrator;
pub mod events;
pub mod mixed;
pub mod objective;
pub mod pipeline;
pub mod stages;

pub use calibrator::{Calibrator, CalibratorBuilder, InitKind, QuantOutcome};
pub use events::{CalibEvent, CalibObserver, EventLog, LogObserver, NullObserver};
pub use pipeline::{calibrate, calibrate_with_init};
