//! Host tensors: the coordinator-side value type.
//!
//! Everything the Rust side owns — model parameters, calibration batches,
//! activation samples — lives as a [`HostTensor`] and crosses into PJRT as
//! an `xla::Literal` only at the runtime boundary (`runtime::engine`).

pub mod init;

/// Dense row-major f32 or i32 host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: Data::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice; panics on dtype mismatch.
    pub fn f(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn f_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn i(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("expected i32 tensor"),
        }
    }

    /// Scalar value of a 0-d / 1-element f32 tensor.
    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.f()[0]
    }

    /// Iterate rows of the last axis when interpreting the tensor as a
    /// matrix `(prod(shape[..-1]), shape[-1])` — used for per-channel
    /// statistics on HWIO conv weights (last axis = output channel).
    pub fn last_axis(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    /// Slice of every element whose last-axis index equals `c`.
    pub fn channel_values(&self, c: usize) -> Vec<f32> {
        let k = self.last_axis();
        self.f().iter().skip(c).step_by(k).copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = HostTensor::f32(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.f()[4], 4.0);
        assert_eq!(HostTensor::scalar_f32(7.0).item(), 7.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn channel_values_stride() {
        // shape (2, 3): channels are columns
        let t = HostTensor::f32(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.channel_values(1), vec![1.0, 4.0]);
        assert_eq!(t.channel_values(2), vec![2.0, 5.0]);
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(vec![4, 5]);
        assert_eq!(t.len(), 20);
        assert!(t.f().iter().all(|&x| x == 0.0));
    }
}
