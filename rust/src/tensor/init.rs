//! Parameter initialization mirroring `python/compile/models/common.py`.
//!
//! The Rust coordinator owns the weights: it initializes them from the
//! manifest's `ParamSpec`s (shape + init kind) and feeds them to the AOT
//! `train_step` artifact.  Exact bit-parity with the Python initializers
//! is *not* required (training happens here, not there) — only the same
//! families: He / Glorot normal, zeros, small-normal embeddings.

use super::HostTensor;
use crate::runtime::manifest::ParamSpec;
use crate::util::rng::Pcg32;

/// Initialize one parameter tensor.
pub fn init_param(spec: &ParamSpec, rng: &mut Pcg32) -> HostTensor {
    let n: usize = spec.shape.iter().product();
    let data = match spec.init.as_str() {
        "zeros" => vec![0.0; n],
        "he" => {
            let std = (2.0 / spec.fan_in.max(1) as f32).sqrt();
            (0..n).map(|_| rng.normal() * std).collect()
        }
        "glorot" => {
            let fan_out = *spec.shape.last().unwrap_or(&1);
            let std = (2.0 / (spec.fan_in + fan_out).max(1) as f32).sqrt();
            (0..n).map(|_| rng.normal() * std).collect()
        }
        "embed" => (0..n).map(|_| rng.normal() * 0.05).collect(),
        other => panic!("unknown init kind '{other}'"),
    };
    HostTensor::f32(spec.shape.clone(), data)
}

/// Initialize the full parameter list of a model.
pub fn init_params(specs: &[ParamSpec], seed: u64) -> Vec<HostTensor> {
    let mut rng = Pcg32::seeded(seed);
    specs.iter().map(|s| init_param(s, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn spec(init: &str, shape: Vec<usize>, fan_in: usize) -> ParamSpec {
        ParamSpec { name: "t".into(), shape, init: init.into(), fan_in }
    }

    #[test]
    fn he_scale_matches() {
        let mut rng = Pcg32::seeded(1);
        let t = init_param(&spec("he", vec![64, 512], 64), &mut rng);
        let std = stats::std_dev(t.f());
        let expect = (2.0f32 / 64.0).sqrt();
        assert!((std - expect).abs() / expect < 0.05, "std {std} vs {expect}");
    }

    #[test]
    fn zeros_is_zero() {
        let mut rng = Pcg32::seeded(1);
        let t = init_param(&spec("zeros", vec![16], 0), &mut rng);
        assert!(t.f().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic_by_seed() {
        let specs = vec![spec("he", vec![8, 8], 8), spec("embed", vec![10, 4], 0)];
        let a = init_params(&specs, 42);
        let b = init_params(&specs, 42);
        assert_eq!(a, b);
        let c = init_params(&specs, 43);
        assert_ne!(a, c);
    }
}
