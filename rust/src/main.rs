//! `repro` — the LAPQ coordinator binary.

fn main() {
    lapq::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = lapq::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
