//! Recommendation-system example (paper §5.2): train NCF on synthetic
//! implicit feedback, then quantize with LAPQ vs MMSE at W8/A8 and
//! compare hit-rate@10 — the Table 2 scenario as an API walkthrough.
//!
//!     cargo run --release --example ncf_recsys

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    let mut cfg = ExperimentConfig::default();
    cfg.model = "ncf".into();
    cfg.train_steps = 400;
    cfg.lr = 0.5;
    cfg.calib_size = 8192;
    cfg.val_size = 2048;

    println!("model  W/A    method   FP32 HR@10   quant HR@10");
    for (bits, method) in [
        (BitSpec::new(8, 8), Method::Lapq),
        (BitSpec::new(8, 8), Method::Mmse),
        (BitSpec::new(32, 8), Method::Lapq),
        (BitSpec::new(8, 32), Method::Lapq),
    ] {
        cfg.bits = bits;
        cfg.method = method;
        let res = runner.run(&cfg)?;
        println!(
            "ncf    {:<6} {:<8} {:>6.1}%      {:>6.1}%",
            res.bits_label.replace(' ', ""),
            res.method,
            res.fp32_metric * 100.0,
            res.quant_metric * 100.0,
        );
    }
    Ok(())
}
