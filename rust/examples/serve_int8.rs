//! Serving walkthrough: calibrate → pack → save/load → integer infer.
//!
//! Trains the small MLP, calibrates it with LAPQ at W8/A8, packs the
//! session into a deployable integer artifact (i8 weights, power-of-two
//! scales), round-trips it through disk, and serves predictions with the
//! integer engine — verifying bit-for-bit parity against the fake-quant
//! reference along the way.
//!
//!     cargo run --release --example serve_int8

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::workload::{Split, Workload};
use lapq::runtime::cpu::ops::argmax_correct;
use lapq::runtime::int::{ExecMode, InferSession, PackOpts, QuantizedModel};
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();

    // 1. Calibrate: train the FP32 model and run LAPQ at INT8.
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp3".into();
    cfg.train_steps = 150;
    cfg.lr = 0.1;
    cfg.bits = BitSpec::new(8, 8);
    cfg.method = Method::Lapq;

    // 2. Pack: quantize the calibrated session into a deployable
    //    artifact (i8 weights, per-channel scales, i32 bias).
    let (sum, _qm) = runner.pack(&cfg, &PackOpts::default())?;
    println!(
        "packed {}: {} int tensors, {} -> {} weight bytes ({:.2}x smaller)",
        sum.key,
        sum.int_params,
        sum.f32_bytes,
        sum.packed_bytes,
        sum.f32_bytes as f64 / sum.packed_bytes.max(1) as f64,
    );
    println!(
        "val metric: fp32 {:.1}% -> packed int grid {:.1}%",
        sum.fp32_metric * 100.0,
        sum.quant_metric * 100.0
    );

    // 3. Ship it: the artifact is two files, quantized.json + weights.bin.
    let dir = std::env::temp_dir().join("lapq_serve_int8_example");
    let cached = runner.packed_get(&sum.key).expect("just packed");
    cached.save(&dir)?;
    let deployed = QuantizedModel::load(&dir)?;
    println!("artifact round-tripped through {dir:?}");

    // 4. Serve: integer forward passes, no engine or session required.
    let spec = runner.eng.manifest().model(&deployed.model)?.clone();
    let sess = InferSession::new(&spec, &deployed)?;
    let workload = Workload::for_model(&spec, cfg.seed)?;
    let mut rows = 0usize;
    let mut correct = 0.0f32;
    let t0 = std::time::Instant::now();
    for batch in workload.eval_batches(&spec, Split::Val, 4) {
        let res = sess.infer(&batch[..1], ExecMode::Int)?;
        correct += argmax_correct(&res.logits, batch[1].i());
        rows += res.logits.shape[0];
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "integer engine: {rows} rows in {dt:.3}s ({:.0} rows/s), accuracy {:.1}%",
        rows as f64 / dt.max(1e-9),
        100.0 * correct / rows.max(1) as f32
    );

    // 5. Trust it: the integer path matches the fake-quant reference
    //    bit-for-bit (power-of-two scales, dense INT8).
    let check = workload.eval_batches(&spec, Split::Val, 1);
    let int_res = sess.infer(&check[0][..1], ExecMode::Int)?;
    let sim_res = sess.infer(&check[0][..1], ExecMode::Simulated)?;
    let exact = int_res
        .logits
        .data
        .iter()
        .zip(&sim_res.logits.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!("parity vs fake-quant reference: {}", if exact { "bit-exact" } else { "DIVERGED" });
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
