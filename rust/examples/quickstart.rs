//! Quickstart: train a small MLP on the synthetic task, quantize it to
//! W4/A4 with LAPQ (watching the calibration phases live), and compare
//! against the MMSE baseline.
//!
//!     cargo run --release --example quickstart

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::events::{CalibEvent, FnObserver};
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();

    // 1. Boot the default backend (pure-Rust CPU; PJRT with --features
    //    xla over `make artifacts`).
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    // 2. Describe the experiment: model, training budget, quantization.
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp3".into();
    cfg.train_steps = 150;
    cfg.lr = 0.1;
    cfg.bits = BitSpec::new(4, 4);

    // 3. Run LAPQ and the baselines (training is cached across jobs).
    //    Any `FnMut(&CalibEvent)` can watch a calibration run.
    for method in [Method::Lapq, Method::Mmse, Method::MinMax] {
        cfg.method = method;
        let mut obs = FnObserver(|ev: &CalibEvent| {
            if let CalibEvent::PhaseEnd { phase, evals, loss, .. } = ev {
                println!("    [{phase}] {evals} evals -> loss {loss:.4}");
            }
        });
        let res = runner.run_observed(&cfg, &mut obs)?;
        println!(
            "{:<7} W{}/A{}  FP32 {:.1}% -> quant {:.1}%   calib loss {:.4} (fp32 {:.4})",
            res.method,
            cfg.bits.weights,
            cfg.bits.acts,
            res.fp32_metric * 100.0,
            res.quant_metric * 100.0,
            res.outcome.calib_loss,
            res.outcome.fp32_calib_loss,
        );
    }
    Ok(())
}
