//! Loss-landscape explorer (Fig. 1): dump the calibration-loss surface
//! over the first two quantized conv layers' weight steps at a chosen
//! bitwidth, as CSV for plotting.
//!
//!     cargo run --release --example loss_landscape -- [bits] [out.csv]

use lapq::analysis::surface::scan_weight_surface;
use lapq::config::{BitSpec, ExperimentConfig};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::stages::layerwise_deltas;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bits: u32 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(3);
    let out = args.get(1).cloned().unwrap_or_else(|| format!("surface_{bits}bit.csv"));

    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "cnn6".into();
    cfg.train_steps = 200;
    cfg.bits = BitSpec::new(bits, 32); // weight-only surface, like Fig. 1
    cfg.lapq.exclude_first_last = false; // we scan layers 1 and 2

    let spec = runner.eng.manifest().model("cnn6")?.clone();
    let (sess, _val, calib) = runner.session_with_calib(&cfg)?;
    let mask = LayerMask::all(spec.n_quant_layers(), cfg.bits);
    let (qmw, qma) = grids(&spec, cfg.bits);
    let mut obj = CalibObjective::new(
        &runner.eng,
        sess,
        calib.loss_batches.clone(),
        mask.clone(),
        qmw.clone(),
        qma.clone(),
    );
    let (dw, da) = layerwise_deltas(&calib, &mask, &qmw, &qma, 2.0);

    let surface = scan_weight_surface(&mut obj, &dw, &da, 1, 2, 0.4, 3.0, 15)?;
    std::fs::write(&out, surface.to_csv())?;
    let (lo, hi) = surface.min_max();
    println!(
        "wrote {out}: loss range [{lo:.4}, {hi:.4}], interaction index {:.4} (0 = separable)",
        surface.interaction_index()
    );
    Ok(())
}
