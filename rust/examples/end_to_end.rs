//! End-to-end driver (the repo's full-system validation): trains the cnn6
//! stand-in from scratch through the AOT `train_step` artifact (logging
//! the loss curve), then runs the complete LAPQ pipeline and every
//! baseline at W4/A4 and W8/A8, evaluating on a held-out validation set.
//! All three layers compose: Pallas kernels inside the JAX-lowered HLO,
//! executed by the Rust coordinator — Python never runs.
//!
//!     cargo run --release --example end_to_end

use lapq::benchkit::Table;
use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::scheduler::Scheduler;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    let mut base = ExperimentConfig::default();
    base.model = "cnn6".into();
    base.train_steps = 300;
    base.lr = 0.02;
    base.calib_size = 512;
    base.val_size = 2048;
    base.lapq.joint.max_evals = 150;

    // 1. Train (cached for all subsequent jobs) and show the loss curve.
    let (_, report) = runner.trained_params(&base)?;
    println!("\n== training loss curve (cnn6, {} steps, {:.1}s) ==", report.steps, report.seconds);
    for (step, loss) in &report.losses {
        let bar = "#".repeat((loss * 20.0) as usize);
        println!("  step {step:>4}  loss {loss:.4}  {bar}");
    }

    // 2. Quantize with every method at two bitwidths.
    let mut sched = Scheduler::new();
    for bits in [BitSpec::new(8, 8), BitSpec::new(4, 4)] {
        for method in [Method::Lapq, Method::Mmse, Method::Aciq, Method::Kld, Method::MinMax] {
            let mut cfg = base.clone();
            cfg.bits = bits;
            cfg.method = method;
            sched.push(cfg);
        }
    }
    sched.run_all(&mut runner)?;
    let table = sched.summary_table("end-to-end: cnn6 quantization");
    table.print();
    let _ = table.write_csv("end_to_end.csv");

    // 3. Engine counters: proof of what ran where.
    let stats = runner.eng.stats()?;
    println!(
        "\nengine: {} executions, {} compiled artifacts, {:.1}s XLA time",
        stats.executions, stats.compiled, stats.exec_seconds
    );
    if !sched.failures.is_empty() {
        anyhow::bail!("{} jobs failed", sched.failures.len());
    }
    Ok(())
}
