//! Perf: the Layer-3 hot path — compiled-artifact execution latency for
//! every entry point, objective evaluation throughput (what Powell pays
//! per step), memoization hit rate, and train-step throughput.
//! Feeds EXPERIMENTS.md §Perf.

use lapq::benchkit::bench;
use lapq::config::{BitSpec, ExperimentConfig};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::pipeline::layerwise_deltas;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    for model in ["mlp3", "cnn6", "resmini", "ncf"] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = model.into();
        cfg.train_steps = 30;
        cfg.bits = BitSpec::new(4, 4);
        cfg.val_size = 512;
        let spec = runner.eng.manifest().model(model)?.clone();
        let (sess, val, calib) = runner.session_with_calib(&cfg)?;
        let b0 = calib.loss_batches[0];

        // raw artifact execution latencies
        let eng = runner.eng.clone();
        bench(&format!("{model}/fwd_fp32 (B={})", spec.eval_batch()), 2, 10, || {
            eng.eval(sess, None, b0).unwrap();
        });
        let mask = LayerMask::all(spec.n_quant_layers(), cfg.bits).exclude_first_last(&[]);
        let (qmw, qma) = grids(&spec, cfg.bits);
        let mut obj = CalibObjective::new(&eng, sess, calib.loss_batches.clone(), mask.clone(), qmw.clone(), qma.clone());
        let (dw, da) = layerwise_deltas(&calib, &mask, &qmw, &qma, 2.0);
        let q = obj.quant_params(&dw, &da);
        bench(&format!("{model}/fwd_quant (B={})", spec.eval_batch()), 2, 10, || {
            eng.eval(sess, Some(q.clone()), b0).unwrap();
        });

        // full objective eval (all calib batches) — Powell's unit of work
        let mut i = 0u32;
        bench(&format!("{model}/objective ({} batches)", obj.batches.len()), 1, 10, || {
            // perturb to defeat the memo cache: measures real evals
            i += 1;
            let mut dwp = dw.clone();
            if let Some(v) = dwp.iter_mut().find(|v| **v > 0.0) {
                *v *= 1.0 + i as f32 * 1e-4;
            }
            obj.loss(&dwp, &da).unwrap();
        });
        // memoized objective eval (cache hit)
        bench(&format!("{model}/objective cached"), 1, 50, || {
            obj.loss(&dw, &da).unwrap();
        });

        // train-step throughput
        let spec_tb = spec.train_batch();
        let wl = lapq::coordinator::workload::Workload::for_model(&spec, 1)?;
        let tb = eng.register_batch(wl.train_batch(&spec, 0))?;
        bench(&format!("{model}/train_step (B={spec_tb})"), 2, 10, || {
            eng.train_step(sess, tb, 0.01).unwrap();
        });

        let _ = val;
        calib.release(&eng);
        eng.drop_session(sess)?;
    }

    let stats = runner.eng.stats()?;
    println!(
        "\nengine totals: {} executions, {:.2}s XLA time, {:.2} ms/exec mean",
        stats.executions,
        stats.exec_seconds,
        1e3 * stats.exec_seconds / stats.executions.max(1) as f64
    );
    Ok(())
}
