//! Perf: the Layer-3 hot path — backend execution latency for every entry
//! point, objective evaluation throughput (what Powell pays per step),
//! memoization hit rate, and train-step throughput.  Feeds
//! EXPERIMENTS.md §Perf.
//!
//! `BENCH_SMOKE=1` runs a bounded subset (CI-sized) — either way the
//! timings land in `bench_results/BENCH_hotpath.json` so the perf
//! trajectory accumulates PR over PR.

use lapq::benchkit::{bench, Timing};
use lapq::config::{BitSpec, ExperimentConfig};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::stages::layerwise_deltas;
use lapq::runtime::EngineHandle;
use lapq::util::json::Json;

fn timing_json(t: &Timing) -> Json {
    Json::obj(vec![
        ("name", Json::Str(t.name.clone())),
        ("iters", Json::Num(t.iters as f64)),
        ("mean_s", Json::Num(t.mean_s)),
        ("p50_s", Json::Num(t.p50_s)),
        ("p95_s", Json::Num(t.p95_s)),
    ])
}

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");
    let models: &[&str] =
        if smoke { &["mlp3", "ncf"] } else { &["mlp3", "cnn6", "resmini", "ncf"] };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 10) };

    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut timings: Vec<Timing> = Vec::new();

    for &model in models {
        let mut cfg = ExperimentConfig::default();
        cfg.model = model.into();
        cfg.train_steps = if smoke { 10 } else { 30 };
        cfg.bits = BitSpec::new(4, 4);
        cfg.val_size = 512;
        let spec = runner.eng.manifest().model(model)?.clone();
        let (sess, val, calib) = runner.session_with_calib(&cfg)?;
        let b0 = calib.loss_batches[0];

        // raw entry-point execution latencies
        let eng = runner.eng.clone();
        let name = format!("{model}/fwd_fp32 (B={})", spec.eval_batch());
        timings.push(bench(&name, warmup, iters, || {
            eng.eval(sess, None, b0).unwrap();
        }));
        let mask = LayerMask::all(spec.n_quant_layers(), cfg.bits).exclude_first_last(&[]);
        let (qmw, qma) = grids(&spec, cfg.bits);
        let mut obj = CalibObjective::new(
            &eng,
            sess,
            calib.loss_batches.clone(),
            mask.clone(),
            qmw.clone(),
            qma.clone(),
        );
        let (dw, da) = layerwise_deltas(&calib, &mask, &qmw, &qma, 2.0);
        let q = obj.quant_params(&dw, &da);
        let name = format!("{model}/fwd_quant (B={})", spec.eval_batch());
        timings.push(bench(&name, warmup, iters, || {
            eng.eval(sess, Some(q.clone()), b0).unwrap();
        }));

        // full objective eval (all calib batches) — Powell's unit of work
        let mut i = 0u32;
        let name = format!("{model}/objective ({} batches)", obj.batches.len());
        timings.push(bench(&name, 1, iters, || {
            // perturb to defeat the memo cache: measures real evals
            i += 1;
            let mut dwp = dw.clone();
            if let Some(v) = dwp.iter_mut().find(|v| **v > 0.0) {
                *v *= 1.0 + i as f32 * 1e-4;
            }
            obj.loss(&dwp, &da).unwrap();
        }));
        // memoized objective eval (cache hit)
        timings.push(bench(&format!("{model}/objective cached"), 1, 5 * iters, || {
            obj.loss(&dw, &da).unwrap();
        }));

        // train-step throughput
        let spec_tb = spec.train_batch();
        let wl = lapq::coordinator::workload::Workload::for_model(&spec, 1)?;
        let tb = eng.register_batch(wl.train_batch(&spec, 0))?;
        timings.push(bench(&format!("{model}/train_step (B={spec_tb})"), warmup, iters, || {
            eng.train_step(sess, tb, 0.01).unwrap();
        }));

        let _ = val;
        calib.release(&eng);
        eng.drop_session(sess)?;
    }

    let stats = runner.eng.stats()?;
    println!(
        "\nengine totals: {} executions, {:.2}s exec time, {:.2} ms/exec mean",
        stats.executions,
        stats.exec_seconds,
        1e3 * stats.exec_seconds / stats.executions.max(1) as f64
    );

    // Perf-trajectory artifact (uploaded by CI).
    let report = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".into())),
        ("smoke", Json::Bool(smoke)),
        ("backend", Json::Str(runner.eng.backend_name().into())),
        ("benches", Json::Arr(timings.iter().map(timing_json).collect())),
        (
            "engine",
            Json::obj(vec![
                ("executions", Json::Num(stats.executions as f64)),
                ("compiled", Json::Num(stats.compiled as f64)),
                ("exec_seconds", Json::Num(stats.exec_seconds)),
            ]),
        ),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_hotpath.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
