//! Fig. 4: the L_p quantization-error curves e_p(Δ) for several p on one
//! weight tensor — pure Layer-1-mirror math, no PJRT needed.
//! Paper shape: each p has an interior optimum and the optimal Δ grows
//! with p (the clipping/rounding trade-off).

use lapq::benchkit::Table;
use lapq::quant::lp::lp_error;
use lapq::quant::GridKind;
use lapq::util::rng::Pcg32;

fn main() {
    lapq::util::logging::init();
    // A realistic weight population: mixture of Gaussians like a trained
    // conv layer (heavier tails than pure Gaussian).
    let mut rng = Pcg32::seeded(42);
    let mut w: Vec<f32> = rng.normal_vec(16_384).iter().map(|x| x * 0.05).collect();
    w.extend(rng.normal_vec(2_048).iter().map(|x| x * 0.15));

    let qmax = GridKind::Signed.qmax(4);
    let ps = [1.0f32, 2.0, 3.0, 4.0];
    let deltas: Vec<f32> = (1..=80).map(|i| i as f32 * 0.002).collect();

    let mut t = Table::new("Fig. 4 — e_p(Δ) curves (4-bit grid)", &["p", "argmin Δ", "min e_p"]);
    let mut csv = String::from("delta");
    for &p in &ps {
        csv += &format!(",p{p}");
    }
    csv.push('\n');
    let mut curves: Vec<Vec<f64>> = Vec::new();
    for &p in &ps {
        curves.push(deltas.iter().map(|&d| lp_error(&w, d, qmax, p, GridKind::Signed)).collect());
    }
    for (i, &d) in deltas.iter().enumerate() {
        csv += &format!("{d}");
        for c in &curves {
            csv += &format!(",{}", c[i]);
        }
        csv.push('\n');
    }
    let mut argmins = Vec::new();
    for (k, &p) in ps.iter().enumerate() {
        let (i, v) = curves[k]
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        argmins.push(deltas[i]);
        t.row(&[format!("{p}"), format!("{:.4}", deltas[i]), format!("{v:.4}")]);
    }
    t.print();
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("fig4_curves.csv"), csv).unwrap();
    let _ = t.write_csv("fig4.csv");

    // shape check: optimal Δ non-decreasing in p
    assert!(
        argmins.windows(2).all(|w| w[1] >= w[0] - 1e-6),
        "optimal Δ should grow with p: {argmins:?}"
    );
    println!("[fig4] optimal Δ grows with p: {argmins:?}");
}
