//! Figs. 1–2: the loss surface over (Δ₁, Δ₂) of two adjacent conv layers
//! at 2/3/4-bit weight quantization, plus the quantization-interaction
//! index (Eq. 7 made measurable).  Paper shape: near-separable at 4 bits,
//! strongly coupled at 2 bits.

use lapq::analysis::surface::scan_weight_surface;
use lapq::benchkit::Table;
use lapq::config::{BitSpec, ExperimentConfig};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::stages::layerwise_deltas;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let spec = runner.eng.manifest().model("cnn6")?.clone();

    let mut t = Table::new(
        "Figs. 1-2 — loss-surface interaction vs bitwidth (cnn6 conv2/conv3)",
        &["bits", "min loss", "max loss", "interaction idx"],
    );

    for bits in [4u32, 3, 2] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "cnn6".into();
        cfg.train_steps = 300;
        cfg.bits = BitSpec::new(bits, 32); // weight-only, like Fig. 1
        cfg.lapq.exclude_first_last = false;
        let (sess, _val, calib) = runner.session_with_calib(&cfg)?;
        // Fig. 1 scans the steps of two layers: quantize ONLY those two
        // (everything else FP32) so the surface isolates their interaction.
        let mut mask = LayerMask::all(spec.n_quant_layers(), cfg.bits);
        for (i, m) in mask.weights.iter_mut().enumerate() {
            *m = i == 1 || i == 2;
        }
        let (qmw, qma) = grids(&spec, cfg.bits);
        let mut obj = CalibObjective::new(
            &runner.eng,
            sess,
            calib.loss_batches.clone(),
            mask.clone(),
            qmw.clone(),
            qma.clone(),
        );
        let (dw, da) = layerwise_deltas(&calib, &mask, &qmw, &qma, 2.0);
        let s = scan_weight_surface(&mut obj, &dw, &da, 1, 2, 0.4, 2.5, 11)?;
        let (lo, hi) = s.min_max();
        t.row(&[
            bits.to_string(),
            format!("{lo:.4}"),
            format!("{hi:.4}"),
            format!("{:.4}", s.interaction_index()),
        ]);
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("fig1_surface_{bits}bit.csv")), s.to_csv())?;
        calib.release(&runner.eng);
        runner.eng.drop_session(sess)?;
    }
    t.print();
    let _ = t.write_csv("fig1_2.csv");
    Ok(())
}
