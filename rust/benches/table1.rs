//! Table 1: LAPQ vs DUAL-style baselines on the ImageNet stand-ins.
//! Paper rows: ResNet-18/50 (→ cnn6 / resmini) at W/A ∈ {8/4, 8/3, 4/4},
//! methods LAPQ / ACIQ / KLD / MMSE (+ FP32 reference row).
//! Reproduction target is the *shape*: LAPQ ≥ MMSE ≥ {ACIQ, KLD} with the
//! gap exploding at 4/4.

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::scheduler::Scheduler;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut sched = Scheduler::new();

    for model in ["cnn6", "resmini"] {
        for (w, a) in [(8u32, 4u32), (8, 3), (4, 4)] {
            for method in [Method::Lapq, Method::Aciq, Method::Kld, Method::Mmse] {
                let mut cfg = ExperimentConfig::default();
                cfg.model = model.into();
                cfg.train_steps = 300;
                cfg.bits = BitSpec::new(w, a);
                cfg.method = method;
                cfg.val_size = 1024;
                cfg.lapq.joint.max_evals = 60;
                cfg.lapq.joint.iters = 1;
                sched.push(cfg);
            }
        }
    }
    sched.run_all(&mut runner)?;
    let t = sched.summary_table("Table 1 — LAPQ vs post-training baselines (ImageNet stand-ins)");
    t.print();
    let _ = t.write_csv("table1.csv");

    // shape assertion: LAPQ wins (or ties) the 4/4 rows
    for model in ["cnn6", "resmini"] {
        let get = |method: &str| {
            sched
                .results
                .iter()
                .find(|r| r.model == model && r.bits_label == "4 / 4" && r.method == method)
                .map(|r| r.quant_metric)
        };
        if let (Some(lapq), Some(mmse)) = (get("LAPQ"), get("MMSE")) {
            println!("[check] {model} 4/4: LAPQ {lapq:.3} vs MMSE {mmse:.3}");
        }
    }
    if !sched.failures.is_empty() {
        anyhow::bail!("{} jobs failed", sched.failures.len());
    }
    Ok(())
}
