//! Perf: the concurrent serving subsystem under load — throughput and
//! p50/p95/p99 latency of single-row INT8 `mlp3` infer requests at
//! client concurrency 1/8/32, worker pool + micro-batching on vs off.
//!
//! Three scenarios share one engine:
//!
//! * `workers1_nobatch` — one worker, batching disabled: the old
//!   strictly-sequential behaviour, expressed through the same code
//!   path.
//! * `pool_batch` — a wide worker pool with the 2 ms coalescing window:
//!   requests arriving together execute as one batch over the
//!   batch-parallel integer kernels.
//! * `pool_batch_bin1` — the same pool, clients negotiated onto the
//!   bin1 binary frames (`proto::frame`) instead of JSON lines.
//!
//! Two more probe the event-driven core:
//!
//! * **idle connections** — a `serve.io = poll` server holding hundreds
//!   (thousands, in full runs) of idle sockets: RSS and thread-count
//!   deltas per connection, plus ping latency through the loaded poll
//!   set (`idle_rss_kib` / `idle_thread_delta` headline keys).
//! * **per-model lanes** — two hot packed models behind `max_lanes` 1
//!   vs 4: the `two_model_lane_speedup` headline is the throughput
//!   ratio once each model coalesces on its own batcher thread.
//!
//! `BENCH_SMOKE=1` runs a bounded subset (CI-sized) — either way the
//! numbers land in `bench_results/BENCH_serve.json`, next to
//! `BENCH_hotpath.json` / `BENCH_int_infer.json` / `BENCH_calib.json`.

use lapq::benchkit::{f3, Table};
use lapq::config::{BitSpec, ExperimentConfig, IoMode, Method, ServeCfg};
use lapq::proto::wire::Client;
use lapq::proto::{InferRequest, Request};
use lapq::runtime::int::kernels::{active_kernel_name, KernelChoice};
use lapq::runtime::EngineHandle;
use lapq::serve::PoolServer;
use lapq::tensor::HostTensor;
use lapq::util::json::Json;
use lapq::util::stats;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

fn infer_req(key: &str, row: &[f32]) -> String {
    Json::obj(vec![
        ("cmd", Json::Str("infer".into())),
        ("key", Json::Str(key.into())),
        ("x", Json::Arr(vec![Json::arr_f32(row)])),
    ])
    .dump()
}

/// A numeric field out of `/proc/self/status` (kB for `Vm*` fields,
/// a plain count for `Threads:`); 0.0 where procfs is unavailable.
fn proc_status(field: &str) -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with(field))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0.0)
}

/// One counter out of the server's `{"cmd":"metrics"}` endpoint.
fn counter(c: &mut Client, name: &str) -> f64 {
    c.call(&Request::Metrics)
        .ok()
        .and_then(|j| j.req("metrics").get(name).and_then(|v| v.as_f64()))
        .unwrap_or(0.0)
}

/// `clients` persistent connections, each issuing `reqs` sequential
/// single-row infer requests over JSON lines or — after the hello
/// handshake — bin1 frames.  Client `ci` targets `keys[ci % len]`, so
/// passing two keys splits the load across two packed models.
/// Returns (throughput req/s, latencies s).
fn run_load(
    addr: SocketAddr,
    keys: &[String],
    clients: usize,
    reqs: usize,
    bin: bool,
) -> (f64, Vec<f32>) {
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for ci in 0..clients {
        let key = keys[ci % keys.len()].clone();
        handles.push(std::thread::spawn(move || {
            // deterministic, distinct per client
            let row: Vec<f32> =
                (0..64).map(|j| ((ci * 31 + j * 7) % 23) as f32 * 0.04 - 0.4).collect();
            let mut lat = Vec::with_capacity(reqs);
            if bin {
                let mut c = Client::connect(&addr).expect("connect");
                c.hello_bin1().expect("hello/bin1");
                let ir = InferRequest {
                    key,
                    inputs: vec![HostTensor::f32(vec![1, row.len()], row)],
                };
                for _ in 0..reqs {
                    let t = Instant::now();
                    let (reply, _preds) = c.infer_bin(&ir).expect("framed infer");
                    lat.push(t.elapsed().as_secs_f64() as f32);
                    assert_eq!(reply.rows, 1);
                }
                return lat;
            }
            let stream = TcpStream::connect(addr).expect("connect");
            let mut w = stream.try_clone().expect("clone");
            let mut r = BufReader::new(stream);
            let req = infer_req(&key, &row);
            let mut line = String::new();
            for _ in 0..reqs {
                let t = Instant::now();
                w.write_all(req.as_bytes()).expect("write");
                w.write_all(b"\n").expect("write");
                w.flush().expect("flush");
                line.clear();
                r.read_line(&mut line).expect("read");
                lat.push(t.elapsed().as_secs_f64() as f32);
                let resp = line.parse::<Json>().expect("json response");
                assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
            }
            lat
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    ((clients * reqs) as f64 / wall, all)
}

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");
    let concurrencies: &[usize] = if smoke { &[1, 8] } else { &[1, 8, 32] };
    let reqs = if smoke { 40 } else { 200 };
    let total_conns: usize = concurrencies.iter().sum();

    // One INT8 mlp3 artifact per scenario (packed at startup, served
    // from the registry throughout).
    let pack_cfg = ExperimentConfig {
        model: "mlp3".into(),
        train_steps: if smoke { 40 } else { 120 },
        lr: 0.1,
        val_size: 512,
        bits: BitSpec::new(8, 8),
        method: Method::Mmse,
        ..Default::default()
    };
    let eng = EngineHandle::start_default()?;

    let base = ServeCfg { queue_bound: 256, registry_cap: 4, ..Default::default() };
    let pool = ServeCfg { workers: 32, batch_window_ms: 2.0, max_batch: 32, ..base.clone() };
    let scenarios: Vec<(&str, ServeCfg, bool)> = vec![
        (
            "workers1_nobatch",
            ServeCfg { workers: 1, batch_window_ms: 0.0, max_batch: 1, ..base },
            false,
        ),
        ("pool_batch", pool.clone(), false),
        ("pool_batch_bin1", pool, true),
    ];

    let mut table = Table::new(
        "concurrent serving: throughput + tail latency (INT8 mlp3, 1-row requests)",
        &["scenario", "conc", "req/s", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut scen_json = Vec::new();
    let mut peaks: Vec<(String, usize, f64)> = Vec::new();
    for (name, scfg, bin) in &scenarios {
        let server = PoolServer::bind("127.0.0.1:0", eng.clone(), scfg.clone())?;
        let key = server.preload(std::slice::from_ref(&pack_cfg))?.remove(0);
        let addr = server.addr;
        let srv = std::thread::spawn(move || server.serve(total_conns));
        let mut runs = Vec::new();
        for &c in concurrencies {
            let (rps, lat) = run_load(addr, std::slice::from_ref(&key), c, reqs, *bin);
            let p50 = stats::percentile(&lat, 50.0) as f64 * 1e3;
            let p95 = stats::percentile(&lat, 95.0) as f64 * 1e3;
            let p99 = stats::percentile(&lat, 99.0) as f64 * 1e3;
            table.row(&[
                name.to_string(),
                c.to_string(),
                format!("{rps:.0}"),
                f3(p50),
                f3(p95),
                f3(p99),
            ]);
            peaks.push((name.to_string(), c, rps));
            runs.push(Json::obj(vec![
                ("concurrency", Json::Num(c as f64)),
                ("requests", Json::Num((c * reqs) as f64)),
                ("throughput_rps", Json::Num(rps)),
                ("p50_ms", Json::Num(p50)),
                ("p95_ms", Json::Num(p95)),
                ("p99_ms", Json::Num(p99)),
            ]));
        }
        srv.join().expect("server thread")?;
        scen_json.push(Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("wire", Json::Str(if *bin { "bin1".into() } else { "json".into() })),
            ("workers", Json::Num(scfg.workers as f64)),
            ("batch_window_ms", Json::Num(scfg.batch_window_ms)),
            ("max_batch", Json::Num(scfg.max_batch as f64)),
            ("queue_bound", Json::Num(scfg.queue_bound as f64)),
            ("runs", Json::Arr(runs)),
        ]));
    }
    table.print();

    let find = |n: &str, c: usize| {
        peaks.iter().find(|kv| kv.0 == n && kv.1 == c).map(|kv| kv.2).unwrap_or(0.0)
    };
    let (seq8, pool8) = (find("workers1_nobatch", 8), find("pool_batch", 8));
    let speedup = pool8 / seq8.max(1e-9);
    println!(
        "\nconcurrency 8: pool+batch {pool8:.0} req/s vs workers=1/no-batch {seq8:.0} req/s ({speedup:.2}x)"
    );
    // the wire delta at the highest concurrency exercised (32 in full
    // runs, 8 under BENCH_SMOKE)
    let top = *concurrencies.iter().max().unwrap_or(&8);
    let (json_top, bin_top) = (find("pool_batch", top), find("pool_batch_bin1", top));
    let wire_speedup = bin_top / json_top.max(1e-9);
    println!(
        "concurrency {top}: bin1 {bin_top:.0} req/s vs JSON {json_top:.0} req/s ({wire_speedup:.2}x)"
    );

    // -- idle connections under the readiness-polled reactor ---------------
    // The core claim of `serve.io = poll`: idle sockets cost reactor
    // bookkeeping, not threads.  Hold `n_idle` open connections and
    // measure the process-wide RSS and thread-count deltas.
    let n_idle: usize = if smoke { 256 } else { 2048 };
    // both ends of every idle connection live in this process
    let _ = poll_shim::raise_nofile((2 * n_idle + 512) as u64);
    let idle_cfg = ServeCfg {
        io: IoMode::Poll,
        workers: 2,
        batch_window_ms: 0.0,
        max_batch: 8,
        queue_bound: 256,
        registry_cap: 4,
        max_conns: n_idle + 64,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng.clone(), idle_cfg)?;
    let addr = server.addr;
    let handle = server.shutdown_handle();
    let srv = std::thread::spawn(move || server.serve(usize::MAX));
    let mut probe = Client::connect(&addr)?;
    probe.call(&Request::Ping)?; // reactor + its workers are up
    let conns0 = counter(&mut probe, "serve_conns");
    let (rss0, thr0) = (proc_status("VmRSS:"), proc_status("Threads:"));
    let mut idles = Vec::with_capacity(n_idle);
    for _ in 0..n_idle {
        idles.push(TcpStream::connect(addr)?);
    }
    // the accept counter says when the reactor has swept them all in
    while counter(&mut probe, "serve_conns") < conns0 + n_idle as f64 {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let (rss1, thr1) = (proc_status("VmRSS:"), proc_status("Threads:"));
    let idle_rss_kib = (rss1 - rss0).max(0.0);
    let idle_thread_delta = thr1 - thr0;
    let mut lat = Vec::with_capacity(50);
    for _ in 0..50 {
        let t = Instant::now();
        probe.call(&Request::Ping)?;
        lat.push(t.elapsed().as_secs_f64() as f32 * 1e3);
    }
    let idle_ping_p50_ms = stats::percentile(&lat, 50.0) as f64;
    drop(idles);
    drop(probe);
    handle.shutdown();
    srv.join().expect("idle server")?;
    println!(
        "idle {n_idle} conns (io poll): +{idle_rss_kib:.0} KiB RSS ({:.2} KiB/conn), \
         +{idle_thread_delta:.0} threads, ping p50 {idle_ping_p50_ms:.3} ms",
        idle_rss_kib / n_idle.max(1) as f64
    );

    // -- per-model batcher lanes -------------------------------------------
    // Two hot models, eight clients split across them: with one lane
    // both models coalesce on a single batcher thread; with four each
    // model gets its own.
    let pack_cfg4 = ExperimentConfig { bits: BitSpec::new(4, 4), ..pack_cfg.clone() };
    let mut lane_rps = Vec::new();
    for max_lanes in [1usize, 4] {
        let scfg = ServeCfg {
            workers: 16,
            batch_window_ms: 0.5,
            max_batch: 32,
            queue_bound: 256,
            registry_cap: 4,
            max_lanes,
            ..Default::default()
        };
        let server = PoolServer::bind("127.0.0.1:0", eng.clone(), scfg)?;
        let keys = server.preload(&[pack_cfg.clone(), pack_cfg4.clone()])?;
        let addr = server.addr;
        let srv = std::thread::spawn(move || server.serve(8));
        let (rps, _lat) = run_load(addr, &keys, 8, reqs, false);
        srv.join().expect("lane server")?;
        lane_rps.push(rps);
    }
    let two_model_lane_speedup = lane_rps[1] / lane_rps[0].max(1e-9);
    println!(
        "two-model lanes: 4 lanes {:.0} req/s vs 1 lane {:.0} req/s ({two_model_lane_speedup:.2}x)",
        lane_rps[1], lane_rps[0]
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_serve".into())),
        ("smoke", Json::Bool(smoke)),
        ("model", Json::Str("mlp3".into())),
        ("kernel", Json::Str(active_kernel_name(KernelChoice::Auto).into())),
        ("requests_per_client", Json::Num(reqs as f64)),
        ("scenarios", Json::Arr(scen_json)),
        ("conc8_seq_rps", Json::Num(seq8)),
        ("conc8_pool_rps", Json::Num(pool8)),
        ("conc8_speedup", Json::Num(speedup)),
        ("wire_top_concurrency", Json::Num(top as f64)),
        ("wire_top_json_rps", Json::Num(json_top)),
        ("wire_top_bin1_rps", Json::Num(bin_top)),
        ("wire_top_speedup", Json::Num(wire_speedup)),
        ("idle_conns", Json::Num(n_idle as f64)),
        ("idle_rss_kib", Json::Num(idle_rss_kib)),
        ("idle_rss_per_conn_kib", Json::Num(idle_rss_kib / n_idle.max(1) as f64)),
        ("idle_thread_delta", Json::Num(idle_thread_delta)),
        ("idle_ping_p50_ms", Json::Num(idle_ping_p50_ms)),
        ("lane1_two_model_rps", Json::Num(lane_rps[0])),
        ("lane4_two_model_rps", Json::Num(lane_rps[1])),
        ("two_model_lane_speedup", Json::Num(two_model_lane_speedup)),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_serve.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
