//! Table 3: initialization ablation — Random vs LW (layer-wise p=2) vs
//! LW+QA (layer-wise + quadratic approximation), each before and after
//! the joint (Powell) phase, on cnn6 at W4/A4 and W32/A2.
//! Paper shape: LW+QA init > LW init > Random, and joint improves all.
//!
//! Each ablation arm is an explicit [`Calibrator`] composition — the
//! builder is the ablation surface — and runs under an [`EventLog`]
//! observer, so the eval trace (phases, eval counts) comes for free.

use lapq::benchkit::{pct, Table};
use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::stages::{BiasCorrection, LayerwiseLp, MinMaxFallback, QuadraticPStar, RandomInit};
use lapq::lapq::{Calibrator, CalibratorBuilder, EventLog};
use lapq::runtime::EngineHandle;

/// The three Table-3 init arms as builder compositions.
fn arm(name: &str) -> CalibratorBuilder {
    let b = Calibrator::builder();
    match name {
        "Random" => b.init(RandomInit { seed: 17 }),
        "LW" => b.init(LayerwiseLp::fixed(vec![2.0])),
        "LW + QA" => b.init(LayerwiseLp::grid()).init(MinMaxFallback).init(QuadraticPStar::grid()),
        other => panic!("unknown arm {other}"),
    }
}

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    let mut t = Table::new(
        "Table 3 — initialization ablation (cnn6)",
        &["W/A", "Init", "Initial acc", "Joint acc", "Initial loss", "Joint loss", "evals"],
    );

    for bits in [BitSpec::new(4, 4), BitSpec::new(32, 2)] {
        for name in ["Random", "LW", "LW + QA"] {
            let mut cfg = ExperimentConfig::default();
            cfg.model = "cnn6".into();
            cfg.train_steps = 300;
            cfg.bits = bits;
            cfg.method = Method::Lapq;
            cfg.val_size = 1024;
            cfg.lapq.joint.max_evals = 60;
            cfg.lapq.joint.iters = 1;

            let post = |b: CalibratorBuilder| {
                if cfg.lapq.bias_correction {
                    b.post(BiasCorrection)
                } else {
                    b
                }
            };
            let init_only = post(arm(name)).build();
            let with_joint = post(arm(name).joint_cfg(&cfg.lapq.joint)).build();

            // Separate logs: the evals column is the cost of the joint
            // run alone, not the sum of both ablation arms.
            let mut before_ev = EventLog::default();
            let before = runner.run_with(&cfg, &init_only, &mut before_ev)?;
            let mut after_ev = EventLog::default();
            let after = runner.run_with(&cfg, &with_joint, &mut after_ev)?;
            t.row(&[
                bits.label(),
                name.to_string(),
                pct(before.quant_metric),
                pct(after.quant_metric),
                format!("{:.4}", before.outcome.calib_loss),
                format!("{:.4}", after.outcome.calib_loss),
                format!("{}", after_ev.evals()),
            ]);
        }
    }
    t.print();
    let _ = t.write_csv("table3.csv");
    Ok(())
}
