//! Table 3: initialization ablation — Random vs LW (layer-wise p=2) vs
//! LW+QA (layer-wise + quadratic approximation), each before and after
//! the joint (Powell) phase, on cnn6 at W4/A4 and W32/A2.
//! Paper shape: LW+QA init > LW init > Random, and joint improves all.

use lapq::benchkit::{pct, Table};
use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::InitKind;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    let mut t = Table::new(
        "Table 3 — initialization ablation (cnn6)",
        &["W/A", "Init", "Initial acc", "Joint acc", "Initial loss", "Joint loss"],
    );

    for bits in [BitSpec::new(4, 4), BitSpec::new(32, 2)] {
        for (name, init) in [
            ("Random", InitKind::Random(17)),
            ("LW", InitKind::Layerwise),
            ("LW + QA", InitKind::LapqQuadratic),
        ] {
            let mut cfg = ExperimentConfig::default();
            cfg.model = "cnn6".into();
            cfg.train_steps = 300;
            cfg.bits = bits;
            cfg.method = Method::Lapq;
            cfg.val_size = 1024;
            cfg.lapq.max_evals = 60;
            cfg.lapq.powell_iters = 1;

            let before = runner.run_with_init(&cfg, init, false)?;
            let after = runner.run_with_init(&cfg, init, true)?;
            t.row(&[
                bits.label(),
                name.to_string(),
                pct(before.quant_metric),
                pct(after.quant_metric),
                format!("{:.4}", before.outcome.calib_loss),
                format!("{:.4}", after.outcome.calib_loss),
            ]);
        }
    }
    t.print();
    let _ = t.write_csv("table3.csv");
    Ok(())
}
