//! Fig. 3: accuracy as a function of the L_p-optimization norm p, at 2-bit
//! and 4-bit quantization (resmini = ResNet-50 stand-in).
//! Paper shape: at 4 bits the curve is flat (any p works); at 2 bits it
//! swings by tens of points and the best p is > 2 (not MSE).

use lapq::benchkit::{pct, Table};
use lapq::config::{BitSpec, ExperimentConfig};
use lapq::coordinator::evaluator::EvalSet;
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::stages::layerwise_deltas;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let spec = runner.eng.manifest().model("cnn6")?.clone();

    let ps = [1.5f32, 2.0, 2.5, 3.0, 3.5, 4.0];
    let mut t = Table::new(
        "Fig. 3 — accuracy vs p-norm of the layer-wise objective (cnn6, A4)",
        &["bits", "p", "accuracy"],
    );

    for bits in [4u32, 2] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "cnn6".into();
        cfg.train_steps = 300;
        cfg.bits = BitSpec::new(bits, 4);
        cfg.val_size = 1024;
        let (sess, val, calib) = runner.session_with_calib(&cfg)?;
        let mask = LayerMask::all(spec.n_quant_layers(), cfg.bits)
            .exclude_first_last(&[]);
        let (qmw, qma) = grids(&spec, cfg.bits);
        let obj = CalibObjective::new(
            &runner.eng,
            sess,
            calib.loss_batches.clone(),
            mask.clone(),
            qmw.clone(),
            qma.clone(),
        );
        let mut accs = Vec::new();
        for &p in &ps {
            let (dw, da) = layerwise_deltas(&calib, &mask, &qmw, &qma, p);
            let q = obj.quant_params(&dw, &da);
            let acc = EvalSet::metric(&val, &runner.eng, sess, Some(&q))?;
            accs.push(acc);
            t.row(&[bits.to_string(), format!("{p}"), pct(acc)]);
        }
        let spread = accs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - accs.iter().cloned().fold(f32::INFINITY, f32::min);
        println!("[fig3] {bits}-bit accuracy spread over p: {:.1} points", spread * 100.0);
        calib.release(&runner.eng);
        runner.eng.drop_session(sess)?;
    }
    t.print();
    let _ = t.write_csv("fig3.csv");
    Ok(())
}
