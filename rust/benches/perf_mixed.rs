//! Perf: the mixed-precision headline — uniform W4 vs sensitivity-
//! allocated per-layer bits at the *same* packed-size budget
//! (`budget_frac = 1.0`), on two builtin models.  For each, a full LAPQ
//! calibration + pack per arm, recording calibration loss, packed bytes
//! and the allocated plan; "win" means the mixed arm is no worse on loss
//! at equal-or-smaller bytes.
//!
//! `BENCH_SMOKE=1` runs a bounded budget (CI-sized) — either way the
//! numbers land in `bench_results/BENCH_mixed.json` so the allocation
//! payoff accumulates PR over PR.

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::runtime::int::PackOpts;
use lapq::runtime::EngineHandle;
use lapq::util::json::Json;

fn cfg_for(model: &str, smoke: bool, mixed: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = model.into();
    cfg.train_steps = if smoke { 40 } else { 150 };
    cfg.lr = 0.1;
    cfg.calib_size = if smoke { 256 } else { 512 };
    cfg.val_size = if smoke { 512 } else { 2048 };
    cfg.bits = BitSpec::new(4, 4);
    cfg.method = Method::Lapq;
    cfg.lapq.joint.max_evals = if smoke { 80 } else { 400 };
    cfg.lapq.joint.iters = if smoke { 1 } else { 2 };
    // every layer participates, so the allocator has real freedom
    cfg.lapq.exclude_first_last = false;
    cfg.mixed.enabled = mixed;
    cfg.mixed.budget_frac = 1.0;
    cfg.mixed.sharpness_k = if smoke { 2 } else { 4 };
    cfg
}

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");

    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut entries: Vec<Json> = Vec::new();

    for model in ["mlp3", "cnn6"] {
        // Training is cached across the two arms, so the seconds deltas
        // are calibration + allocation alone.
        let uni_cfg = cfg_for(model, smoke, false);
        let mix_cfg = cfg_for(model, smoke, true);

        let uni = runner.run(&uni_cfg)?;
        let (uni_sum, _) = runner.pack(&uni_cfg, &PackOpts::default())?;
        let mix = runner.run(&mix_cfg)?;
        let (mix_sum, _) = runner.pack(&mix_cfg, &PackOpts::default())?;

        let win = mix.outcome.calib_loss <= uni.outcome.calib_loss
            && mix_sum.packed_bytes <= uni_sum.packed_bytes;
        println!(
            "{model:<6} uniform w4: loss {:.5} acc {:.3} {} B | mixed {:?}: loss {:.5} acc {:.3} {} B  {}",
            uni.outcome.calib_loss,
            uni.quant_metric,
            uni_sum.packed_bytes,
            mix_sum.wbits,
            mix.outcome.calib_loss,
            mix.quant_metric,
            mix_sum.packed_bytes,
            if win { "WIN" } else { "no-win" },
        );
        entries.push(Json::obj(vec![
            ("model", Json::Str(model.into())),
            ("uniform_calib_loss", Json::Num(uni.outcome.calib_loss)),
            ("mixed_calib_loss", Json::Num(mix.outcome.calib_loss)),
            ("uniform_quant_metric", Json::Num(uni.quant_metric as f64)),
            ("mixed_quant_metric", Json::Num(mix.quant_metric as f64)),
            ("uniform_packed_bytes", Json::Num(uni_sum.packed_bytes as f64)),
            ("mixed_packed_bytes", Json::Num(mix_sum.packed_bytes as f64)),
            (
                "wbits",
                Json::Arr(mix_sum.wbits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("uniform_key", Json::Str(uni_sum.key.clone())),
            ("mixed_key", Json::Str(mix_sum.key.clone())),
            ("uniform_seconds", Json::Num(uni.outcome.seconds)),
            ("mixed_seconds", Json::Num(mix.outcome.seconds)),
            ("win", Json::Bool(win)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_mixed".into())),
        ("smoke", Json::Bool(smoke)),
        ("bits", Json::Str("w4a4 budget, candidates 2/4/8".into())),
        ("backend", Json::Str(runner.eng.backend_name().into())),
        ("entries", Json::Arr(entries)),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_mixed.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
