//! Fig. B.2: accuracy vs calibration-set size at several bitwidths —
//! the generalization/running-time trade-off behind the paper's choice
//! of 512 calibration images.

use lapq::benchkit::{pct, Table};
use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);

    let mut t = Table::new(
        "Fig. B.2 — accuracy vs calibration set size (cnn6)",
        &["W/A", "calib size", "accuracy", "seconds"],
    );
    for bits in [BitSpec::new(4, 4), BitSpec::new(8, 3)] {
        for calib in [128usize, 256, 512, 1024] {
            let mut cfg = ExperimentConfig::default();
            cfg.model = "cnn6".into();
            cfg.train_steps = 300;
            cfg.bits = bits;
            cfg.method = Method::Lapq;
            cfg.calib_size = calib;
            cfg.val_size = 1024;
            cfg.lapq.joint.max_evals = 60;
            cfg.lapq.joint.iters = 1;
            let res = runner.run(&cfg)?;
            t.row(&[
                bits.label(),
                calib.to_string(),
                pct(res.quant_metric),
                format!("{:.1}", res.seconds),
            ]);
        }
    }
    t.print();
    let _ = t.write_csv("figb2.csv");
    Ok(())
}
