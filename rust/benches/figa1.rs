//! Fig. A.1 + Eq. 10–11: the Hessian of the loss w.r.t. the per-layer
//! quantization steps at 4-bit vs 2-bit, its coupling structure (adjacent
//! layers interact most) and the Gaussian curvature at the MMSE point.
//! Paper shape: K(2-bit) is *many orders of magnitude* above K(4-bit),
//! and off-diagonal mass grows as bits shrink.

use lapq::analysis::curvature::gaussian_curvature;
use lapq::analysis::hessian::weight_hessian;
use lapq::benchkit::Table;
use lapq::config::{BitSpec, ExperimentConfig};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::stages::layerwise_deltas;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let spec = runner.eng.manifest().model("cnn6")?.clone();

    let mut t = Table::new(
        "Fig. A.1 / Eq. 10-11 — Hessian structure and Gaussian curvature (cnn6)",
        &["bits", "coupling ratio", "band d=1", "band d=2+", "Gaussian K"],
    );
    let mut ks = Vec::new();
    for bits in [4u32, 2] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "cnn6".into();
        cfg.train_steps = 300;
        cfg.bits = BitSpec::new(bits, 32);
        cfg.lapq.joint.max_evals = 50;
        let (sess, _val, calib) = runner.session_with_calib(&cfg)?;
        let mask = LayerMask::all(spec.n_quant_layers(), cfg.bits).exclude_first_last(&[]);
        let (qmw, qma) = grids(&spec, cfg.bits);
        let mut obj = CalibObjective::new(
            &runner.eng,
            sess,
            calib.loss_batches.clone(),
            mask.clone(),
            qmw.clone(),
            qma.clone(),
        );
        // Measure at the joint optimum: the paper uses the L2-min point,
        // but on the smaller stand-in that point is inside the collapsed
        // plateau at 2 bits (zero curvature); the LAPQ optimum preserves
        // the 2-vs-4-bit curvature contrast the figure is about.
        let (dw0, da0) = layerwise_deltas(&calib, &mask, &qmw, &qma, 2.0);
        let (dw, da, _, _) =
            lapq::lapq::calibrator::joint_optimize(&mut obj, &dw0, &da0, &cfg.lapq)?;
        let rep = weight_hessian(&mut obj, &dw, &da, 0.08)?;
        let k = gaussian_curvature(&rep);
        ks.push(k);
        let far = (2..rep.h.len()).map(|d| rep.band_mean(d)).sum::<f64>()
            / (rep.h.len() - 2).max(1) as f64;
        t.row(&[
            bits.to_string(),
            format!("{:.3}", rep.coupling_ratio()),
            format!("{:.3e}", rep.band_mean(1)),
            format!("{far:.3e}"),
            format!("{k:.3e}"),
        ]);
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("figa1_hessian_{bits}bit.csv")), rep.csv())?;
        calib.release(&runner.eng);
        runner.eng.drop_session(sess)?;
    }
    t.print();
    let _ = t.write_csv("figa1.csv");
    println!(
        "[figa1] curvature ratio K(2bit)/K(4bit) = {:.3e} (paper: ~8.7e23)",
        (ks[1].abs() / ks[0].abs().max(1e-300))
    );
    Ok(())
}
