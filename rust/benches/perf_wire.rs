//! Perf: the wire codecs in isolation — what one infer request/reply
//! costs to decode and encode in each dialect, away from sockets and
//! kernels.
//!
//! Parse side (a 32x64 f32 infer request):
//! * `parse_tree`  — the owned `Json` tree (the pre-redesign path).
//! * `parse_typed` — `Request::from_line` through the borrowing reader,
//!   straight into `HostTensor`s.
//! * `decode_bin`  — the bin1 frame payload decoder.
//!
//! Serialize side (a 32x16 infer reply):
//! * `write_tree`  — build the `Json` tree, then dump (old path).
//! * `write_typed` — `Response::write_json` into a reused buffer.
//! * `encode_bin`  — the bin1 frame encoder into a reused buffer.
//!
//! `BENCH_SMOKE=1` shrinks iteration counts (CI-sized).  Results land
//! in `bench_results/BENCH_wire.json`.

use lapq::benchkit::{bench, Table};
use lapq::coordinator::jobs::InferReply;
use lapq::proto::{frame, predict_row, InferRequest, Request, Response};
use lapq::runtime::cpu::ops::Arr;
use lapq::tensor::HostTensor;
use lapq::util::json::Json;
use std::hint::black_box;

/// The reply as the pre-redesign code built it: an owned tree, dumped.
fn reply_tree_dump(reply: &InferReply) -> String {
    let c = reply.logits.last_dim().max(1);
    let logits: Vec<Json> = reply.logits.data.chunks(c).map(Json::arr_f32).collect();
    let preds: Vec<Json> =
        reply.logits.data.chunks(c).map(|r| Json::Num(predict_row(r) as f64)).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "result",
            Json::obj(vec![
                ("key", Json::Str(reply.key.clone())),
                ("rows", Json::Num(reply.rows as f64)),
                ("int_layers", Json::Num(reply.int_layers as f64)),
                ("seconds", Json::Num(reply.seconds)),
                ("logits", Json::Arr(logits)),
                ("predictions", Json::Arr(preds)),
            ]),
        ),
    ])
    .dump()
}

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");
    let (warmup, iters) = if smoke { (20, 100) } else { (100, 1000) };

    // -- fixtures -------------------------------------------------------
    let (rows, cols, classes) = (32usize, 64usize, 16usize);
    let xdata: Vec<f32> =
        (0..rows * cols).map(|i| ((i * 37) % 19) as f32 * 0.05 - 0.45).collect();
    let ir = InferRequest {
        key: "mlp3-int8-mmse".into(),
        inputs: vec![HostTensor::f32(vec![rows, cols], xdata)],
    };
    let mut line = String::new();
    Request::Infer(ir.clone()).write_json(&mut line);
    let mut framed = Vec::new();
    frame::encode_infer_request(&ir, &mut framed);
    let payload = framed[frame::HEADER_LEN..framed.len() - frame::CRC_LEN].to_vec();

    let ldata: Vec<f32> =
        (0..rows * classes).map(|i| ((i * 53) % 31) as f32 * 0.0625 - 1.0).collect();
    let reply = InferReply {
        key: "mlp3-int8-mmse".into(),
        logits: Arr::new(vec![rows, classes], ldata),
        rows,
        int_layers: 3,
        seconds: 0.000244140625,
    };
    let resp = Response::Infer { reply: reply.clone() };

    // cross-check before timing: the typed writer and the tree dump are
    // the same bytes (the byte-compat contract the tests also pin)
    let mut typed_out = String::new();
    resp.write_json(&mut typed_out);
    assert_eq!(typed_out, reply_tree_dump(&reply), "typed writer drifted from the tree dump");

    // -- parse side -----------------------------------------------------
    let mut cases = Vec::new();
    let t = bench("parse_tree (owned Json)", warmup, iters, || {
        let j: Json = black_box(&line).parse().expect("tree parse");
        black_box(&j);
    });
    cases.push((t, line.len()));
    let t = bench("parse_typed (borrowing reader)", warmup, iters, || {
        let r = Request::from_line(black_box(&line)).expect("typed parse");
        black_box(&r);
    });
    cases.push((t, line.len()));
    let t = bench("decode_bin (bin1 payload)", warmup, iters, || {
        let r = frame::decode_infer_request(black_box(&payload)).expect("bin decode");
        black_box(&r);
    });
    cases.push((t, payload.len()));

    // -- serialize side -------------------------------------------------
    let t = bench("write_tree (build + dump)", warmup, iters, || {
        black_box(reply_tree_dump(black_box(&reply)));
    });
    cases.push((t, typed_out.len()));
    let mut out = String::new();
    let t = bench("write_typed (reused buffer)", warmup, iters, || {
        out.clear();
        black_box(&resp).write_json(&mut out);
        black_box(&out);
    });
    cases.push((t, typed_out.len()));
    let mut bin = Vec::new();
    let t = bench("encode_bin (reused buffer)", warmup, iters, || {
        frame::encode_infer_reply(black_box(&reply), &mut bin);
        black_box(&bin);
    });
    let bin_len = bin.len();
    cases.push((t, bin_len));

    // -- report ---------------------------------------------------------
    let mut table = Table::new(
        "wire codecs: one 32x64 infer request / 32x16 reply",
        &["case", "bytes", "mean us", "p50 us", "ops/s"],
    );
    let mut case_json = Vec::new();
    for (t, bytes) in &cases {
        let ops = 1.0 / t.mean_s.max(1e-12);
        table.row(&[
            t.name.clone(),
            bytes.to_string(),
            format!("{:.2}", t.mean_s * 1e6),
            format!("{:.2}", t.p50_s * 1e6),
            format!("{ops:.0}"),
        ]);
        case_json.push(Json::obj(vec![
            ("name", Json::Str(t.name.clone())),
            ("bytes", Json::Num(*bytes as f64)),
            ("mean_us", Json::Num(t.mean_s * 1e6)),
            ("p50_us", Json::Num(t.p50_s * 1e6)),
            ("p95_us", Json::Num(t.p95_s * 1e6)),
            ("ops_per_s", Json::Num(ops)),
        ]));
    }
    table.print();

    let mean = |i: usize| cases[i].0.mean_s.max(1e-12);
    let parse_typed_speedup = mean(0) / mean(1);
    let parse_bin_speedup = mean(0) / mean(2);
    let write_typed_speedup = mean(3) / mean(4);
    let write_bin_speedup = mean(3) / mean(5);
    println!(
        "\nparse: typed {parse_typed_speedup:.2}x vs tree, bin1 {parse_bin_speedup:.2}x; \
         write: typed {write_typed_speedup:.2}x vs tree, bin1 {write_bin_speedup:.2}x"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_wire".into())),
        ("smoke", Json::Bool(smoke)),
        ("request_shape", Json::Arr(vec![Json::Num(rows as f64), Json::Num(cols as f64)])),
        ("reply_shape", Json::Arr(vec![Json::Num(rows as f64), Json::Num(classes as f64)])),
        ("iters", Json::Num(iters as f64)),
        ("cases", Json::Arr(case_json)),
        ("parse_typed_speedup_vs_tree", Json::Num(parse_typed_speedup)),
        ("parse_bin_speedup_vs_tree", Json::Num(parse_bin_speedup)),
        ("write_typed_speedup_vs_tree", Json::Num(write_typed_speedup)),
        ("write_bin_speedup_vs_tree", Json::Num(write_bin_speedup)),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_wire.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
