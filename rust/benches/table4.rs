//! Table 4: bias-correction ablation — LAPQ with and without Banner-style
//! per-channel correction at W/A ∈ {32/4, 32/2, 4/32, 4/4} on cnn6,
//! resmini and dwsep (MobileNet stand-in).
//! Paper shape: bias correction matters most for the depthwise model.

use lapq::benchkit::{pct, Table};
use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::scheduler::Scheduler;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut sched = Scheduler::new();

    // weight-quantizing settings where bias correction applies
    let settings = [BitSpec::new(4, 32), BitSpec::new(4, 4)];
    for model in ["cnn6", "resmini", "dwsep"] {
        for bits in settings {
            for bc in [false, true] {
                let mut cfg = ExperimentConfig::default();
                cfg.model = model.into();
                cfg.train_steps = 300;
                cfg.bits = bits;
                cfg.method = Method::Lapq;
                cfg.val_size = 1024;
                cfg.lapq.joint.max_evals = 60;
                cfg.lapq.joint.iters = 1;
                cfg.lapq.bias_correction = bc;
                sched.push(cfg);
            }
        }
    }
    sched.run_all(&mut runner)?;

    let mut t = Table::new(
        "Table 4 — bias correction on top of LAPQ",
        &["Model", "W/A", "LAPQ", "LAPQ + bias corr", "FP32"],
    );
    let mut it = sched.results.iter();
    while let (Some(off), Some(on)) = (it.next(), it.next()) {
        t.row(&[
            off.model.clone(),
            off.bits_label.clone(),
            pct(off.quant_metric),
            pct(on.quant_metric),
            pct(off.fp32_metric),
        ]);
    }
    t.print();
    let _ = t.write_csv("table4.csv");
    if !sched.failures.is_empty() {
        anyhow::bail!("{} jobs failed", sched.failures.len());
    }
    Ok(())
}
