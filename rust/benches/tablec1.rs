//! Table C.1 (appendix): the extreme settings — W8/A2 (LAPQ vs ACIQ) and
//! W4/A32 (LAPQ vs MMSE/OCS-analog) on cnn6 and resmini.
//! Paper shape: at A2 every layer-wise method collapses far below LAPQ.

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::scheduler::Scheduler;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut sched = Scheduler::new();

    for model in ["cnn6", "resmini"] {
        for (w, a, methods) in [
            (8u32, 2u32, vec![Method::Lapq, Method::Aciq]),
            (4, 32, vec![Method::Lapq, Method::Mmse, Method::MinMax]),
        ] {
            for method in methods {
                let mut cfg = ExperimentConfig::default();
                cfg.model = model.into();
                cfg.train_steps = 300;
                cfg.bits = BitSpec::new(w, a);
                cfg.method = method;
                cfg.val_size = 1024;
                cfg.lapq.joint.max_evals = 60;
                cfg.lapq.joint.iters = 1;
                sched.push(cfg);
            }
        }
    }
    sched.run_all(&mut runner)?;
    let t = sched.summary_table("Table C.1 — appendix settings (W8/A2, W4/A32)");
    t.print();
    let _ = t.write_csv("tablec1.csv");
    if !sched.failures.is_empty() {
        anyhow::bail!("{} jobs failed", sched.failures.len());
    }
    Ok(())
}
