//! Perf: the integer kernel tiers against each other and the f32 path —
//! scalar reference vs blocked vs auto (SIMD when detected) i8×i8→i32
//! GEMM, the nibble-domain INT4-direct kernel, and an end-to-end INT8
//! `mlp3` infer against the fake-quant eval it replaces.
//!
//! `BENCH_SMOKE=1` runs a bounded subset (CI-sized) — either way the
//! timings land in `bench_results/BENCH_int_infer.json`, whose
//! `blocked_vs_scalar_speedup` headline tracks the micro-kernel
//! architecture's win on the heaviest shape.

use lapq::benchkit::{bench, f3, Table};
use lapq::quant::{minmax, GridKind};
use lapq::runtime::cpu::{ops, zoo};
use lapq::runtime::int::kernels::{self, KernelChoice};
use lapq::runtime::int::model::{pack, snap_po2, PackOpts};
use lapq::runtime::int::{ExecMode, InferSession};
use lapq::runtime::{Manifest, QuantParams};
use lapq::tensor::init::init_params;
use lapq::util::json::Json;
use lapq::util::rng::Pcg32;
use std::hint::black_box;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");
    let shapes: &[(usize, usize, usize)] = if smoke {
        &[(128, 256, 256)]
    } else {
        &[(256, 512, 512), (512, 768, 768), (256, 1024, 1024)]
    };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 10) };
    let mut rng = Pcg32::seeded(17);
    let auto_name = kernels::active_kernel_name(KernelChoice::Auto);

    let mut table = Table::new(
        &format!("integer GEMM tiers vs f32 matmul (auto = {auto_name})"),
        &["shape", "f32 ms", "scalar ms", "blocked ms", "auto ms", "i4 ms", "auto/scalar"],
    );
    let mut gemm_rows: Vec<Json> = Vec::new();
    let mut headline = 0.0f64;
    let mut best_work = 0usize;
    for &(m, k, n) in shapes {
        let a8: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b8: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let af: Vec<f32> = a8.iter().map(|&v| v as f32 * 0.05).collect();
        let bf: Vec<f32> = b8.iter().map(|&v| v as f32 * 0.05).collect();
        let t_f32 = bench(&format!("f32 matmul {m}x{k}x{n}"), warmup, iters, || {
            black_box(ops::matmul(&af, &bf, m, k, n));
        });
        let t_scalar = bench(&format!("i8 scalar {m}x{k}x{n}"), warmup, iters, || {
            black_box(kernels::gemm_with(KernelChoice::Scalar, &a8, &b8, m, k, n));
        });
        let t_blocked = bench(&format!("i8 blocked {m}x{k}x{n}"), warmup, iters, || {
            black_box(kernels::gemm_with(KernelChoice::Blocked, &a8, &b8, m, k, n));
        });
        let t_auto = bench(&format!("i8 {auto_name} {m}x{k}x{n}"), warmup, iters, || {
            black_box(kernels::gemm_with(KernelChoice::Auto, &a8, &b8, m, k, n));
        });
        // INT4-direct: weights stay in the nibble domain end to end —
        // packed i4 panels, never widened to an i8 buffer.
        let b4: Vec<i8> = b8.iter().map(|&v| v.clamp(-7, 7)).collect();
        let t_i4 = bench(&format!("i4 direct {m}x{k}x{n}"), warmup, iters, || {
            black_box(kernels::gemm_i4_with(KernelChoice::Auto, &a8, &b4, m, k, n));
        });
        let speedup = t_scalar.mean_s / t_auto.mean_s.max(1e-12);
        if m * k * n > best_work {
            best_work = m * k * n;
            headline = speedup;
        }
        table.row(&[
            format!("{m}x{k}x{n}"),
            f3(t_f32.mean_s * 1e3),
            f3(t_scalar.mean_s * 1e3),
            f3(t_blocked.mean_s * 1e3),
            f3(t_auto.mean_s * 1e3),
            f3(t_i4.mean_s * 1e3),
            format!("{speedup:.2}x"),
        ]);
        gemm_rows.push(Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("f32_ms", Json::Num(t_f32.mean_s * 1e3)),
            ("scalar_ms", Json::Num(t_scalar.mean_s * 1e3)),
            ("blocked_ms", Json::Num(t_blocked.mean_s * 1e3)),
            ("auto_ms", Json::Num(t_auto.mean_s * 1e3)),
            ("i4_ms", Json::Num(t_i4.mean_s * 1e3)),
            ("auto_vs_scalar", Json::Num(speedup)),
            ("f32_vs_auto", Json::Num(t_f32.mean_s / t_auto.mean_s.max(1e-12))),
        ]));
    }
    table.print();
    println!("\nheadline blocked_vs_scalar_speedup ({auto_name}): {headline:.2}x");

    // End-to-end: packed INT8 mlp3 infer vs the fake-quant eval it
    // replaces, same batch, all layers quantized.
    let manifest = Manifest::builtin();
    let spec = manifest.model("mlp3")?.clone();
    let params = init_params(&spec.params, 7);
    let data = lapq::data::vision::SynthVision::new(7);
    let rows = if smoke { 256 } else { 512 };
    let (x, y) = data.batch_features(0, rows, 64);
    let acts = zoo::acts(&spec, &params, &[x.clone()])?;
    let nq = spec.n_quant_layers();
    let mut q = QuantParams {
        dw: vec![0.0; nq],
        qmw: vec![127.0; nq],
        da: vec![0.0; nq],
        qma: vec![0.0; nq],
    };
    for (i, ql) in spec.quant_layers.iter().enumerate() {
        let w = params[ql.weight_param].f();
        q.dw[i] = snap_po2(minmax::minmax_delta(w, 127.0, GridKind::Signed));
        let kind = GridKind::from_signed(ql.act_signed);
        q.qma[i] = kind.qmax(8);
        q.da[i] = snap_po2(minmax::minmax_delta(acts[i].f(), q.qma[i], kind));
    }
    let qm = pack(&spec, &params, &q, None, &PackOpts::default())?;
    let sess = InferSession::new(&spec, &qm)?;
    let infer_batch = [x.clone()];
    let eval_batch = vec![x, y];
    let t_int = bench(&format!("mlp3 int8 infer (B={rows})"), warmup, 2 * iters, || {
        black_box(sess.infer(&infer_batch, ExecMode::Int).unwrap());
    });
    let t_fq = bench(&format!("mlp3 fake-quant eval (B={rows})"), warmup, 2 * iters, || {
        black_box(zoo::eval(&spec, &params, Some(&qm.quant), &eval_batch).unwrap());
    });
    println!(
        "\nmlp3 INT8: {:.0} rows/s integer vs {:.0} rows/s fake-quant ({:.2}x)",
        rows as f64 / t_int.mean_s.max(1e-12),
        rows as f64 / t_fq.mean_s.max(1e-12),
        t_fq.mean_s / t_int.mean_s.max(1e-12),
    );

    // Perf-trajectory artifact (uploaded by CI).
    let report = Json::obj(vec![
        ("bench", Json::Str("perf_int_gemm".into())),
        ("smoke", Json::Bool(smoke)),
        ("kernel", Json::Str(auto_name.into())),
        ("blocked_vs_scalar_speedup", Json::Num(headline)),
        ("gemm", Json::Arr(gemm_rows)),
        (
            "infer",
            Json::obj(vec![
                ("model", Json::Str("mlp3".into())),
                ("rows", Json::Num(rows as f64)),
                ("int8_ms", Json::Num(t_int.mean_s * 1e3)),
                ("fake_quant_ms", Json::Num(t_fq.mean_s * 1e3)),
                ("speedup", Json::Num(t_fq.mean_s / t_int.mean_s.max(1e-12))),
            ]),
        ),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_int_infer.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
