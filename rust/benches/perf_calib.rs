//! Perf: the calibration-speed trajectory — one full LAPQ calibration of
//! mlp3 at W4/A4 per joint optimizer (Powell / Nelder–Mead / coordinate
//! descent), recording objective evals, wall seconds and final loss.
//! Feeds EXPERIMENTS.md §Perf next to the hot-path and int-infer
//! trajectories.
//!
//! `BENCH_SMOKE=1` runs a bounded budget (CI-sized) — either way the
//! numbers land in `bench_results/BENCH_calib.json` so calibration speed
//! accumulates PR over PR.

use lapq::config::{BitSpec, ExperimentConfig, JointOpt, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::EventLog;
use lapq::runtime::EngineHandle;
use lapq::util::json::Json;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");

    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut entries: Vec<Json> = Vec::new();

    for opt in JointOpt::ALL {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mlp3".into();
        cfg.train_steps = if smoke { 40 } else { 150 };
        cfg.lr = 0.1;
        cfg.val_size = 1024;
        cfg.bits = BitSpec::new(4, 4);
        cfg.method = Method::Lapq;
        cfg.lapq.joint.optimizer = opt;
        cfg.lapq.joint.max_evals = if smoke { 80 } else { 400 };
        cfg.lapq.joint.iters = if smoke { 1 } else { 2 };

        // Training is cached across optimizers, so the seconds delta is
        // calibration alone; the EventLog trace rides along for free.
        let mut events = EventLog::default();
        let res = runner.run_observed(&cfg, &mut events)?;
        println!(
            "{:<18} evals {:>5}  loss {:.5} (init {:.5})  acc {:.3}  {:.2}s",
            opt.name(),
            res.outcome.joint_evals,
            res.outcome.calib_loss,
            res.outcome.init_loss,
            res.quant_metric,
            res.outcome.seconds,
        );
        entries.push(Json::obj(vec![
            ("optimizer", Json::Str(opt.name().into())),
            ("joint_evals", Json::Num(res.outcome.joint_evals as f64)),
            ("events", Json::Num(events.events.len() as f64)),
            ("seconds", Json::Num(res.outcome.seconds)),
            ("calib_loss", Json::Num(res.outcome.calib_loss)),
            ("init_loss", Json::Num(res.outcome.init_loss)),
            ("fp32_calib_loss", Json::Num(res.outcome.fp32_calib_loss)),
            ("quant_metric", Json::Num(res.quant_metric as f64)),
            (
                "trace",
                Json::Arr(res.outcome.trace.iter().map(|t| t.to_json()).collect()),
            ),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_calib".into())),
        ("smoke", Json::Bool(smoke)),
        ("model", Json::Str("mlp3".into())),
        ("bits", Json::Str("4 / 4".into())),
        ("backend", Json::Str(runner.eng.backend_name().into())),
        ("entries", Json::Arr(entries)),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_calib.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
